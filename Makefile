PYTHONPATH := src

.PHONY: test bench bench-smoke

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# full paper-protocol benchmark sweep (slow)
bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

# <60s perf smoke: seed-vs-current RSKPCA fit/transform at n in {2k,8k,32k};
# refreshes BENCH_rskpca.json so every PR leaves a perf trajectory point
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --smoke
