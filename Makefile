PYTHONPATH := src

.PHONY: test bench bench-smoke bench-shard bench-stream bench-serve \
	bench-ingest bench-ingest-full bench-methods bench-obs bench-chaos

# the tier-1 gate — CI and humans run the SAME command (ROADMAP.md)
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# full paper-protocol benchmark sweep (slow)
bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

# perf smoke: seed-vs-current RSKPCA fit/transform at n in {2k,8k,32k}
# (interleaved min-of-reps timing) PLUS the matrix-free fit gate at m=8192
# (mode=matfree row; asserts no m x m buffer via XLA memory analysis and
# fit_speedup >= 1.0 vs the seed dense Gram + full eigh).  Refreshes
# BENCH_rskpca.json so every PR leaves a perf trajectory point, and fails
# if any freshly-measured row has fit_speedup < 1.0
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --smoke --matfree

# smoke + the sharded mixed-precision path: appends sharded/bf16 rows
# (multi-host-device mesh, bf16 MXU operands) to BENCH_rskpca.json
bench-shard:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --smoke --mesh --precision bf16

# streaming operator maintenance: per-update incremental patch vs full refit
# at m in {256, 1024, 4096}; appends mode=stream rows to BENCH_rskpca.json
# and fails if any update_speedup < 1.0
bench-stream:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --stream

# serving latency: Poisson open-loop p50/p99 of continuous batching vs
# request-at-a-time over the same hot-swap server, plus per-precision-tier
# transform throughput (f32/bf16/int8/fp8).  Appends mode=serve and
# mode=serve_tier_* rows to BENCH_rskpca.json; fails if batching loses on
# p99 at 2x saturation or a gated quantized tier is slower than bf16
bench-serve:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --serve

# out-of-core ingestion smoke (CI): end-to-end select->fit over the chunked
# n=1M source on one device; appends a mode=ingest row to BENCH_rskpca.json
# and fails on the rows/s floor or overlap_fraction < 0.5
bench-ingest:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --ingest

# the non-CI full point: n=10M rows, m budget 32768, chunk rows sharded over
# an 8-host-device mesh (several minutes); additionally gates peak host RSS
# growth < 25% of the dataset's 640MB f32 footprint
bench-ingest-full:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --ingest --full

# method zoo (ISSUE 8): nystrom / wnystrom / rff on the optimized stack.
# Gate point n=262144 m=2048 (interleaved vs the pre-PR dense nystrom;
# fails under 5x speedup or > 1pt knn drift from the dense oracle) plus
# out-of-core n=1M children per method (fails if any holds >= 25% of the
# data live).  Appends mode=methods rows to BENCH_rskpca.json — the
# measured Pareto that fit(..., method="auto") selects from
bench-methods:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --methods

# telemetry overhead (DESIGN.md §16): interleaved A/B/A of obs-enabled vs
# disabled on the serving dispatch and ingest selection paths.  Appends
# mode=obs rows to BENCH_rskpca.json; fails if enabled overhead exceeds
# both the 2% budget and the run's own A/A noise floor
bench-obs:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --obs

# fault tolerance (DESIGN.md §17): the same ingest + serving workloads
# fault-free vs under a deterministic ~1% chaos plan.  Appends mode=chaos
# rows to BENCH_rskpca.json; fails unless faulted ingest (checkpointing on)
# is BIT-EXACT vs fault-free at <= 1.5x slowdown, and faulted serving holds
# p99 <= 2x fault-free with zero non-shed drops and a finite degraded-mode
# staleness bound
bench-chaos:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --chaos
