"""Version compatibility shims for the jax API surface this repo uses.

The repo targets the modern jax API (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types``), but the container pins an older jax where those live
under ``jax.experimental.shard_map`` / have no ``axis_types`` kwarg.  All
mesh construction and shard_map entry points route through here so the rest
of the code can be written once against the new names.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
_HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")

#: The optimized deferred-grad schedule (2D expert sharding inside a
#: partially-manual shard_map) aborts the SPMD partitioner on old jax (XLA
#: CHECK ``sharding.IsManualSubgroup()``, an uncatchable process abort);
#: it needs the native ``jax.shard_map``.  Callers gate on this flag.
HAS_PARTIAL_AUTO_SHARD_MAP = _HAS_JAX_SHARD_MAP


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              **kwargs: Any) -> jax.sharding.Mesh:
    """``jax.make_mesh`` that tolerates jax versions without ``axis_types``.

    All call sites in this repo only ever pass ``AxisType.Auto``, which is
    also the modern default, so dropping the kwarg is semantics-preserving.
    """
    if _HAS_AXIS_TYPES:
        kwargs.setdefault(
            "axis_types",
            (jax.sharding.AxisType.Auto,) * len(tuple(axis_names)),
        )
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
    kwargs.pop("axis_types", None)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """Dispatch to ``jax.shard_map`` (new) or experimental shard_map (old).

    ``axis_names`` is the NEW api's set of manual axes; the old api takes the
    complement as ``auto``.  ``check_vma`` maps to the old ``check_rep``.
    """
    if _HAS_JAX_SHARD_MAP:
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    manual = set(axis_names) if axis_names is not None else set(mesh.axis_names)
    auto = frozenset(mesh.axis_names) - manual
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, auto=auto)
