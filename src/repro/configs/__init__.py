"""Architecture registry: one module per assigned architecture (+ the paper's
own RSKPCA experiment config).  Each module defines CONFIG (exact published
geometry) and SMOKE (reduced same-family config for CPU tests)."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "pixtral_12b", "rwkv6_1b6", "gemma3_4b", "gemma2_9b", "qwen2_72b",
    "yi_9b", "jamba_52b", "whisper_base", "kimi_k2", "mixtral_8x7b",
]

_ALIASES = {
    "pixtral-12b": "pixtral_12b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "gemma3-4b": "gemma3_4b",
    "gemma2-9b": "gemma2_9b",
    "qwen2-72b": "qwen2_72b",
    "yi-9b": "yi_9b",
    "jamba-v0.1-52b": "jamba_52b",
    "whisper-base": "whisper_base",
    "kimi-k2-1t-a32b": "kimi_k2",
    "mixtral-8x7b": "mixtral_8x7b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS}
