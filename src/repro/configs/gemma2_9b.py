"""gemma2-9b [dense] — alternating local/global attention + logit softcaps.

42L d_model=3584 16H (GQA kv=8) head_dim=256 d_ff=14336 vocab=256000.
[arXiv:2408.00118; hf]  1:1 local:global -> runs long_500k.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    attn_kind="local_global", local_global_period=2, window_size=4096,
    softcap=50.0, final_softcap=30.0,
    act="gelu_tanh", tie_embeddings=True, embed_scale=True,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="gemma2-smoke", family="dense",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, vocab_pad_multiple=32,
    attn_kind="local_global", local_global_period=2, window_size=8,
    softcap=50.0, final_softcap=30.0,
    act="gelu_tanh", tie_embeddings=True, embed_scale=True,
    attn_chunk=16, subquadratic=True,
)
