"""The paper's own experiment configuration (Tables 1-2, Figs. 2-8).

Datasets are synthetic stand-ins matched on (n, dim, classes) — DESIGN.md §14.
``ell_grid`` is the paper's sweep [3.0, 5.0] in 0.1 steps; ``rank`` r=5 for
the eigenembedding experiments; k-nn k per dataset from Table 1.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RSKPCAExperimentConfig:
    datasets: tuple = ("german", "pendigits", "usps", "yale")
    kernel: str = "gaussian"
    ell_min: float = 3.0
    ell_max: float = 5.0
    ell_step: float = 0.1
    rank: int = 5
    train_frac: float = 0.8
    n_runs: int = 50          # paper averages over 50 runs
    methods: tuple = ("kpca", "uniform", "nystrom", "wnystrom", "shadow",
                      "rff", "auto")
    rsde_schemes: tuple = ("shadow", "kmeans", "paring", "herding")

    def ell_grid(self):
        import numpy as np
        return np.round(np.arange(self.ell_min, self.ell_max + 1e-9,
                                  self.ell_step), 2)


CONFIG = RSKPCAExperimentConfig()
# fast variant used by CI-scale benchmark runs in this container
SMOKE = RSKPCAExperimentConfig(
    datasets=("german", "pendigits"), ell_min=3.0, ell_max=5.0, ell_step=0.5,
    n_runs=3,
)
