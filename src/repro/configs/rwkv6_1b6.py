"""rwkv6-1.6b [ssm] — Finch, attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536, head_size 64 (32 heads).
[arXiv:2404.05892; unverified]  O(1)/token state -> runs long_500k.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    mixer="rwkv6", rwkv_head_size=64, act="relu", use_rope=False,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="rwkv6-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, vocab_pad_multiple=32,
    mixer="rwkv6", rwkv_head_size=16, act="relu", use_rope=False,
    scan_chunk=16, subquadratic=True,
)
