"""pixtral-12b [vlm] — Pixtral-ViT frontend (stub) + Mistral-Nemo backbone.

40L d_model=5120 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409; unverified]  Full attention -> long_500k SKIP.
Vision tower is a stub: input_specs provides precomputed patch embeddings.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    attn_kind="full", rope_theta=1_000_000.0,
    frontend="vision", num_patch_tokens=256,
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="pixtral-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, vocab_pad_multiple=32,
    attn_kind="full", frontend="vision", num_patch_tokens=4,
    attn_chunk=16, subquadratic=False,
)
