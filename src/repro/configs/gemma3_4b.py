"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.

34L d_model=2560 8H (GQA kv=4) head_dim=256 d_ff=10240 vocab=262144.
[hf:google/gemma-3-1b-pt; unverified]  Sliding-window locals dominate ->
runs long_500k (global layers decode O(seq) with KV cache).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144,
    attn_kind="local_global", local_global_period=6, window_size=1024,
    act="gelu_tanh", tie_embeddings=True, embed_scale=True,
    rope_theta=1_000_000.0, subquadratic=True,
)

SMOKE = ArchConfig(
    name="gemma3-smoke", family="dense",
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, vocab_pad_multiple=32,
    attn_kind="local_global", local_global_period=6, window_size=8,
    act="gelu_tanh", tie_embeddings=True, embed_scale=True,
    attn_chunk=16, subquadratic=True,
)
