"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE (paper-table geometry).

61L d_model=7168 64H (GQA kv=8) vocab=163840, MoE 384 experts top-8 with
expert d_ff=2048 (+1 shared expert), first layer dense (d_ff=18432,
DeepSeek-V3-style).  [arXiv:2501.kimi2; unverified]
head_dim 128 (7168/64=112 rounded to the MXU-aligned 128, as in DSv3).
Memory adaptation for a 256-chip v5e pod (DESIGN.md §14): bf16 params +
Adafactor (factored second moment) — f32 AdamW for 1T params needs 12 TB,
a v5e pod has 4 TB HBM.  Full attention -> long_500k SKIP.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=18432, vocab_size=163840,
    attn_kind="full",
    num_experts=384, top_k=8, moe_d_ff=2048, moe_every=1, moe_offset=0,
    first_dense=1, shared_expert=True,
    param_dtype="bfloat16", optimizer="adafactor",
    rope_theta=50_000.0, subquadratic=False,
)

SMOKE = ArchConfig(
    name="kimi-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, vocab_pad_multiple=32,
    attn_kind="full",
    num_experts=8, top_k=2, moe_d_ff=32, moe_every=1, moe_offset=0,
    first_dense=1, shared_expert=True,
    attn_chunk=16, capacity_factor=8.0, subquadratic=False,
)
