"""whisper-base [audio] — encoder-decoder, conv frontend STUB.

6L(+6L enc) d_model=512 8H (kv=8) head_dim=64 d_ff=2048 vocab=51865.
[arXiv:2212.04356; unverified]  input_specs provides precomputed frame
embeddings (B, 1500, 512).  Enc-dec (not encoder-only) -> decode shapes run;
full attention -> long_500k SKIP.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    norm="layernorm", act="gelu", use_rope=False, tie_embeddings=True,
    encoder_layers=6, encoder_seq=1500, frontend="audio",
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, vocab_pad_multiple=32,
    norm="layernorm", act="gelu", use_rope=False, tie_embeddings=True,
    encoder_layers=2, encoder_seq=16, frontend="audio",
    attn_chunk=16, subquadratic=False,
)
