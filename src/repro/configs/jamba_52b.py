"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE every 2.

32L d_model=4096 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=65536,
MoE 16 experts top-2.  [arXiv:2403.19887; hf]
Attention at layer i % 8 == 4 (one per Jamba block of 8); MoE on odd layers.
7/8 layers are O(1)-state Mamba -> runs long_500k.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    mixer="hybrid_mamba", attn_every=8, attn_offset=4,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    num_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
    use_rope=False,  # jamba uses no positional encoding (Mamba carries order)
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="jamba-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, vocab_pad_multiple=32,
    mixer="hybrid_mamba", attn_every=8, attn_offset=4,
    mamba_d_state=4, mamba_d_conv=4, mamba_expand=2,
    num_experts=4, top_k=2, moe_d_ff=128, moe_every=2, moe_offset=1,
    use_rope=False, attn_chunk=16, scan_chunk=16, capacity_factor=8.0, subquadratic=True,
)
