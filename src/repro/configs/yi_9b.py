"""yi-9b [dense] — llama-architecture GQA.

48L d_model=4096 32H (GQA kv=4) head_dim=128 d_ff=11008 vocab=64000.
[arXiv:2403.04652; hf]  Pure full attention -> long_500k SKIP.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b", family="dense",
    num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    attn_kind="full", subquadratic=False,
)

SMOKE = ArchConfig(
    name="yi-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, vocab_pad_multiple=32,
    attn_kind="full", attn_chunk=16, subquadratic=False,
)
