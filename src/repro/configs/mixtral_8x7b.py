"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=32000.
[arXiv:2401.04088; hf]  SWA throughout (window 4096) -> runs long_500k.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    attn_kind="swa", window_size=4096,
    num_experts=8, top_k=2, moe_d_ff=14336, moe_every=1, moe_offset=0,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="mixtral-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, vocab_pad_multiple=32,
    attn_kind="swa", window_size=8,
    num_experts=4, top_k=2, moe_d_ff=128, moe_every=1, moe_offset=0,
    attn_chunk=16, capacity_factor=8.0, subquadratic=True,
)
