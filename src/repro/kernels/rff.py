"""Pallas TPU kernel: fused random-Fourier-feature KPCA projection.

z = phi_D(x) @ U with phi_D(x) = sqrt(2/D) cos(x Omega^T + b) — the O(D(d+k))
test path of RFF-KPCA (Sriperumbudur & Sterge; DESIGN.md §15).  Fusing the
feature map with the component contraction keeps the (bn x D) feature block
in VMEM and writes only the (bn x r) embedding to HBM, the same bandwidth
argument as kpca_project.

Grid over row tiles of X; Omega (D x d), phase (1 x D) and U (D x r) are
VMEM-resident (D plays the role m plays for the center-based methods).  Both
matmuls hit the MXU; the cosine runs f32 regardless of operand precision.

Padding contract (enforced upstream in ops.rff_project): padded FEATURE rows
must carry zero Omega rows, zero phase, and zero U rows — cos(0 + 0) = 1
times a zero U row contributes nothing.  Padded data columns are zero in
both x and Omega (they don't move the inner product).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _rff_kernel(x_ref, w_ref, b_ref, u_ref, o_ref, *, scale: float):
    # mixed precision: bf16 x/Omega feed the MXU as-is with f32 accumulation;
    # the phase add and the cosine stay f32 (DESIGN.md §3 conventions)
    x = x_ref[...]                        # (bn, d) f32 or bf16
    w = w_ref[...]                        # (D, d)
    b = b_ref[...].astype(jnp.float32)    # (1, D)
    u = u_ref[...]                        # (D, r)
    s = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )                                     # (bn, D) f32
    feat = jnp.cos(s + b) * scale         # f32 feature block, never to HBM
    o_ref[...] = jnp.dot(
        feat.astype(x.dtype), u.astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def rff_project_pallas(x: Array, omega: Array, phase: Array, u: Array, *,
                       scale: float, block_n: int = 512,
                       interpret: bool = False,
                       out_dtype=jnp.float32) -> Array:
    """Fused z = (scale * cos(x Omega^T + b)) @ U.  Pad n to block_n and
    (D, r) to lane multiples upstream (padding contract in the module doc);
    ``scale`` is sqrt(2/D) with the TRUE (unpadded) feature count."""
    n, d = x.shape
    nfeat, d2 = omega.shape
    nfeat2, r = u.shape
    assert d == d2 and nfeat == nfeat2 and n % block_n == 0
    assert phase.shape == (1, nfeat), phase.shape

    kernel = functools.partial(_rff_kernel, scale=float(scale))
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((nfeat, d), lambda i: (0, 0)),
            pl.BlockSpec((1, nfeat), lambda i: (0, 0)),
            pl.BlockSpec((nfeat, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), out_dtype),
        interpret=interpret,
    )(x, omega, phase, u)
