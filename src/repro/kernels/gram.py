"""Pallas TPU kernel: blocked (optionally weighted) Gram matrix.

The paper's O(mn)/O(n^2) hot spot.  TPU adaptation (DESIGN.md §3): the cross
term of ||x-y||^2 is a matmul -> MXU; the kernel nonlinearity exp(-d/sigma^p)
and the sqrt(w_i) sqrt(w_j) RSKPCA weighting (Algorithm 1's W K W) are fused
into the same VMEM block pass, so no n x m distance matrix ever touches HBM.

Grid: (ceil(n/bn), ceil(m/bm)) output tiles.  Per tile the working set is
  x_blk (bn, d) + y_blk (bm, d) + out (bn, bm)   [f32]
With bn = bm = 256 and d <= 8192 that is 256*8192*4*2 + 256*256*4 ~= 17 MB --
too big for v5e's 16 MB VMEM at the extreme, so ``ops.py`` picks the block
size from d to stay under a VMEM budget (default 8 MB) and keeps the matmul
dims multiples of the 128-lane MXU width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _gram_kernel(x_ref, y_ref, wx_ref, wy_ref, o_ref, *, sigma: float, p: int,
                 weighted: bool, k_steps: int):
    """Grid step (i, j, k): accumulate the partial squared-distance for the
    (i, j) output tile over feature chunk k; apply the kernel nonlinearity
    (and the RSKPCA sqrt(w) weighting) on the LAST chunk.

    K-chunking keeps large-d working sets inside VMEM without shrinking the
    output tile — at d=4096 this raises arithmetic intensity from 31.5 (the
    128x128 fallback tile) to ~117 FLOP/byte (the P2 table in
    benchmarks/rskpca_scale.py).
    """
    k = pl.program_id(2)
    # mixed precision: bf16 inputs go to the MXU as-is (half the operand
    # bandwidth); norms, accumulation, and the nonlinearity stay f32
    x = x_ref[...]                      # (bn, dk) f32 or bf16
    y = y_ref[...]                      # (bm, dk)
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    xx = jnp.sum(xf * xf, axis=-1, keepdims=True)        # (bn, 1)
    yy = jnp.sum(yf * yf, axis=-1, keepdims=True).T      # (1, bm)
    cross = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # (bn, bm) on the MXU
    partial = xx + yy - 2.0 * cross

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial.astype(o_ref.dtype)

    @pl.when(k > 0)
    def _accum():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + partial
                      ).astype(o_ref.dtype)

    @pl.when(k == k_steps - 1)
    def _finish():
        d2 = jnp.maximum(o_ref[...].astype(jnp.float32), 0.0)
        if p == 2:
            s = d2 / (sigma * sigma)
        elif p == 1:
            s = jnp.sqrt(d2) / sigma
        else:
            s = d2 ** (p / 2.0) / sigma**p
        g = jnp.exp(-s)
        if weighted:
            g = g * jnp.sqrt(wx_ref[...].astype(jnp.float32))[:, None]
            g = g * jnp.sqrt(wy_ref[...].astype(jnp.float32))[None, :]
        o_ref[...] = g.astype(o_ref.dtype)


def _gram_row_kernel(x_ref, c_ref, w_ref, k_ref, d2_ref, *, sigma: float,
                     p: int, weighted: bool, k_steps: int):
    """Grid step (j, k): rank-one Gram-ROW pass for the streaming update path
    (repro/streaming): one new point against the center tile j, accumulating
    the partial squared distance over feature chunk k.  On the LAST chunk it
    emits BOTH the (optionally weight-fused) kernel row — the new row/column
    of the weighted Gram — and the raw squared distances (the online
    absorption decision of Algorithm 2 needs them in f32).
    """
    k = pl.program_id(1)
    x = x_ref[...]                      # (8, bk) f32 or bf16 (row 0 is real)
    c = c_ref[...]                      # (bm, bk)
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    xx = jnp.sum(xf[0] * xf[0])                          # scalar
    cc = jnp.sum(cf * cf, axis=-1)                       # (bm,)
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[0]                                                 # (bm,) on the MXU
    partial = xx + cc - 2.0 * cross

    @pl.when(k == 0)
    def _init():
        d2_ref[...] = partial

    @pl.when(k > 0)
    def _accum():
        d2_ref[...] = d2_ref[...] + partial

    @pl.when(k == k_steps - 1)
    def _finish():
        d2 = jnp.maximum(d2_ref[...], 0.0)
        d2_ref[...] = d2
        if p == 2:
            s = d2 / (sigma * sigma)
        elif p == 1:
            s = jnp.sqrt(d2) / sigma
        else:
            s = d2 ** (p / 2.0) / sigma**p
        g = jnp.exp(-s)
        if weighted:
            g = g * jnp.sqrt(w_ref[...].astype(jnp.float32))
        k_ref[...] = g.astype(k_ref.dtype)


def gram_row_pallas(x: Array, centers: Array, *, sigma: float, p: int = 2,
                    w: Array | None = None, block_m: int = 512,
                    block_k: int | None = None,
                    interpret: bool = False) -> tuple[Array, Array]:
    """(k_row, d2_row) of one point against all centers in one fused pass.

    x must be padded to (8, d) rows (row 0 real, the rest zero — the 8-row
    sublane minimum keeps the MXU happy); centers to (m % block_m == 0, d)
    and d % block_k == 0 (ops.gram_row handles the padding).  ``w`` fuses the
    sqrt(w_j) column weighting of Algorithm 1's W K W into the same pass.
    """
    m, d = centers.shape
    assert x.shape == (8, d), (x.shape, d)
    assert m % block_m == 0, (m, block_m)
    block_k = block_k or d
    assert d % block_k == 0, (d, block_k)
    k_steps = d // block_k
    weighted = w is not None
    if w is None:
        w = jnp.ones((m,), jnp.float32)

    kernel = functools.partial(_gram_row_kernel, sigma=float(sigma),
                               p=int(p), weighted=weighted, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, k_steps),
        in_specs=[
            pl.BlockSpec((8, block_k), lambda j, k: (0, k)),
            pl.BlockSpec((block_m, block_k), lambda j, k: (j, k)),
            pl.BlockSpec((block_m,), lambda j, k: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m,), lambda j, k: (j,)),
            pl.BlockSpec((block_m,), lambda j, k: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=interpret,
    )(x, centers, w)


def _gram_matvec_kernel(x_ref, y_ref, wx_ref, wy_ref, v_ref, o_ref, d2_ref, *,
                        sigma: float, p: int, weighted: bool, k_steps: int):
    """Grid step (i, j, k): matrix-free K_w @ V, flash-attention style.

    For output row-tile i, column-tile j accumulates the partial squared
    distance over feature chunk k into the VMEM scratch ``d2_ref`` (the
    (bn, bm) Gram tile lives ONLY there — it is never written to HBM).  On
    the last feature chunk the kernel nonlinearity and the RSKPCA sqrt(w)
    weighting are applied in-register and the tile is immediately contracted
    against V's j-tile on the MXU, accumulating into the (bn, r) output
    tile.  f32 accumulation throughout; bf16 operands only feed the matmuls.
    """
    j = pl.program_id(1)
    k = pl.program_id(2)
    x = x_ref[...]                      # (bn, bk) f32 or bf16
    y = y_ref[...]                      # (bm, bk)
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    xx = jnp.sum(xf * xf, axis=-1, keepdims=True)        # (bn, 1)
    yy = jnp.sum(yf * yf, axis=-1, keepdims=True).T      # (1, bm)
    cross = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # (bn, bm) on the MXU
    partial = xx + yy - 2.0 * cross

    @pl.when(k == 0)
    def _init():
        d2_ref[...] = partial

    @pl.when(k > 0)
    def _accum():
        d2_ref[...] = d2_ref[...] + partial

    @pl.when(k == k_steps - 1)
    def _contract():
        d2 = jnp.maximum(d2_ref[...], 0.0)
        if p == 2:
            s = d2 / (sigma * sigma)
        elif p == 1:
            s = jnp.sqrt(d2) / sigma
        else:
            s = d2 ** (p / 2.0) / sigma**p
        g = jnp.exp(-s)
        if weighted:
            g = g * jnp.sqrt(wx_ref[...].astype(jnp.float32))[:, None]
            g = g * jnp.sqrt(wy_ref[...].astype(jnp.float32))[None, :]
        v = v_ref[...]                                   # (bm, r)
        pv = jax.lax.dot_general(
            g.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (bn, r) on the MXU

        @pl.when(j == 0)
        def _first():
            o_ref[...] = pv.astype(o_ref.dtype)

        @pl.when(j > 0)
        def _rest():
            o_ref[...] = (o_ref[...].astype(jnp.float32) + pv
                          ).astype(o_ref.dtype)


def gram_matvec_pallas(x: Array, y: Array, v: Array, *, sigma: float,
                       p: int = 2, wx: Array | None = None,
                       wy: Array | None = None, block_n: int = 256,
                       block_m: int = 256, block_k: int | None = None,
                       interpret: bool = False) -> Array:
    """out = K_w @ v without materializing K_w: out[i] = sum_j sqrt(wx_i)
    phi(||x_i-y_j||^p/sigma^p) sqrt(wy_j) v[j].

    Peak memory is O(n*r + tiles), never O(n*m) — the Gram tile exists only
    in the (block_n, block_m) VMEM scratch.  Shapes must be pre-padded:
    n % block_n == 0, m % block_m == 0, d % block_k == 0, and v's row count
    equal to m with zero rows on any padded tail (``ops.gram_matvec``
    handles all padding; zero v-rows make unweighted padding exact, and
    zero-weight padding already kills padded columns on the weighted path).
    """
    n, d = x.shape
    m, d2_ = y.shape
    assert d == d2_, (x.shape, y.shape)
    assert v.shape[0] == m, (v.shape, m)
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    block_k = block_k or d
    assert d % block_k == 0, (d, block_k)
    k_steps = d // block_k
    r = v.shape[1]
    weighted = wx is not None
    if wx is None:
        wx = jnp.ones((n,), jnp.float32)
    if wy is None:
        wy = jnp.ones((m,), jnp.float32)

    grid = (n // block_n, m // block_m, k_steps)
    kernel = functools.partial(_gram_matvec_kernel, sigma=float(sigma),
                               p=int(p), weighted=weighted, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_n,), lambda i, j, k: (i,)),
            pl.BlockSpec((block_m,), lambda i, j, k: (j,)),
            pl.BlockSpec((block_m, r), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, r), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n, block_m), jnp.float32)],
        interpret=interpret,
    )(x, y, wx, wy, v)


def gram_pallas(x: Array, y: Array, *, sigma: float, p: int = 2,
                wx: Array | None = None, wy: Array | None = None,
                block_n: int = 256, block_m: int = 256,
                block_k: int | None = None,
                interpret: bool = False, out_dtype=jnp.float32) -> Array:
    """K[i, j] = sqrt(wx_i) phi(||x_i-y_j||^p/sigma^p) sqrt(wy_j).

    Shapes must already be padded: n % block_n == 0, m % block_m == 0,
    d % block_k == 0 (ops.gram handles padding/unpadding).
    """
    n, d = x.shape
    m, d2_ = y.shape
    assert d == d2_, (x.shape, y.shape)
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    block_k = block_k or d
    assert d % block_k == 0, (d, block_k)
    k_steps = d // block_k
    weighted = wx is not None
    if wx is None:
        wx = jnp.ones((n,), jnp.float32)
    if wy is None:
        wy = jnp.ones((m,), jnp.float32)

    grid = (n // block_n, m // block_m, k_steps)
    kernel = functools.partial(_gram_kernel, sigma=float(sigma), p=int(p),
                               weighted=weighted, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_n,), lambda i, j, k: (i,)),
            pl.BlockSpec((block_m,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), out_dtype),
        interpret=interpret,
    )(x, y, wx, wy)
