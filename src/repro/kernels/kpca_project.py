"""Pallas TPU kernel: fused RSKPCA test-time projection.

z = phi(dists(x, C)) @ A with A = diag(sqrt(w)) U Lambda^{-1/2} (m x r).
This is the O(km) evaluation path the paper accelerates; fusing the Gram
block with the projection matmul keeps the (bn x m) kernel block in VMEM and
writes only the (bn x r) embedding to HBM — an (m/r)x reduction in output
bandwidth (m ~ thousands, r ~ 5-64).

Grid over row tiles of X; centers and projector are VMEM-resident (m x d and
m x r are small by the paper's construction).  Both matmuls hit the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import quantize as _quant

Array = jax.Array


def _project_kernel(x_ref, c_ref, a_ref, o_ref, *, sigma: float, p: int):
    # mixed precision: bf16 x/c go to the MXU as-is; norms, the distance
    # accumulation, and the exp nonlinearity stay f32 (DESIGN.md §3)
    x = x_ref[...]                       # (bn, d) f32 or bf16
    c = c_ref[...]                       # (m, d)
    a = a_ref[...].astype(jnp.float32)   # (m, r)
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    xx = jnp.sum(xf * xf, axis=-1, keepdims=True)
    cc = jnp.sum(cf * cf, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    d2 = jnp.maximum(xx + cc - 2.0 * cross, 0.0)
    if p == 2:
        s = d2 / (sigma * sigma)
    elif p == 1:
        s = jnp.sqrt(d2) / sigma
    else:
        s = d2 ** (p / 2.0) / sigma**p
    g = jnp.exp(-s)                       # (bn, m) f32
    o_ref[...] = jnp.dot(
        g.astype(x.dtype), a.astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def kpca_project_pallas(x: Array, centers: Array, projector: Array, *,
                        sigma: float, p: int = 2, block_n: int = 512,
                        interpret: bool = False,
                        out_dtype=jnp.float32) -> Array:
    """Fused z = k(x, C) @ A.  Pad n to block_n and (m, r) to lane multiples
    upstream (padded centers must carry zero projector rows)."""
    n, d = x.shape
    m, d2_ = centers.shape
    m2, r = projector.shape
    assert d == d2_ and m == m2 and n % block_n == 0

    kernel = functools.partial(_project_kernel, sigma=float(sigma), p=int(p))
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), out_dtype),
        interpret=interpret,
    )(x, centers, projector)


# --------------------------------------------------------------------------
# quantized projector tier (int8 / fp8; kernels/quantize.py)
# --------------------------------------------------------------------------


def _project_kernel_quant(x_ref, c_ref, q_ref, s_ref, o_ref, *, sigma: float,
                          p: int, qmode: str, sg: float):
    # distances and the exp nonlinearity stay f32 — exactly the f32 kernel
    # above; ONLY the projector contraction drops precision (DESIGN.md §8)
    xf = x_ref[...].astype(jnp.float32)          # (bn, d)
    cf = c_ref[...].astype(jnp.float32)          # (m, d)
    xx = jnp.sum(xf * xf, axis=-1, keepdims=True)
    cc = jnp.sum(cf * cf, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(
        xf, cf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    d2 = jnp.maximum(xx + cc - 2.0 * cross, 0.0)
    if p == 2:
        s = d2 / (sigma * sigma)
    elif p == 1:
        s = jnp.sqrt(d2) / sigma
    else:
        s = d2 ** (p / 2.0) / sigma**p
    g = jnp.exp(-s)                              # (bn, m) f32, in [0, kappa]
    scale = s_ref[...].astype(jnp.float32)       # (1, r) channel scales
    if qmode == "int8":
        # integer contraction with int32 accumulation: EXACT, so this path
        # agrees bitwise with the dense quantized fallback in ops.py
        gq = jnp.round(g * (1.0 / sg)).astype(jnp.int8)
        acc = jax.lax.dot_general(
            gq, q_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        o_ref[...] = (acc.astype(jnp.float32) * sg * scale).astype(
            o_ref.dtype)
    else:  # fp8: round operands to e4m3, accumulate f32.  The f32 upcast
        # before the dot is exact on the rounded operands, so this IS the
        # fp8-operand / f32-accumulation semantics on any backend (an
        # fp8-MXU backend may fuse the cast away).
        gq = g.astype(_quant.FP8_DTYPE)
        acc = jax.lax.dot_general(
            gq.astype(jnp.float32), q_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
        o_ref[...] = (acc * scale).astype(o_ref.dtype)


def kpca_project_quant_pallas(x: Array, centers: Array, q: Array,
                              scale: Array, *, sigma: float, p: int = 2,
                              qmode: str = "int8", block_n: int = 512,
                              interpret: bool = False,
                              out_dtype=jnp.float32) -> Array:
    """Fused z ≈ k(x, C) @ A with the projector pre-quantized
    (kernels/quantize.py): ``q`` (m, r) int8|fp8, ``scale`` (1, r) f32.
    Padding contract as the f32 kernel: padded centers carry zero q rows,
    padded scale columns are 1 and stripped by the caller."""
    n, d = x.shape
    m, d2_ = centers.shape
    m2, r = q.shape
    assert d == d2_ and m == m2 and n % block_n == 0
    assert scale.shape == (1, r), scale.shape

    kernel = functools.partial(
        _project_kernel_quant, sigma=float(sigma), p=int(p), qmode=str(qmode),
        sg=_quant.gram_scale(qmode))
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), out_dtype),
        interpret=interpret,
    )(x, centers, q, scale)
