# Pallas TPU kernels for the paper's compute hot-spots:
#   gram.py          — blocked (weighted) Gram matrix, Algorithm 1's W K^C W
#   shadow_assign.py — nearest-center assignment (alpha map / blocked shadow)
#   kpca_project.py  — fused k(x, C) @ A test-time projection
# ops.py = public jit'd wrappers (padding, block sizing, TPU/interpret dispatch)
# ref.py = pure-jnp oracles the kernels are swept against.
from repro.kernels import ops, ref  # noqa: F401
