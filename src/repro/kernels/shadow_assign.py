"""Pallas TPU kernel: nearest-center assignment pass.

Drives (a) the data->center map alpha of §5 and (b) the inner absorption pass
of blocked shadow selection (DESIGN.md §3).  Grid over row tiles of X; the
(small) center set is resident in VMEM and swept in ``block_m`` column tiles
with a running (argmin, min) pair so arbitrary m fits the same kernel.

Padding protocol: callers pad centers to a multiple of block_m and pass a
``valid`` float mask (1 = real center); invalid slots are forced to +inf so
they can never win the argmin.  The mask is DATA, not a static argument —
blocked selection calls this kernel once per round with a different mask and
must not retrace (the round loop is host-driven).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _assign_kernel(x_ref, c_ref, v_ref, o_idx_ref, o_d2_ref, *, block_m: int):
    x = x_ref[...].astype(jnp.float32)      # (bn, d)
    c = c_ref[...].astype(jnp.float32)      # (m_pad, d)
    v = v_ref[...].astype(jnp.float32)      # (m_pad,)
    m_pad = c.shape[0]
    xx = jnp.sum(x * x, axis=-1, keepdims=True)  # (bn, 1)

    def sweep(k, carry):
        best_d2, best_idx = carry
        blk = jax.lax.dynamic_slice_in_dim(c, k * block_m, block_m, axis=0)
        vblk = jax.lax.dynamic_slice_in_dim(v, k * block_m, block_m, axis=0)
        yy = jnp.sum(blk * blk, axis=-1, keepdims=True).T   # (1, bm)
        cross = jax.lax.dot_general(
            x, blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        d2 = jnp.maximum(xx + yy - 2.0 * cross, 0.0)        # (bn, bm)
        col = k * block_m + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
        d2 = jnp.where(vblk[None, :] > 0.0, d2, jnp.inf)
        blk_d2 = jnp.min(d2, axis=1)
        blk_idx = col[jnp.arange(d2.shape[0]), jnp.argmin(d2, axis=1)]
        take = blk_d2 < best_d2
        return (jnp.where(take, blk_d2, best_d2),
                jnp.where(take, blk_idx, best_idx))

    bn = x.shape[0]
    best = (jnp.full((bn,), jnp.inf, jnp.float32),
            jnp.zeros((bn,), jnp.int32))
    best_d2, best_idx = jax.lax.fori_loop(0, m_pad // block_m, sweep, best)
    o_idx_ref[...] = best_idx
    o_d2_ref[...] = best_d2


def shadow_assign_pallas(x: Array, centers: Array, valid: Array, *,
                         block_n: int = 512, block_m: int = 128,
                         interpret: bool = False):
    """Returns (idx (n,), d2min (n,)) of the nearest valid center.

    ``valid`` is a (m_pad,) float mask; slots with valid <= 0 never win.  If
    NO center is valid, d2min is +inf and idx is 0 — callers gate on d2min.
    """
    n, d = x.shape
    m_pad, d2_ = centers.shape
    assert d == d2_ and n % block_n == 0 and m_pad % block_m == 0
    assert valid.shape == (m_pad,)

    kernel = functools.partial(_assign_kernel, block_m=block_m)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((m_pad, d), lambda i: (0, 0)),  # centers resident
            pl.BlockSpec((m_pad,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x, centers, valid)
