"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the numerical ground truth the kernels are swept against in
tests/test_kernels.py (interpret=True on CPU, shapes x dtypes x kernel-p).
"""
from __future__ import annotations

import jax.numpy as jnp


def _sq_dists(x, y):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1, keepdims=True)
    yy = jnp.sum(y * y, axis=-1, keepdims=True).T
    return jnp.maximum(xx + yy - 2.0 * (x @ y.T), 0.0)


def _kernel_of_sq(d2, sigma: float, p: int):
    if p == 2:
        s = d2 / (sigma * sigma)
    elif p == 1:
        s = jnp.sqrt(d2) / sigma
    else:
        s = d2 ** (p / 2.0) / sigma**p
    return jnp.exp(-s)


def gram_ref(x, y, sigma: float, p: int = 2,
             wx=None, wy=None) -> jnp.ndarray:
    """(Optionally weighted) Gram block:
    G_ij = sqrt(wx_i) * phi(||x_i - y_j||^p / sigma^p) * sqrt(wy_j).
    """
    g = _kernel_of_sq(_sq_dists(x, y), sigma, p)
    if wx is not None:
        g = g * jnp.sqrt(wx.astype(g.dtype))[:, None]
    if wy is not None:
        g = g * jnp.sqrt(wy.astype(g.dtype))[None, :]
    return g


def shadow_assign_ref(x, centers, m_valid: int):
    """Nearest valid center: returns (idx (n,), d2min (n,)).

    Centers beyond ``m_valid`` are padding and must never win.
    """
    d2 = _sq_dists(x, centers)
    mask = jnp.arange(centers.shape[0])[None, :] < m_valid
    d2 = jnp.where(mask, d2, jnp.inf)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def kpca_project_ref(x, centers, projector, sigma: float, p: int = 2):
    """Fused embedding z = phi(dists(x, C)) @ A, A: (m, r)."""
    g = _kernel_of_sq(_sq_dists(x, centers), sigma, p)
    return g @ projector.astype(g.dtype)
