"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * pad inputs to block multiples (and mask/strip on the way out);
  * pick block sizes from a VMEM budget (v5e ~16 MB/core; we budget 8 MB);
  * dispatch: real pallas on TPU, interpret=True elsewhere (this container is
    CPU-only, so interpret mode is also what the tests exercise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import gram as _gram
from repro.kernels import shadow_assign as _assign
from repro.kernels import kpca_project as _project

Array = jax.Array

_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pad_rows(a: Array, mult: int, value: float = 0.0) -> Array:
    n = a.shape[0]
    pad = _round_up(max(n, 1), mult) - n
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def pick_gram_blocks(d: int, budget: int = _VMEM_BUDGET_BYTES):
    """(bn, bm, bk): output tile + K-chunk so the working set
    (bn*bk + bm*bk + bn*bm) * 4B fits the VMEM budget.

    K-chunking (accumulating partial distances over feature chunks) keeps
    the 512x512 output tile at ANY d - without it d=4096 forced 128x128
    tiles and dropped arithmetic intensity to ~31 FLOP/byte (see
    EXPERIMENTS.md Perf-RSKPCA)."""
    for b in (512, 256, 128):
        for bk in (min(d, 512), 256, 128):
            if bk > d:
                continue
            if (2 * b * bk + b * b) * 4 <= budget:
                return b, b, bk
    return 128, 128, 128


@functools.partial(jax.jit, static_argnames=("sigma", "p", "interpret",
                                             "bn", "bm", "bk"))
def _gram_call(xp, yp, wxp, wyp, *, sigma, p, interpret, bn, bm, bk):
    return _gram.gram_pallas(xp, yp, sigma=sigma, p=p, wx=wxp, wy=wyp,
                             block_n=bn, block_m=bm, block_k=bk,
                             interpret=interpret)


def gram(x, y, *, sigma: float, p: int = 2, wx=None, wy=None,
         interpret: bool | None = None) -> Array:
    """(Weighted) Gram matrix via the Pallas kernel; pads and strips."""
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n, m = x.shape[0], y.shape[0]
    bn, bm, bk = pick_gram_blocks(x.shape[1])
    # shrink tiles toward small inputs so a 150-row Gram doesn't pad to 512
    bn = min(bn, _round_up(n, 128))
    bm = min(bm, _round_up(m, 128))
    bk = min(bk, _round_up(x.shape[1], 128))
    # pad the feature dim to the K-chunk (zero features don't move distances)
    dpad = _round_up(x.shape[1], bk) - x.shape[1]
    if dpad:
        x = jnp.pad(x, ((0, 0), (0, dpad)))
        y = jnp.pad(y, ((0, 0), (0, dpad)))
    xp = _pad_rows(x, bn)
    yp = _pad_rows(y, bm)
    wxp = _pad_rows(jnp.asarray(wx, jnp.float32), bn) if wx is not None \
        else jnp.ones((xp.shape[0],), jnp.float32)
    wyp = _pad_rows(jnp.asarray(wy, jnp.float32), bm) if wy is not None \
        else jnp.ones((yp.shape[0],), jnp.float32)
    out = _gram_call(xp, yp, wxp, wyp, sigma=float(sigma), p=int(p),
                     interpret=bool(interpret), bn=bn, bm=bm, bk=bk)
    return out[:n, :m]


def weighted_gram(centers, weights, *, sigma: float, p: int = 2,
                  interpret: bool | None = None) -> Array:
    """Algorithm 1's K-tilde = W K^C W in one fused pass."""
    return gram(centers, centers, sigma=sigma, p=p, wx=weights, wy=weights,
                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def _assign_call(xp, cp, vp, *, bn, bm, interpret):
    return _assign.shadow_assign_pallas(xp, cp, vp, block_n=bn, block_m=bm,
                                        interpret=interpret)


def shadow_assign(x, centers, m_valid: int | None = None, *, valid=None,
                  interpret: bool | None = None):
    """Nearest-center (idx, d2min) via the Pallas assignment kernel.

    Validity can be given as a static prefix length ``m_valid`` or as a
    dynamic per-center ``valid`` mask (used by blocked shadow selection: the
    round loop reuses one compiled kernel with a fresh mask each round).
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    n, m = x.shape[0], centers.shape[0]
    # off-TPU the grid loop itself is the overhead (no VMEM limit to respect),
    # so take far fewer, fatter row tiles: 8192 rows ~2.3x faster than 512 at
    # n=32k in interpret mode
    block_n, block_m = (8192, 128) if interpret else (512, 128)
    # split the 128-padded row count into equal fat tiles rather than padding
    # up to a block_n multiple (that would waste up to block_n-1 rows of
    # distance work per call, ~2x for n just above a multiple)
    npad = _round_up(n, 128)
    tiles = -(-npad // block_n)
    bn = min(block_n, _round_up(-(-npad // tiles), 128))
    xp = _pad_rows(x, bn)
    cp = _pad_rows(centers, block_m)
    if valid is None:
        m_valid = m if m_valid is None else int(m_valid)
        valid = (jnp.arange(m) < m_valid).astype(jnp.float32)
    vp = _pad_rows(jnp.asarray(valid, jnp.float32), block_m)
    idx, d2 = _assign_call(xp, cp, vp, bn=bn, bm=block_m,
                           interpret=bool(interpret))
    return idx[:n], d2[:n]


@functools.partial(jax.jit, static_argnames=("sigma", "p", "bn", "interpret"))
def _project_call(xp, cp, ap, *, sigma, p, bn, interpret):
    return _project.kpca_project_pallas(xp, cp, ap, sigma=sigma, p=p,
                                        block_n=bn, interpret=interpret)


def kpca_project(x, centers, projector, *, sigma: float, p: int = 2,
                 chunk: int | None = None,
                 interpret: bool | None = None) -> Array:
    """Fused z = k(x, C) @ A.  Pads m with zero projector rows (harmless:
    padded centers contribute k(x, 0-pad)*0).

    ``chunk`` streams query rows through the kernel in fixed-size slices, so
    arbitrarily large query sets never materialize more than a
    (chunk, m_pad) working set on device (the fused kernel never writes the
    q x m Gram to HBM either way — this bounds the padded INPUT residency).
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    projector = jnp.asarray(projector, jnp.float32)
    n, r = x.shape[0], projector.shape[1]
    # pad m to a lane multiple; padded projector rows are zero so padded
    # centers cannot contribute
    cp = _pad_rows(centers, 128)
    ap = _pad_rows(projector, 128)
    rp = _round_up(r, 128)
    ap = jnp.pad(ap, ((0, 0), (0, rp - r)))

    def run(xs):
        bn = min(512, _round_up(xs.shape[0], 128))
        xsp = _pad_rows(xs, bn)
        out = _project_call(xsp, cp, ap, sigma=float(sigma), p=int(p),
                            bn=bn, interpret=bool(interpret))
        return out[: xs.shape[0], :r]

    if chunk is None or n <= chunk:
        return run(x)
    chunk = _round_up(chunk, 128)
    pieces = [run(x[s : s + chunk]) for s in range(0, n, chunk)]
    return jnp.concatenate(pieces, axis=0)
