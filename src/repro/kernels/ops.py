"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * pad inputs to block multiples (and mask/strip on the way out);
  * pick block sizes from a VMEM budget (v5e ~16 MB/core; we budget 8 MB);
  * dispatch: real pallas on TPU, interpret=True elsewhere (this container is
    CPU-only, so interpret mode is also what the tests exercise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import gram as _gram
from repro.kernels import shadow_assign as _assign
from repro.kernels import kpca_project as _project

Array = jax.Array

_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pad_rows(a: Array, mult: int, value: float = 0.0) -> Array:
    n = a.shape[0]
    pad = _round_up(max(n, 1), mult) - n
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def pick_gram_blocks(d: int, budget: int = _VMEM_BUDGET_BYTES):
    """(bn, bm, bk): output tile + K-chunk so the working set
    (bn*bk + bm*bk + bn*bm) * 4B fits the VMEM budget.

    K-chunking (accumulating partial distances over feature chunks) keeps
    the 512x512 output tile at ANY d - without it d=4096 forced 128x128
    tiles and dropped arithmetic intensity to ~31 FLOP/byte (see
    EXPERIMENTS.md Perf-RSKPCA)."""
    for b in (512, 256, 128):
        for bk in (min(d, 512), 256, 128):
            if bk > d:
                continue
            if (2 * b * bk + b * b) * 4 <= budget:
                return b, b, bk
    return 128, 128, 128


@functools.partial(jax.jit, static_argnames=("sigma", "p", "interpret"))
def _gram_call(xp, yp, wxp, wyp, *, sigma, p, interpret):
    bn, bm, bk = pick_gram_blocks(xp.shape[1])
    bn = min(bn, xp.shape[0])
    bm = min(bm, yp.shape[0])
    return _gram.gram_pallas(xp, yp, sigma=sigma, p=p, wx=wxp, wy=wyp,
                             block_n=bn, block_m=bm, block_k=bk,
                             interpret=interpret)


def gram(x, y, *, sigma: float, p: int = 2, wx=None, wy=None,
         interpret: bool | None = None) -> Array:
    """(Weighted) Gram matrix via the Pallas kernel; pads and strips."""
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n, m = x.shape[0], y.shape[0]
    bn, bm, bk = pick_gram_blocks(x.shape[1])
    # pad the feature dim to the K-chunk (zero features don't move distances)
    dpad = _round_up(x.shape[1], bk) - x.shape[1]
    if dpad:
        x = jnp.pad(x, ((0, 0), (0, dpad)))
        y = jnp.pad(y, ((0, 0), (0, dpad)))
    xp = _pad_rows(x, bn)
    yp = _pad_rows(y, bm)
    wxp = _pad_rows(jnp.asarray(wx, jnp.float32), bn) if wx is not None \
        else jnp.ones((xp.shape[0],), jnp.float32)
    wyp = _pad_rows(jnp.asarray(wy, jnp.float32), bm) if wy is not None \
        else jnp.ones((yp.shape[0],), jnp.float32)
    out = _gram_call(xp, yp, wxp, wyp, sigma=float(sigma), p=int(p),
                     interpret=bool(interpret))
    return out[:n, :m]


def weighted_gram(centers, weights, *, sigma: float, p: int = 2,
                  interpret: bool | None = None) -> Array:
    """Algorithm 1's K-tilde = W K^C W in one fused pass."""
    return gram(centers, centers, sigma=sigma, p=p, wx=weights, wy=weights,
                interpret=interpret)


def shadow_assign(x, centers, m_valid: int | None = None, *,
                  interpret: bool | None = None):
    """Nearest-center (idx, d2min) via the Pallas assignment kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    n = x.shape[0]
    m_valid = centers.shape[0] if m_valid is None else int(m_valid)
    block_n, block_m = 512, 128
    xp = _pad_rows(x, block_n)
    cp = _pad_rows(centers, block_m)
    idx, d2 = _assign.shadow_assign_pallas(
        xp, cp, m_valid, block_n=min(block_n, xp.shape[0]),
        block_m=block_m, interpret=bool(interpret),
    )
    return idx[:n], d2[:n]


def kpca_project(x, centers, projector, *, sigma: float, p: int = 2,
                 interpret: bool | None = None) -> Array:
    """Fused z = k(x, C) @ A.  Pads m with zero projector rows (harmless:
    padded centers contribute k(x, 0-pad)*0)."""
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    projector = jnp.asarray(projector, jnp.float32)
    n, r = x.shape[0], projector.shape[1]
    block_n = 512
    xp = _pad_rows(x, block_n)
    # pad m to a lane multiple; padded projector rows are zero so padded
    # centers cannot contribute
    cp = _pad_rows(centers, 128)
    ap = _pad_rows(projector, 128)
    rp = _round_up(r, 128)
    ap = jnp.pad(ap, ((0, 0), (0, rp - r)))
    out = _project.kpca_project_pallas(
        xp, cp, ap, sigma=float(sigma), p=int(p),
        block_n=min(block_n, xp.shape[0]), interpret=bool(interpret),
    )
    return out[:n, :r]
