"""Public jit'd wrappers around the Pallas kernels.

Responsibilities (DESIGN.md §3):
  * pad inputs to block multiples (and mask/strip on the way out);
  * pick a compute plan per call via the measured autotuner in
    ``repro.kernels.autotune``: the Pallas kernel (tuned tiles) above the
    crossover, a dense-jnp fallback below it so small problems stop paying
    Pallas interpret/grid overhead;
  * mixed precision: ``precision="bf16"`` feeds bf16 operands to the MXU
    matmuls while the distance accumulation and the exp nonlinearity stay
    f32;
  * dispatch: real pallas on TPU, interpret=True elsewhere (this container is
    CPU-only, so interpret mode is also what the tests exercise).

``plan=`` forces a path explicitly ("pallas" | "pallas_fat" | "dense");
tests use it to keep the kernel bodies exercised regardless of what the
autotuner would pick.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import autotune

# NOTE on donation: the donated fit/transform entry points mark their
# scratch operands dead for the caller; XLA only ALIASES a donated buffer
# into an output of matching shape and emits a trace-time UserWarning
# otherwise.  Off-alias donation is the expected steady state here
# (projector outputs rarely match center-buffer shapes), and the warning is
# deliberately NOT suppressed: a global filter would swallow user code's own
# donation diagnostics and a per-call catch_warnings races across serving
# threads.  Python's default dedup shows it once per compiled shape;
# aliasing success is asserted where it matters, in tests/test_matfree.py.
from repro.kernels import gram as _gram
from repro.kernels import shadow_assign as _assign
from repro.kernels import kpca_project as _project
from repro.kernels import quantize as _quantize
from repro.kernels import rff as _rff

Array = jax.Array

_VMEM_BUDGET_BYTES = 8 * 1024 * 1024

#: "int8"/"fp8" are the SERVING tiers (DESIGN.md §8): they quantize only the
#: kpca_project projector contraction; every other Gram-shaped op (fit-side
#: gram/gram_matvec/gram_row) treats them as the bf16 MXU tier, and
#: shadow_assign always resolves distances in f32 regardless.
_PRECISIONS = ("f32", "bf16") + _quantize.QUANT_PRECISIONS


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pad_rows(a: Array, mult: int, value: float = 0.0) -> Array:
    n = a.shape[0]
    pad = _round_up(max(n, 1), mult) - n
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=value)


def _compute_dtype(precision: str):
    if precision not in _PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {_PRECISIONS}")
    # every reduced tier (bf16 AND the int8/fp8 serving tiers) feeds bf16
    # operands to the non-projector MXU matmuls; f32 stays f32
    return jnp.float32 if precision == "f32" else jnp.bfloat16


def pick_gram_blocks(d: int, budget: int = _VMEM_BUDGET_BYTES):
    """(bn, bm, bk): output tile + K-chunk so the working set
    (bn*bk + bm*bk + bn*bm) * 4B fits the VMEM budget.

    K-chunking (accumulating partial distances over feature chunks) keeps
    the 512x512 output tile at ANY d - without it d=4096 forced 128x128
    tiles and dropped arithmetic intensity to ~31 FLOP/byte (the P2 table in
    benchmarks/rskpca_scale.py reports the per-d numbers).

    This is the VMEM-safety baseline the autotuner starts from; the measured
    plan (repro.kernels.autotune) may instead pick fatter interpret-mode
    tiles or the dense fallback."""
    for b in (512, 256, 128):
        for bk in (min(d, 512), 256, 128):
            if bk > d:
                continue
            if (2 * b * bk + b * b) * 4 <= budget:
                return b, b, bk
    return 128, 128, 128


def _fat_gram_blocks(d: int):
    """Interpret-mode tiles: off-TPU there is no VMEM limit and the grid
    loop itself is the overhead, so take far fewer, fatter row tiles."""
    return 2048, 512, min(512, _round_up(d, 128))


# --------------------------------------------------------------------------
# dense-jnp fallbacks (the below-crossover plan; also honor bf16 operands)
# --------------------------------------------------------------------------


def _dist_pow(d2: Array, p: int) -> Array:
    if p == 2:
        return d2
    if p == 1:
        return jnp.sqrt(d2)
    return d2 ** (p / 2.0)


def _dense_sq_dists(x: Array, y: Array, precision: str) -> Array:
    """f32 norms + (optionally bf16) MXU cross term, f32 accumulation."""
    cd = _compute_dtype(precision)
    xx = jnp.sum(x * x, axis=-1, keepdims=True)
    yy = jnp.sum(y * y, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(
        x.astype(cd), y.astype(cd), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return jnp.maximum(xx + yy - 2.0 * cross, 0.0)


@functools.partial(jax.jit,
                   static_argnames=("sigma", "p", "weighted", "precision"))
def _gram_dense(x, y, wx, wy, *, sigma, p, weighted, precision):
    d2 = _dense_sq_dists(x, y, precision)
    g = jnp.exp(-_dist_pow(d2, p) / sigma**p)
    if weighted:
        g = g * jnp.sqrt(wx)[:, None] * jnp.sqrt(wy)[None, :]
    return g


@functools.partial(jax.jit,
                   static_argnames=("sigma", "p", "weighted", "precision"))
def _gram_matvec_dense(x, y, wx, wy, v, *, sigma, p, weighted, precision):
    """Below-crossover fallback: materialize the (small) Gram, then matmul."""
    g = _gram_dense(x, y, wx, wy, sigma=sigma, p=p, weighted=weighted,
                    precision=precision)
    cd = _compute_dtype(precision)
    return jax.lax.dot_general(
        g.astype(cd), v.astype(cd), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=())
def _assign_dense(x, c, valid):
    d2 = _dense_sq_dists(x, c, "f32")  # assignment always resolves in f32
    d2 = jnp.where(valid[None, :] > 0.0, d2, jnp.inf)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


@functools.partial(jax.jit, static_argnames=("sigma", "p", "precision"))
def _project_dense(x, c, a, *, sigma, p, precision):
    cd = _compute_dtype(precision)
    d2 = _dense_sq_dists(x, c, precision)
    g = jnp.exp(-_dist_pow(d2, p) / sigma**p)  # nonlinearity stays f32
    return jax.lax.dot_general(
        g.astype(cd), a.astype(cd), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("sigma", "p", "qmode"))
def _project_dense_quant(x, c, q, s, *, sigma, p, qmode):
    # dense fallback of the quantized serving tier: IDENTICAL quantized
    # arithmetic to kernels/kpca_project._project_kernel_quant — the int8
    # contraction accumulates in int32 (integer-exact), so this path and
    # the Pallas path agree bitwise (asserted in tests/test_quantized.py)
    d2 = _dense_sq_dists(x, c, "f32")
    g = jnp.exp(-_dist_pow(d2, p) / sigma**p)
    sj = jnp.asarray(s, jnp.float32)[None, :]
    if qmode == "int8":
        sg = _quantize.gram_scale(qmode)
        gq = jnp.round(g * (1.0 / sg)).astype(jnp.int8)
        acc = jax.lax.dot_general(
            gq, q, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) * sg * sj
    gq = g.astype(_quantize.FP8_DTYPE)
    acc = jax.lax.dot_general(
        gq.astype(jnp.float32), q.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return acc * sj


# --------------------------------------------------------------------------
# autotuned plan selection
# --------------------------------------------------------------------------


#: Plan measurement runs on shapes clamped to this many rows: beyond it the
#: relative ranking of candidates is stable, and an unclamped measurement at
#: a 64k-row bucket would cost a full Gram just to pick tiles.
_MEASURE_MAX_ROWS = 8192


def _bench_rows(n: int, d: int) -> Array:
    # deterministic synthetic operands for plan measurement (values are
    # irrelevant to timing; arange avoids a PRNG compile)
    n = min(n, _MEASURE_MAX_ROWS)
    return (jnp.arange(n * d, dtype=jnp.float32) % 977.0
            ).reshape(n, d) / 977.0


def _gram_plan(n: int, m: int, d: int, precision: str, interpret: bool):
    """Returns ("dense", None) or ("pallas", (bn, bm, bk))."""
    nb, mb = autotune.bucket(n), autotune.bucket(m)
    db = autotune.bucket(d, lo=8, hi=8192)
    mode = "interp" if interpret else "tpu"
    if not autotune.measurement_enabled():
        kind = autotune.heuristic_plan(n, m, interpret)
        return ((kind, None) if kind == "dense"
                else ("pallas", pick_gram_blocks(d)))
    key = f"gram|n{nb}|m{mb}|d{db}|{precision}|{mode}"
    x, y = _bench_rows(nb, db), _bench_rows(mb, db)

    def run(plan):
        return lambda: jax.block_until_ready(gram(
            x, y, sigma=1.0, p=2, interpret=interpret,
            precision=precision, plan=plan))

    cands = {"pallas": run("pallas")}
    if interpret:
        cands["pallas_fat"] = run("pallas_fat")
    if nb * mb <= autotune.DENSE_MAX_CELLS:
        cands["dense"] = run("dense")
    winner = autotune.best(key, cands, default="pallas")
    if winner == "dense":
        return "dense", None
    blocks = _fat_gram_blocks(d) if winner == "pallas_fat" \
        else pick_gram_blocks(d)
    return "pallas", blocks


def _matvec_plan(n: int, m: int, d: int, r: int, precision: str,
                 interpret: bool, allow_dense: bool = True):
    """Returns ("dense", None) or ("pallas", (bn, bm, bk)) for gram_matvec.

    ``allow_dense=False`` keeps the dense (Gram-materializing) fallback out
    of the candidate set entirely — the matrix-free fit's memory guarantee
    must hold even where dense would win on wall-clock, so it tunes only
    over the streaming tile shapes (under its own cache key).
    """
    nb, mb = autotune.bucket(n), autotune.bucket(m)
    db = autotune.bucket(d, lo=8, hi=8192)
    rb = autotune.bucket(r, lo=8, hi=512)
    if not autotune.measurement_enabled():
        kind = autotune.heuristic_plan(n, m, interpret)
        return ((kind, None) if kind == "dense" and allow_dense
                else ("pallas", pick_gram_blocks(d)))
    mode = "interp" if interpret else "tpu"
    key = f"gmv|n{nb}|m{mb}|d{db}|r{rb}|{precision}|{mode}" \
        + ("" if allow_dense else "|nd")
    x, y = _bench_rows(nb, db), _bench_rows(mb, db)
    v = _bench_rows(min(mb, _MEASURE_MAX_ROWS), rb)

    def run(plan):
        return lambda: jax.block_until_ready(gram_matvec(
            x, y, v, sigma=1.0, p=2, interpret=interpret,
            precision=precision, plan=plan))

    cands = {"pallas": run("pallas")}
    if interpret:
        cands["pallas_fat"] = run("pallas_fat")
    if allow_dense and nb * mb <= autotune.DENSE_MAX_CELLS:
        cands["dense"] = run("dense")
    winner = autotune.best(key, cands, default="pallas")
    if winner == "dense":
        return "dense", None
    blocks = _fat_gram_blocks(d) if winner == "pallas_fat" \
        else pick_gram_blocks(d)
    return "pallas", blocks


def _assign_plan(n: int, m: int, d: int, interpret: bool,
                 tag: str = "") -> str:
    """``tag`` namespaces the measured plan: the chunked ingest path
    (streaming merge + per-chunk assign, DESIGN.md §9) replays ONE shape
    thousands of times back-to-back, so its crossover is measured and
    cached under its own ``|<tag>`` key instead of sharing (and fighting
    over) the serving-shape entry."""
    nb, mb = autotune.bucket(n), autotune.bucket(m)
    db = autotune.bucket(d, lo=8, hi=8192)
    if not autotune.measurement_enabled():
        return autotune.heuristic_plan(n, m, interpret)
    mode = "interp" if interpret else "tpu"
    key = f"assign|n{nb}|m{mb}|d{db}|{mode}" + (f"|{tag}" if tag else "")
    x, c = _bench_rows(nb, db), _bench_rows(mb, db)

    def run(plan):
        return lambda: jax.block_until_ready(shadow_assign(
            x, c, interpret=interpret, plan=plan)[1])

    cands = {"pallas": run("pallas")}
    if nb * mb <= autotune.DENSE_MAX_CELLS:
        cands["dense"] = run("dense")
    return autotune.best(key, cands, default="pallas")


#: Row-tile candidates for the fused projection kernel.  Off-TPU the
#: interpret-mode grid loop dominates, so larger tiles (fewer grid steps)
#: tend to win; on hardware VMEM residency of the (bn, m) Gram block pulls
#: the other way.  The roofline tuner picks among these from measured
#: bytes/FLOPs crossovers, not raw time (autotune.best_roofline).
_PROJECT_TILES_TPU = (256, 512, 1024)
_PROJECT_TILES_INTERPRET = (512, 1024, 2048)


def _project_costs(n: int, m: int, d: int, r: int, bn: int, dense: bool,
                   precision: str) -> tuple[float, float]:
    """Analytic (flops, bytes) of one projection at the measured shape.

    FLOPs are plan-invariant: n rows x (distance matmul 2md + exp/dist
    pointwise ~4m + projection matmul 2mr).  Bytes are where plans differ —
    the fused kernel re-reads centers + projector from HBM once per grid
    step, the dense fallback streams each once but writes AND re-reads the
    materialized (n, m) Gram; a quantized projector moves 1 byte/element.
    """
    qb = 1.0 if precision in _quantize.QUANT_PRECISIONS else 4.0
    flops = float(n) * (2.0 * m * d + 4.0 * m + 2.0 * m * r)
    if dense:
        byts = 4.0 * (n * d + m * d + n * r + 2.0 * n * m) + qb * m * r
    else:
        tiles = max(1, -(-n // bn))
        byts = 4.0 * (n * d + n * r) + tiles * (4.0 * m * d + qb * m * r)
    return flops, byts


def _project_plan(n: int, m: int, d: int, r: int, precision: str,
                  interpret: bool) -> str:
    """Roofline-tuned plan: "dense" or "pallas:<row-tile>"."""
    nb, mb = autotune.bucket(n), autotune.bucket(m)
    db = autotune.bucket(d, lo=8, hi=8192)
    rb = autotune.bucket(r, lo=8, hi=512)
    if not autotune.measurement_enabled():
        return autotune.heuristic_plan(n, m, interpret)
    mode = "interp" if interpret else "tpu"
    key = f"project|n{nb}|m{mb}|d{db}|r{rb}|{precision}|{mode}"
    x, c = _bench_rows(nb, db), _bench_rows(mb, db)
    a = _bench_rows(c.shape[0], rb)
    # pre-quantize the bench projector: the serving contract quantizes at
    # snapshot publish, so per-call quantization must not pollute the timing
    aq = (_quantize.quantize_projector(a, precision)
          if precision in _quantize.QUANT_PRECISIONS else None)

    def run(plan):
        return lambda: jax.block_until_ready(kpca_project(
            x, c, a, sigma=1.0, p=2, interpret=interpret,
            precision=precision, plan=plan, projector_q=aq))

    neff, meff = x.shape[0], c.shape[0]
    tiles = _PROJECT_TILES_INTERPRET if interpret else _PROJECT_TILES_TPU
    cands, costs = {}, {}
    for t in tiles:
        name = f"pallas:{t}"
        bn_eff = min(t, _round_up(neff, 128))
        cands[name] = run(name)
        costs[name] = _project_costs(neff, meff, db, rb, bn_eff,
                                     dense=False, precision=precision)
    if nb * mb <= autotune.DENSE_MAX_CELLS:
        cands["dense"] = run("dense")
        costs["dense"] = _project_costs(neff, meff, db, rb, 0, dense=True,
                                        precision=precision)
    return autotune.best_roofline(key, cands, costs,
                                  default=f"pallas:{tiles[0]}")


# --------------------------------------------------------------------------
# gram
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("sigma", "p", "interpret",
                                             "bn", "bm", "bk"))
def _gram_call(xp, yp, wxp, wyp, *, sigma, p, interpret, bn, bm, bk):
    return _gram.gram_pallas(xp, yp, sigma=sigma, p=p, wx=wxp, wy=wyp,
                             block_n=bn, block_m=bm, block_k=bk,
                             interpret=interpret)


def gram(x, y, *, sigma: float, p: int = 2, wx=None, wy=None,
         interpret: bool | None = None, precision: str = "f32",
         plan: str | None = None) -> Array:
    """(Weighted) Gram matrix; pads and strips.

    ``plan=None`` consults the autotuner (Pallas with tuned tiles vs the
    dense fallback); ``precision="bf16"`` runs the cross-term matmul on bf16
    operands with f32 accumulation (parity tolerances documented in
    tests/test_precision.py).
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n, m = x.shape[0], y.shape[0]
    blocks = None
    if plan is None:
        plan, blocks = _gram_plan(n, m, x.shape[1], precision, interpret)
    if plan == "dense":
        ones_n = jnp.ones((n,), jnp.float32)
        ones_m = jnp.ones((m,), jnp.float32)
        weighted = wx is not None or wy is not None
        return _gram_dense(
            x, y,
            jnp.asarray(wx, jnp.float32) if wx is not None else ones_n,
            jnp.asarray(wy, jnp.float32) if wy is not None else ones_m,
            sigma=float(sigma), p=int(p), weighted=weighted,
            precision=precision)
    if blocks is None:
        blocks = _fat_gram_blocks(x.shape[1]) if plan == "pallas_fat" \
            else pick_gram_blocks(x.shape[1])
    bn, bm, bk = blocks
    # shrink tiles toward small inputs so a 150-row Gram doesn't pad to 512
    bn = min(bn, _round_up(n, 128))
    bm = min(bm, _round_up(m, 128))
    bk = min(bk, _round_up(x.shape[1], 128))
    # pad the feature dim to the K-chunk (zero features don't move distances)
    dpad = _round_up(x.shape[1], bk) - x.shape[1]
    if dpad:
        x = jnp.pad(x, ((0, 0), (0, dpad)))
        y = jnp.pad(y, ((0, 0), (0, dpad)))
    cd = _compute_dtype(precision)
    xp = _pad_rows(x, bn).astype(cd)
    yp = _pad_rows(y, bm).astype(cd)
    wxp = _pad_rows(jnp.asarray(wx, jnp.float32), bn) if wx is not None \
        else jnp.ones((xp.shape[0],), jnp.float32)
    wyp = _pad_rows(jnp.asarray(wy, jnp.float32), bm) if wy is not None \
        else jnp.ones((yp.shape[0],), jnp.float32)
    out = _gram_call(xp, yp, wxp, wyp, sigma=float(sigma), p=int(p),
                     interpret=bool(interpret), bn=bn, bm=bm, bk=bk)
    return out[:n, :m]


def weighted_gram(centers, weights, *, sigma: float, p: int = 2,
                  interpret: bool | None = None, precision: str = "f32",
                  plan: str | None = None) -> Array:
    """Algorithm 1's K-tilde = W K^C W in one fused pass."""
    return gram(centers, centers, sigma=sigma, p=p, wx=weights, wy=weights,
                interpret=interpret, precision=precision, plan=plan)


# --------------------------------------------------------------------------
# gram_matvec (matrix-free fit operator)
# --------------------------------------------------------------------------


#: The materialized-Gram fit path is abandoned once the f32 m x m buffer
#: would exceed this many bytes (override with REPRO_GRAM_BYTES_BUDGET);
#: beyond it the LOBPCG matvec recomputes Gram tiles on-chip instead
#: (DESIGN.md §6).  128 MB puts the crossover at m_pad ~ 5793, so every
#: m <= 4096 fit stays bit-identical to the materialized path.
DEFAULT_GRAM_BYTES_BUDGET = 128 * 1024 * 1024


def gram_bytes_budget() -> int:
    env = os.environ.get("REPRO_GRAM_BYTES_BUDGET")
    return int(env) if env else DEFAULT_GRAM_BYTES_BUDGET


def matfree_fit(m: int) -> bool:
    """Crossover policy for the fit eigensolve: go matrix-free (LOBPCG
    through ``gram_matvec``) once materializing the m x m weighted Gram
    would blow the bytes budget.  ``REPRO_MATFREE_MIN_M`` forces an explicit
    threshold (tests use it to exercise the matfree path at small m)."""
    env = os.environ.get("REPRO_MATFREE_MIN_M")
    if env:
        return m >= int(env)
    return 4 * m * m > gram_bytes_budget()


@functools.partial(jax.jit, static_argnames=("sigma", "p", "interpret",
                                             "bn", "bm", "bk"))
def _gram_matvec_call(xp, yp, wxp, wyp, vp, *, sigma, p, interpret, bn, bm,
                      bk):
    return _gram.gram_matvec_pallas(xp, yp, vp, sigma=sigma, p=p, wx=wxp,
                                    wy=wyp, block_n=bn, block_m=bm,
                                    block_k=bk, interpret=interpret)


def gram_matvec(x, y, v, *, sigma: float, p: int = 2, wx=None, wy=None,
                interpret: bool | None = None, precision: str = "f32",
                plan: str | None = None, allow_dense: bool = True) -> Array:
    """Matrix-free (weighted) Gram matvec: K_w @ v with K_w never leaving
    VMEM — peak memory O(n*r + m*r + tiles) instead of O(n*m).

    This is the fit-side operator of the matrix-free eigensolve (DESIGN.md
    §6): LOBPCG calls it once per sweep with v = the current (m, r) search
    block.  ``plan=None`` consults the autotuner (tuned Pallas tiles, fatter
    interpret-mode tiles, or — below the crossover — a dense fallback that
    materializes the small Gram); ``precision="bf16"`` feeds bf16 operands
    to BOTH fused matmuls (distance cross term and the tile-V contraction)
    with f32 accumulation.  ``allow_dense=False`` (the matrix-free fit)
    bars the materializing fallback no matter what the autotuner measures —
    the O(n*m)-free memory guarantee is part of the contract there.
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    n, m, r = x.shape[0], y.shape[0], v.shape[1]
    assert v.shape[0] == m, (v.shape, y.shape)
    blocks = None
    if plan is None:
        plan, blocks = _matvec_plan(n, m, x.shape[1], r, precision,
                                    interpret, allow_dense=allow_dense)
    assert allow_dense or plan != "dense", \
        "dense plan forced where the matrix-free contract forbids it"
    ones_n = jnp.ones((n,), jnp.float32)
    ones_m = jnp.ones((m,), jnp.float32)
    weighted = wx is not None or wy is not None
    wxj = jnp.asarray(wx, jnp.float32) if wx is not None else ones_n
    wyj = jnp.asarray(wy, jnp.float32) if wy is not None else ones_m
    if plan == "dense":
        return _gram_matvec_dense(x, y, wxj, wyj, v, sigma=float(sigma),
                                  p=int(p), weighted=weighted,
                                  precision=precision)
    if blocks is None:
        blocks = _fat_gram_blocks(x.shape[1]) if plan == "pallas_fat" \
            else pick_gram_blocks(x.shape[1])
    bn, bm, bk = blocks
    bn = min(bn, _round_up(n, 128))
    bm = min(bm, _round_up(m, 128))
    bk = min(bk, _round_up(x.shape[1], 128))
    dpad = _round_up(x.shape[1], bk) - x.shape[1]
    if dpad:
        x = jnp.pad(x, ((0, 0), (0, dpad)))
        y = jnp.pad(y, ((0, 0), (0, dpad)))
    cd = _compute_dtype(precision)
    xp = _pad_rows(x, bn).astype(cd)
    yp = _pad_rows(y, bm).astype(cd)
    # weights pad with ZEROS (sqrt(0) kills padded columns on the weighted
    # path); v pads with zero rows so padded columns of the UNWEIGHTED
    # kernel — k(x, 0-pad) != 0 — contribute exactly nothing either way
    wxp = _pad_rows(wxj, bn) if weighted else jnp.ones((xp.shape[0],),
                                                       jnp.float32)
    wyp = _pad_rows(wyj, bm) if weighted else jnp.ones((yp.shape[0],),
                                                       jnp.float32)
    rp = _round_up(r, 128)
    vp = _pad_rows(v, bm).astype(cd)
    vp = jnp.pad(vp, ((0, 0), (0, rp - r)))
    out = _gram_matvec_call(xp, yp, wxp, wyp, vp, sigma=float(sigma),
                            p=int(p), interpret=bool(interpret), bn=bn,
                            bm=bm, bk=bk)
    return out[:n, :r]


def weighted_gram_matvec(centers, weights, v, *, sigma: float, p: int = 2,
                         interpret: bool | None = None,
                         precision: str = "f32",
                         plan: str | None = None,
                         allow_dense: bool = True) -> Array:
    """Algorithm 1's K-tilde @ v without ever materializing K-tilde."""
    return gram_matvec(centers, centers, v, sigma=sigma, p=p, wx=weights,
                       wy=weights, interpret=interpret, precision=precision,
                       plan=plan, allow_dense=allow_dense)


# --------------------------------------------------------------------------
# gram_row (streaming rank-one update)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("sigma", "p", "weighted"))
def _gram_row_dense(x, c, w, *, sigma, p, weighted):
    d2 = _dense_sq_dists(x[None, :], c, "f32")[0]
    g = jnp.exp(-_dist_pow(d2, p) / sigma**p)
    if weighted:
        g = g * jnp.sqrt(w)
    return g, d2


@functools.partial(jax.jit, static_argnames=("sigma", "p", "interpret", "bm",
                                             "bk", "weighted"))
def _gram_row_call(xp, cp, wp, *, sigma, p, interpret, bm, bk, weighted):
    return _gram.gram_row_pallas(xp, cp, sigma=sigma, p=p,
                                 w=wp if weighted else None,
                                 block_m=bm, block_k=bk, interpret=interpret)


def _gram_row_plan(m: int, d: int, interpret: bool) -> str:
    mb = autotune.bucket(m)
    db = autotune.bucket(d, lo=8, hi=8192)
    if not autotune.measurement_enabled():
        # a single row is always a tiny problem off-TPU; on TPU the fused
        # kernel avoids materializing intermediates
        return "dense" if interpret else "pallas"
    mode = "interp" if interpret else "tpu"
    key = f"gramrow|m{mb}|d{db}|{mode}"
    x, c = _bench_rows(8, db)[0], _bench_rows(mb, db)

    def run(plan):
        return lambda: jax.block_until_ready(gram_row(
            x, c, sigma=1.0, p=2, interpret=interpret, plan=plan)[1])

    return autotune.best(key, {"pallas": run("pallas"), "dense": run("dense")},
                         default="pallas")


def gram_row(x, centers, w=None, *, sigma: float, p: int = 2,
             interpret: bool | None = None, plan: str | None = None):
    """Rank-one Gram-row update: one fused pass computing the new row/column
    of the (optionally weighted) Gram against ALL centers, plus the raw
    squared distances the online absorption rule needs.

    Returns ``(k_row, d2_row)``, both (m,) f32: k_row[j] = k(x, c_j)
    (times sqrt(w_j) when ``w`` is given — Algorithm 1's W K W column
    factor); d2_row[j] = ||x - c_j||^2.  This is the streaming subsystem's
    per-update hot path (repro/streaming/updates.py): the full m x m Gram is
    never rebuilt — only this row is.
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    centers = jnp.asarray(centers, jnp.float32)
    m, d = centers.shape
    assert x.shape == (d,), (x.shape, centers.shape)
    weighted = w is not None
    wj = jnp.asarray(w, jnp.float32) if weighted \
        else jnp.ones((m,), jnp.float32)
    if plan is None:
        plan = _gram_row_plan(m, d, interpret)
    if plan == "dense":
        return _gram_row_dense(x, centers, wj, sigma=float(sigma), p=int(p),
                               weighted=weighted)
    bm = min(512, _round_up(m, 128))
    bk = min(512, _round_up(d, 128))
    dpad = _round_up(d, bk) - d
    cp = centers if dpad == 0 else jnp.pad(centers, ((0, 0), (0, dpad)))
    xp = jnp.zeros((8, cp.shape[1]), jnp.float32).at[0, :d].set(x)
    cp = _pad_rows(cp, bm)
    wp = _pad_rows(wj, bm)
    krow, d2 = _gram_row_call(xp, cp, wp, sigma=float(sigma), p=int(p),
                              interpret=bool(interpret), bm=bm, bk=bk,
                              weighted=weighted)
    return krow[:m], d2[:m]


# --------------------------------------------------------------------------
# shadow_assign
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def _assign_call(xp, cp, vp, *, bn, bm, interpret):
    return _assign.shadow_assign_pallas(xp, cp, vp, block_n=bn, block_m=bm,
                                        interpret=interpret)


def shadow_assign(x, centers, m_valid: int | None = None, *, valid=None,
                  interpret: bool | None = None, plan: str | None = None,
                  tag: str = ""):
    """Nearest-center (idx, d2min) via the Pallas assignment kernel.

    Validity can be given as a static prefix length ``m_valid`` or as a
    dynamic per-center ``valid`` mask (used by blocked shadow selection: the
    round loop reuses one compiled kernel with a fresh mask each round).
    Assignment always resolves distances in f32 — a bf16 argmin could flip
    nearest centers, so ``precision`` deliberately does not thread here.
    ``tag`` gives a caller its own autotune-key namespace (the chunked
    ingest path passes ``tag="ingest"`` — see ``_assign_plan``).
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    n, m = x.shape[0], centers.shape[0]
    if plan is None:
        plan = _assign_plan(n, m, x.shape[1], interpret, tag=tag)
    if valid is None:
        m_valid = m if m_valid is None else int(m_valid)
        valid = (jnp.arange(m) < m_valid).astype(jnp.float32)
    else:
        valid = jnp.asarray(valid, jnp.float32)
    if plan == "dense":
        return _assign_dense(x, centers, valid)
    # off-TPU the grid loop itself is the overhead (no VMEM limit to respect),
    # so take far fewer, fatter row tiles: 8192 rows ~2.3x faster than 512 at
    # n=32k in interpret mode
    block_n, block_m = (8192, 128) if interpret else (512, 128)
    # split the 128-padded row count into equal fat tiles rather than padding
    # up to a block_n multiple (that would waste up to block_n-1 rows of
    # distance work per call, ~2x for n just above a multiple)
    npad = _round_up(n, 128)
    tiles = -(-npad // block_n)
    bn = min(block_n, _round_up(-(-npad // tiles), 128))
    xp = _pad_rows(x, bn)
    cp = _pad_rows(centers, block_m)
    vp = _pad_rows(valid, block_m)
    idx, d2 = _assign_call(xp, cp, vp, bn=bn, bm=block_m,
                           interpret=bool(interpret))
    return idx[:n], d2[:n]


# --------------------------------------------------------------------------
# kpca_project
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("sigma", "p", "bn", "interpret"),
                   donate_argnums=(0,))
def _project_call(xp, cp, ap, *, sigma, p, bn, interpret):
    # xp (the padded query chunk) is donated: it is a serving-loop temporary
    # (kpca_project guarantees ownership before calling), so XLA reuses its
    # storage instead of holding chunk x d alive across the kernel
    return _project.kpca_project_pallas(xp, cp, ap, sigma=sigma, p=p,
                                        block_n=bn, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("sigma", "p", "bn", "qmode", "interpret"),
                   donate_argnums=(0,))
def _project_call_quant(xp, cp, qp, sp, *, sigma, p, bn, qmode, interpret):
    # same donation contract as _project_call: xp is an owned padded chunk
    return _project.kpca_project_quant_pallas(
        xp, cp, qp, sp, sigma=sigma, p=p, qmode=qmode, block_n=bn,
        interpret=interpret)


def projection_compile_count() -> int:
    """Total jit traces of the projection entry points (test hook for the
    recompile-free serving contract) — the quantized tier included."""
    return int(_project_call._cache_size() + _project_dense._cache_size()
               + _project_call_quant._cache_size()
               + _project_dense_quant._cache_size())


def kpca_project(x, centers, projector, *, sigma: float, p: int = 2,
                 chunk: int | None = None,
                 interpret: bool | None = None, precision: str = "f32",
                 plan: str | None = None, projector_q=None) -> Array:
    """Fused z = k(x, C) @ A.  Pads m with zero projector rows (harmless:
    padded centers contribute k(x, 0-pad)*0).

    ``chunk`` streams query rows through the kernel in fixed-size slices, so
    arbitrarily large query sets never materialize more than a
    (chunk, m_pad) working set on device (the fused kernel never writes the
    q x m Gram to HBM either way — this bounds the padded INPUT residency).
    The tail slice is padded UP to the same fixed chunk and stripped after,
    so a ragged query stream compiles exactly once — the recompile-free
    serving contract (asserted in tests/test_kernels.py).

    ``precision`` "int8"/"fp8" runs the quantized projector contraction
    (kernels/quantize.py) — distances and the exp nonlinearity stay f32.
    ``projector_q`` optionally supplies the pre-quantized ``(Aq, s)`` pair
    (snapshot-publish caching, streaming/swap.py); when omitted the
    projector is quantized here per call.

    ``plan`` forces a compute plan: "dense", "pallas" (default row tile) or
    "pallas:<row-tile>"; ``None`` asks the roofline autotuner.
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    projector = jnp.asarray(projector, jnp.float32)
    n, r = x.shape[0], projector.shape[1]
    m, d = centers.shape
    quant = precision in _quantize.QUANT_PRECISIONS
    if projector_q is not None and not quant:
        raise ValueError(
            f"projector_q only applies to {_quantize.QUANT_PRECISIONS}, "
            f"got precision={precision!r}")
    if plan is None:
        plan = _project_plan(min(n, chunk or n), m, d, r, precision,
                             interpret)
    # the quantized tier keeps distance operands f32 (only the projector
    # contraction drops precision); f32/bf16 tiers cast as before
    cd = jnp.float32 if quant else _compute_dtype(precision)
    # pad m to a lane multiple; padded projector rows are zero so padded
    # centers cannot contribute
    cp = _pad_rows(centers, 128).astype(cd)
    rp = _round_up(r, 128)
    if quant:
        if projector_q is None:
            projector_q = _quantize.quantize_projector(projector, precision)
        qv, qs = projector_q
        # padded q rows/cols are zero (can't contribute); padded scale
        # columns are 1 (never divide/NaN) and stripped with the output
        qp = jnp.pad(qv, ((0, cp.shape[0] - m), (0, rp - r)))
        sp = jnp.pad(jnp.asarray(qs, jnp.float32), (0, rp - r),
                     constant_values=1.0).reshape(1, rp)
    else:
        ap = _pad_rows(projector, 128)
        ap = jnp.pad(ap, ((0, 0), (0, rp - r)))
    tile = int(plan.split(":", 1)[1]) if plan.startswith("pallas:") else 512

    def run(xs, owned):
        if plan == "dense":
            if quant:
                return _project_dense_quant(xs, centers, qv, qs,
                                            sigma=float(sigma), p=int(p),
                                            qmode=precision)
            return _project_dense(xs, centers, projector,
                                  sigma=float(sigma), p=int(p),
                                  precision=precision)
        bn = min(tile, _round_up(xs.shape[0], 128))
        xsp = _pad_rows(xs, bn).astype(cd)
        if xsp is xs and not owned:
            # nothing was padded or cast, so xsp still IS the caller's
            # buffer; _project_call donates its first argument, and donating
            # memory we do not own would consume it out from under the
            # caller — copy first (the owned chunked slices skip this)
            xsp = jnp.array(xsp, copy=True)
        if quant:
            out = _project_call_quant(xsp, cp, qp, sp, sigma=float(sigma),
                                      p=int(p), bn=bn, qmode=precision,
                                      interpret=bool(interpret))
        else:
            out = _project_call(xsp, cp, ap, sigma=float(sigma), p=int(p),
                                bn=bn, interpret=bool(interpret))
        return out[: xs.shape[0], :r]

    if chunk is None or n <= chunk:
        return run(x, owned=False)
    chunk = _round_up(chunk, 128)
    # fixed-shape streaming: pad the row count to a chunk multiple so EVERY
    # slice (the ragged tail included) traces with one shape; each slice is
    # a fresh buffer this function owns, so donation needs no copy
    xpad = _pad_rows(x, chunk)
    pieces = [run(xpad[s : s + chunk], owned=True)  # slices are fresh buffers
              for s in range(0, xpad.shape[0], chunk)]
    return jnp.concatenate(pieces, axis=0)[:n]


# --------------------------------------------------------------------------
# rff_project (random-Fourier-feature transform; kernels/rff.py)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("scale", "precision"))
def rff_features(x, omega, phase, *, scale, precision="f32"):
    """Dense feature map phi_D(x) = scale * cos(x Omega^T + b), f32 out.

    The RFF fit accumulates the D x D feature covariance phi^T phi
    chunk-by-chunk off this (core/random_features.py), so the (n, D) feature
    matrix never materializes beyond one chunk.  bf16 runs the x Omega^T
    matmul on bf16 operands with f32 accumulation; the cosine stays f32.
    """
    cd = _compute_dtype(precision)
    s = jax.lax.dot_general(
        jnp.asarray(x, jnp.float32).astype(cd),
        jnp.asarray(omega, jnp.float32).astype(cd),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    return jnp.cos(s + jnp.asarray(phase, jnp.float32)[None, :]) * scale


@functools.partial(jax.jit, static_argnames=("scale", "precision"))
def _rff_dense(x, omega, phase, u, *, scale, precision):
    z = rff_features(x, omega, phase, scale=scale, precision=precision)
    cd = _compute_dtype(precision)
    return jax.lax.dot_general(
        z.astype(cd), jnp.asarray(u, jnp.float32).astype(cd),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )


_RFF_TILES_TPU = (256, 512, 1024)
_RFF_TILES_INTERPRET = (512, 1024, 2048)


def _rff_costs(n: int, nfeat: int, d: int, r: int, bn: int,
               dense: bool) -> tuple[float, float]:
    """Analytic (flops, bytes): n rows x (feature matmul 2Dd + cosine ~2D +
    component matmul 2Dr).  The fused kernel re-reads Omega/phase/U per grid
    step; the dense fallback writes AND re-reads the (n, D) feature block."""
    flops = float(n) * (2.0 * nfeat * d + 2.0 * nfeat + 2.0 * nfeat * r)
    if dense:
        byts = 4.0 * (n * d + nfeat * d + n * r + 2.0 * n * nfeat
                      + nfeat * r)
    else:
        tiles = max(1, -(-n // bn))
        byts = 4.0 * (n * d + n * r) \
            + tiles * 4.0 * (nfeat * d + nfeat + nfeat * r)
    return flops, byts


def _rff_plan(n: int, nfeat: int, d: int, r: int, precision: str,
              interpret: bool) -> str:
    """Roofline-tuned plan for rff_project: "dense" or "pallas:<row-tile>"."""
    nb, fb = autotune.bucket(n), autotune.bucket(nfeat)
    db = autotune.bucket(d, lo=8, hi=8192)
    rb = autotune.bucket(r, lo=8, hi=512)
    if not autotune.measurement_enabled():
        return autotune.heuristic_plan(n, nfeat, interpret)
    mode = "interp" if interpret else "tpu"
    key = f"rffproj|n{nb}|D{fb}|d{db}|r{rb}|{precision}|{mode}"
    x, w = _bench_rows(nb, db), _bench_rows(fb, db)
    u = _bench_rows(w.shape[0], rb)
    phase = w[:, 0]
    scale = (2.0 / w.shape[0]) ** 0.5

    def run(plan):
        return lambda: jax.block_until_ready(rff_project(
            x, w, phase, u, scale=scale, interpret=interpret,
            precision=precision, plan=plan))

    neff, feff = x.shape[0], w.shape[0]
    tiles = _RFF_TILES_INTERPRET if interpret else _RFF_TILES_TPU
    cands, costs = {}, {}
    for t in tiles:
        name = f"pallas:{t}"
        bn_eff = min(t, _round_up(neff, 128))
        cands[name] = run(name)
        costs[name] = _rff_costs(neff, feff, db, rb, bn_eff, dense=False)
    if nb * fb <= autotune.DENSE_MAX_CELLS:
        cands["dense"] = run("dense")
        costs["dense"] = _rff_costs(neff, feff, db, rb, 0, dense=True)
    return autotune.best_roofline(key, cands, costs,
                                  default=f"pallas:{tiles[0]}")


@functools.partial(jax.jit, static_argnames=("scale", "bn", "interpret"),
                   donate_argnums=(0,))
def _rff_call(xp, wp, bp, up, *, scale, bn, interpret):
    # xp (the padded query chunk) is donated under the same ownership
    # contract as _project_call
    return _rff.rff_project_pallas(xp, wp, bp, up, scale=scale, block_n=bn,
                                   interpret=interpret)


def rff_project(x, omega, phase, u, *, scale: float | None = None,
                chunk: int | None = None, interpret: bool | None = None,
                precision: str = "f32", plan: str | None = None) -> Array:
    """Fused z = sqrt(2/D) cos(x Omega^T + b) @ U — the RFF-KPCA transform.

    Pads the feature count D to a lane multiple with zero Omega/phase/U rows
    (cos(0+0)=1 times a zero U row contributes nothing); ``chunk`` streams
    query rows in fixed-size slices exactly like kpca_project, so a ragged
    query stream compiles once.  ``scale`` defaults to sqrt(2/D) with the
    true (unpadded) D.
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    omega = jnp.asarray(omega, jnp.float32)
    phase_j = jnp.asarray(phase, jnp.float32)
    u = jnp.asarray(u, jnp.float32)
    n, r = x.shape[0], u.shape[1]
    nfeat, d = omega.shape
    assert u.shape[0] == nfeat and phase_j.shape == (nfeat,), \
        (omega.shape, phase_j.shape, u.shape)
    if scale is None:
        scale = (2.0 / nfeat) ** 0.5
    if plan is None:
        plan = _rff_plan(min(n, chunk or n), nfeat, d, r, precision,
                         interpret)
    cd = _compute_dtype(precision)
    fpad = _round_up(nfeat, 128) - nfeat
    wp = _pad_rows(omega, 128).astype(cd)
    bp = jnp.pad(phase_j, (0, fpad)).reshape(1, -1)
    rp = _round_up(r, 128)
    up = _pad_rows(u, 128)
    up = jnp.pad(up, ((0, 0), (0, rp - r)))
    tile = int(plan.split(":", 1)[1]) if plan.startswith("pallas:") else 512

    def run(xs, owned):
        if plan == "dense":
            return _rff_dense(xs, omega, phase_j, u, scale=float(scale),
                              precision=precision)
        bn = min(tile, _round_up(xs.shape[0], 128))
        xsp = _pad_rows(xs, bn).astype(cd)
        if xsp is xs and not owned:
            # same ownership guard as kpca_project: _rff_call donates its
            # first argument, never donate a buffer the caller still owns
            xsp = jnp.array(xsp, copy=True)
        out = _rff_call(xsp, wp, bp, up, scale=float(scale), bn=bn,
                        interpret=bool(interpret))
        return out[: xs.shape[0], :r]

    if chunk is None or n <= chunk:
        return run(x, owned=False)
    chunk = _round_up(chunk, 128)
    xpad = _pad_rows(x, chunk)
    pieces = [run(xpad[s : s + chunk], owned=True)
              for s in range(0, xpad.shape[0], chunk)]
    return jnp.concatenate(pieces, axis=0)[:n]
