"""Measured compute-plan autotuner for the Gram-shaped kernels (DESIGN.md §3).

``pick_gram_blocks`` in ``ops.py`` is a static VMEM heuristic; it knows
nothing about the Pallas interpret/grid overhead that dominates small
problems off-TPU (the n=2048 fit regression in BENCH_rskpca.json), nor about
which tile shape actually wins on a given backend.  This module replaces the
heuristic with a tiny measured tuner:

  * each op asks for a plan under a key ``(op, n-bucket, m-bucket, d,
    precision, backend, device-kind, jax-version)`` — buckets are
    power-of-two ceilings so nearby shapes share one measurement, and the
    device/runtime qualifier (plus a schema version on the disk envelope)
    keeps a cache measured on one machine from being replayed on another;
  * the first request per key times every legal candidate (one warmup for
    compile, then best-of-``_REPS``) and records the winner;
  * winners are cached in-process and persisted to disk (JSON), so a process
    pays each measurement at most once and a machine at most once.

Candidates always include the Pallas kernel (tuned tiles) and, below a size
cap, a dense-jnp fallback — the crossover that stops small problems from
paying Pallas interpret/grid overhead.  ``REPRO_AUTOTUNE=0`` disables
measurement entirely and falls back to a deterministic size heuristic
(useful for tests that assert compile counts).  ``REPRO_AUTOTUNE_CACHE``
overrides the on-disk cache location; under pytest the disk layer defaults
OFF (hermetic runs) unless that variable is set explicitly.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable

from repro.obs import metrics as _om
from repro.obs.trace import span as _span

# plan-cache telemetry: a "miss" pays a measurement (warmup + reps per
# candidate) inside the request, so the hit/miss ratio is the difference
# between a warm serving process and one paying autotune latency on live
# traffic.  ``autotune.roofline_abs_rel_err`` records |predicted-measured|
# / measured of each roofline winner — the model-vs-hardware error.
_M_HITS = _om.counter("autotune.plan_hits")
_M_MISSES = _om.counter("autotune.plan_misses")
_ROOFLINE_ERR_BOUNDS = (0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0)

_LOCK = threading.RLock()
_MEM: dict[str, dict] = {}     # key -> {"winner": name, "us": {name: micros}}
_DISK_LOADED = False

#: On-disk cache format version.  Bumping it orphans every older cache file
#: (schema 1 was a bare key->plan dict with no environment qualifier, so a
#: plan measured on one device kind / jax version could be replayed on
#: another — exactly the staleness this versioned envelope prevents).
_SCHEMA = 2

_ENV_TAG = None


def env_tag() -> str:
    """Hardware + software qualifier appended to every plan key: a plan is
    only ever replayed on the device kind and jax version that measured it."""
    global _ENV_TAG
    if _ENV_TAG is None:
        import jax
        kind = jax.devices()[0].device_kind.replace(" ", "_").replace("|", "_")
        _ENV_TAG = f"{kind}|jax{jax.__version__}"
    return _ENV_TAG


def qualified(key: str) -> str:
    """The full cache key ``best`` stores measurements under."""
    return f"{key}|{env_tag()}"

#: Dense fallback is only a candidate (and the heuristic only picks it) below
#: this many output cells — beyond it the dense path's n x m intermediates
#: stop fitting comfortably in memory and the blocked kernel always wins.
DENSE_MAX_CELLS = 1 << 25

#: Deterministic crossover used when measurement is disabled or fails:
#: off-TPU (interpret mode) the grid loop overhead makes dense win far later
#: than on real hardware.
HEURISTIC_DENSE_CELLS_INTERPRET = 1 << 22
HEURISTIC_DENSE_CELLS_TPU = 1 << 14

_REPS = 2


def measurement_enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def _cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    # repo root: src/repro/kernels/autotune.py -> three levels up from src/
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, ".autotune_cache.json")


def _disk_enabled() -> bool:
    """Disk persistence is OFF under pytest unless a cache path is set
    explicitly: a test run must neither inherit a developer's measured
    plans nor pollute the repo with its own (hermetic CI runs point
    ``REPRO_AUTOTUNE_CACHE`` at a temp file instead).  The in-process
    cache is unaffected — each test process still measures at most once
    per key."""
    if os.environ.get("REPRO_AUTOTUNE_CACHE"):
        return True
    return "PYTEST_CURRENT_TEST" not in os.environ


def bucket(v: int, lo: int = 128, hi: int = 1 << 17) -> int:
    """Power-of-two ceiling clipped to [lo, hi]: nearby shapes share a key."""
    v = max(int(v), 1)
    b = 1 << (v - 1).bit_length()
    return max(lo, min(b, hi))


def _load_disk() -> None:
    global _DISK_LOADED
    if _DISK_LOADED:
        return
    _DISK_LOADED = True
    if not _disk_enabled():
        return
    try:
        with open(_cache_path()) as f:
            disk = json.load(f)
        if not isinstance(disk, dict) or disk.get("schema") != _SCHEMA:
            return  # pre-versioned or foreign cache: invalidate wholesale
        for k, v in disk.get("plans", {}).items():
            _MEM.setdefault(k, v)
    except (OSError, ValueError):
        pass


def _save_disk() -> None:
    if not _disk_enabled():
        return
    path = _cache_path()
    try:
        # merge with whatever is on disk (a concurrent process may have
        # persisted other keys since we loaded) — our measurements win ties;
        # an old-schema file is dropped, not merged
        merged: dict[str, dict] = {}
        try:
            with open(path) as f:
                disk = json.load(f)
            if isinstance(disk, dict) and disk.get("schema") == _SCHEMA:
                merged.update(disk.get("plans", {}))
        except (OSError, ValueError):
            pass
        merged.update(_MEM)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + f".tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"schema": _SCHEMA, "plans": merged}, f, indent=1,
                      sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS: in-process cache still works


def clear(in_memory_only: bool = True) -> None:
    """Drop cached plans (tests)."""
    global _DISK_LOADED
    with _LOCK:
        _MEM.clear()
        _DISK_LOADED = in_memory_only  # True: don't re-read disk either


def best(key: str, candidates: dict[str, Callable[[], object]],
         default: str) -> str:
    """Winner for ``key``: cached if known, else measured once and persisted.

    ``candidates`` maps name -> thunk running that plan on bucket-shaped
    synthetic data (the thunk must block until the result is ready).  A thunk
    that raises is disqualified.  With a single candidate, or measurement
    disabled, no timing happens.

    Keys are qualified with the device kind and jax version (``env_tag``)
    before lookup/storage, so a persisted plan can never be replayed on
    hardware or a runtime that did not measure it.
    """
    if not measurement_enabled():
        return default
    key = qualified(key)
    with _LOCK:
        _load_disk()
        hit = _MEM.get(key)
        if hit is not None and hit.get("winner") in candidates:
            _M_HITS.inc()
            return hit["winner"]
        if len(candidates) == 1:
            return next(iter(candidates))
        _M_MISSES.inc()
        times: dict[str, float] = {}
        with _span("autotune.measure", key=key, n_candidates=len(candidates)):
            for name, thunk in candidates.items():
                try:
                    thunk()  # compile warmup
                    t = []
                    for _ in range(_REPS):
                        t0 = time.perf_counter()
                        thunk()
                        t.append(time.perf_counter() - t0)
                    times[name] = min(t) * 1e6
                except Exception:
                    continue
        if not times:
            return default
        winner = min(times, key=times.get)
        _MEM[key] = {"winner": winner,
                     "us": {k: round(v, 1) for k, v in times.items()}}
        _save_disk()
        return winner


def best_roofline(key: str, candidates: dict[str, Callable[[], object]],
                  costs: dict[str, tuple[float, float]], default: str) -> str:
    """Roofline-driven winner: measured bytes/FLOPs crossover, not raw time.

    ``costs`` maps each candidate to its analytic ``(flops, bytes)`` for the
    measured shape (the caller's cost model — e.g. per-tile HBM re-reads of
    the centers/projector for the transform kernel).  Every candidate is
    timed once (same warmup + best-of-``_REPS`` as ``best``); the
    measurements are then used to estimate the device's achieved compute
    peak ``P = max flops/t`` and bandwidth ``B = max bytes/t`` ACROSS the
    candidate fleet, and the winner minimizes the roofline-predicted time

        t_pred(c) = max(flops_c / P, bytes_c / B)

    with measured time breaking near-ties (within 10%).  Unlike time-only
    search, one noisy sample cannot crown a tile shape whose byte traffic
    is strictly worse — the prediction uses analytic costs with fleet-level
    peaks, so a slowdown window hitting one candidate perturbs P/B a little
    rather than that candidate's ranking entirely.  The measured peaks, the
    ridge point, and the per-candidate predictions are recorded alongside
    the winner in the same schema-2 cache envelope as ``best``'s entries.
    """
    if not measurement_enabled():
        return default
    key = qualified(key)
    with _LOCK:
        _load_disk()
        hit = _MEM.get(key)
        if hit is not None and hit.get("winner") in candidates:
            _M_HITS.inc()
            return hit["winner"]
        if len(candidates) == 1:
            return next(iter(candidates))
        _M_MISSES.inc()
        times: dict[str, float] = {}
        with _span("autotune.measure_roofline", key=key,
                   n_candidates=len(candidates)):
            for name, thunk in candidates.items():
                try:
                    thunk()  # compile warmup
                    t = []
                    for _ in range(_REPS):
                        t0 = time.perf_counter()
                        thunk()
                        t.append(time.perf_counter() - t0)
                    times[name] = min(t)
                except Exception:
                    continue
        if not times:
            return default
        peak_flops = max(costs[c][0] / t for c, t in times.items())
        peak_bytes = max(costs[c][1] / t for c, t in times.items())
        pred = {c: max(costs[c][0] / peak_flops, costs[c][1] / peak_bytes)
                for c in times}
        t_best = min(pred.values())
        near = [c for c in pred if pred[c] <= 1.10 * t_best]
        winner = min(near, key=times.get)
        # roofline model error on the winner: how far the analytic
        # prediction sat from what the hardware actually did
        _om.histogram("autotune.roofline_abs_rel_err",
                      bounds=_ROOFLINE_ERR_BOUNDS).observe(
            abs(pred[winner] - times[winner]) / max(times[winner], 1e-12))
        _MEM[key] = {
            "winner": winner,
            "us": {c: round(t * 1e6, 1) for c, t in times.items()},
            "roofline": {
                "peak_gflops": round(peak_flops / 1e9, 2),
                "peak_gbs": round(peak_bytes / 1e9, 2),
                "ridge_flop_per_byte": round(peak_flops / peak_bytes, 2),
                "pred_us": {c: round(t * 1e6, 1) for c, t in pred.items()},
            },
        }
        _save_disk()
        return winner


def roofline_entry(key: str) -> dict | None:
    """The full recorded entry ({winner, us, roofline}) for an unqualified
    key, if ``best_roofline`` measured it — benchmarks/roofline.py reads
    these to report the transform crossover."""
    with _LOCK:
        _load_disk()
        hit = _MEM.get(qualified(key))
        return None if hit is None or "roofline" not in hit else hit


def heuristic_plan(n: int, m: int, interpret: bool) -> str:
    """Deterministic dense/pallas crossover for when measurement is off."""
    cells = n * m
    cap = (HEURISTIC_DENSE_CELLS_INTERPRET if interpret
           else HEURISTIC_DENSE_CELLS_TPU)
    return "dense" if cells <= min(cap, DENSE_MAX_CELLS) else "pallas"
