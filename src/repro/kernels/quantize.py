"""Low-precision projector tier for the serving transform (DESIGN.md §8).

The serving hot path is z = g @ A with g = k(x, C) ∈ [0, kappa]^m per row and
A the (m, r) projector.  Distances and the exp nonlinearity stay f32 (they
feed the same numerics as assignment, which must never change precision);
only the projector CONTRACTION — the m-deep matmul that dominates transform
bytes at serving batch sizes — drops precision:

  * ``int8``: per-channel symmetric scales.  Column j of A gets
    s_j = max_i |A_ij| / 127 and Aq = round(A / s) (int8); the Gram row is
    quantized against the STATIC range [0, kappa]: sg = kappa / 127,
    gq = round(g / sg).  The contraction is an integer matmul with int32
    accumulation — exact — so the Pallas and dense quantized paths agree
    BITWISE, and z ≈ (gq @ Aq) * sg * s.
  * ``fp8`` (e4m3fn): per-channel scales s_j = max_i |A_ij| / 448 put each
    column onto the format's full range; g ∈ [0, kappa] already sits inside
    e4m3's range and casts unscaled.  The contraction runs on fp8-rounded
    operands with f32 accumulation (casting the rounded operands up to f32
    before the dot IS that semantics exactly, and is what non-fp8-MXU
    backends execute).

Scales are computed at snapshot-PUBLISH time (streaming/swap.py), never per
query batch: a publish pays one O(m r) pass, every serve reuses the cached
(Aq, s) pair from the swap tuple.

Worst-case error bounds (per output channel, derived below, property-tested
in tests/test_quantized.py) close the loop with the Theorem-5.x budget
machinery: a serving tier is admissible when its projection-error bound is
small against the spectral budget the operator already spends (DESIGN.md
§8).  Writing Δg, ΔA for the rounding perturbations,

    |z_j - ẑ_j| <= Σ_i |Δg_i||A_ij| + Σ_i ĝ_i |ΔA_ij|

  * int8:  |Δg| <= sg/2,  |ΔA_ij| <= s_j/2,  ĝ <= kappa
           bound_j = (sg/2) ||A_:j||_1 + kappa m s_j / 2
  * fp8:   |Δg| <= u·kappa + q,  |ΔA_ij| <= u|A_ij| + s_j q,  ĝ <= (1+u)kappa
           with u = 2^-4 (half-ulp of the 3-bit mantissa) and q = 2^-10
           (half of e4m3fn's smallest subnormal)
           bound_j = (u kappa + q) ||A_:j||_1
                     + (1+u) kappa (u ||A_:j||_1 + m s_j q)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

#: Precisions served by this module; ``Kernel.precision`` accepts these in
#: addition to the f32/bf16 tiers (core/kernels_math.py).
QUANT_PRECISIONS = ("int8", "fp8")

INT8_QMAX = 127.0
FP8_DTYPE = jnp.float8_e4m3fn
FP8_MAX = 448.0          # largest finite e4m3fn value
FP8_U = 2.0 ** -4        # half-ulp relative roundoff (3 mantissa bits)
FP8_Q = 2.0 ** -10       # half of the smallest subnormal (2^-9)


def gram_scale(precision: str, kappa: float = 1.0) -> float:
    """Static quantization scale of the Gram row: kernel values live in
    [0, kappa] by construction, so the range never needs measuring."""
    assert precision in QUANT_PRECISIONS, precision
    return kappa / INT8_QMAX if precision == "int8" else 1.0


def channel_scales(projector: Array, precision: str) -> Array:
    """(r,) per-channel symmetric scales; an all-zero channel gets scale 1
    (its quantized values are all zero either way, and 1 never divides-by-0
    or NaN-poisons the dequantized output)."""
    assert precision in QUANT_PRECISIONS, precision
    qmax = INT8_QMAX if precision == "int8" else FP8_MAX
    amax = jnp.max(jnp.abs(jnp.asarray(projector, jnp.float32)), axis=0)
    return jnp.where(amax > 0.0, amax / qmax, 1.0)


@functools.partial(jax.jit, static_argnames=("precision",))
def quantize_projector(projector: Array, precision: str):
    """(Aq, s): the quantized (m, r) projector and its (r,) channel scales.

    Runs as one jitted device pass — this is the snapshot-publish step; the
    pair is cached in the swap tuple and reused by every serve until the
    next publish (streaming/swap.py).
    """
    a = jnp.asarray(projector, jnp.float32)
    s = channel_scales(a, precision)
    if precision == "int8":
        q = jnp.clip(jnp.round(a / s[None, :]), -INT8_QMAX, INT8_QMAX)
        return q.astype(jnp.int8), s
    return (a / s[None, :]).astype(FP8_DTYPE), s


def dequantize_projector(q: Array, s: Array) -> Array:
    """f32 view of a quantized projector (the parity oracle's operand)."""
    return q.astype(jnp.float32) * jnp.asarray(s, jnp.float32)[None, :]


def projection_error_bound(projector: Array, precision: str,
                           kappa: float = 1.0) -> Array:
    """(r,) worst-case |z_j - ẑ_j| per output channel (derivation in the
    module docstring).  Holds for EVERY query row — the hypothesis property
    in tests/test_quantized.py sweeps random queries against it."""
    assert precision in QUANT_PRECISIONS, precision
    a = jnp.asarray(projector, jnp.float32)
    m = a.shape[0]
    s = channel_scales(a, precision)
    l1 = jnp.sum(jnp.abs(a), axis=0)
    if precision == "int8":
        sg = gram_scale(precision, kappa)
        return 0.5 * sg * l1 + 0.5 * kappa * m * s
    u, q = FP8_U, FP8_Q
    return (u * kappa + q) * l1 + (1.0 + u) * kappa * (u * l1 + m * s * q)
