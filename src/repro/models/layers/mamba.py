"""Mamba (S6) selective state-space block — the SSM layers of Jamba.

    h_t = Abar_t * h_{t-1} + Bbar_t x_t        (diagonal A, per-channel)
    y_t = C_t . h_t + D * x_t

with input-dependent (selective) B_t, C_t, dt_t.  Discretization: ZOH on the
diagonal:  Abar = exp(dt * A),  Bbar = dt * B  (simplified Euler for B, as in
the reference minimal implementations).

Training: chunked associative scan — within a chunk ``jax.lax.associative_scan``
over the (a, b) pairs (first-order linear recurrence), across chunks a
sequential ``lax.scan`` carries the (d_inner, d_state) state.  Decode is the
O(1) recurrence (why jamba runs long_500k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def init_mamba(key, d_model: int, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dtype=jnp.float32):
    d_inner = expand * d_model
    ks = jax.random.split(key, 7)
    s = 1.0 / jnp.sqrt(d_model)
    dt_rank = max(1, d_model // 16)
    return {
        "w_in": (jax.random.normal(ks[0], (d_model, 2 * d_inner)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner)) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        # selective projections: x -> (dt_rank + 2*d_state)
        "w_bcdt": (jax.random.normal(ks[2], (d_inner, dt_rank + 2 * d_state))
                   * (1.0 / jnp.sqrt(d_inner))).astype(dtype),
        "w_dt": (jax.random.normal(ks[3], (dt_rank, d_inner)) * 0.1).astype(dtype),
        "dt_bias": jnp.full((d_inner,), -2.0, dtype),  # softplus(-2) ~ 0.13
        "a_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                  (d_inner, 1))).astype(dtype),  # (d_inner, N)
        "d_skip": jnp.ones((d_inner,), dtype),
        "w_out": (jax.random.normal(ks[4], (d_inner, d_model))
                  * (1.0 / jnp.sqrt(d_inner))).astype(dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C). Returns (y, new_state)
    where state is the last K-1 inputs (for decode)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return y + b[None, None, :], new_state


def _selective_terms(params, u):
    """u: (B, S, d_inner) post-conv activations -> (abar, bx, c, d_skip)."""
    d_state = params["a_log"].shape[1]
    dt_rank = params["w_dt"].shape[0]
    proj = u @ params["w_bcdt"].astype(u.dtype)  # (B, S, dt_rank + 2N)
    dt_r, b_t, c_t = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        dt_r @ params["w_dt"].astype(u.dtype)
        + params["dt_bias"].astype(u.dtype)
    ).astype(jnp.float32)                        # (B, S, d_inner)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (d_inner, N), < 0
    abar = jnp.exp(dt[..., None] * a[None, None])      # (B, S, d_inner, N)
    bx = (dt * u.astype(jnp.float32))[..., None] * \
        b_t.astype(jnp.float32)[..., None, :]          # (B, S, d_inner, N)
    return abar, bx, c_t.astype(jnp.float32)


def mamba_forward(params, x: Array, *, chunk: int = 256):
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    d_inner = params["w_in"].shape[1] // 2
    xz = x @ params["w_in"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    u, _ = _causal_conv(u, params["conv_w"].astype(x.dtype),
                        params["conv_b"].astype(x.dtype))
    u = jax.nn.silu(u)
    abar, bx, c_t = _selective_terms(params, u)

    chunk = min(chunk, s)
    assert s % chunk == 0
    n_chunks = s // chunk

    def resh(t):  # (B, S, ...) -> (N, B, chunk, ...)
        return t.reshape((b, n_chunks, chunk) + t.shape[2:]).swapaxes(0, 1)

    ac, bc, cc = resh(abar), resh(bx), resh(c_t)

    def outer(state, xs):
        a_blk, b_blk, c_blk = xs  # (B, c, d_inner, N), (B, c, N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        a_cum, h = jax.lax.associative_scan(combine, (a_blk, b_blk), axis=1)
        h = h + a_cum * state[:, None]          # inject carry state
        y = jnp.einsum("bcdn,bcn->bcd", h, c_blk)
        return h[:, -1], y

    state0 = jnp.zeros((b, d_inner, params["a_log"].shape[1]), jnp.float32)
    _, ys = jax.lax.scan(outer, state0, (ac, bc, cc))
    y = ys.swapaxes(0, 1).reshape(b, s, d_inner)
    y = y + u.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return y @ params["w_out"].astype(x.dtype)


def mamba_decode(params, x: Array, ssm_state: Array, conv_state: Array):
    """One-token step. x: (B, 1, D); ssm_state: (B, d_inner, N);
    conv_state: (B, K-1, d_inner)."""
    xz = x @ params["w_in"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _causal_conv(u, params["conv_w"].astype(x.dtype),
                                 params["conv_b"].astype(x.dtype),
                                 state=conv_state)
    u = jax.nn.silu(u)
    abar, bx, c_t = _selective_terms(params, u)  # (B, 1, d_inner, N)
    h = abar[:, 0] * ssm_state + bx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0])[:, None, :]
    y = y + u.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["w_out"].astype(x.dtype), h, conv_state
