"""Normalization layers (functional, param-dict style)."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((dim,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x / jnp.sqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) / jnp.sqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dtype)
