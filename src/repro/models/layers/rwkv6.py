"""RWKV-6 "Finch" time-mix block (arXiv:2404.05892) — attention-free.

Linear recurrence with data-dependent per-channel decay:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Training uses the chunked form (flash-linear-attention style): within a chunk
of length c the interaction is an O(c^2) masked matmul with relative decays in
log space; across chunks the (hd x hd) state is carried by a scan.  Decode is
the O(1)-per-token recurrence — this is why rwkv6 runs the long_500k cell.

Heads of size ``head_size`` (64): d_model = H * head_size.
Token-shift (mixing with the previous token) uses the simplified static mix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def init_rwkv6(key, d_model: int, head_size: int, dtype=jnp.float32):
    n_heads = d_model // head_size
    ks = jax.random.split(key, 8)
    s = 1.0 / jnp.sqrt(d_model)

    def lin(k):
        return (jax.random.normal(k, (d_model, d_model)) * s).astype(dtype)

    return {
        "w_r": lin(ks[0]), "w_k": lin(ks[1]), "w_v": lin(ks[2]),
        "w_g": lin(ks[3]), "w_o": lin(ks[4]),
        # decay projection (data-dependent, Finch's signature feature)
        "w_decay": lin(ks[5]),
        "decay_bias": jnp.full((d_model,), -4.0, dtype),  # slow decay init
        "bonus_u": (jax.random.normal(ks[6], (n_heads, head_size)) * 0.1
                    ).astype(dtype),
        "mix": (0.5 * jnp.ones((5, d_model))).astype(dtype),  # r,k,v,g,decay
    }


def _token_shift(x):
    """x_{t-1} with zero pad at t=0. x: (B, S, D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def _project(params, x):
    xs = _token_shift(x)
    mix = params["mix"].astype(x.dtype)
    xr = x * mix[0] + xs * (1 - mix[0])
    xk = x * mix[1] + xs * (1 - mix[1])
    xv = x * mix[2] + xs * (1 - mix[2])
    xg = x * mix[3] + xs * (1 - mix[3])
    xw = x * mix[4] + xs * (1 - mix[4])
    r = xr @ params["w_r"].astype(x.dtype)
    k = xk @ params["w_k"].astype(x.dtype)
    v = xv @ params["w_v"].astype(x.dtype)
    g = jax.nn.silu(xg @ params["w_g"].astype(x.dtype))
    # per-channel decay in (0, 1):  w = exp(-exp(logw))
    logw = (xw @ params["w_decay"].astype(x.dtype)
            + params["decay_bias"].astype(x.dtype))
    return r, k, v, g, logw


def rwkv6_forward(params, x: Array, *, head_size: int, chunk: int = 128):
    """Chunked-parallel forward. x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    h = d // head_size
    r, k, v, g, logw = _project(params, x)

    def heads(t):  # (B, S, D) -> (B, H, S, hd)
        return t.reshape(b, s, h, head_size).transpose(0, 2, 1, 3)

    r, k, v = heads(r), heads(k), heads(v)
    # neg decay rate per channel, clamped for chunk-local log-space safety
    nw = -jnp.exp(jnp.clip(logw.astype(jnp.float32), -8.0, 2.0))  # (B,S,D) <0
    nw = heads(nw)  # (B, H, S, hd)
    u = params["bonus_u"].astype(jnp.float32)  # (H, hd)

    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    def to_chunks(t):
        return t.reshape(b, h, n_chunks, chunk, head_size).transpose(2, 0, 1, 3, 4)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, nw))  # (N, B, H, c, hd)

    def step(state, xs):
        # state: (B, H, hd_k, hd_v)
        rb, kb, vb, wb = (t.astype(jnp.float32) for t in xs)
        c = rb.shape[2]
        cum = jnp.cumsum(wb, axis=2)                       # (B,H,c,hd) log decay
        cum_excl = cum - wb                                # decay up to t-1
        # inter-chunk: state contribution decayed to each position
        r_dec = rb * jnp.exp(cum_excl)
        o_state = jnp.einsum("bhck,bhkv->bhcv", r_dec, state)
        # intra-chunk: A[t,s] = exp(cum_excl[t] - cum[s]) per channel, s < t
        kt = kb * jnp.exp(-cum)                            # (B,H,c,hd)
        att = jnp.einsum("bhck,bhsk->bhcs", rb * jnp.exp(cum_excl), kt)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = att * mask[None, None]
        o_intra = jnp.einsum("bhcs,bhsv->bhcv", att, vb)
        # current token via bonus u:  o_cur_t = (r_t . (u * k_t)) v_t
        o_cur = ((rb * kb * jnp.exp(u)[None, :, None, :]).sum(-1, keepdims=True)
                 * vb)
        out = o_state + o_intra + o_cur
        # state update: S' = diag(exp(sum w)) S + sum_s exp(cum_last - cum_s) k_s v_s
        total = cum[:, :, -1:, :]                          # (B,H,1,hd)
        k_carry = kb * jnp.exp(total - cum)
        s_new = state * jnp.exp(total.squeeze(2))[..., None] + jnp.einsum(
            "bhsk,bhsv->bhkv", k_carry, vb)
        return s_new, out

    state0 = jnp.zeros((b, h, head_size, head_size), jnp.float32)
    _, outs = jax.lax.scan(step, state0, (rc, kc, vc, wc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, head_size)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    return (out * g) @ params["w_o"].astype(x.dtype)


def rwkv6_decode(params, x: Array, state: Array, shift: Array, *,
                 head_size: int):
    """One-token step. x: (B, 1, D); state: (B, H, hd, hd); shift: (B, D)."""
    b, _, d = x.shape
    h = d // head_size
    xs = shift[:, None, :]
    mix = params["mix"].astype(x.dtype)
    xr = x * mix[0] + xs * (1 - mix[0])
    xk = x * mix[1] + xs * (1 - mix[1])
    xv = x * mix[2] + xs * (1 - mix[2])
    xg = x * mix[3] + xs * (1 - mix[3])
    xw = x * mix[4] + xs * (1 - mix[4])
    r = (xr @ params["w_r"].astype(x.dtype)).reshape(b, h, head_size)
    k = (xk @ params["w_k"].astype(x.dtype)).reshape(b, h, head_size)
    v = (xv @ params["w_v"].astype(x.dtype)).reshape(b, h, head_size)
    g = jax.nn.silu(xg @ params["w_g"].astype(x.dtype))
    logw = (xw @ params["w_decay"].astype(x.dtype)
            + params["decay_bias"].astype(x.dtype))
    w = jnp.exp(-jnp.exp(jnp.clip(logw.astype(jnp.float32), -8.0, 2.0)))
    w = w.reshape(b, h, head_size)
    u = jnp.exp(params["bonus_u"].astype(jnp.float32))[None]
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    o = jnp.einsum("bhk,bhkv->bhv", rf, state + u[..., None] * kv)
    state = state * w[..., None] + kv
    out = o.reshape(b, 1, d).astype(x.dtype) * g
    y = out @ params["w_o"].astype(x.dtype)
    return y, state, x[:, 0, :]
