"""Mixture-of-Experts with sort-based capacity dispatch (production style).

Dense one-hot dispatch tensors (T, E, C) are infeasible at kimi scale
(T~65k local tokens x 384 experts) — we use the sort-based router every
large-scale MoE framework converges on:

  1. top-k(router logits)                 -> (T, k) expert ids + weights
  2. flatten to T*k assignments, stable-sort by expert id
  3. position-within-expert via cumsum over the sorted one-hot-free segment
  4. keep position < capacity, scatter tokens into an (E*C, D) buffer
  5. batched expert FFN  einsum('ecd,edf->ecf')  — E shards over the
     'model'/'expert' mesh axis, which turns steps 4/5's gather/scatter into
     an all-to-all under SPMD
  6. combine: weighted scatter-add back to (T, D)

Aux losses: load-balance (Switch-style) + router z-loss, returned for logging.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding_hooks import shard

Array = jax.Array


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s_in = 1.0 / jnp.sqrt(d_model)
    s_out = 1.0 / jnp.sqrt(d_ff)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, n_experts)) * s_in
                   ).astype(jnp.float32),  # router stays f32
        "w_in": (jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * s_in
                 ).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (n_experts, d_ff, d_model)) * s_out
                  ).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[3], (n_experts, d_model, d_ff))
                       * s_in).astype(dtype)
    return p


def moe_forward(params, x: Array, *, top_k: int, capacity_factor: float = 1.25,
                act=jax.nn.silu):
    """x: (B, S, D) -> (y, aux) with aux = {load_balance_loss, router_z_loss}.

    Sharding hooks (identity by default; the optimized variant activates
    them INSIDE its manual-over-data shard_map — EXPERIMENTS.md §Perf):
      moe_gather_logits — all-gather router logits over data (tiny);
      moe_slice_d       — all-to-all (T_loc, D) -> (T_glob, D_loc): every
                          rank sees ALL tokens but only its D-slice, so the
                          D-sharded-over-data expert weights never move and
                          their grads are local-complete;
      moe_partial_sum   — psum over data completing the D-contraction of the
                          (small) expert hidden h/g;
      moe_out_gather    — inverse all-to-all (T_glob, D_loc) -> (T_loc, D).
    """
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    e = params["router"].shape[1]

    logits = shard("moe_gather_logits",
                   xf.astype(jnp.float32) @ params["router"])   # (T, E)
    xf_d = shard("moe_slice_d", xf)                             # (T, D_loc)
    t, d_loc = xf_d.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, top_k)                # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses ----
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[gate_e.reshape(-1)].add(
        jnp.ones((t * top_k,), jnp.float32)) / (t * top_k)
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- sort-based dispatch ----
    cap = int(max(1, capacity_factor * t * top_k / e))  # t = dispatched rows
    flat_e = gate_e.reshape(-1)                                 # (T*k,)
    flat_w = gate_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    order = jnp.argsort(flat_e, stable=True)                    # sort by expert
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    # position within expert segment: running index minus segment start
    ones = jnp.ones_like(se)
    pos_global = jnp.cumsum(ones) - 1
    seg_start = jnp.full((e,), t * top_k, se.dtype).at[se].min(
        pos_global.astype(se.dtype))
    pos_in_e = pos_global.astype(jnp.int32) - seg_start[se].astype(jnp.int32)
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)        # overflow slot

    buf = jnp.zeros((e * cap + 1, d_loc), x.dtype).at[slot].add(xf_d[stok])
    buf = shard("moe_buffer", buf[:-1].reshape(e, cap, d_loc))  # (E, C, D_loc)

    # ---- expert FFN (batched over E; E shards over the expert axis) ----
    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"].astype(x.dtype))
    h = shard("moe_partial_sum", h)   # completes the D-contraction over data
    if "w_gate" in params:
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
        g = shard("moe_partial_sum", g)
        h = act(g) * h
    else:
        h = act(h)
    out = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(x.dtype))

    # ---- combine ----
    out_flat = out.reshape(e * cap, d_loc)
    contrib = out_flat[jnp.minimum(slot, e * cap - 1)] * (
        sw * keep.astype(jnp.float32))[:, None].astype(x.dtype)
    y = jnp.zeros((t, d_loc), x.dtype).at[stok].add(contrib)
    y = shard("moe_out_gather", y)                              # (T, D)
    aux = {"load_balance_loss": lb_loss, "router_z_loss": z_loss}
    return y.reshape(b, s, d), aux
