"""Dense feed-forward blocks: gated (SwiGLU/GeGLU) and plain (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def init_mlp(key, d_model: int, d_ff: int, gated: bool = True,
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    s_in = 1.0 / jnp.sqrt(d_model)
    s_out = 1.0 / jnp.sqrt(d_ff)
    p = {
        "w_in": (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[1], (d_ff, d_model)) * s_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * s_in).astype(dtype)
    return p


def mlp_forward(params, x, act: str = "silu"):
    h = x @ params["w_in"].astype(x.dtype)
    if "w_gate" in params:
        h = _act(act)(x @ params["w_gate"].astype(x.dtype)) * h
    else:
        h = _act(act)(h)
    return h @ params["w_out"].astype(x.dtype)
