"""Attention: GQA + RoPE + sliding-window + logit softcap, flash-style.

``flash_attention`` is a pure-JAX blockwise (online-softmax) attention: a
``lax.scan`` over KV chunks with running (max, denom, acc) — O(chunk * Sq)
workspace instead of O(Sq * Skv).  This is what makes prefill_32k lowerable
at production shapes.  GQA is computed grouped — q reshaped to
(B, KV, group, Sq, hd) — so KV heads are never materialized repeated.

Decode attention is a single fused einsum pair over the (sharded) KV cache;
the softmax reductions over a sequence-sharded cache become XLA all-reduces
(DESIGN.md §10 decode policy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.rope import apply_rope

Array = jax.Array


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool = False, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(k1, (d_model, n_heads, head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, n_kv, head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, n_kv, head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads, head_dim, d_model)) * s).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((n_kv, head_dim), dtype)
        p["bv"] = jnp.zeros((n_kv, head_dim), dtype)
    return p


def _attn_mask(q_pos, k_pos, *, causal: bool, window: int | None):
    """Boolean (Sq, Sk) mask; True = attend."""
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    return mask


def _softcap(s, cap: float | None):
    if cap is None:
        return s
    return cap * jnp.tanh(s / cap)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None, softcap: float | None = None,
                    chunk: int = 1024, q_offset: int = 0) -> Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Returns (B, Sq, H, hd).

    ``q_offset`` is the absolute position of q[0] (for chunked prefill).
    """
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = hd**-0.5
    qh = q.reshape(b, sq, kv, g, hd).transpose(0, 2, 3, 1, 4)  # (B,KV,G,Sq,hd)
    q_pos = q_offset + jnp.arange(sq)

    chunk = min(chunk, sk)
    if sk % chunk:  # non-power-of-two kv length (whisper's 1500 frames):
        chunk = next(c for c in range(chunk, 0, -1) if sk % c == 0)
    n_chunks = sk // chunk
    kc = k.reshape(b, n_chunks, chunk, kv, hd)
    vc = v.reshape(b, n_chunks, chunk, kv, hd)

    def step(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, idx = xs  # (B, chunk, KV, hd)
        k_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bkgqd,bckd->bkgqc", qh.astype(jnp.float32),
            k_blk.astype(jnp.float32),
        ) * scale
        s = _softcap(s, softcap)
        mask = _attn_mask(q_pos, k_pos, causal=causal, window=window)
        s = jnp.where(mask, s, -1e30)  # finite sentinel — keeps exp() NaN-free
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None]) * mask.astype(jnp.float32)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, v_blk.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    init = (
        jnp.full((b, kv, g, sq), -1e30, jnp.float32),
        jnp.zeros((b, kv, g, sq), jnp.float32),
        jnp.zeros((b, kv, g, sq, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        step, init,
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         jnp.arange(n_chunks)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attention_forward(params, x: Array, *, n_kv: int, rope_theta: float,
                      causal: bool = True, window: int | None = None,
                      softcap: float | None = None, chunk: int = 1024,
                      q_offset: int = 0, kv_input: Array | None = None,
                      use_rope: bool = True, return_kv: bool = False):
    """Full attention sub-block: projections + flash + output projection.

    ``kv_input`` switches to cross-attention (whisper decoder): K/V come from
    the encoder output, no causal mask, no rope on K.
    """
    kv_src = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if use_rope:
        q_pos = q_offset + jnp.arange(x.shape[1])
        k_pos = jnp.arange(kv_src.shape[1])
        q = apply_rope(q, q_pos[None, :], rope_theta)
        k = apply_rope(k, k_pos[None, :], rope_theta)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, chunk=chunk, q_offset=q_offset)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    if return_kv:
        return y, (k, v)
    return y


def attention_decode(params, x: Array, k_cache: Array, v_cache: Array,
                     pos: Array, *, n_kv: int, rope_theta: float,
                     window: int | None = None, softcap: float | None = None,
                     use_rope: bool = True):
    """One-token decode step.

    x: (B, 1, D); k_cache/v_cache: (B, S, KV, hd) with valid prefix < pos.
    Returns (y (B, 1, D), k_cache', v_cache').
    """
    b, _, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if use_rope:
        q = apply_rope(q, jnp.full((1, 1), pos, jnp.int32), rope_theta)
        k = apply_rope(k, jnp.full((1, 1), pos, jnp.int32), rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), pos, axis=1)

    h = q.shape[2]
    kv = k_cache.shape[2]
    g = h // kv
    hd = q.shape[3]
    qh = q.reshape(b, kv, g, hd)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qh.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    ) * hd**-0.5
    s = _softcap(s, softcap)
    k_pos = jnp.arange(k_cache.shape[1])
    valid = k_pos <= pos
    if window is not None:
        valid &= k_pos > pos - window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, k_cache, v_cache
