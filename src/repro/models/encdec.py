"""Encoder-decoder assembly (whisper-base backbone).

Audio frontend (log-mel + conv downsampler) is a STUB per the brief:
``input_specs`` provides precomputed frame embeddings (B, encoder_seq, D).
Encoder: bidirectional attention + plain GELU MLP, learned positions,
LayerNorm.  Decoder: causal self-attention + cross-attention + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import attention as attn_mod
from repro.models.layers import mlp as mlp_mod
from repro.models.layers.norm import layernorm_init, layernorm
from repro.models.sharding_hooks import shard

Array = jax.Array


def _init_enc_layer(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layernorm_init(cfg.d_model),
        "attn": attn_mod.init_attention(k1, cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, cfg.head_dim,
                                        dtype=cfg.pdtype),
        "ln2": layernorm_init(cfg.d_model),
        "mlp": mlp_mod.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False,
                                dtype=cfg.pdtype),
    }


def _init_dec_layer(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layernorm_init(cfg.d_model),
        "self_attn": attn_mod.init_attention(k1, cfg.d_model, cfg.num_heads,
                                             cfg.num_kv_heads, cfg.head_dim,
                                             dtype=cfg.pdtype),
        "ln_x": layernorm_init(cfg.d_model),
        "cross_attn": attn_mod.init_attention(k2, cfg.d_model, cfg.num_heads,
                                              cfg.num_kv_heads, cfg.head_dim,
                                              dtype=cfg.pdtype),
        "ln2": layernorm_init(cfg.d_model),
        "mlp": mlp_mod.init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False,
                                dtype=cfg.pdtype),
    }


def init_params(key, cfg: ArchConfig):
    ke, kd, kt, kp1, kp2, kh = jax.random.split(key, 6)
    v = cfg.padded_vocab
    params = {
        "embed": (jax.random.normal(kt, (v, cfg.d_model)) * 0.02
                  ).astype(cfg.pdtype),
        "enc_pos": (jax.random.normal(kp1, (cfg.encoder_seq, cfg.d_model))
                    * 0.01).astype(cfg.pdtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_layer(k, cfg))(
            jax.random.split(ke, cfg.encoder_layers)),
        "enc_norm": layernorm_init(cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: _init_dec_layer(k, cfg))(
            jax.random.split(kd, cfg.num_layers)),
        "final_norm": layernorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(kh, (cfg.d_model, v))
                             / jnp.sqrt(cfg.d_model)).astype(cfg.pdtype)
    return params


def param_specs(cfg: ArchConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


def encode(params, features: Array, cfg: ArchConfig) -> Array:
    """features: (B, encoder_seq, D) precomputed frame embeddings (stub)."""
    x = features.astype(cfg.cdtype) + params["enc_pos"].astype(cfg.cdtype)
    x = shard("hidden", x)

    def body(x, p):
        h = layernorm(p["ln1"], x, cfg.norm_eps)
        h = attn_mod.attention_forward(
            p["attn"], h, n_kv=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
            causal=False, use_rope=False, chunk=cfg.attn_chunk)
        x = x + shard("residual", h)
        h = layernorm(p["ln2"], x, cfg.norm_eps)
        x = x + shard("residual", mlp_mod.mlp_forward(p["mlp"], h, "gelu"))
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer_fwd(p, x, enc_out, cfg: ArchConfig):
    h = layernorm(p["ln1"], x, cfg.norm_eps)
    h = attn_mod.attention_forward(
        p["self_attn"], h, n_kv=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
        causal=True, use_rope=False, chunk=cfg.attn_chunk)
    x = x + shard("residual", h)
    h = layernorm(p["ln_x"], x, cfg.norm_eps)
    h = attn_mod.attention_forward(
        p["cross_attn"], h, n_kv=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
        causal=False, use_rope=False, kv_input=enc_out, chunk=cfg.attn_chunk)
    x = x + shard("residual", h)
    h = layernorm(p["ln2"], x, cfg.norm_eps)
    return x + shard("residual", mlp_mod.mlp_forward(p["mlp"], h, "gelu"))


def loss_fn(params, batch: dict, cfg: ArchConfig, *, remat: bool = True):
    """batch: audio_embed (B, enc_seq, D), tokens (B, S), labels (B, S)."""
    enc_out = encode(params, batch["audio_embed"], cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cfg.cdtype)
    x = shard("hidden", x)

    def body(x, p):
        return _dec_layer_fwd(p, x, enc_out, cfg), None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = layernorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    logits = shard("logits", logits)
    from repro.models.transformer import cross_entropy
    loss = cross_entropy(logits, batch["labels"])
    return loss, {"ce_loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    """Self-attn KV caches + cross-attn K/V (computed once from enc_out)."""
    hd, kv = cfg.head_dim, cfg.num_kv_heads
    dt = cfg.cdtype

    def one(_):
        return {
            "k": jnp.zeros((batch, max_seq, kv, hd), dt),
            "v": jnp.zeros((batch, max_seq, kv, hd), dt),
            "xk": jnp.zeros((batch, cfg.encoder_seq, kv, hd), dt),
            "xv": jnp.zeros((batch, cfg.encoder_seq, kv, hd), dt),
        }

    return {"dec": jax.vmap(one)(jnp.arange(cfg.num_layers))}


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))


def decode_step(params, cache, token: Array, pos: Array, cfg: ArchConfig):
    """One-token decode with self-attn cache + precomputed cross K/V."""
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.cdtype)
    x = shard("decode_hidden", x)

    def body(x, xs):
        p, c = xs
        h = layernorm(p["ln1"], x, cfg.norm_eps)
        y, kc, vc = attn_mod.attention_decode(
            p["self_attn"], h, c["k"], c["v"], pos, n_kv=cfg.num_kv_heads,
            rope_theta=cfg.rope_theta, use_rope=False)
        x = x + y
        h = layernorm(p["ln_x"], x, cfg.norm_eps)
        # cross attention against the fixed encoder K/V
        b = h.shape[0]
        q = jnp.einsum("bsd,dhk->bshk", h,
                       p["cross_attn"]["wq"].astype(h.dtype))
        g = cfg.num_heads // cfg.num_kv_heads
        qh = q.reshape(b, cfg.num_kv_heads, g, cfg.head_dim)
        s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                       c["xk"].astype(jnp.float32)) * cfg.head_dim**-0.5
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskd->bkgd", pr, c["xv"].astype(jnp.float32))
        o = o.reshape(b, 1, cfg.num_heads, cfg.head_dim).astype(h.dtype)
        y = jnp.einsum("bshk,hkd->bsd", o,
                       p["cross_attn"]["wo"].astype(h.dtype))
        x = x + y
        h = layernorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp_mod.mlp_forward(p["mlp"], h, "gelu")
        return x, {"k": kc, "v": vc, "xk": c["xk"], "xv": c["xv"]}

    x, new_dec = jax.lax.scan(body, x, (params["dec_blocks"], cache["dec"]))
    x = layernorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x.astype(jnp.float32) @ head.astype(jnp.float32))[:, 0, :]
    return logits, {"dec": new_dec}
