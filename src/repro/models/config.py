"""Unified architecture config for the 10 assigned architectures.

One frozen dataclass covers dense / GQA / SWA / MoE / SSM / hybrid / enc-dec;
``layer_kinds`` resolves the per-layer (mixer, ffn) pattern, and
``scan_grouping`` factors the layer list into
    [unrolled prefix] + [scanned periods] + [unrolled tail]
so heterogeneous patterns (gemma3 5:1, jamba 1:7+MoE:2) still lower as a
single compact ``lax.scan`` body per period.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention pattern ---
    attn_kind: str = "full"     # full | swa | local_global
    local_global_period: int = 0  # gemma3: 6 (5 local + 1 global); gemma2: 2
    window_size: int = 0
    softcap: float = 0.0        # attention logit softcap (gemma2)
    final_softcap: float = 0.0  # lm-head logit softcap (gemma2)
    qkv_bias: bool = False
    # --- mixer family ---
    mixer: str = "attention"    # attention | rwkv6 | hybrid_mamba
    attn_every: int = 0         # hybrid: attention at i % attn_every == attn_offset
    attn_offset: int = 4
    rwkv_head_size: int = 64
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1          # jamba: 2
    moe_offset: int = 1
    first_dense: int = 0        # kimi: 1
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- misc arch ---
    act: str = "silu"
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rope_theta: float = 1e4
    use_rope: bool = True
    tie_embeddings: bool = False
    embed_scale: bool = False   # gemma multiplies embeddings by sqrt(D)
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0
    # --- modality frontend stubs ---
    frontend: str = ""          # "" | audio | vision
    num_patch_tokens: int = 0   # pixtral image tokens (precomputed embeds)
    # --- numerics / optimizer ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"    # adamw | adafactor (memory-factored, kimi)
    vocab_pad_multiple: int = 256
    # --- runtime knobs ---
    attn_chunk: int = 1024      # flash KV chunk
    scan_chunk: int = 128       # rwkv/mamba chunk
    # long-context support marker (decides long_500k applicability)
    subquadratic: bool = False

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def d_inner(self) -> int:  # mamba
        return self.mamba_expand * self.d_model

    def is_encdec(self) -> bool:
        return self.encoder_layers > 0


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str   # attn | swa | mamba | rwkv
    ffn: str     # dense | moe
    d_ff: int

    def cache_kind(self) -> str:
        return {"attn": "kv", "swa": "kv_ring", "mamba": "ssm",
                "rwkv": "rwkv"}[self.mixer]


def layer_kinds(cfg: ArchConfig) -> list[LayerKind]:
    kinds = []
    for i in range(cfg.num_layers):
        # mixer
        if cfg.mixer == "rwkv6":
            mixer = "rwkv"
        elif cfg.mixer == "hybrid_mamba":
            mixer = "attn" if (cfg.attn_every and
                               i % cfg.attn_every == cfg.attn_offset) else "mamba"
        elif cfg.attn_kind == "swa":
            mixer = "swa"
        elif cfg.attn_kind == "local_global":
            p = cfg.local_global_period
            mixer = "attn" if i % p == p - 1 else "swa"
        else:
            mixer = "attn"
        # ffn
        if (cfg.num_experts and i >= cfg.first_dense
                and i % cfg.moe_every == cfg.moe_offset % cfg.moe_every):
            ffn, d_ff = "moe", cfg.moe_d_ff or cfg.d_ff
        else:
            ffn, d_ff = "dense", cfg.d_ff
        kinds.append(LayerKind(mixer, ffn, d_ff))
    return kinds


def scan_grouping(cfg: ArchConfig):
    """Factor layers into (prefix_kinds, period_kinds, n_periods, tail_kinds).

    The repeating period is the smallest p such that kinds[prefix:] is
    p-periodic (up to a remainder tail of < p layers).
    """
    kinds = layer_kinds(cfg)
    prefix = kinds[: cfg.first_dense]
    rest = kinds[cfg.first_dense:]
    if not rest:
        return prefix, [], 0, []
    period = 1
    for p in range(1, len(rest) + 1):
        ok = all(rest[i] == rest[i % p] for i in range(len(rest) - len(rest) % p))
        if ok:
            period = p
            break
    n_periods = len(rest) // period
    tail = rest[n_periods * period:]
    return prefix, rest[:period], n_periods, tail
