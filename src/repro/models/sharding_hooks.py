"""Activation-sharding hook.

Models call ``shard("logical_name", x)`` at block boundaries; by default it is
the identity.  ``launch/sharding.py`` installs a mesh-aware implementation
(``with use_sharder(fn): ...``) that maps logical activation names to
``jax.lax.with_sharding_constraint`` specs.  Keeping the hook out of model
code keeps model definitions mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def shard(name: str, x):
    fn = getattr(_state, "sharder", None)
    return x if fn is None else fn(name, x)


@contextlib.contextmanager
def use_sharder(fn):
    prev = getattr(_state, "sharder", None)
    _state.sharder = fn
    try:
        yield
    finally:
        _state.sharder = prev
