"""Unified model facade + per-(arch x shape) input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) — the
dry-run lowers against these.  Modality frontends are stubs: vision/audio
configs receive precomputed patch/frame embeddings as inputs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import transformer, encdec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """The DESIGN.md §8 skip policy."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch — long_500k needs sub-quadratic"
    return True, ""


# ---------------------------------------------------------------- facade ---

def init_params(key, cfg: ArchConfig):
    return (encdec.init_params(key, cfg) if cfg.is_encdec()
            else transformer.init_params(key, cfg))


def param_specs(cfg: ArchConfig):
    return (encdec.param_specs(cfg) if cfg.is_encdec()
            else transformer.param_specs(cfg))


def loss_fn(params, batch, cfg: ArchConfig, *, remat: bool = True):
    return (encdec.loss_fn(params, batch, cfg, remat=remat)
            if cfg.is_encdec()
            else transformer.loss_fn(params, batch, cfg, remat=remat))


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return (encdec.init_cache(cfg, batch, max_seq) if cfg.is_encdec()
            else transformer.init_cache(cfg, batch, max_seq))


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int):
    return (encdec.cache_specs(cfg, batch, max_seq) if cfg.is_encdec()
            else transformer.cache_specs(cfg, batch, max_seq))


def decode_step(params, cache, token, pos, cfg: ArchConfig):
    return (encdec.decode_step(params, cache, token, pos, cfg)
            if cfg.is_encdec()
            else transformer.decode_step(params, cache, token, pos, cfg))


def prefill_logits(params, batch, cfg: ArchConfig):
    if cfg.is_encdec():
        # encode once + teacher-forced decoder forward = the prefill analogue
        loss_inputs = dict(batch)
        enc = encdec.encode(params, batch["audio_embed"], cfg)
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cfg.cdtype)

        def body(x, p):
            return encdec._dec_layer_fwd(p, x, enc, cfg), None

        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        from repro.models.layers.norm import layernorm
        x = layernorm(params["final_norm"], x, cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        return x.astype(jnp.float32) @ head.astype(jnp.float32)
    return transformer.prefill(params, batch, cfg)


# ------------------------------------------------------------ input specs ---

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the step's data inputs (excluding params/cache)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            return {
                "audio_embed": _sds((b, cfg.encoder_seq, cfg.d_model),
                                    jnp.float32),
                "tokens": _sds((b, s), i32),
                "labels": _sds((b, s), i32),
            }
        if cfg.frontend == "vision":
            text = s - cfg.num_patch_tokens
            return {
                "img_embed": _sds((b, cfg.num_patch_tokens, cfg.d_model),
                                  jnp.float32),
                "tokens": _sds((b, text), i32),
                "labels": _sds((b, text), i32),
            }
        return {"tokens": _sds((b, s), i32), "labels": _sds((b, s), i32)}
    # decode: one new token against a seq_len KV cache
    return {"token": _sds((b, 1), i32), "pos": _sds((), i32)}


def make_host_batch(cfg: ArchConfig, shape: ShapeSpec, seed: int = 0):
    """Small concrete batch for smoke tests (use with SMOKE configs only)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)
    out = {}
    for k, sds in specs.items():
        if sds.dtype == jnp.int32 and k in ("tokens", "labels"):
            out[k] = rng.integers(0, cfg.vocab_size, sds.shape).astype("int32")
        elif k == "pos":
            out[k] = np.int32(0)
        elif k == "token":
            out[k] = rng.integers(0, cfg.vocab_size, sds.shape).astype("int32")
        else:
            out[k] = rng.normal(size=sds.shape).astype("float32")
    return out
