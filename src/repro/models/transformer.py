"""Decoder-only LM assembly for all non-enc-dec architectures.

Layers are grouped as [unrolled prefix] + [scan over periods] + [unrolled
tail] (models/config.py::scan_grouping); the scan body covers one period of
the layer pattern and is rematerialized (jax.checkpoint) for training.

Params are plain nested dicts; scanned groups carry leaves stacked along a
leading (n_periods,) axis — vmap over per-period RNG keys builds them without
host loops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, LayerKind, layer_kinds, scan_grouping
from repro.models.layers import attention as attn_mod
from repro.models.layers import mamba as mamba_mod
from repro.models.layers import mlp as mlp_mod
from repro.models.layers import moe as moe_mod
from repro.models.layers import rwkv6 as rwkv_mod
from repro.models.layers.norm import (
    rmsnorm_init, rmsnorm, layernorm_init, layernorm,
)
from repro.models.sharding_hooks import shard

Array = jax.Array


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def _norm_init(cfg: ArchConfig, dtype):
    return (rmsnorm_init(cfg.d_model, dtype) if cfg.norm == "rmsnorm"
            else layernorm_init(cfg.d_model, dtype))


def _norm(cfg: ArchConfig, params, x):
    return (rmsnorm(params, x, cfg.norm_eps) if cfg.norm == "rmsnorm"
            else layernorm(params, x, cfg.norm_eps))


# --------------------------------------------------------------------------
# per-layer params
# --------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, kind: LayerKind):
    kmix, kffn, kn1, kn2, kshared = jax.random.split(key, 5)
    dtype = cfg.pdtype
    p: dict = {"ln1": _norm_init(cfg, dtype), "ln2": _norm_init(cfg, dtype)}
    if kind.mixer in ("attn", "swa"):
        p["attn"] = attn_mod.init_attention(
            kmix, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=dtype)
    elif kind.mixer == "mamba":
        p["mamba"] = mamba_mod.init_mamba(
            kmix, cfg.d_model, cfg.mamba_d_state, cfg.mamba_d_conv,
            cfg.mamba_expand, dtype=dtype)
    elif kind.mixer == "rwkv":
        p["rwkv"] = rwkv_mod.init_rwkv6(
            kmix, cfg.d_model, cfg.rwkv_head_size, dtype=dtype)
    if kind.ffn == "moe":
        p["moe"] = moe_mod.init_moe(
            kffn, cfg.d_model, kind.d_ff, cfg.num_experts, dtype=dtype)
        if cfg.shared_expert:
            p["shared_mlp"] = mlp_mod.init_mlp(
                kshared, cfg.d_model, kind.d_ff, dtype=dtype)
    else:
        p["mlp"] = mlp_mod.init_mlp(
            kffn, cfg.d_model, kind.d_ff,
            gated=(cfg.act != "gelu" or cfg.norm == "rmsnorm"), dtype=dtype)
    return p


def init_params(key, cfg: ArchConfig):
    prefix, period, n_periods, tail = scan_grouping(cfg)
    k_embed, k_head, k_pre, k_body, k_tail, k_fn = jax.random.split(key, 6)
    dtype = cfg.pdtype
    v = cfg.padded_vocab
    params: dict = {
        "embed": (jax.random.normal(k_embed, (v, cfg.d_model)) * 0.02
                  ).astype(dtype),
        "final_norm": _norm_init(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, v)) /
            jnp.sqrt(cfg.d_model)).astype(dtype)
    if prefix:
        params["prefix"] = [
            _init_layer(k, cfg, kind)
            for k, kind in zip(jax.random.split(k_pre, len(prefix)), prefix)
        ]
    if n_periods:
        def one_period(k):
            ks = jax.random.split(k, len(period))
            return [_init_layer(ki, cfg, kind) for ki, kind in zip(ks, period)]
        params["blocks"] = jax.vmap(one_period)(
            jax.random.split(k_body, n_periods))
    if tail:
        params["tail"] = [
            _init_layer(k, cfg, kind)
            for k, kind in zip(jax.random.split(k_tail, len(tail)), tail)
        ]
    return params


def param_specs(cfg: ArchConfig):
    """ShapeDtypeStruct pytree of the params (no allocation) for the dry-run."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _apply_mixer_fwd(p, x, cfg: ArchConfig, kind: LayerKind):
    if kind.mixer in ("attn", "swa"):
        window = cfg.window_size if kind.mixer == "swa" else None
        return attn_mod.attention_forward(
            p["attn"], x, n_kv=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
            causal=True, window=window, softcap=cfg.softcap or None,
            chunk=cfg.attn_chunk, use_rope=cfg.use_rope)
    if kind.mixer == "mamba":
        return mamba_mod.mamba_forward(p["mamba"], x, chunk=cfg.scan_chunk)
    if kind.mixer == "rwkv":
        return rwkv_mod.rwkv6_forward(p["rwkv"], x,
                                      head_size=cfg.rwkv_head_size,
                                      chunk=cfg.scan_chunk)
    raise ValueError(kind.mixer)


def _apply_ffn_fwd(p, x, cfg: ArchConfig, kind: LayerKind):
    if kind.ffn == "moe":
        y, aux = moe_mod.moe_forward(
            p["moe"], x, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor)
        if cfg.shared_expert:
            y = y + mlp_mod.mlp_forward(p["shared_mlp"], x, cfg.act)
        return y, aux["load_balance_loss"] + 1e-3 * aux["router_z_loss"]
    return mlp_mod.mlp_forward(p["mlp"], x, cfg.act), jnp.zeros((), jnp.float32)


def _apply_layer_fwd(p, x, cfg: ArchConfig, kind: LayerKind):
    h = _norm(cfg, p["ln1"], x)
    if kind.mixer in ("attn", "swa"):
        # hooks for sequence-parallel attention (optimized variant resharding
        # when num_heads doesn't divide the model axis); identity by default
        h = shard("attn_in", h)
        y = shard("attn_out", _apply_mixer_fwd(p, h, cfg, kind))
    else:
        y = _apply_mixer_fwd(p, h, cfg, kind)
    x = x + shard("residual", y)
    h = _norm(cfg, p["ln2"], x)
    y, aux = _apply_ffn_fwd(p, h, cfg, kind)
    return x + shard("residual", y), aux


def backbone_forward(params, x: Array, cfg: ArchConfig, *,
                     remat: bool = False):
    """Embedded input -> final hidden states. x: (B, S, D) compute dtype."""
    prefix, period, n_periods, tail = scan_grouping(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    for p, kind in zip(params.get("prefix", []), prefix):
        x, aux = _apply_layer_fwd(p, x, cfg, kind)
        aux_total += aux

    if n_periods:
        def body(x, p_period):
            aux_p = jnp.zeros((), jnp.float32)
            for p, kind in zip(p_period, period):
                x, aux = _apply_layer_fwd(p, x, cfg, kind)
                aux_p += aux
            return x, aux_p

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, auxs = jax.lax.scan(body, x, params["blocks"])
        aux_total += auxs.sum()

    for p, kind in zip(params.get("tail", []), tail):
        x, aux = _apply_layer_fwd(p, x, cfg, kind)
        aux_total += aux
    return _norm(cfg, params["final_norm"], x), aux_total


def embed_tokens(params, tokens: Array, cfg: ArchConfig) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), cfg.cdtype)
    return x


def lm_logits(params, hidden: Array, cfg: ArchConfig) -> Array:
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = hidden.astype(jnp.float32) @ head.astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return shard("logits", logits)


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean CE over positions with label >= 0 (mask = frontend positions)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, batch: dict, cfg: ArchConfig, *, remat: bool = True):
    """batch: tokens (B,S), labels (B,S) [-1 = masked], optional
    img_embed (B,P,D)."""
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "img_embed" in batch:
        img = batch["img_embed"].astype(cfg.cdtype)
        x = jnp.concatenate([img, x], axis=1)
        pad = -jnp.ones(img.shape[:2], labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    x = shard("hidden", x)
    hidden, aux = backbone_forward(params, x, cfg, remat=remat)
    logits = lm_logits(params, hidden, cfg)
    loss = cross_entropy(logits, labels)
    return loss + 1e-2 * aux, {"ce_loss": loss, "aux_loss": aux}


# --------------------------------------------------------------------------
# decode (serve path)
# --------------------------------------------------------------------------

def _cache_entry(cfg: ArchConfig, kind: LayerKind, batch: int, max_seq: int):
    hd, kv = cfg.head_dim, cfg.num_kv_heads
    dt = cfg.cdtype
    if kind.mixer == "attn":
        return {"k": jnp.zeros((batch, max_seq, kv, hd), dt),
                "v": jnp.zeros((batch, max_seq, kv, hd), dt)}
    if kind.mixer == "swa":
        w = min(cfg.window_size, max_seq)
        return {"k": jnp.zeros((batch, w, kv, hd), dt),
                "v": jnp.zeros((batch, w, kv, hd), dt),
                "slot_pos": -jnp.ones((w,), jnp.int32)}
    if kind.mixer == "mamba":
        return {"ssm": jnp.zeros((batch, cfg.d_inner, cfg.mamba_d_state),
                                 jnp.float32),
                "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.d_inner),
                                  dt)}
    if kind.mixer == "rwkv":
        h = cfg.d_model // cfg.rwkv_head_size
        return {"state": jnp.zeros((batch, h, cfg.rwkv_head_size,
                                    cfg.rwkv_head_size), jnp.float32),
                "shift": jnp.zeros((batch, cfg.d_model), dt)}
    raise ValueError(kind.mixer)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    prefix, period, n_periods, tail = scan_grouping(cfg)
    cache: dict = {}
    if prefix:
        cache["prefix"] = [_cache_entry(cfg, k, batch, max_seq) for k in prefix]
    if n_periods:
        def one(_):
            return [_cache_entry(cfg, k, batch, max_seq) for k in period]
        cache["blocks"] = jax.vmap(one)(jnp.arange(n_periods))
    if tail:
        cache["tail"] = [_cache_entry(cfg, k, batch, max_seq) for k in tail]
    return cache


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))


def _decode_attn_ring(p, x, cache, pos, cfg: ArchConfig):
    """SWA decode against a ring buffer of window slots."""
    b, _, d = x.shape
    w = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["attn"]["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["attn"]["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["attn"]["wv"].astype(x.dtype))
    if "bq" in p["attn"]:
        q = q + p["attn"]["bq"].astype(x.dtype)
        k = k + p["attn"]["bk"].astype(x.dtype)
        v = v + p["attn"]["bv"].astype(x.dtype)
    if cfg.use_rope:
        from repro.models.layers.rope import apply_rope
        q = apply_rope(q, jnp.full((1, 1), pos, jnp.int32), cfg.rope_theta)
        k = apply_rope(k, jnp.full((1, 1), pos, jnp.int32), cfg.rope_theta)
    slot = pos % w
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], pos[None].astype(jnp.int32), slot, axis=0)

    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kv
    qh = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd**-0.5
    if cfg.softcap:
        s = cfg.softcap * jnp.tanh(s / cfg.softcap)
    valid = (slot_pos >= 0) & (slot_pos <= pos) & \
            (slot_pos > pos - cfg.window_size)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(x.dtype))
    return y, {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}


def _apply_layer_decode(p, x, cache, pos, cfg: ArchConfig, kind: LayerKind):
    h = _norm(cfg, p["ln1"], x)
    if kind.mixer == "attn":
        y, kc, vc = attn_mod.attention_decode(
            p["attn"], h, cache["k"], cache["v"], pos,
            n_kv=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
            window=None, softcap=cfg.softcap or None, use_rope=cfg.use_rope)
        cache = {"k": kc, "v": vc}
    elif kind.mixer == "swa":
        y, cache = _decode_attn_ring(p, h, cache, pos, cfg)
    elif kind.mixer == "mamba":
        y, ssm, conv = mamba_mod.mamba_decode(
            p["mamba"], h, cache["ssm"], cache["conv"])
        cache = {"ssm": ssm, "conv": conv}
    elif kind.mixer == "rwkv":
        y, state, shiftv = rwkv_mod.rwkv6_decode(
            p["rwkv"], h, cache["state"], cache["shift"],
            head_size=cfg.rwkv_head_size)
        cache = {"state": state, "shift": shiftv}
    x = x + y
    h = _norm(cfg, p["ln2"], x)
    if kind.ffn == "moe":
        y, _ = moe_mod.moe_forward(p["moe"], h, top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor)
        if cfg.shared_expert:
            y = y + mlp_mod.mlp_forward(p["shared_mlp"], h, cfg.act)
    else:
        y = mlp_mod.mlp_forward(p["mlp"], h, cfg.act)
    return x + y, cache


def decode_step(params, cache, token: Array, pos: Array, cfg: ArchConfig):
    """One-token decode. token: (B, 1) int32; pos: scalar int32 (shared —
    batched serving uses per-slot position via vmap upstream if needed).
    Returns (logits (B, V), new_cache)."""
    prefix, period, n_periods, tail = scan_grouping(cfg)
    x = embed_tokens(params, token, cfg)
    x = shard("decode_hidden", x)
    new_cache: dict = {}
    if prefix:
        outs = []
        for p, c, kind in zip(params["prefix"], cache["prefix"], prefix):
            x, c2 = _apply_layer_decode(p, x, c, pos, cfg, kind)
            outs.append(c2)
        new_cache["prefix"] = outs

    if n_periods:
        def body(x, xs):
            p_period, c_period = xs
            c_out = []
            for p, c, kind in zip(p_period, c_period, period):
                x, c2 = _apply_layer_decode(p, x, c, pos, cfg, kind)
                c_out.append(c2)
            return x, c_out
        x, blocks_cache = jax.lax.scan(
            body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = blocks_cache

    if tail:
        outs = []
        for p, c, kind in zip(params["tail"], cache["tail"], tail):
            x, c2 = _apply_layer_decode(p, x, c, pos, cfg, kind)
            outs.append(c2)
        new_cache["tail"] = outs

    hidden = _norm(cfg, params["final_norm"], x)
    logits = lm_logits(params, hidden, cfg)[:, 0, :]
    return logits, new_cache


def prefill(params, batch: dict, cfg: ArchConfig, max_seq: int | None = None):
    """Full-sequence forward returning (last-token logits, populated cache).

    Used by serve examples at smoke scale; for the dry-run, prefill_32k
    lowers the forward (logits over the full sequence), which dominates cost.
    """
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision" and "img_embed" in batch:
        x = jnp.concatenate([batch["img_embed"].astype(cfg.cdtype), x], axis=1)
    x = shard("hidden", x)
    hidden, _ = backbone_forward(params, x, cfg, remat=False)
    logits = lm_logits(params, hidden, cfg)
    return logits
