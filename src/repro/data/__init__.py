from repro.data.kpca_datasets import (  # noqa: F401
    ChunkedDataset, make_dataset, DATASETS, median_sigma, train_test_split,
    knn_classify,
)
from repro.data.tokens import TokenPipeline, synthetic_batch  # noqa: F401
