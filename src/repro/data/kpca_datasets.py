"""Synthetic stand-ins for the paper's datasets (german/pendigits/usps/yale).

The originals are not redistributable offline; we generate Gaussian-mixture
datasets that match each one's (n, d, #classes) and — crucially for the shadow
method — carry the same kind of *redundancy*: many points per cluster with
within-cluster spread small relative to the kernel bandwidth, so that the
ShDE retains <~10-30% of the data for ell in [3, 5] exactly as in Fig. 6.

Bandwidths are re-derived with the median-distance heuristic (the paper used
cross-validation on the real data; DESIGN.md §14 records this changed
assumption).  All claims validated against the paper are therefore the
*relative* ones: speedup ratios, method orderings, convergence in ell.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core.kernels_math import pairwise_sq_dists


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    dim: int
    classes: int
    clusters_per_class: int
    cluster_std: float   # relative to unit box
    knn_k: int           # paper Table 1 'k'
    label_noise: float   # flip fraction — sets the k-nn accuracy ceiling
    std_jitter: float    # lognormal sigma of per-cluster scale (smooths the
                         # all-or-nothing shadow absorption in high dim)


# paper Table 1 geometry; cluster_std / jitter / label_noise calibrated so the
# retention-vs-ell curves and accuracy levels resemble the paper's Figs. 4-6
# (validated in tests/test_paper_experiments.py).
DATASETS = {
    "german": DatasetSpec("german", 1000, 24, 2, 4, 0.10, 5, 0.25, 0.3),
    "pendigits": DatasetSpec("pendigits", 3500, 16, 10, 3, 0.08, 5, 0.02, 0.3),
    "usps": DatasetSpec("usps", 9298, 256, 10, 2, 0.07, 15, 0.04, 0.5),
    "yale": DatasetSpec("yale", 5768, 520, 10, 2, 0.07, 10, 0.25, 0.5),
}


def median_sigma(x: np.ndarray, sample: int = 2000, seed: int = 0) -> float:
    """Median-pairwise-distance bandwidth heuristic."""
    rng = np.random.default_rng(seed)
    if x.shape[0] > sample:
        x = x[rng.choice(x.shape[0], sample, replace=False)]
    d2 = np.asarray(pairwise_sq_dists(jnp.asarray(x), jnp.asarray(x)))
    iu = np.triu_indices(d2.shape[0], k=1)
    return float(np.sqrt(np.median(d2[iu])))


def make_dataset(name: str, seed: int = 0, n: int | None = None):
    """Returns (x, y, sigma): features (n, d), labels (n,), bandwidth."""
    spec = DATASETS[name]
    n = n or spec.n
    rng = np.random.default_rng(seed)
    total_clusters = spec.classes * spec.clusters_per_class
    means = rng.uniform(0.0, 1.0, size=(total_clusters, spec.dim))
    stds = spec.cluster_std * rng.lognormal(
        0.0, spec.std_jitter, size=total_clusters)
    # assign points to clusters round-robin so classes are balanced
    cluster_of_point = rng.integers(0, total_clusters, size=n)
    x = means[cluster_of_point] + rng.normal(
        0.0, 1.0, size=(n, spec.dim)) * stds[cluster_of_point][:, None]
    y = cluster_of_point % spec.classes
    if spec.label_noise > 0:
        flip = rng.random(n) < spec.label_noise
        y = np.where(flip, rng.integers(0, spec.classes, size=n), y)
    sigma = median_sigma(x, seed=seed)
    return x.astype(np.float32), y.astype(np.int32), sigma


#: Row-generation granule of ``ChunkedDataset``: row i is always produced by
#: tile i // _TILE from its own counter-derived seed, so chunk size (and even
#: the requested n) never changes a row's value.
_TILE = 4096


class ChunkedDataset:
    """Deterministic out-of-core chunk stream over the synthetic mixtures
    (DESIGN.md §9): the n x d dataset NEVER materializes — rows are
    generated tile-by-tile on demand and handed out in fixed-shape chunks.

    Determinism contract (tested in tests/test_ingest.py): row i depends
    only on ``(name, seed, i)``.  Rows are produced in ``_TILE``-row
    granules, each from ``SeedSequence([seed, tile_index])``, and chunks are
    assembled from tile slices — so two streams with different ``chunk``
    (or different total ``n``) agree bit-exactly on every shared row.  This
    is what makes the distributed ingest restartable and its selection
    reproducible across chunk-size/retries.

    ``chunks()`` yields ``(x, n_valid)`` with ``x`` always exactly
    ``(chunk, d)`` (the ragged final chunk is zero-padded and masked by
    ``n_valid < chunk``), so every chunk of the stream runs through ONE
    compiled selection program — the same fixed-shape contract as
    streaming ingest batches.
    """

    def __init__(self, name: str, n: int, chunk: int, seed: int = 0):
        self.spec = DATASETS[name]
        self.name = name
        self.n = int(n)
        self.chunk = int(chunk)
        self.seed = int(seed)
        assert self.n > 0 and self.chunk > 0
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, 0]))
        spec = self.spec
        total_clusters = spec.classes * spec.clusters_per_class
        self._means = rng.uniform(0.0, 1.0, size=(total_clusters, spec.dim))
        self._stds = spec.cluster_std * rng.lognormal(
            0.0, spec.std_jitter, size=total_clusters)
        self._tile_cache: tuple[int, np.ndarray] | None = None
        self._sigma: float | None = None

    @property
    def d(self) -> int:
        return self.spec.dim

    @property
    def num_chunks(self) -> int:
        return -(-self.n // self.chunk)

    @property
    def nbytes_f32(self) -> int:
        """Full f32 footprint IF the dataset were materialized — the
        denominator of the ingest bench's peak-host-memory gate."""
        return 4 * self.n * self.d

    def _tile(self, t: int) -> np.ndarray:
        """The full ``_TILE`` rows of tile t (generated whole regardless of
        n, so truncating n never shifts surviving rows)."""
        if self._tile_cache is not None and self._tile_cache[0] == t:
            return self._tile_cache[1]
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 1 + t]))
        k = self._means.shape[0]
        cluster = rng.integers(0, k, size=_TILE)
        x = self._means[cluster] + rng.normal(
            0.0, 1.0, size=(_TILE, self.spec.dim)
        ) * self._stds[cluster][:, None]
        x = x.astype(np.float32)
        self._tile_cache = (t, x)
        return x

    def rows(self, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) assembled from tiles (hi clamped to n)."""
        hi = min(hi, self.n)
        out = np.empty((hi - lo, self.d), np.float32)
        pos = 0
        for t in range(lo // _TILE, (hi - 1) // _TILE + 1):
            ts = t * _TILE
            s, e = max(lo, ts) - ts, min(hi, ts + _TILE) - ts
            out[pos : pos + e - s] = self._tile(t)[s:e]
            pos += e - s
        return out

    def _chunk_at(self, ci: int):
        """Chunk ``ci`` as ``(x (chunk, d) f32, n_valid)``; pure in
        ``(name, seed, ci)``, so a failed/retried read regenerates the
        SAME bytes.  ``data.chunk`` is the chaos injection site for flaky
        chunk reads — transient faults are retried in place (the chunk is
        a pure function, the canonical safe-retry situation), permanent
        ones propagate."""
        from repro.runtime import chaos
        chaos.inject("data.chunk")
        s = ci * self.chunk
        e = min(s + self.chunk, self.n)
        if e - s == self.chunk:
            return self.rows(s, e), self.chunk
        x = np.zeros((self.chunk, self.d), np.float32)  # ragged tail
        x[: e - s] = self.rows(s, e)
        return x, e - s

    def chunks(self, start: int = 0):
        """Yield ``(x (chunk, d) f32, n_valid)`` fixed-shape host chunks
        from chunk index ``start`` (the resume cursor of a checkpointed
        ingest: chunk ci covers rows [ci*chunk, (ci+1)*chunk))."""
        from repro.runtime.fault import retry_call
        for ci in range(start, self.num_chunks):
            yield retry_call(self._chunk_at, ci, key=f"chunk{ci}")

    def materialize(self, limit: int = 1 << 22) -> np.ndarray:
        """The whole dataset as one array — small-n tests/oracles only."""
        assert self.n <= limit, \
            f"refusing to materialize n={self.n} rows (limit {limit})"
        return self.rows(0, self.n)

    def bandwidth(self) -> float:
        """Median-distance sigma from a fixed 2048-row prefix sample (the
        stream analogue of ``median_sigma``; deterministic in ``seed``)."""
        if self._sigma is None:
            self._sigma = median_sigma(
                self.rows(0, min(self.n, 2048)), seed=self.seed)
        return self._sigma


def train_test_split(x, y, frac: float = 0.8, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(x.shape[0])
    cut = int(frac * x.shape[0])
    tr, te = idx[:cut], idx[cut:]
    return x[tr], y[tr], x[te], y[te]


def knn_classify(train_emb: np.ndarray, train_y: np.ndarray,
                 test_emb: np.ndarray, k: int) -> np.ndarray:
    """k-nn in the (KPCA) embedding space — the paper's §6 classifier."""
    d2 = np.asarray(
        pairwise_sq_dists(jnp.asarray(test_emb), jnp.asarray(train_emb))
    )
    nn = np.argsort(d2, axis=1)[:, :k]
    votes = train_y[nn]  # (n_test, k)
    n_cls = int(train_y.max()) + 1
    counts = np.stack([(votes == c).sum(axis=1) for c in range(n_cls)], axis=1)
    return counts.argmax(axis=1)
