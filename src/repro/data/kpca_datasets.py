"""Synthetic stand-ins for the paper's datasets (german/pendigits/usps/yale).

The originals are not redistributable offline; we generate Gaussian-mixture
datasets that match each one's (n, d, #classes) and — crucially for the shadow
method — carry the same kind of *redundancy*: many points per cluster with
within-cluster spread small relative to the kernel bandwidth, so that the
ShDE retains <~10-30% of the data for ell in [3, 5] exactly as in Fig. 6.

Bandwidths are re-derived with the median-distance heuristic (the paper used
cross-validation on the real data; DESIGN.md §12 records this changed
assumption).  All claims validated against the paper are therefore the
*relative* ones: speedup ratios, method orderings, convergence in ell.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core.kernels_math import pairwise_sq_dists


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    dim: int
    classes: int
    clusters_per_class: int
    cluster_std: float   # relative to unit box
    knn_k: int           # paper Table 1 'k'
    label_noise: float   # flip fraction — sets the k-nn accuracy ceiling
    std_jitter: float    # lognormal sigma of per-cluster scale (smooths the
                         # all-or-nothing shadow absorption in high dim)


# paper Table 1 geometry; cluster_std / jitter / label_noise calibrated so the
# retention-vs-ell curves and accuracy levels resemble the paper's Figs. 4-6
# (validated in tests/test_paper_experiments.py).
DATASETS = {
    "german": DatasetSpec("german", 1000, 24, 2, 4, 0.10, 5, 0.25, 0.3),
    "pendigits": DatasetSpec("pendigits", 3500, 16, 10, 3, 0.08, 5, 0.02, 0.3),
    "usps": DatasetSpec("usps", 9298, 256, 10, 2, 0.07, 15, 0.04, 0.5),
    "yale": DatasetSpec("yale", 5768, 520, 10, 2, 0.07, 10, 0.25, 0.5),
}


def median_sigma(x: np.ndarray, sample: int = 2000, seed: int = 0) -> float:
    """Median-pairwise-distance bandwidth heuristic."""
    rng = np.random.default_rng(seed)
    if x.shape[0] > sample:
        x = x[rng.choice(x.shape[0], sample, replace=False)]
    d2 = np.asarray(pairwise_sq_dists(jnp.asarray(x), jnp.asarray(x)))
    iu = np.triu_indices(d2.shape[0], k=1)
    return float(np.sqrt(np.median(d2[iu])))


def make_dataset(name: str, seed: int = 0, n: int | None = None):
    """Returns (x, y, sigma): features (n, d), labels (n,), bandwidth."""
    spec = DATASETS[name]
    n = n or spec.n
    rng = np.random.default_rng(seed)
    total_clusters = spec.classes * spec.clusters_per_class
    means = rng.uniform(0.0, 1.0, size=(total_clusters, spec.dim))
    stds = spec.cluster_std * rng.lognormal(
        0.0, spec.std_jitter, size=total_clusters)
    # assign points to clusters round-robin so classes are balanced
    cluster_of_point = rng.integers(0, total_clusters, size=n)
    x = means[cluster_of_point] + rng.normal(
        0.0, 1.0, size=(n, spec.dim)) * stds[cluster_of_point][:, None]
    y = cluster_of_point % spec.classes
    if spec.label_noise > 0:
        flip = rng.random(n) < spec.label_noise
        y = np.where(flip, rng.integers(0, spec.classes, size=n), y)
    sigma = median_sigma(x, seed=seed)
    return x.astype(np.float32), y.astype(np.int32), sigma


def train_test_split(x, y, frac: float = 0.8, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(x.shape[0])
    cut = int(frac * x.shape[0])
    tr, te = idx[:cut], idx[cut:]
    return x[tr], y[tr], x[te], y[te]


def knn_classify(train_emb: np.ndarray, train_y: np.ndarray,
                 test_emb: np.ndarray, k: int) -> np.ndarray:
    """k-nn in the (KPCA) embedding space — the paper's §6 classifier."""
    d2 = np.asarray(
        pairwise_sq_dists(jnp.asarray(test_emb), jnp.asarray(train_emb))
    )
    nn = np.argsort(d2, axis=1)[:, :k]
    votes = train_y[nn]  # (n_test, k)
    n_cls = int(train_y.max()) + 1
    counts = np.stack([(votes == c).sum(axis=1) for c in range(n_cls)], axis=1)
    return counts.argmax(axis=1)
