"""Deterministic synthetic LM token pipeline.

Design constraints (fault tolerance, DESIGN.md §11):
  * STATELESS indexing — batch contents are a pure function of (seed, step),
    so a restarted job resumes the exact stream by fast-forwarding `step`
    with zero replayed work and no iterator state in checkpoints.
  * Host-shardable — each data-parallel host materializes only its slice
    (process_index / process_count), then forms a global jax.Array.
  * Structured enough to train on: a mixture of Zipfian unigrams and a
    first-order Markov chain so a ~100M model shows a real loss curve.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _zipf_logits(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    return (-alpha * np.log(ranks)).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order_mix: float = 0.7  # weight of the Markov component

    def _batch_key(self, step: int) -> Array:
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), step)

    def global_batch_np(self, step: int, batch: int | None = None,
                        seq: int | None = None) -> np.ndarray:
        """Host-side batch materialization (numpy; used by tests/examples)."""
        batch = batch or self.global_batch
        seq = seq or self.seq_len
        rng = np.random.default_rng((self.seed, step))
        v = self.vocab_size
        # Zipf unigram draws
        logits = _zipf_logits(min(v, 4096))
        p = np.exp(logits - logits.max()); p /= p.sum()
        uni = rng.choice(len(p), size=(batch, seq), p=p)
        # cheap deterministic "Markov" structure: next token is a fixed
        # permutation of the previous with prob markov_order_mix
        perm = np.random.default_rng(self.seed).permutation(v)
        out = uni.copy()
        take_markov = rng.random((batch, seq)) < self.markov_order_mix
        out[:, 1:] = np.where(take_markov[:, 1:],
                              perm[out[:, :-1] % v],
                              out[:, 1:])
        return (out % v).astype(np.int32)

    def batch(self, step: int) -> dict[str, Array]:
        """Pure-jax batch (jit-friendly); labels are next-token shifted."""
        key = self._batch_key(step)
        v = self.vocab_size
        k1, k2 = jax.random.split(key)
        base = jax.random.categorical(
            k1, jnp.asarray(_zipf_logits(min(v, 4096))),
            shape=(self.global_batch, self.seq_len + 1),
        ).astype(jnp.int32)
        perm = jax.random.permutation(jax.random.PRNGKey(self.seed), v)
        markov = perm[base[:, :-1] % v]
        gate = jax.random.bernoulli(
            k2, self.markov_order_mix, (self.global_batch, self.seq_len)
        )
        nxt = jnp.where(gate, markov, base[:, 1:]) % v
        tokens = base[:, :-1] % v
        return {"tokens": tokens, "labels": nxt}

    def host_shard(self, step: int, process_index: int,
                   process_count: int) -> dict[str, np.ndarray]:
        """The slice of the global batch owned by one data-parallel host."""
        full = self.global_batch_np(step)
        per = self.global_batch // process_count
        sl = slice(process_index * per, (process_index + 1) * per)
        tokens = full[sl]
        labels = np.roll(tokens, -1, axis=1)
        return {"tokens": tokens, "labels": labels}


def synthetic_batch(vocab_size: int, batch: int, seq: int, seed: int = 0,
                    step: int = 0) -> dict[str, Array]:
    """One-off batch for smoke tests."""
    pipe = TokenPipeline(vocab_size=vocab_size, seq_len=seq, global_batch=batch,
                         seed=seed)
    return pipe.batch(step)
