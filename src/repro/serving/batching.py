"""Continuous-batching front end for the serving transform (DESIGN.md §8).

Request-at-a-time serving leaves the projection kernel badly underfed: a
single query row still pays a full dispatch, padded to the 128-lane floor,
and concurrent callers serialize on the device anyway.  This front end gives
the transform the batch sizes it was compiled for without giving up latency
SLOs:

  * ``submit`` enqueues a request (one or more query rows) and returns a
    ``concurrent.futures.Future`` immediately;
  * a dispatcher coalesces whatever is pending into ONE transform call,
    padding the fused row count up to the SAME power-of-two buckets the
    compiled projection already serves (``_pow2_ceil`` — the single
    bucketing rule repo-wide), so continuous batching introduces **zero new
    compiled shapes**; the ragged tail is padding rows whose outputs are
    sliced off before scatter (they never reach a caller);
  * coalescing is DEADLINE-AWARE: each request carries an absolute deadline
    (``slo_ms``), and the dispatcher waits for more work only while the
    oldest deadline's slack — minus an EWMA estimate of the bucket's service
    time — allows it.  Under light load that slack is never used (the
    dispatcher is idle, the batch ships at once: request-at-a-time latency);
    under heavy load batches form while the previous batch is in flight,
    which is where the p99 win comes from (measured in
    benchmarks/serve_latency.py).

Hot-swap compatibility: the batch's transform reads the published snapshot
exactly once (swap.HotSwapServer.transform), and ``publish`` is a single
attribute store on the publisher's thread — a publish landing mid-batch
never blocks, and never tears an in-flight batch (it keeps the operator it
already read; the NEXT batch sees the new one).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.core.shadow import _pow2_ceil
from repro.obs import metrics as _om
from repro.obs.trace import span as _span
from repro.runtime import chaos
from repro.runtime.fault import RetryPolicy, retry_call

# serving metrics (DESIGN.md §16): created once at import, no-ops until
# obs.enable().  Per-bucket series use the pow2 bucket as the only label —
# bounded cardinality by construction.
_M_REQS = _om.counter("serve.requests")
_M_ROWS = _om.counter("serve.rows")
_M_BATCHES = _om.counter("serve.batches")
_M_ERRORS = _om.counter("serve.errors")
_M_QDEPTH = _om.gauge("serve.queue_depth")
_M_COALESCE = _om.histogram("serve.coalesce_rows", bounds=_om.SIZE_BUCKETS)
_M_SLACK = _om.histogram("serve.deadline_slack_ms")
# failure-path metrics (DESIGN.md §17): load shed at admission, dispatch
# retries that recovered, and batches served against a degraded snapshot.
_M_SHED = _om.counter("serve.shed")
_M_DEGRADED_BATCH = _om.counter("serve.degraded_batches")


class RequestShed(RuntimeError):
    """Admission control rejected the request: the queue was at
    ``max_queue`` when it arrived.  Delivered THROUGH the request's future
    (never raised at ``submit``), so shed and served requests flow through
    one code path on the caller side; a shed request was never queued and
    consumed no device time."""


class ServedRows(np.ndarray):
    """(k, r) result rows, optionally carrying serving metadata.

    ``info`` is a ``streaming.swap.SnapshotInfo`` when the batch was served
    DEGRADED (a failed publish left queries on the last good snapshot —
    ``info.staleness_bound`` is that snapshot's §5 error budget), else
    ``None``.  A plain ndarray subclass so every existing caller keeps
    working unchanged; only fault-aware callers look at ``.info``."""

    info = None  # class-level default: views/copies read as not-degraded

    @classmethod
    def _wrap(cls, z: np.ndarray, info) -> "ServedRows":
        out = z.view(cls)
        out.info = info
        return out

#: EWMA smoothing for the per-bucket service-time estimate.
_EWMA_ALPHA = 0.3
#: Safety margin subtracted from a deadline's slack before choosing to wait:
#: a relative cushion on the service estimate plus a scheduler-jitter floor.
_SLACK_REL = 0.25
_SLACK_ABS_S = 1e-3


@dataclasses.dataclass
class ServeStats:
    """Counters a bench/test can read (guarded by the front end's lock)."""

    requests: int = 0
    rows: int = 0
    batches: int = 0
    batched_rows: int = 0      # rows that shared a batch with another request
    full_dispatches: int = 0   # batches shipped because max_batch was hit
    max_batch_rows: int = 0
    shed: int = 0              # requests rejected at admission (max_queue)
    retries: int = 0           # transient dispatch faults absorbed in place
    degraded_batches: int = 0  # batches served against a stale snapshot
    ewma_service_s: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _Pending:
    x: np.ndarray        # (k, d) f32 query rows
    future: Future
    deadline: float      # absolute time.monotonic() deadline
    enqueued: float


class BatchingFrontEnd:
    """Deadline-aware continuous batching over a hot-swap transform.

    ``server`` needs a ``transform(x) -> (n, r) array`` method (normally a
    ``streaming.HotSwapServer``); anything else rides along untouched.
    ``max_batch`` caps fused rows per dispatch (one oversized request still
    ships, alone).  ``slo_ms`` is the default per-request latency target;
    ``min_wait_ms`` optionally floors the coalescing window (0 = ship as
    soon as the dispatcher is free — the right default, since batches form
    naturally while a previous batch occupies the device).

    ``autostart=False`` skips the dispatcher thread; tests then drive the
    queue deterministically with ``step()``/``drain()``.
    """

    def __init__(self, server, *, max_batch: int = 1024, slo_ms: float = 50.0,
                 min_wait_ms: float = 0.0, autostart: bool = True,
                 max_queue: int | None = None,
                 retry: RetryPolicy | None = None, guard=None):
        assert max_batch >= 1
        self.server = server
        self.max_batch = int(max_batch)
        self.slo_s = float(slo_ms) * 1e-3
        self.min_wait_s = float(min_wait_ms) * 1e-3
        #: admission bound (DESIGN.md §17): beyond ``max_queue`` pending
        #: requests, new arrivals SHED (RequestShed through their future)
        #: instead of queueing into certain SLO violation — bounded queue,
        #: bounded tail latency, and zero non-shed drops by construction.
        self.max_queue = None if max_queue is None else int(max_queue)
        #: transient-dispatch retry schedule; deadline-bounded per batch
        #: (never retries past the newest deadline in the batch).
        self.retry = RetryPolicy() if retry is None else retry
        #: optional runtime.PreemptionGuard: on SIGTERM the dispatcher
        #: closes admission and drains everything already queued.
        self._guard = guard
        self.stats = ServeStats()
        # per-bucket (histogram, gauge) handles, resolved once per bucket:
        # a registry lookup per dispatch (label-dict alloc + registry lock)
        # is exactly the kind of hot-path cost the <= 2% budget forbids
        self._obs_bucket: dict[int, tuple] = {}
        self._pending: list[_Pending] = []
        self._cond = threading.Condition()
        self._closed = False
        self._thread = None
        if autostart:
            self._thread = threading.Thread(
                target=self._loop, name="repro-serve-batcher", daemon=True)
            self._thread.start()

    # -- client side -------------------------------------------------------

    def submit(self, x, slo_ms: float | None = None) -> Future:
        """Enqueue a (k, d) or (d,) query; resolves to its (k, r) rows."""
        x = np.atleast_2d(np.asarray(x, np.float32))
        slo = self.slo_s if slo_ms is None else float(slo_ms) * 1e-3
        fut: Future = Future()
        now = time.monotonic()
        req = _Pending(x=x, future=fut, deadline=now + slo, enqueued=now)
        with self._cond:
            if self._closed:
                raise RuntimeError("submit() on a closed BatchingFrontEnd")
            if self.max_queue is not None \
                    and len(self._pending) >= self.max_queue:
                self.stats.shed += 1
                _M_SHED.inc()
                fut.set_exception(RequestShed(
                    f"queue at max_queue={self.max_queue}; request shed"))
                return fut
            self._pending.append(req)
            self.stats.requests += 1
            self.stats.rows += x.shape[0]
            _M_QDEPTH.set(len(self._pending))
            self._cond.notify_all()
        _M_REQS.inc()
        _M_ROWS.inc(x.shape[0])
        return fut

    def snapshot(self) -> ServeStats:
        """Consistent copy of the counters, taken under the front-end lock.

        ``stats`` itself is mutated by the dispatcher thread under the lock
        (``ewma_service_s`` in particular is updated per batch); reading its
        fields directly from another thread can observe a torn view — e.g.
        ``batches`` from before a dispatch with the EWMA from after it.
        Benches and monitors read THIS instead (benchmarks/serve_latency.py
        does)."""
        with self._cond:
            return dataclasses.replace(
                self.stats, ewma_service_s=dict(self.stats.ewma_service_s))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self) -> None:
        """Stop the dispatcher and serve everything still pending."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.drain()

    # -- dispatcher --------------------------------------------------------

    def _bucket(self, rows: int) -> int:
        return min(_pow2_ceil(max(1, rows)), _pow2_ceil(self.max_batch))

    def _estimate_s(self, rows: int) -> float:
        est = self.stats.ewma_service_s.get(self._bucket(rows))
        if est is None:
            # no measurement for this bucket yet: fall back to the largest
            # known estimate (pessimistic => dispatches earlier, never later)
            est = max(self.stats.ewma_service_s.values(), default=0.0)
        return est

    def _wait_s_locked(self, now: float) -> float:
        """Seconds the dispatcher may still wait for more work; <= 0 means
        dispatch now.  Never waits past the oldest deadline's slack."""
        if self._closed:
            return 0.0
        rows = sum(p.x.shape[0] for p in self._pending)
        if rows >= self.max_batch:
            return 0.0
        oldest = self._pending[0]
        est = self._estimate_s(rows)
        slack = (oldest.deadline - now) - est * (1.0 + _SLACK_REL) \
            - _SLACK_ABS_S
        window = self.min_wait_s - (now - oldest.enqueued)
        return min(window, slack)

    def _pop_batch_locked(self) -> list[_Pending]:
        """FIFO-coalesce whole requests up to max_batch rows (an oversized
        first request ships alone — transform chunks internally)."""
        batch, rows = [], 0
        if self._pending and _om.enabled():
            # slack left on the OLDEST deadline at dispatch: negative means
            # the request already blew its SLO before the batch even formed
            _M_SLACK.observe(
                (self._pending[0].deadline - time.monotonic()) * 1e3)
        while self._pending:
            nxt = self._pending[0].x.shape[0]
            if batch and rows + nxt > self.max_batch:
                break
            rows += nxt
            batch.append(self._pending.pop(0))
        if rows >= self.max_batch:
            self.stats.full_dispatches += 1
        _M_QDEPTH.set(len(self._pending))
        return batch

    def _serve(self, batch: list[_Pending]) -> None:
        """One fused transform for the whole batch + scatter to futures."""
        xs = np.concatenate([p.x for p in batch], axis=0)
        rows = xs.shape[0]
        bucket = self._bucket(rows)
        if rows < bucket:  # ragged tail: pad rows, mask on the way out
            xs = np.concatenate(
                [xs, np.zeros((bucket - rows, xs.shape[1]), xs.dtype)])
        t0 = time.monotonic()

        def dispatch():
            # the chaos site fires INSIDE the retried closure, before the
            # (idempotent: pure function of xs + snapshot) transform — a
            # transient here is absorbed by the backoff schedule, bounded
            # by the newest deadline in the batch so retries never burn
            # time no request can use
            chaos.inject("serve.dispatch")
            with _span("serve.batch", rows=rows, bucket=bucket,
                       requests=len(batch)):
                return np.asarray(self.server.transform(xs))[:rows]

        retries = [0]

        def _on_retry(attempt, exc):
            retries[0] = attempt

        try:
            z = retry_call(
                dispatch, policy=self.retry,
                deadline=max(p.deadline for p in batch),
                key=f"batch{self.stats.batches}", on_retry=_on_retry)
        except BaseException as e:  # noqa: BLE001 — every caller must learn
            _M_ERRORS.inc()
            for p in batch:
                p.future.set_exception(e)
            return
        finally:
            if retries[0]:
                with self._cond:
                    self.stats.retries += retries[0]
        dt = time.monotonic() - t0
        with self._cond:
            prev = self.stats.ewma_service_s.get(bucket)
            ewma = dt if prev is None \
                else _EWMA_ALPHA * dt + (1.0 - _EWMA_ALPHA) * prev
            self.stats.ewma_service_s[bucket] = ewma
            self.stats.batches += 1
            self.stats.max_batch_rows = max(self.stats.max_batch_rows, rows)
            if len(batch) > 1:
                self.stats.batched_rows += rows
        _M_BATCHES.inc()
        _M_COALESCE.observe(rows)
        if _om.enabled():  # per-bucket series: one histogram + one gauge
            handles = self._obs_bucket.get(bucket)
            if handles is None:
                handles = self._obs_bucket.setdefault(bucket, (
                    _om.histogram("serve.service_ms", {"bucket": bucket}),
                    _om.gauge("serve.ewma_service_ms", {"bucket": bucket})))
            handles[0].observe(dt * 1e3)
            handles[1].set(ewma * 1e3)
        info = None
        if getattr(self.server, "degraded", False):
            # stale-snapshot serving (failed publish): tag every response
            # in this batch with the SnapshotInfo carrying the §5
            # staleness error budget, so callers can price the answer
            info = self.server.degraded_info()
            with self._cond:
                self.stats.degraded_batches += 1
            _M_DEGRADED_BATCH.inc()
        off = 0
        for p in batch:
            k = p.x.shape[0]
            out = z[off : off + k]
            if info is not None:
                out = ServedRows._wrap(out, info)
            p.future.set_result(out)
            off += k

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._closed:
                    if self._guard is not None and self._guard.should_stop:
                        self._closed = True  # preemption: close admission
                        break
                    self._cond.wait(timeout=0.05 if self._guard else None)
                if self._guard is not None and self._guard.should_stop:
                    # drain mode: everything already admitted still serves
                    # (zero non-shed drops), nothing new gets in
                    self._closed = True
                if self._closed and not self._pending:
                    return
                wait = self._wait_s_locked(time.monotonic())
                if wait > 0:
                    self._cond.wait(timeout=wait)
                    continue  # re-evaluate: arrivals may have filled the batch
                batch = self._pop_batch_locked()
            if batch:
                self._serve(batch)

    # -- deterministic drivers (tests; close()) ----------------------------

    def step(self) -> int:
        """Serve ONE coalesced batch immediately, ignoring the coalescing
        window (deterministic test hook; use autostart=False).  Returns the
        number of real rows served (0 if nothing was pending)."""
        with self._cond:
            batch = self._pop_batch_locked()
        if not batch:
            return 0
        self._serve(batch)
        return sum(p.x.shape[0] for p in batch)

    def drain(self) -> int:
        """step() until the queue is empty; returns total rows served."""
        total = 0
        while True:
            served = self.step()
            if served == 0:
                return total
            total += served
