# Latency-SLO serving tier (DESIGN.md §8): a continuous-batching front end
# over the hot-swap transform — deadline-aware request coalescing into the
# power-of-two padding buckets the compiled projection already serves.
from repro.serving.batching import (  # noqa: F401
    BatchingFrontEnd, RequestShed, ServeStats, ServedRows,
)
