"""Deterministic, seed-keyed fault injection (DESIGN.md §17).

Every failure mode the fault-tolerance layer claims to survive must be
REPRODUCIBLE in CI, not just argued about.  This module provides the
injection harness: named injection sites threaded through the host-side
drivers (ingest feed, chunk generation, streaming merge, snapshot publish,
checkpoint I/O, serving dispatch) fire faults according to an installed
:class:`FaultPlan` — and fire the SAME faults on every run with the same
plan, because triggering is a pure function of ``(seed, site, call#)``.

Contract:

  * **zero-cost when disabled** — with no plan installed, :func:`inject`
    is one module-global load plus a ``None`` check (the same budget as a
    disabled obs metric; gated by ``bench-obs`` staying green on the
    instrumented paths).  :func:`corrupt` additionally returns its value
    untouched.
  * **host-side only** — injection sites live exclusively in host driver
    code, never inside jitted programs, so installing/uninstalling a plan
    can never retrace anything (compile-count asserted in
    tests/test_chaos.py).
  * **deterministic** — probabilistic faults hash ``(seed, site, call#)``
    through crc32, NOT Python's process-randomized ``hash``, so a plan
    reproduces bit-identically across processes (the subprocess crash
    tests rely on this).

Fault kinds:

  * ``"transient"`` — raises :class:`TransientFault`; the retry machinery
    in ``runtime.fault.retry_call`` recovers these (a flaky disk read, a
    preempted RPC).
  * ``"error"``     — raises :class:`InjectedFault`; permanent, retries
    must NOT absorb it (a poisoned input, an assertion).
  * ``"delay"``     — sleeps ``delay_s`` (a straggler feed, a slow disk);
    the watchdog/straggler machinery is what should notice.
  * ``"corrupt"``   — :func:`corrupt` returns a bit-flipped COPY of the
    payload (torn write, bad DMA); checksums downstream must catch it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
import zlib

import numpy as np

from repro.obs import metrics as _om

__all__ = [
    "FaultPlan", "FaultSpec", "InjectedFault", "TransientFault",
    "install", "uninstall", "active", "plan", "inject", "corrupt",
]

_M_INJECTED = _om.counter("chaos.injected")
_M_DELAY_MS = _om.histogram("chaos.delay_ms")


class InjectedFault(RuntimeError):
    """A fault fired by the harness.  Permanent: retries must re-raise."""

    def __init__(self, site: str, call: int, kind: str = "error"):
        super().__init__(f"injected {kind} fault at {site!r} (call {call})")
        self.site = site
        self.call = call
        self.kind = kind


class TransientFault(InjectedFault):
    """A retryable injected fault (``runtime.fault.retry_call`` absorbs
    these up to its policy's attempt/deadline limits)."""

    def __init__(self, site: str, call: int):
        super().__init__(site, call, kind="transient")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """When and how one site misbehaves.

    Triggering is the union of three schedules, all on the site's 1-based
    call counter: ``every`` fires on every nth call, ``at`` on the exact
    listed calls, ``p`` on a deterministic pseudo-coin keyed by
    ``(plan.seed, site, call#)``.  ``kind`` picks the failure mode (module
    docstring); ``delay_s`` is the sleep for ``"delay"`` faults.
    """

    kind: str = "transient"
    every: int | None = None
    at: tuple = ()
    p: float = 0.0
    delay_s: float = 0.0

    def __post_init__(self):
        assert self.kind in ("transient", "error", "delay", "corrupt"), \
            f"unknown fault kind {self.kind!r}"
        assert self.every is None or self.every >= 1

    def fires(self, seed: int, site: str, call: int) -> bool:
        if self.every is not None and call % self.every == 0:
            return True
        if call in self.at:
            return True
        if self.p > 0.0:
            # crc32 of the (seed, site, call) triple -> uniform in [0, 1):
            # stable across processes and platforms (unlike hash()).
            h = zlib.crc32(f"{seed}:{site}:{call}".encode())
            return (h / 2**32) < self.p
        return False


class FaultPlan:
    """A named-site -> fault-spec map with deterministic triggering.

    ``sites`` maps an injection-site name to one :class:`FaultSpec` or a
    list of them (first firing spec wins).  Per-site call and injection
    counts are kept under a lock (sites are hit from producer/dispatcher
    threads) and exposed via :meth:`stats` so tests and the chaos bench
    can assert exactly how many faults a run absorbed.
    """

    def __init__(self, sites: dict, seed: int = 0):
        self.seed = int(seed)
        self.sites: dict[str, tuple[FaultSpec, ...]] = {}
        for name, specs in sites.items():
            if isinstance(specs, FaultSpec):
                specs = (specs,)
            self.sites[name] = tuple(specs)
        self.calls: dict[str, int] = {}
        self.injected: dict[str, int] = {}
        self._lock = threading.Lock()

    def _fire(self, site: str):
        """Count the call; return ``(firing spec or None, call#)``."""
        specs = self.sites.get(site)
        with self._lock:
            call = self.calls.get(site, 0) + 1
            self.calls[site] = call
            if specs is None:
                return None, call
            for spec in specs:
                if spec.fires(self.seed, site, call):
                    self.injected[site] = self.injected.get(site, 0) + 1
                    return spec, call
        return None, call

    def stats(self) -> dict:
        with self._lock:
            return {"calls": dict(self.calls),
                    "injected": dict(self.injected),
                    "total_injected": sum(self.injected.values())}


#: The installed plan.  ``None`` (the default) short-circuits every
#: injection site to one global load + compare — the zero-cost contract.
_PLAN: FaultPlan | None = None


def install(p: FaultPlan) -> None:
    global _PLAN
    _PLAN = p


def uninstall() -> None:
    global _PLAN
    _PLAN = None


def plan() -> FaultPlan | None:
    return _PLAN


@contextlib.contextmanager
def active(p: FaultPlan):
    """Scope a plan: installs on entry, ALWAYS uninstalls on exit (so one
    failing chaos test cannot leak faults into the rest of the suite)."""
    install(p)
    try:
        yield p
    finally:
        uninstall()


def inject(site: str) -> None:
    """The injection point: raise/sleep per the installed plan.

    No plan -> returns immediately (one global load + None check).  Sites
    are plain string constants in host driver code; the jitted programs
    they bracket are never aware of the harness.
    """
    p = _PLAN
    if p is None:
        return
    spec, call = p._fire(site)
    if spec is None:
        return
    _M_INJECTED.inc()
    _om.counter("chaos.faults", {"site": site, "kind": spec.kind}).inc()
    if spec.kind == "delay":
        _M_DELAY_MS.observe(spec.delay_s * 1e3)
        time.sleep(spec.delay_s)
        return
    if spec.kind == "corrupt":
        return  # corruption applies to payloads: see corrupt()
    if spec.kind == "transient":
        raise TransientFault(site, call)
    raise InjectedFault(site, call)


def corrupt(site: str, value: np.ndarray) -> np.ndarray:
    """Payload-corrupting injection point: returns ``value`` untouched
    unless a ``"corrupt"`` spec fires, in which case a COPY with one bit
    flipped per 4KiB page comes back (a torn write / bad DMA model —
    checksums downstream are expected to catch it, see checkpoint/store).
    Non-corrupt specs at the same site behave exactly like :func:`inject`.
    """
    p = _PLAN
    if p is None:
        return value
    spec, call = p._fire(site)
    if spec is None:
        return value
    _M_INJECTED.inc()
    _om.counter("chaos.faults", {"site": site, "kind": spec.kind}).inc()
    if spec.kind == "delay":
        _M_DELAY_MS.observe(spec.delay_s * 1e3)
        time.sleep(spec.delay_s)
        return value
    if spec.kind == "transient":
        raise TransientFault(site, call)
    if spec.kind == "error":
        raise InjectedFault(site, call)
    out = np.array(value, copy=True)
    raw = out.view(np.uint8).reshape(-1)
    # one deterministic bit flip per 4KiB page, position keyed like fires()
    for page in range(0, raw.size, 4096):
        h = zlib.crc32(f"{p.seed}:{site}:{call}:{page}".encode())
        raw[page + h % min(4096, raw.size - page)] ^= 1 << (h >> 29)
    return out
