"""Fault-tolerance runtime: preemption, stragglers, elastic re-mesh.

At 1000+ node scale the failure model is: (a) SIGTERM preemptions with a
grace window, (b) slow/hung hosts (stragglers), (c) permanent node loss that
requires restarting on a different device count.  The pieces here are
host-side and framework-agnostic; the distributed decisions they trigger
(checkpoint now, skip ahead, re-lower) live in launch/train.py.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from collections import deque
from typing import Callable


class PreemptionGuard:
    """SIGTERM/SIGINT -> cooperative shutdown flag.

    The train loop polls ``should_stop`` each step and performs a final
    synchronous checkpoint inside the grace window instead of dying mid-step.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = threading.Event()
        self._prev = {}
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:  # not the main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._stop.set()

    def request_stop(self):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()


class StepWatchdog:
    """Step-time tracker with straggler detection.

    Keeps a rolling window of step durations; a step slower than
    ``threshold x median`` is flagged.  On real pods the flag feeds the
    controller, which can (1) exclude the slow host from the next data
    assignment (we reshard the batch: see ElasticPlan) or (2) trigger an
    early checkpoint.  Here it also powers the straggler-mitigation test.
    """

    def __init__(self, window: int = 32, threshold: float = 3.0,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.durations: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.flags: list[tuple[int, float]] = []
        self._t0: float | None = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        if len(self.durations) >= 8:
            med = sorted(self.durations)[len(self.durations) // 2]
            if dt > self.threshold * med:
                self.flags.append((step, dt))
                if self.on_straggler:
                    self.on_straggler(step, dt)
        self.durations.append(dt)
        return dt

    @property
    def median(self) -> float | None:
        if not self.durations:
            return None
        return sorted(self.durations)[len(self.durations) // 2]


@dataclasses.dataclass
class ElasticPlan:
    """Describes how to resume on a different device count.

    The checkpoint format is sharding-agnostic (checkpoint/store.py), so
    elasticity is: build the new mesh, recompute shardings from the SAME
    logical rules, restore, and fast-forward the data stream (stateless
    by-step indexing makes that a no-op).  ``batch_policy`` decides whether
    the global batch is preserved (grad-accum increases) or scaled down.
    """

    old_devices: int
    new_devices: int
    batch_policy: str = "preserve_global"  # or "scale_with_devices"

    def microbatch_factor(self, old_accum: int) -> int:
        if self.batch_policy == "scale_with_devices":
            return old_accum
        # preserve global batch: accumulate more on fewer devices
        assert self.old_devices % self.new_devices == 0 or \
            self.new_devices % self.old_devices == 0, \
            "elastic resize must be by an integer factor"
        if self.new_devices < self.old_devices:
            return old_accum * (self.old_devices // self.new_devices)
        return max(1, old_accum // (self.new_devices // self.old_devices))
