"""Fault-tolerance runtime: preemption, retries, stragglers, elastic re-mesh.

At 1000+ node scale the failure model is: (a) SIGTERM preemptions with a
grace window, (b) transient I/O and dispatch faults that a bounded retry
absorbs, (c) slow/hung hosts (stragglers), (d) permanent node loss that
requires restarting on a different device count.  The pieces here are
host-side and framework-agnostic; the distributed decisions they trigger
(checkpoint now, skip ahead, re-lower) live in the ingest/serving drivers
and launch/train.py.  DESIGN.md §17 maps each primitive onto the
ingest/serving failure model; runtime/chaos.py makes every mode
reproducible in CI.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
import zlib
from collections import deque
from typing import Callable

from repro.obs import metrics as _om
from repro.runtime.chaos import TransientFault

_M_RETRIES = _om.counter("fault.retries")
_M_RECOVERED = _om.counter("fault.recovered")
_M_GIVEUPS = _om.counter("fault.giveups")


class Preempted(RuntimeError):
    """Raised by a drain-aware loop that stopped cleanly on SIGTERM after
    persisting its state; ``step`` is the checkpoint the resume starts
    from (None when the loop had nothing durable to save)."""

    def __init__(self, message: str, step: int | None = None):
        super().__init__(message)
        self.step = step


class PreemptionGuard:
    """SIGTERM/SIGINT -> cooperative shutdown flag.

    The ingest/serving/train loops poll ``should_stop`` each step and
    perform a final synchronous checkpoint/drain inside the grace window
    instead of dying mid-step.  Guards NEST: ``uninstall()`` (or leaving
    the ``with`` block) restores the exact handlers it displaced, so a
    guard embedded in a library call cannot clobber the caller's — the
    restore is LIFO like the installs (tested in tests/test_runtime.py).
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = threading.Event()
        self._prev = {}
        self._installed = False
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
                self._installed = True
            except ValueError:  # not the main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._stop.set()

    def request_stop(self):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def uninstall(self) -> None:
        """Restore the handlers this guard displaced (idempotent).  A
        pending stop flag survives — uninstalling stops LISTENING, it does
        not un-ring the bell."""
        if not self._installed:
            return
        for sig, prev in self._prev.items():
            try:
                signal.signal(sig, prev)
            except ValueError:  # not the main thread anymore
                pass
        self._installed = False

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.uninstall()


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded jittered-exponential-backoff schedule.

    Attempt k (0-based retry count) sleeps
    ``min(base_s * factor**k, max_s) * (1 + jitter * u)`` with ``u`` a
    DETERMINISTIC pseudo-uniform in [0, 1) keyed by ``(seed, key, k)`` —
    retries de-synchronize across callers (no thundering herd) yet replay
    bit-identically under a chaos plan.  ``max_attempts`` counts total
    tries, so ``max_attempts=1`` means no retry at all.
    """

    max_attempts: int = 4
    base_s: float = 0.01
    factor: float = 2.0
    max_s: float = 1.0
    jitter: float = 0.5
    seed: int = 0

    def backoff_s(self, k: int, key: str = "") -> float:
        base = min(self.base_s * self.factor**k, self.max_s)
        h = zlib.crc32(f"{self.seed}:{key}:{k}".encode()) / 2**32
        return base * (1.0 + self.jitter * h)


def retry_call(fn: Callable, *args, policy: RetryPolicy | None = None,
               retry_on: tuple = (TransientFault,), deadline: float | None = None,
               key: str = "", on_retry: Callable | None = None, **kw):
    """Call ``fn`` with bounded retries on transient faults.

    Retries only exceptions in ``retry_on`` (everything else propagates on
    the first throw); honors an absolute ``deadline`` (``time.monotonic``
    seconds) — a retry whose backoff would land past the deadline is not
    attempted, the last transient error re-raises instead.  ``fn`` must be
    safe to re-run (the call sites wrap pure chunk generation / staging /
    idempotent transforms, never partially-applied mutations).
    ``on_retry(attempt, exc)`` observes each recovery (tests count them).
    """
    policy = RetryPolicy() if policy is None else policy
    attempt = 0
    while True:
        try:
            out = fn(*args, **kw)
            if attempt:
                _M_RECOVERED.inc()
            return out
        except retry_on as e:
            attempt += 1
            if attempt >= policy.max_attempts:
                _M_GIVEUPS.inc()
                raise
            pause = policy.backoff_s(attempt - 1, key)
            if deadline is not None \
                    and time.monotonic() + pause > deadline:
                _M_GIVEUPS.inc()
                raise
            _M_RETRIES.inc()
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(pause)


class StepWatchdog:
    """Step-time tracker with straggler detection.

    Keeps a rolling window of step durations; a step slower than
    ``threshold x median`` is flagged.  On real pods the flag feeds the
    controller, which can (1) exclude the slow host from the next data
    assignment (we reshard the batch: see ElasticPlan) or (2) trigger an
    early checkpoint.  Here it also powers the straggler-mitigation test.
    """

    def __init__(self, window: int = 32, threshold: float = 3.0,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.durations: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.flags: list[tuple[int, float]] = []
        self._t0: float | None = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        if len(self.durations) >= 8:
            med = sorted(self.durations)[len(self.durations) // 2]
            if dt > self.threshold * med:
                self.flags.append((step, dt))
                if self.on_straggler:
                    self.on_straggler(step, dt)
        self.durations.append(dt)
        return dt

    @property
    def median(self) -> float | None:
        if not self.durations:
            return None
        return sorted(self.durations)[len(self.durations) // 2]


@dataclasses.dataclass
class ElasticPlan:
    """Describes how to resume on a different device count.

    The checkpoint format is sharding-agnostic (checkpoint/store.py), so
    elasticity is: build the new mesh, recompute shardings from the SAME
    logical rules, restore, and fast-forward the data stream (stateless
    by-step indexing makes that a no-op).  ``batch_policy`` decides whether
    the global batch is preserved (grad-accum increases) or scaled down.
    """

    old_devices: int
    new_devices: int
    batch_policy: str = "preserve_global"  # or "scale_with_devices"

    def microbatch_factor(self, old_accum: int) -> int:
        if self.batch_policy == "scale_with_devices":
            return old_accum
        # preserve global batch: accumulate more on fewer devices
        assert self.old_devices % self.new_devices == 0 or \
            self.new_devices % self.old_devices == 0, \
            "elastic resize must be by an integer factor"
        if self.new_devices < self.old_devices:
            return old_accum * (self.old_devices // self.new_devices)
        return max(1, old_accum // (self.new_devices // self.old_devices))
