from repro.runtime.fault import (  # noqa: F401
    PreemptionGuard, StepWatchdog, ElasticPlan, Preempted, RetryPolicy,
    retry_call,
)
from repro.runtime import chaos  # noqa: F401
from repro.runtime.chaos import (  # noqa: F401
    FaultPlan, FaultSpec, InjectedFault, TransientFault,
)
