from repro.runtime.fault import (  # noqa: F401
    PreemptionGuard, StepWatchdog, ElasticPlan,
)
