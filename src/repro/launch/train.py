"""Production training driver.

Wires together every substrate: sharded step functions (launch/steps.py),
deterministic stateless data (data/tokens.py), checkpoint/restart
(checkpoint/), preemption + straggler watchdog (runtime/), and the paper's
RSKPCA activation probe (core/probe.py) as a first-class monitoring feature.

On this CPU container it runs smoke-scale configs on a host-device mesh; the
same code lowers for the production pod meshes (launch/dryrun.py proves it).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import api
from repro.models.config import ArchConfig
from repro.data.tokens import TokenPipeline
from repro.launch import steps, sharding as shd
from repro.launch.mesh import smoke_mesh
from repro.checkpoint import AsyncCheckpointer, restore_checkpoint, latest_step
from repro.runtime import PreemptionGuard, StepWatchdog
from repro.core.probe import RSKPCAProbe


@dataclasses.dataclass
class TrainRun:
    cfg: ArchConfig
    global_batch: int = 8
    seq_len: int = 64
    steps: int = 20
    accum: int = 1
    lr: float = 3e-4
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 10
    probe_every: int = 0       # 0 disables the RSKPCA probe
    probe_rank: int = 4


def run(tr: TrainRun, mesh=None, resume: bool = True, max_steps=None):
    cfg = tr.cfg
    mesh = mesh or smoke_mesh()
    guard = PreemptionGuard()
    watchdog = StepWatchdog()
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=tr.seq_len,
                         global_batch=tr.global_batch, seed=tr.seed)

    params_spec = api.param_specs(cfg)
    p_sh = shd.param_shardings(params_spec, mesh, cfg)
    opt_spec = steps.opt_specs(cfg, params_spec)
    o_sh = shd.opt_shardings(opt_spec, params_spec, mesh, cfg)

    start_step = 0
    if tr.ckpt_dir and resume and latest_step(tr.ckpt_dir) is not None:
        (params, opt_state), start_step = restore_checkpoint(
            tr.ckpt_dir, (params_spec, opt_spec), shardings=(p_sh, o_sh))
        print(f"[train] restored checkpoint at step {start_step}")
    else:
        with mesh:
            params = jax.jit(
                lambda k: api.init_params(k, cfg), out_shardings=p_sh
            )(jax.random.PRNGKey(tr.seed))
            opt_state = jax.jit(
                lambda p: steps.init_opt(cfg, p), out_shardings=o_sh
            )(params)

    step_fn = steps.make_train_step(cfg, mesh, accum=tr.accum, lr=tr.lr,
                                    remat=True)
    batch_spec = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                  for k, v in pipe.batch(0).items()}
    b_sh = shd.batch_shardings(batch_spec, mesh)
    jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh,
                                            NamedSharding(mesh, P())),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))

    ckpt = AsyncCheckpointer(tr.ckpt_dir) if tr.ckpt_dir else None
    probe = (RSKPCAProbe(dim=cfg.d_model, rank=tr.probe_rank,
                         period=tr.probe_every)
             if tr.probe_every else None)
    hidden_fn = None
    if probe is not None:
        def pooled_hidden(params, batch):
            from repro.models import transformer
            x = transformer.embed_tokens(params, batch["tokens"], cfg)
            h, _ = transformer.backbone_forward(params, x, cfg, remat=False)
            return h.mean(axis=1)  # (B, D) pooled
        hidden_fn = jax.jit(pooled_hidden)

    history = []
    end = min(tr.steps, max_steps or tr.steps)
    for step in range(start_step, end):
        if guard.should_stop:
            print(f"[train] preempted at step {step}; final checkpoint")
            break
        watchdog.start()
        batch = pipe.batch(step)
        with mesh:
            params, opt_state, metrics = jitted(
                params, opt_state, batch, jnp.int32(step))
        loss = float(metrics["loss"])
        dt = watchdog.stop(step)
        history.append({"step": step, "loss": loss, "time_s": dt})
        if probe is not None and hidden_fn is not None:
            with mesh:
                probe.observe(np.asarray(hidden_fn(params, batch)))
            rep = probe.maybe_probe(step)
            if rep:
                print(" ", rep.summary())
        if step % 5 == 0 or step == end - 1:
            print(f"[train {cfg.name}] step {step} loss {loss:.4f} "
                  f"({dt*1e3:.0f} ms)")
        if ckpt and (step + 1) % tr.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state))
    if ckpt:
        ckpt.wait()
        final_step = len(history) + start_step
        if latest_step(tr.ckpt_dir) != final_step:  # skip redundant re-save
            from repro.checkpoint import save_checkpoint
            save_checkpoint(tr.ckpt_dir, final_step, (params, opt_state))
    return params, opt_state, history, {"straggler_flags": watchdog.flags,
                                         "probe": probe}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--probe-every", type=int, default=0)
    args = ap.parse_args()
    cfg = get_config(args.arch, smoke=args.smoke)
    tr = TrainRun(cfg=cfg, steps=args.steps, global_batch=args.batch,
                  seq_len=args.seq, accum=args.accum, ckpt_dir=args.ckpt_dir,
                  probe_every=args.probe_every)
    _, _, history, _ = run(tr)
    print(f"[train] done: loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f} over {len(history)} steps")


if __name__ == "__main__":
    main()
