"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis composes
with 'data' for batch/FSDP sharding (DCN-connected in production, so only
gradient/FSDP traffic crosses pods — attention/MoE TP stays intra-pod).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """The composed batch/FSDP axes for this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def smoke_mesh(n: int | None = None, with_model: bool = False):
    """Host-device mesh for tests (requires xla_force_host_platform_device_count)."""
    n = n or len(jax.devices())
    if with_model and n >= 4:
        return make_mesh((n // 2, 2), ("data", "model"))
    return make_mesh((n,), ("data",))


def data_mesh(ndev: int | None = None):
    """1-D ('data',) mesh over all (or the first ``ndev``) devices — the axis
    the sharded RSKPCA fit/transform path shards rows over (DESIGN.md §5).
    Works identically on a single device, so ``fit(..., mesh=data_mesh())``
    is always safe."""
    devices = jax.devices()
    ndev = ndev or len(devices)
    return make_mesh((ndev,), ("data",))
