import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_disable_hlo_passes=all-reduce-promotion")

# --------------------------------------------------------------------------
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
# ShapeDtypeStruct stand-ins (no allocation), record memory/cost analysis and
# the collective schedule parsed from the partitioned HLO.
#
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.
# --------------------------------------------------------------------------
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Map computation name -> its instruction lines."""
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
        if m:
            current = m.group(1)
            comps[current] = []
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is not None:
            comps[current].append(line.strip())
    return comps


_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _loop_multipliers(comps: dict[str, list[str]]) -> dict[str, int]:
    """Execution count of each computation, via the while-nesting tree.

    Trip counts are read from the loop-condition computation (the bound
    appears as ``constant(N)`` in the counter comparison).  Scan-lowered
    loops always carry that literal; if no constant is found we fall back
    to 1 (conservative).
    """
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name or name == "main":
            entry = name
    if entry is None:  # last computation printed is ENTRY by convention
        entry = list(comps)[-1]
    mult = {name: 0 for name in comps}
    mult[entry] = 1
    # propagate to fixpoint (nesting depth is small)
    for _ in range(12):
        new = {name: 0 for name in comps}
        new[entry] = 1
        for name, lines in comps.items():
            if mult.get(name, 0) == 0:
                continue
            for line in lines:
                m = _WHILE_RE.search(line)
                if not m:
                    continue
                cond, body = m.group(1), m.group(2)
                consts = [int(c) for c in _CONST_RE.findall(
                    "\n".join(comps.get(cond, [])))]
                trip = max(consts) if consts else 1
                new[body] = new.get(body, 0) + mult[name] * trip
                new[cond] = new.get(cond, 0) + mult[name] * trip
        if new == mult:
            break
        mult = new
    return mult


def parse_collectives(hlo_text: str) -> dict:
    """Loop-aware per-op-kind byte totals from the partitioned HLO.

    XLA prints (and costs) while bodies ONCE; scan-lowered loops execute them
    trip-count times.  We attribute each collective to its computation and
    scale by the computation's execution count (``_loop_multipliers``).

    Byte model (per device, documented in EXPERIMENTS.md §Roofline):
      all-gather          -> output bytes          (ring receive volume)
      all-reduce          -> 2 x output bytes      (reduce-scatter + all-gather)
      reduce-scatter      -> operand bytes
      all-to-all          -> output bytes
      collective-permute  -> output bytes
    """
    comps = _split_computations(hlo_text)
    mult = _loop_multipliers(comps)
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    static_counts = {k: 0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        scale = mult.get(name, 0)
        if scale == 0:
            continue
        for stripped in lines:
            kind = None
            for c in _COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", stripped):
                    kind = c
                    break
            if kind is None:
                continue
            shapes = _SHAPE_RE.findall(stripped)
            if not shapes:
                continue
            out_dtype, out_dims = shapes[0]
            out_b = _shape_bytes(out_dtype, out_dims)
            operand_b = sum(_shape_bytes(d, s) for d, s in shapes[1:]) or out_b
            if kind == "all-gather":
                b = out_b
            elif kind == "all-reduce":
                b = 2 * out_b
            elif kind == "reduce-scatter":
                b = operand_b
            else:
                b = out_b
            totals[kind] += b * scale
            counts[kind] += scale
            static_counts[kind] += 1
    return {"bytes": totals, "counts": counts,
            "static_counts": static_counts,
            "total_bytes": sum(totals.values())}


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        out = {}
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            if hasattr(ma, field):
                out[field] = int(getattr(ma, field))
        out["repr"] = str(ma)
        return out
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             verbose: bool = True, variant: str = "baseline") -> dict:
    import jax
    from repro.configs import get_config
    from repro.models import api
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps
    from repro.launch.flops import (
        model_flops, active_params, total_params,
        executed_flops_per_device, executed_hbm_bytes_per_device,
    )

    cfg = get_config(arch)
    shape = api.SHAPES[shape_name]
    ok, why = api.shape_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = "" if variant == "baseline" else f"__{variant}"
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "status": "skip" if not ok else "pending",
        "skip_reason": why, "variant": variant,
    }
    if not ok:
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                    out_dir,
                    f"{arch}__{shape_name}__{mesh_name}{suffix}.json"),
                    "w") as f:
                json.dump(record, f, indent=1)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    if variant == "optimized":
        lowered, info = steps.lower_cell_opt(cfg, shape, mesh)
    else:
        lowered, info = steps.lower_cell(cfg, shape, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = _memory_dict(compiled)
    cost = _cost_dict(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = parse_collectives(hlo)
    mf = model_flops(cfg, shape)
    mesh_shape = dict(mesh.shape)
    ex_flops = executed_flops_per_device(cfg, shape, mesh_shape,
                                         variant=variant)
    ex_bytes = executed_hbm_bytes_per_device(cfg, shape, mesh_shape,
                                             accum=info.get("accum", 1),
                                             variant=variant)

    # --- roofline terms (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s link,
    #     3 usable ICI links per chip on a 2D torus direction pair) ---
    PEAK_FLOPS, HBM_BW, LINK_BW, LINKS = 197e12, 819e9, 50e9, 3.0
    compute_s = ex_flops["per_device_total"] / PEAK_FLOPS
    memory_s = ex_bytes["total"] / HBM_BW
    collective_s = coll["total_bytes"] / (LINK_BW * LINKS)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mfu = (mf / n_dev / PEAK_FLOPS) / step_s if step_s > 0 else 0.0

    record.update({
        "status": "ok",
        "n_devices": int(n_dev),
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": mem,
        "cost": cost,
        "collectives": coll,
        "model_flops_total": mf,
        "active_params": active_params(cfg),
        "total_params": total_params(cfg),
        "executed_flops": ex_flops,
        "executed_bytes": ex_bytes,
        "roofline": {**terms, "dominant": dominant,
                     "roofline_step_s": step_s, "model_mfu_bound": mfu,
                     "useful_ratio": mf / max(ex_flops["executed_total"], 1.0)},
        "hlo_bytes": len(hlo),
        **info,
    })
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"lower={record['lower_s']}s compile={record['compile_s']}s")
        print("  memory_analysis:", mem.get("repr", mem))
        flops = cost.get("flops", float("nan"))
        print(f"  cost_analysis(raw, loops-once): flops/device={flops:.3e} "
              f"bytes={cost.get('bytes accessed', float('nan')):.3e}")
        print(f"  executed: flops/dev={ex_flops['per_device_total']:.3e} "
              f"hbm_bytes/dev={ex_bytes['total']:.3e}")
        print(f"  collectives(loop-scaled): {coll['counts']} "
              f"total={coll['total_bytes']:.3e} B")
        print(f"  roofline: compute={compute_s*1e3:.2f}ms "
              f"memory={memory_s*1e3:.2f}ms coll={collective_s*1e3:.2f}ms "
              f"dominant={dominant} mfu_bound={mfu:.3f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def _cell_done(out_dir, arch, shape, mesh_name):
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            return json.load(f).get("status") in ("ok", "skip")
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell for this mesh "
                         "in subprocesses (resumable)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()

    if args.all:
        from repro.configs import ARCH_IDS
        from repro.models.api import SHAPES
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        failures = []
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            for arch in ARCH_IDS:
                for shape in SHAPES:
                    if _cell_done(args.out, arch, shape, mesh_name):
                        print(f"[cached] {arch} x {shape} x {mesh_name}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--out", args.out]
                    if mp:
                        cmd.append("--multi-pod")
                    print(">>", " ".join(cmd), flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh_name))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("ALL CELLS OK")
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                       variant=args.variant)
        if rec["status"] == "skip":
            print(f"[skip] {args.arch} x {args.shape}: {rec['skip_reason']}")
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
