"""Step builders: train / prefill / decode, with full sharding annotations.

``build_*`` return (jitted_fn, in_shardings, arg_specs) so both the real
launcher (train.py / serve.py) and the dry-run (dryrun.py) use the SAME
partitioned programs — the dry-run lowers exactly what production runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.models import api
from repro.models.config import ArchConfig
from repro.models.sharding_hooks import use_sharder
from repro.launch import sharding as shd
from repro.optim import adamw_init, adamw_update
from repro.optim.adafactor import adafactor_init, adafactor_update

Array = jax.Array


def _reshape_microbatches(batch, accum: int):
    def one(x):
        if x.ndim == 0:
            return x
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape((accum, b // accum) + x.shape[1:])
    return jax.tree.map(one, batch)


def make_train_step(cfg: ArchConfig, mesh, *, accum: int = 1,
                    lr: float = 3e-4, remat: bool = True):
    """Returns train_step(params, opt_state, batch, step)->(params, opt, metrics).

    Gradient accumulation via lax.scan over ``accum`` microbatches; optimizer
    per cfg.optimizer (adamw | adafactor).
    """
    sharder = shd.make_activation_sharder(mesh, cfg)
    use_adafactor = cfg.optimizer == "adafactor"

    def train_step(params, opt_state, batch, step):
        with use_sharder(sharder):
            mb = _reshape_microbatches(batch, accum)

            def micro(carry, b1):
                g_acc, l_acc = carry
                (loss, _), grads = jax.value_and_grad(
                    lambda p: api.loss_fn(p, b1, cfg, remat=remat),
                    has_aux=True)(params)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum

            if use_adafactor:
                new_params, new_opt, om = adafactor_update(
                    grads, opt_state, params, lr=lr)
                om = dict(om)
            else:
                new_params, new_opt, om = adamw_update(
                    grads, opt_state, params, lr=lr)
            metrics = {"loss": loss, **om, "step": step + 1}
        return new_params, new_opt, metrics

    return train_step


def init_opt(cfg: ArchConfig, params):
    return (adafactor_init(params) if cfg.optimizer == "adafactor"
            else adamw_init(params))


def opt_specs(cfg: ArchConfig, params_spec):
    return jax.eval_shape(lambda p: init_opt(cfg, p), params_spec)


def make_prefill_step(cfg: ArchConfig, mesh):
    sharder = shd.make_activation_sharder(mesh, cfg)

    def prefill_step(params, batch):
        with use_sharder(sharder):
            return api.prefill_logits(params, batch, cfg)

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh):
    sharder = shd.make_activation_sharder(mesh, cfg)

    def serve_step(params, cache, token, pos):
        with use_sharder(sharder):
            return api.decode_step(params, cache, token, pos, cfg)

    return serve_step


# -------------------------------------------------------------- lowering ---

def lower_train(cfg: ArchConfig, shape: api.ShapeSpec, mesh, *,
                accum: int | None = None, lr: float = 3e-4,
                donate: bool = True):
    """Lower the production train_step for (cfg x shape) on ``mesh``."""
    dp = 1
    for a in shd._fsdp_axes(mesh):
        dp *= mesh.shape[a]
    accum = accum or max(1, shape.global_batch // dp)
    params_spec = api.param_specs(cfg)
    opt_spec = opt_specs(cfg, params_spec)
    batch_spec = api.input_specs(cfg, shape)

    p_sh = shd.param_shardings(params_spec, mesh, cfg)
    o_sh = shd.opt_shardings(opt_spec, params_spec, mesh, cfg)
    b_sh = shd.batch_shardings(batch_spec, mesh)
    s_sh = NamedSharding(mesh, P())

    step_fn = make_train_step(cfg, mesh, accum=accum, lr=lr)
    jitted = jax.jit(
        step_fn,
        in_shardings=(p_sh, o_sh, b_sh, s_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    args = (params_spec, opt_spec, batch_spec,
            jax.ShapeDtypeStruct((), jnp.int32))
    with mesh:
        lowered = jitted.lower(*args)
    return lowered, {"accum": accum}


def lower_prefill(cfg: ArchConfig, shape: api.ShapeSpec, mesh):
    params_spec = api.param_specs(cfg)
    batch_spec = api.input_specs(cfg, shape)
    p_sh = shd.param_shardings(params_spec, mesh, cfg)
    b_sh = shd.batch_shardings(batch_spec, mesh)
    jitted = jax.jit(make_prefill_step(cfg, mesh),
                     in_shardings=(p_sh, b_sh))
    with mesh:
        lowered = jitted.lower(params_spec, batch_spec)
    return lowered, {}


def lower_decode(cfg: ArchConfig, shape: api.ShapeSpec, mesh):
    params_spec = api.param_specs(cfg)
    cache_spec = api.cache_specs(cfg, shape.global_batch, shape.seq_len)
    tok_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)

    p_sh = shd.param_shardings(params_spec, mesh, cfg)
    c_sh = shd.cache_shardings(cache_spec, mesh, cfg)
    t_sh = NamedSharding(
        mesh, P("data" if shape.global_batch % mesh.shape["data"] == 0
                else None, None))
    jitted = jax.jit(
        make_decode_step(cfg, mesh),
        in_shardings=(p_sh, c_sh, t_sh, NamedSharding(mesh, P())),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )
    with mesh:
        lowered = jitted.lower(params_spec, cache_spec, tok_spec, pos_spec)
    return lowered, {}


def lower_cell(cfg: ArchConfig, shape: api.ShapeSpec, mesh, **kw):
    if shape.kind == "train":
        return lower_train(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return lower_prefill(cfg, shape, mesh)
    return lower_decode(cfg, shape, mesh)


# ===========================================================================
# OPTIMIZED variant (EXPERIMENTS.md §Perf): ZeRO-1 deferred grad reduction
# (one bf16 reduce-scatter per step instead of `accum` f32 all-reduces),
# per-step weight gather (instead of per-microstep FSDP gathers), 2D-resident
# expert weights, and sequence-parallel attention for narrow-head archs.
# ===========================================================================

def _is_expert_leaf(path_str: str) -> bool:
    import re
    return bool(re.search(r"moe.*w_(in|gate|out)$", path_str))


def _moe_2d_active(cfg, mesh) -> bool:
    """D-over-data resident experts pay an h/g psum O(C*F) and an out a2a
    O(T*D); worth it only when the expert hidden F is small relative to
    d_model (kimi: F=2048 << D=7168).  For wide experts (jamba/mixtral
    F=14336) TP-inside-the-expert moves O(C*D) instead — cheaper."""
    import numpy as _np
    dp = int(_np.prod([mesh.shape[a] for a in shd._fsdp_axes(mesh)]))
    return bool(cfg.num_experts) and cfg.num_experts % mesh.shape["model"] \
        == 0 and cfg.d_model % dp == 0 and \
        (cfg.moe_d_ff or cfg.d_ff) <= cfg.d_model


def master_shardings_opt(params_spec, mesh, cfg):
    """Masters/opt-state: baseline FSDP+TP for non-experts (ZeRO-1 keeps
    optimizer state sharded over data), 2D-resident layout for experts."""
    moe_2d = _moe_2d_active(cfg, mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec)
    out = []
    for path, leaf in flat:
        ps = shd._path_str(path)
        if _is_expert_leaf(ps) and moe_2d:
            # 2D-resident experts: the master IS the compute layout
            spec = shd.param_spec_for_opt(ps, leaf.shape, mesh, cfg)
        else:
            spec = shd.param_spec_for(ps, leaf.shape, mesh, cfg)
        out.append(jax.sharding.NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _grad_reduce_plan(params_spec, mesh, cfg):
    """Per-leaf plan: ('local', None) experts — complete local grads;
    ('scatter', dim) — psum_scatter along the master's fsdp dim;
    ('psum', None) — small replicated leaves."""
    fsdp = set(shd._fsdp_axes(mesh))
    moe_2d = _moe_2d_active(cfg, mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec)
    plans = []
    for path, leaf in flat:
        ps = shd._path_str(path)
        if _is_expert_leaf(ps) and moe_2d:
            plans.append(("local", None))
            continue
        spec = shd.param_spec_for(ps, leaf.shape, mesh, cfg)
        dim = None
        for i, part in enumerate(tuple(spec)):
            parts = part if isinstance(part, (tuple, list)) else (part,)
            if any(a in fsdp for a in parts if a):
                dim = i
                break
        plans.append(("scatter", dim) if dim is not None else ("psum", None))
    return jax.tree_util.tree_unflatten(treedef, plans)


def make_train_step_opt(cfg: ArchConfig, mesh, *, accum: int = 1,
                        lr: float = 3e-4, remat: bool = True,
                        grad_dtype=jnp.bfloat16):
    dp_axes = shd._fsdp_axes(mesh)
    params_spec = api.param_specs(cfg)
    compute_sh = shd.param_shardings_opt(params_spec, mesh, cfg)
    manual_p_specs = shd.manual_in_specs(params_spec, mesh, cfg)
    plan = _grad_reduce_plan(params_spec, mesh, cfg)
    sharder_in = shd.make_activation_sharder_opt(mesh, cfg)
    use_adafactor = cfg.optimizer == "adafactor"

    is_plan = lambda x: isinstance(x, tuple) and len(x) == 2 and \
        isinstance(x[0], str)

    def grad_out_specs(batch_spec):
        def one(pspec, pl):
            kind, dim = pl
            if kind == "local":
                return pspec  # expert grads stay data-sharded (complete)
            if kind == "scatter":
                parts = [None] * dim + [dp_axes]
                return jax.sharding.PartitionSpec(*parts)
            return jax.sharding.PartitionSpec()
        return jax.tree.map(one, manual_p_specs, plan, is_leaf=None)

    def train_step(master, opt_state, batch, step):
        # per-step gather: bf16 compute params in the TP-resident layout
        params_c = jax.tree.map(
            lambda p, s: jax.lax.with_sharding_constraint(
                p.astype(cfg.cdtype), s),
            master, compute_sh)
        mb = _reshape_microbatches(batch, accum)

        def local(params_c, mb):
            with use_sharder(sharder_in):
                def micro(carry, b1):
                    g_acc, l_acc = carry
                    (loss, _), grads = jax.value_and_grad(
                        lambda p: api.loss_fn(p, b1, cfg, remat=remat),
                        has_aux=True)(params_c)
                    g_acc = jax.tree.map(
                        lambda a, g: a + g.astype(grad_dtype), g_acc, grads)
                    return (g_acc, l_acc + loss), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, grad_dtype), params_c)
                (grads, loss_sum), _ = jax.lax.scan(
                    micro, (g0, jnp.zeros((), jnp.float32)), mb)

            # deferred reduction: ONE bf16 collective per leaf per step
            # (gradient compression).  NOTE: compiling this on the CPU
            # backend requires --xla_disable_hlo_passes=all-reduce-promotion
            # (an XLA CPU bug: the pass crashes cloning a bf16 all-reduce
            # whose user is a `copy`; float-normalization-bf16 legalizes the
            # op anyway).  TPU reduces bf16 natively — no flag needed.
            def reduce_leaf(g, pl):
                kind, dim = pl
                if kind == "local":
                    return g
                g = g.astype(grad_dtype)
                if kind == "scatter":
                    return jax.lax.psum_scatter(
                        g, dp_axes, scatter_dimension=dim, tiled=True)
                return jax.lax.psum(g, dp_axes)

            grads = jax.tree.map(reduce_leaf, grads, plan)
            loss = jax.lax.psum(loss_sum, dp_axes)
            return grads, loss

        n_dp = 1
        for a in dp_axes:
            n_dp *= mesh.shape[a]

        batch_manual = jax.tree.map(
            lambda x: jax.sharding.PartitionSpec(None, dp_axes)
            if hasattr(x, "ndim") and x.ndim >= 2
            else jax.sharding.PartitionSpec(), mb)
        # pad specs to full rank
        def bspec(x):
            if x.ndim == 0:
                return jax.sharding.PartitionSpec()
            return jax.sharding.PartitionSpec(
                None, dp_axes, *([None] * (x.ndim - 2)))
        batch_manual = jax.tree.map(bspec, mb)

        grads, loss_sum = compat.shard_map(
            local, mesh=mesh,
            in_specs=(manual_p_specs, batch_manual),
            out_specs=(grad_out_specs(mb), jax.sharding.PartitionSpec()),
            axis_names=set(dp_axes), check_vma=False,
        )(params_c, mb)

        grads = jax.tree.map(lambda g: g.astype(jnp.float32) / accum, grads)
        loss = loss_sum / (accum * n_dp)
        if use_adafactor:
            new_master, new_opt, _ = adafactor_update(
                grads, opt_state, master, lr=lr)
        else:
            new_master, new_opt, _ = adamw_update(
                grads, opt_state, master, lr=lr)
        return new_master, new_opt, {"loss": loss, "step": step + 1}

    return train_step


def lower_train_opt(cfg: ArchConfig, shape: api.ShapeSpec, mesh, *,
                    accum: int | None = None, lr: float = 3e-4):
    dp = 1
    for a in shd._fsdp_axes(mesh):
        dp *= mesh.shape[a]
    accum = accum or max(1, shape.global_batch // dp)
    params_spec = api.param_specs(cfg)
    opt_spec = opt_specs(cfg, params_spec)
    batch_spec = api.input_specs(cfg, shape)

    m_sh = master_shardings_opt(params_spec, mesh, cfg)
    # optimizer state follows the master layout leaf-by-leaf
    flat_m, _ = jax.tree_util.tree_flatten(m_sh)

    def opt_sh_fn(opt_spec):
        p_flat, _ = jax.tree_util.tree_flatten_with_path(params_spec)
        by_suffix = {shd._path_str(p): (l.shape, s.spec)
                     for (p, l), s in zip(p_flat, flat_m)}

        def spec_of(path, leaf):
            ps = shd._path_str(path)
            for key, (shape_, spec_) in by_suffix.items():
                if ps.endswith(key):
                    if leaf.shape == shape_:
                        return spec_
                    specs = list(tuple(spec_)) + [None] * (
                        len(shape_) - len(tuple(spec_)))
                    if leaf.shape == shape_[:-1]:
                        return jax.sharding.PartitionSpec(*specs[:-1])
                    if leaf.shape == shape_[:-2] + shape_[-1:]:
                        return jax.sharding.PartitionSpec(
                            *(specs[:-2] + specs[-1:]))
                    return jax.sharding.PartitionSpec()
            return jax.sharding.PartitionSpec()

        flat, treedef = jax.tree_util.tree_flatten_with_path(opt_spec)
        return jax.tree_util.tree_unflatten(
            treedef, [jax.sharding.NamedSharding(mesh, spec_of(p, l))
                      for p, l in flat])

    o_sh = opt_sh_fn(opt_spec)
    b_sh = shd.batch_shardings(batch_spec, mesh)
    s_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    step_fn = make_train_step_opt(cfg, mesh, accum=accum, lr=lr)
    jitted = jax.jit(step_fn,
                     in_shardings=(m_sh, o_sh, b_sh, s_sh),
                     out_shardings=(m_sh, o_sh, None),
                     donate_argnums=(0, 1))
    args = (params_spec, opt_spec, batch_spec,
            jax.ShapeDtypeStruct((), jnp.int32))
    with mesh:
        lowered = jitted.lower(*args)
    return lowered, {"accum": accum, "variant": "optimized"}


def serve_shardings_opt(params_spec, mesh, cfg):
    """Serve-time layout: TP-resident non-expert weights (no per-layer FSDP
    gathers), expert weights keep the baseline (model, fsdp) layout — the 2D
    train layout requires the manual-mode MoE hooks, which only exist inside
    the train shard_map (measured: applying it to prefill emitted
    catastrophic per-layer collectives, MFU 0.049 -> 0.011 — refuted)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec)
    out = []
    for path, leaf in flat:
        ps = shd._path_str(path)
        if _is_expert_leaf(ps):
            spec = shd.param_spec_for(ps, leaf.shape, mesh, cfg)
        else:
            spec = shd.param_spec_for_opt(ps, leaf.shape, mesh, cfg)
        out.append(jax.sharding.NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def lower_cell_opt(cfg: ArchConfig, shape: api.ShapeSpec, mesh, **kw):
    if shape.kind == "train":
        return lower_train_opt(cfg, shape, mesh, **kw)
    # prefill/decode: weight-resident layout (no FSDP gathers at serve time)
    if shape.kind == "prefill":
        params_spec = api.param_specs(cfg)
        batch_spec = api.input_specs(cfg, shape)
        p_sh = serve_shardings_opt(params_spec, mesh, cfg)
        b_sh = shd.batch_shardings(batch_spec, mesh)
        jitted = jax.jit(make_prefill_step(cfg, mesh),
                         in_shardings=(p_sh, b_sh))
        with mesh:
            lowered = jitted.lower(params_spec, batch_spec)
        return lowered, {"variant": "optimized"}
    params_spec = api.param_specs(cfg)
    cache_spec = api.cache_specs(cfg, shape.global_batch, shape.seq_len)
    tok_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    p_sh = serve_shardings_opt(params_spec, mesh, cfg)
    c_sh = shd.cache_shardings(cache_spec, mesh, cfg)
    t_sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(
            "data" if shape.global_batch % mesh.shape["data"] == 0 else None,
            None))
    jitted = jax.jit(make_decode_step(cfg, mesh),
                     in_shardings=(p_sh, c_sh, t_sh,
                                   jax.sharding.NamedSharding(
                                       mesh, jax.sharding.PartitionSpec())),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
    with mesh:
        lowered = jitted.lower(params_spec, cache_spec, tok_spec,
                               jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, {"variant": "optimized"}
