"""Logical-axis sharding rules (MaxText-style), divisibility-aware.

Every param leaf is matched by its path suffix to a rule assigning logical
axes per trailing dim; scanned leaves (leading n_periods dim) get an extra
None.  Logical axes resolve to mesh axes with a divisibility check — a dim
that doesn't divide falls back to replication (e.g. gemma3's 8 q-heads on a
16-way model axis; see EXPERIMENTS.md §Perf for the hillclimbed alternative).

Strategy (baseline):
  * FSDP: every large param shards its 'embed'-like dim over ("pod","data")
  * TP (Megatron): heads / d_ff / vocab / experts shard over "model"
  * activations: batch over ("pod","data"); MoE expert buffers over "model"
  * decode KV caches: batch over "data" when divisible, else sequence over
    ("data","model"); sequence over "model" otherwise
"""
from __future__ import annotations

import re
from functools import partial

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def _fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, axes) -> int:
    """Axes absent from the mesh (e.g. 'model' on a data-only smoke mesh)
    count as size 1 — the rule then falls back to replication."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape.get(axes, 1)
    return int(np.prod([mesh.shape.get(a, 1) for a in axes]))


def _resolve(mesh: Mesh, dims, logical):
    """logical: tuple of None | 'tp' | 'fsdp' | ('fsdp','tp')... aligned to
    the TRAILING dims; leading dims get None.  Non-divisible -> None."""
    spec = [None] * (len(dims) - len(logical))
    for dim, log in zip(dims[len(dims) - len(logical):], logical):
        if log is None:
            spec.append(None)
            continue
        axes = {"tp": "model", "fsdp": _fsdp_axes(mesh)}[log] \
            if isinstance(log, str) else log
        size = _axis_size(mesh, axes)
        present = (axes in mesh.axis_names) if isinstance(axes, str) else \
            all(a in mesh.axis_names for a in axes)
        spec.append(axes if present and size > 1 and dim % size == 0 else None)
    return P(*spec)


# rule table: path-suffix regex -> logical axes for the trailing dims
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("tp", "fsdp")),             # (V, D)
    (r"lm_head$", ("fsdp", "tp")),           # (D, V)
    (r"enc_pos$", (None, None)),
    (r"attn.*wq$", ("fsdp", "tp", None)),    # (D, H, hd)
    (r"attn.*wk$", ("fsdp", "tp", None)),    # (D, KV, hd)
    (r"attn.*wv$", ("fsdp", "tp", None)),
    (r"attn.*wo$", ("tp", None, "fsdp")),    # (H, hd, D)
    (r"attn.*b[qkv]$", ("tp", None)),
    (r"(mlp|shared_mlp).*w_(in|gate)$", ("fsdp", "tp")),   # (D, F)
    (r"(mlp|shared_mlp).*w_out$", ("tp", "fsdp")),         # (F, D)
    (r"moe.*router$", ("fsdp", None)),       # (D, E)
    (r"moe.*w_(in|gate)$", ("tp", "fsdp", None)),  # (E, D, F): experts on model
    (r"moe.*w_out$", ("tp", None, "fsdp")),        # (E, F, D)
    (r"rwkv.*w_(r|k|v|g|decay)$", ("fsdp", "tp")),
    (r"rwkv.*w_o$", ("tp", "fsdp")),
    (r"rwkv.*bonus_u$", ("tp", None)),
    (r"rwkv.*(decay_bias)$", (None,)),
    (r"rwkv.*mix$", (None, None)),
    (r"mamba.*w_in$", ("fsdp", "tp")),       # (D, 2*inner)
    (r"mamba.*conv_w$", (None, "tp")),       # (K, inner)
    (r"mamba.*conv_b$", ("tp",)),
    (r"mamba.*w_bcdt$", ("tp", None)),       # (inner, r)
    (r"mamba.*w_dt$", (None, "tp")),         # (r, inner)
    (r"mamba.*dt_bias$", ("tp",)),
    (r"mamba.*a_log$", ("tp", None)),        # (inner, N)
    (r"mamba.*d_skip$", ("tp",)),
    (r"mamba.*w_out$", ("tp", "fsdp")),      # (inner, D)
    (r"(ln1|ln2|ln_x|final_norm|enc_norm).*", (None,)),
]

# fallback for MoE when the expert count doesn't divide the model axis
# (mixtral: 8 experts on 16-way model) — TP inside each expert instead.
_MOE_FALLBACK = {
    r"moe.*w_(in|gate)$": (None, "fsdp", "tp"),
    r"moe.*w_out$": (None, "tp", "fsdp"),
}


def param_spec_for(path: str, shape, mesh: Mesh, cfg: ArchConfig) -> P:
    for pattern, logical in _PARAM_RULES:
        if re.search(pattern, path):
            if pattern in ("moe.*w_(in|gate)$", "moe.*w_out$") and \
                    cfg.num_experts % mesh.shape.get("model", 1) != 0:
                logical = _MOE_FALLBACK[pattern]
            return _resolve(mesh, shape, logical)
    return P()  # replicate anything unmatched (scalars, misc)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_shardings(params_spec, mesh: Mesh, cfg: ArchConfig):
    """NamedSharding pytree matching a params (or params-spec) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec)
    out = []
    for path, leaf in flat:
        spec = param_spec_for(_path_str(path), leaf.shape, mesh, cfg)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_shardings(opt_spec, params_spec, mesh: Mesh, cfg: ArchConfig):
    """Optimizer states inherit their parameter's sharding where shapes
    match; factored/scalar states fall back to replication-compatible specs."""
    p_flat, _ = jax.tree_util.tree_flatten_with_path(params_spec)
    by_suffix = {_path_str(path): leaf.shape for path, leaf in p_flat}

    def spec_of(path, leaf):
        ps = _path_str(path)
        # strip the OptState field prefix ('mu/', 'nu/', 'vr/', 'vc/', '0/'...)
        for key, shape in by_suffix.items():
            if ps.endswith(key):
                if leaf.shape == shape:
                    return param_spec_for(key, leaf.shape, mesh, cfg)
                # factored adafactor leaf: reuse the matching leading dims
                full = param_spec_for(key, shape, mesh, cfg)
                specs = list(full) + [None] * (len(shape) - len(tuple(full)))
                if leaf.shape == shape[:-1]:       # vr: drop last dim
                    return P(*specs[:-1])
                if leaf.shape == shape[:-2] + shape[-1:]:  # vc: drop dim -2
                    return P(*(specs[:-2] + specs[-1:]))
                return P()
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_spec)
    out = [NamedSharding(mesh, spec_of(path, leaf)) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------------------ activations ---

def make_activation_sharder(mesh: Mesh, cfg: ArchConfig, *, decode_batch=None):
    """Returns shard(name, x) used by models via sharding_hooks."""
    fsdp = _fsdp_axes(mesh)
    tp_ok = partial(_divides, mesh)

    def fn(name, x):
        if name in ("hidden", "residual"):
            spec = P(fsdp, *([None] * (x.ndim - 1)))
        elif name == "logits":
            v = x.shape[-1]
            spec = P(fsdp, None,
                     "model" if v % mesh.shape.get("model", 0 or 1) == 0 and
                     "model" in mesh.axis_names else None)
        elif name == "decode_hidden":
            b = x.shape[0]
            spec = P("data" if b % mesh.shape["data"] == 0 else None,
                     *([None] * (x.ndim - 1)))
        elif name == "moe_buffer":  # (E, C, D)
            e = x.shape[0]
            tp = mesh.shape.get("model", 1)
            spec = (P("model", None, None)
                    if "model" in mesh.axis_names and e % tp == 0
                    else P(None, None, None))
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return fn


def _divides(mesh, axis, dim):
    return dim % mesh.shape[axis] == 0


# ------------------------------------------------------------ data/caches ---

def batch_shardings(batch_spec, mesh: Mesh):
    """Train/prefill inputs: batch dim over the composed data axes."""
    fsdp = _fsdp_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        b = leaf.shape[0]
        ok = b % _axis_size(mesh, fsdp) == 0
        return NamedSharding(
            mesh, P(fsdp if ok else None, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(one, batch_spec)


def cache_shardings(cache_spec, mesh: Mesh, cfg: ArchConfig):
    """Decode KV caches: batch over data when divisible; sequence dim over
    'model' (or over everything when batch=1: long_500k)."""
    dsize = mesh.shape["data"]
    msize = mesh.shape.get("model", 1)
    fsdp = _fsdp_axes(mesh)
    all_axes = tuple(a for a in (fsdp + ("model",)))

    def one(path, leaf):
        name = _path_str(path).rsplit("/", 1)[-1]
        shape = leaf.shape
        if name in ("k", "v", "xk", "xv"):  # (B, S, KV, hd)
            b, s = shape[0], shape[1]
            if b % dsize == 0:
                seq_ax = "model" if s % msize == 0 else None
                return NamedSharding(mesh, P("data", seq_ax, None, None))
            if s % _axis_size(mesh, all_axes) == 0:
                return NamedSharding(mesh, P(None, all_axes, None, None))
            return NamedSharding(mesh, P(None, "model" if s % msize == 0
                                         else None, None, None))
        if name == "ssm":   # (B, d_inner, N)
            b, d_inner = shape[0], shape[1]
            return NamedSharding(mesh, P(
                "data" if b % dsize == 0 else None,
                "model" if d_inner % msize == 0 else None, None))
        if name == "conv":  # (B, K-1, d_inner)
            b, d_inner = shape[0], shape[2]
            return NamedSharding(mesh, P(
                "data" if b % dsize == 0 else None, None,
                "model" if d_inner % msize == 0 else None))
        if name == "state":  # rwkv (B, H, hd, hd)
            b, h = shape[0], shape[1]
            return NamedSharding(mesh, P(
                "data" if b % dsize == 0 else None,
                "model" if h % msize == 0 else None, None, None))
        if name == "shift":  # (B, D)
            b, d = shape
            return NamedSharding(mesh, P(
                "data" if b % dsize == 0 else None,
                "model" if d % msize == 0 else None))
        return NamedSharding(mesh, P())  # slot_pos etc.

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_spec)
    # scanned cache leaves carry a leading (n_periods,) dim — detect by the
    # 'blocks' path component and shift specs right by one.
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        if "blocks" in ps or ("dec" in ps and leaf.ndim >= 3):
            inner = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
            ns = one(path, inner)
            out.append(NamedSharding(mesh, P(None, *tuple(ns.spec))))
        else:
            out.append(one(path, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


# ===========================================================================
# OPTIMIZED variant (EXPERIMENTS.md §Perf) — beyond-paper distribution schedule
#
#   1. ZeRO-1 deferred gradient reduction: the microbatch loop runs inside a
#      shard_map that is MANUAL over the data axes (model stays auto), so
#      weight-gradient all-reduces collapse from `accum` per step to ONE
#      (and are communicated in bf16 — gradient compression).
#   2. 2D-resident expert weights (E over 'model', expert-FFN F over the data
#      axes): expert weights never move; the token set is all-gathered across
#      data before the expert FFN and reduce-scattered after (token traffic
#      ~36x smaller than the weight traffic it replaces at kimi scale).
#   3. Sequence-parallel attention for archs whose head count doesn't divide
#      the model axis (gemma3/whisper): attention inputs are resharded
#      seq-over-model so the attention core runs 256-way instead of 16-way.
# ===========================================================================

def param_spec_for_opt(path: str, shape, mesh: Mesh, cfg: ArchConfig) -> P:
    """Optimized param layout: TP-resident (replicated over data) except the
    expert FFN weights, which shard F over the data axes (2D-resident)."""
    tp = mesh.shape["model"]
    fsdp = _fsdp_axes(mesh)
    fsdp_size = _axis_size(mesh, fsdp)
    lead = (None,) * (len(shape) - 3)  # scanned leaves: (n_periods, E, ., .)
    if re.search(r"moe.*w_(in|gate)$", path):       # (..., E, D, F)
        e, dd, ff = shape[-3:]
        if e % tp == 0 and dd % fsdp_size == 0 and ff <= dd:
            # 2D-resident: E over model, D over data (tokens all-to-all'd)
            return P(*lead, "model", fsdp, None)
        # few-experts fallback (mixtral): TP inside the expert FFN, weights
        # replicated over data (grads deferred to the one per-step RS)
        return P(*lead, None, None, "model" if ff % tp == 0 else None)
    if re.search(r"moe.*w_out$", path):             # (..., E, F, D)
        e, ff, dd = shape[-3:]
        if e % tp == 0 and dd % fsdp_size == 0 and ff <= dd:
            return P(*lead, "model", None, fsdp)
        return P(*lead, None, "model" if ff % tp == 0 else None, None)
    # everything else: drop the fsdp components (params replicated over data,
    # gathered once per step instead of once per microstep) but keep TP.
    base = param_spec_for(path, shape, mesh, cfg)
    cleaned = []
    for part in tuple(base):
        if part is None or part == "model":
            cleaned.append(part)
        elif isinstance(part, (tuple, list)):
            kept = tuple(a for a in part if a == "model")
            cleaned.append(kept[0] if len(kept) == 1 else
                           (kept if kept else None))
        else:  # a single fsdp axis name
            cleaned.append(None)
    return P(*cleaned)


def param_shardings_opt(params_spec, mesh: Mesh, cfg: ArchConfig):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec)
    out = [NamedSharding(mesh, param_spec_for_opt(_path_str(p), l.shape,
                                                  mesh, cfg))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def manual_in_specs(params_spec, mesh: Mesh, cfg: ArchConfig):
    """shard_map in_specs for the params: only the DATA-axis components of
    each optimized spec (the model axis stays auto inside)."""
    fsdp = set(_fsdp_axes(mesh))

    def one(path, leaf):
        spec = param_spec_for_opt(_path_str(path), leaf.shape, mesh, cfg)
        parts = []
        for part in tuple(spec):
            if part is None or part == "model":
                parts.append(None)
            elif isinstance(part, (tuple, list)):
                kept = tuple(a for a in part if a in fsdp)
                parts.append(kept if kept else None)
            else:
                parts.append(part if part in fsdp else None)
        return P(*parts)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec)
    return jax.tree_util.tree_unflatten(treedef,
                                        [one(p, l) for p, l in flat])


def make_activation_sharder_opt(mesh: Mesh, cfg: ArchConfig):
    """Activation hook for the optimized variant, used INSIDE the manual-
    over-data shard_map: batch dims are local (no dp constraints), the model
    axis uses auto constraints, and the MoE gather/reduce hooks become real
    collectives over the data axes."""
    dp_axes = _fsdp_axes(mesh)
    tp = mesh.shape["model"]

    def constraint(x, spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    moe_2d = bool(cfg.num_experts) and cfg.num_experts % tp == 0 and \
        cfg.d_model % _axis_size(mesh, dp_axes) == 0 and \
        (cfg.moe_d_ff or cfg.d_ff) <= cfg.d_model

    def fn(name, x):
        if name == "moe_gather_logits":
            return (jax.lax.all_gather(x, dp_axes, axis=0, tiled=True)
                    if moe_2d else x)
        if name == "moe_slice_d":
            # (T_loc, D) -> (T_glob, D_loc): every rank sees all tokens,
            # D-sliced, matching the D-over-data expert weight shards
            return (jax.lax.all_to_all(x, dp_axes, split_axis=1,
                                       concat_axis=0, tiled=True)
                    if moe_2d else x)
        if name == "moe_partial_sum":
            return jax.lax.psum(x, dp_axes) if moe_2d else x
        if name == "moe_out_gather":
            return (jax.lax.all_to_all(x, dp_axes, split_axis=0,
                                       concat_axis=1, tiled=True)
                    if moe_2d else x)
        if name == "moe_buffer":  # (E, C, D_loc): experts over model (auto)
            e = x.shape[0]
            return constraint(x, P("model" if e % tp == 0 else None,
                                   None, None))
        if name == "residual":
            # keep the residual stream replicated over 'model' inside the
            # manual region (prevents sharding churn around MoE/attention)
            return constraint(x, P(*([None] * x.ndim)))
        if name in ("attn_in", "attn_out") and cfg.num_heads % tp != 0:
            # sequence-parallel attention: queries sharded over 'model'
            s = x.shape[1]
            if name == "attn_in" and s % tp == 0:
                return constraint(x, P(None, "model", None))
            if name == "attn_out":
                return constraint(x, P(None, None, None))
        if name == "logits":
            v = x.shape[-1]
            return constraint(x, P(None, None,
                                   "model" if v % tp == 0 else None))
        return x

    return fn
