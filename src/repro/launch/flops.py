"""Analytic MODEL_FLOPS (the 'useful' FLOPs) per (arch x shape).

train:   6 * N_active * tokens  + attention-core term (3x fwd for bwd)
prefill: 2 * N_active * tokens  + attention-core term
decode:  2 * N_active * batch   + attention-over-cache term

Attention core (fwd) = 4 * B * Sq * Skv_eff * H * hd per attention layer
(2 for QK^T, 2 for AV), causal halves Skv_eff for self-attention training;
sliding-window caps Skv_eff at the window.  MoE counts top_k (+shared)
experts only — that is the point of N_active.
"""
from __future__ import annotations

from repro.models.config import ArchConfig, layer_kinds
from repro.models.api import ShapeSpec


def _attn_proj_params(cfg: ArchConfig) -> int:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return d * h * hd + 2 * d * kv * hd + h * hd * d


def _mlp_params(cfg: ArchConfig, d_ff: int, gated: bool = True) -> int:
    mult = 3 if gated else 2
    return mult * cfg.d_model * d_ff


def _mamba_params(cfg: ArchConfig) -> int:
    di, n = cfg.d_inner, cfg.mamba_d_state
    dt_rank = max(1, cfg.d_model // 16)
    return (cfg.d_model * 2 * di + cfg.mamba_d_conv * di
            + di * (dt_rank + 2 * n) + dt_rank * di + di * cfg.d_model)


def _rwkv_params(cfg: ArchConfig) -> int:
    return 6 * cfg.d_model * cfg.d_model


def active_params(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: top_k + shared experts only)."""
    total = cfg.padded_vocab * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.padded_vocab
    gated = cfg.norm == "rmsnorm"
    for kind in layer_kinds(cfg):
        if kind.mixer in ("attn", "swa"):
            total += _attn_proj_params(cfg)
        elif kind.mixer == "mamba":
            total += _mamba_params(cfg)
        elif kind.mixer == "rwkv":
            total += _rwkv_params(cfg)
        if kind.ffn == "moe":
            total += cfg.top_k * _mlp_params(cfg, kind.d_ff, True)
            total += cfg.d_model * cfg.num_experts  # router
            if cfg.shared_expert:
                total += _mlp_params(cfg, kind.d_ff, True)
        else:
            total += _mlp_params(cfg, kind.d_ff, gated)
    if cfg.is_encdec():
        total += cfg.encoder_layers * (
            _attn_proj_params(cfg) + _mlp_params(cfg, cfg.d_ff, False))
        total += cfg.num_layers * _attn_proj_params(cfg)  # cross attention
    return int(total)


def expert_params(cfg: ArchConfig) -> int:
    """All expert-FFN weights (the 2D-resident tensors in the opt variant)."""
    total = 0
    for kind in layer_kinds(cfg):
        if kind.ffn == "moe":
            total += cfg.num_experts * _mlp_params(cfg, kind.d_ff, True)
    return int(total)


def total_params(cfg: ArchConfig) -> int:
    total = active_params(cfg)
    for kind in layer_kinds(cfg):
        if kind.ffn == "moe":
            total += (cfg.num_experts - cfg.top_k) * _mlp_params(
                cfg, kind.d_ff, True)
    return int(total)


def _attn_core_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Forward attention-core FLOPs for the whole step (all layers)."""
    b, s = shape.global_batch, shape.seq_len
    h, hd = cfg.num_heads, cfg.head_dim
    total = 0.0
    for kind in layer_kinds(cfg):
        if kind.mixer not in ("attn", "swa"):
            continue
        if shape.kind == "decode":
            skv = min(s, cfg.window_size) if kind.mixer == "swa" else s
            total += 4.0 * b * 1 * skv * h * hd
        else:
            if kind.mixer == "swa":
                skv_avg = min(cfg.window_size, s)
                total += 4.0 * b * s * skv_avg * h * hd
            else:
                total += 4.0 * b * s * (s / 2.0) * h * hd  # causal half
    if cfg.is_encdec() and shape.kind != "decode":
        total += cfg.encoder_layers * 4.0 * b * cfg.encoder_seq**2 * h * hd
        total += cfg.num_layers * 4.0 * b * s * cfg.encoder_seq * h * hd
    if cfg.is_encdec() and shape.kind == "decode":
        total += cfg.num_layers * 4.0 * b * cfg.encoder_seq * h * hd
    return total


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Total useful FLOPs for one step across ALL devices."""
    n_act = active_params(cfg)
    b, s = shape.global_batch, shape.seq_len
    attn = _attn_core_flops(cfg, shape)
    if shape.kind == "train":
        return 6.0 * n_act * b * s + 3.0 * attn
    if shape.kind == "prefill":
        return 2.0 * n_act * b * s + attn
    return 2.0 * n_act * b + attn  # decode: one token per sequence


# ---------------------------------------------------------------------------
# Executed-cost model (per device) for the roofline, DESIGN.md §12.
#
# XLA's compiled.cost_analysis() counts while-loop (scan) bodies ONCE, so at
# these shapes it underreports by the trip counts (verified empirically in
# EXPERIMENTS.md §Dry-run).  The compiled HLO still gives the collective
# schedule (loop-scaled in dryrun.parse_collectives); FLOPs and HBM bytes come
# from this analytic model of the exact program we lowered:
#
#   train  = 8 * N_active * tokens + 4 * attn_core_fwd     (remat: fwd +
#            recomputed fwd + bwd(2x fwd) = 4x fwd multiplier on matmuls,
#            6ND ideal -> 8ND executed)
#   prefill = 2 * N * tokens + attn_core_fwd
#   decode  = 2 * N_active * batch + attn_over_cache
#
# Per-device = per-component / sharding degree.  Components shard differently:
# dense/moe/embed matmuls shard over data x model; attention (projections and
# core) loses the model axis when heads don't divide it (gemma3: 8 q-heads on
# a 16-way axis -> attention replicated across 'model', degree 16 not 256).
# ---------------------------------------------------------------------------

def _degrees(cfg: ArchConfig, mesh_shape: dict,
             variant: str = "baseline") -> dict:
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    heads_tp = tp if cfg.num_heads % tp == 0 else 1
    if variant == "optimized" and heads_tp == 1:
        # sequence-parallel attention reshard recovers the model axis
        heads_tp = tp
    ff_tp = tp if cfg.d_ff % tp == 0 else 1
    vocab_tp = tp if cfg.padded_vocab % tp == 0 else 1
    expert_tp = tp if (cfg.num_experts and cfg.num_experts % tp == 0) else \
        (tp if cfg.moe_d_ff and cfg.moe_d_ff % tp == 0 else 1)
    if variant == "optimized" and cfg.num_experts:
        expert_tp = tp  # 2D-resident layout: E over model, F over data
    rwkv_tp = tp if cfg.d_model % tp == 0 else 1
    return {
        "attn": dp * heads_tp,
        "mlp": dp * ff_tp,
        "embed": dp * vocab_tp,
        "moe": dp * expert_tp,
        "ssm": dp * rwkv_tp,
    }


def executed_flops_per_device(cfg: ArchConfig, shape: ShapeSpec,
                              mesh_shape: dict,
                              variant: str = "baseline") -> dict:
    """Returns {'total': flops/device, 'by_component': {...}, 'executed_total'}."""
    b, s = shape.global_batch, shape.seq_len
    tokens = b * (1 if shape.kind == "decode" else s)
    mult = {"train": 8.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    attn_mult = {"train": 4.0, "prefill": 1.0, "decode": 1.0}[shape.kind]
    deg = _degrees(cfg, mesh_shape, variant)
    gated = cfg.norm == "rmsnorm"

    comp = {k: 0.0 for k in ("attn_proj", "attn_core", "mlp", "moe", "ssm",
                             "embed")}
    for kind in layer_kinds(cfg):
        if kind.mixer in ("attn", "swa"):
            comp["attn_proj"] += mult * _attn_proj_params(cfg) * tokens
        elif kind.mixer == "mamba":
            comp["ssm"] += mult * _mamba_params(cfg) * tokens
            comp["ssm"] += attn_mult * 10.0 * tokens * cfg.d_inner * \
                cfg.mamba_d_state
        elif kind.mixer == "rwkv":
            comp["ssm"] += mult * _rwkv_params(cfg) * tokens
            c = cfg.scan_chunk
            comp["ssm"] += attn_mult * 4.0 * tokens * c * cfg.d_model
        if kind.ffn == "moe":
            active = cfg.top_k + (1 if cfg.shared_expert else 0)
            comp["moe"] += mult * active * _mlp_params(cfg, kind.d_ff, True) \
                * tokens
        else:
            comp["mlp"] += mult * _mlp_params(cfg, kind.d_ff, gated) * tokens
    comp["attn_core"] = attn_mult * _attn_core_flops(cfg, shape)
    v_mult = 2.0 if cfg.tie_embeddings else 2.0
    comp["embed"] = mult * cfg.padded_vocab * cfg.d_model * tokens \
        + v_mult * 0  # embedding lookup is gather (no flops); logits matmul:
    comp["embed"] = mult * cfg.d_model * cfg.padded_vocab * tokens
    if cfg.is_encdec():
        enc_tokens = b * cfg.encoder_seq if shape.kind != "decode" else 0
        enc = cfg.encoder_layers * (
            _attn_proj_params(cfg) + _mlp_params(cfg, cfg.d_ff, False))
        comp["attn_proj"] += mult * enc * enc_tokens
        comp["attn_proj"] += mult * cfg.num_layers * _attn_proj_params(cfg) \
            * tokens  # cross-attn projections

    deg_of = {"attn_proj": deg["attn"], "attn_core": deg["attn"],
              "mlp": deg["mlp"], "moe": deg["moe"], "ssm": deg["ssm"],
              "embed": deg["embed"]}
    per_dev = {k: v / deg_of[k] for k, v in comp.items()}
    return {
        "per_device_total": sum(per_dev.values()),
        "per_device": per_dev,
        "executed_total": sum(comp.values()),
        "degrees": deg_of,
    }


def executed_hbm_bytes_per_device(cfg: ArchConfig, shape: ShapeSpec,
                                  mesh_shape: dict, accum: int = 1,
                                  variant: str = "baseline") -> dict:
    """HBM traffic model (per device, bytes) — coarse but term-dominant:

      weights : gathered bf16 weights read (fwd + remat + bwd = 3x) per
                microstep, divided by the TP degree only (FSDP gathers
                re-materialize the full layer on every device)
      grads   : f32 grad accumulate read+write per microstep, /(dp*tp)
      opt     : params + moments read/write once per step, /(dp*tp)
      acts    : ~12 passes over (B_local, S, D) bf16 per layer per microstep
      cache   : decode reads the KV/state cache once per step (sharded)
    """
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    n_dev = dp * tp
    p_total = total_params(cfg)
    p_active = active_params(cfg)
    bpe = 2 if cfg.param_dtype == "bfloat16" else 4
    out = {}
    if shape.kind == "train":
        b_local = max(shape.global_batch // dp, 1)
        micro_b = max(b_local // accum, 1)
        # MoE: only active experts' weights stream from HBM per token-batch;
        # a microbatch of micro_b*S tokens generally touches ALL experts.
        if variant == "optimized":
            # experts resident 2D-sharded (/n_dev); the rest TP-resident (/tp)
            p_exp = expert_params(cfg)
            w_read = 3.0 * accum * ((p_total - p_exp) * 2 / tp
                                    + p_exp * 2 / n_dev)
            g_rw = 3.0 * accum * ((p_total - p_exp) * 2 / tp
                                  + p_exp * 2 / n_dev)  # bf16 local grads
        else:
            w_read = 3.0 * accum * (p_total * 2 / tp)
            g_rw = 3.0 * accum * (p_total * 4 / n_dev)
        o_rw = 6.0 * (p_total * (4 if cfg.optimizer == "adamw" else 1)
                      + p_total * bpe) / n_dev
        acts = accum * 12.0 * cfg.num_layers * micro_b * shape.seq_len \
            * cfg.d_model * 2
        out = {"weights": w_read, "grads": g_rw, "opt": o_rw, "acts": acts}
    elif shape.kind == "prefill":
        b_local = max(shape.global_batch // dp, 1)
        out = {
            "weights": (p_total * bpe) / tp,
            "acts": 12.0 * cfg.num_layers * b_local * shape.seq_len
                    * cfg.d_model * 2,
        }
    else:  # decode
        cache_bytes = 0.0
        for kind in layer_kinds(cfg):
            if kind.mixer == "attn":
                cache_bytes += 2 * shape.global_batch * shape.seq_len * \
                    cfg.num_kv_heads * cfg.head_dim * 2
            elif kind.mixer == "swa":
                w = min(cfg.window_size, shape.seq_len)
                cache_bytes += 2 * shape.global_batch * w * \
                    cfg.num_kv_heads * cfg.head_dim * 2
            elif kind.mixer == "mamba":
                cache_bytes += shape.global_batch * cfg.d_inner * \
                    cfg.mamba_d_state * 4
            elif kind.mixer == "rwkv":
                hh = cfg.d_model // cfg.rwkv_head_size
                cache_bytes += shape.global_batch * hh * \
                    cfg.rwkv_head_size**2 * 4
        out = {
            "weights": (p_active * bpe) / tp,   # active experts stream in
            "cache": 2.0 * cache_bytes / n_dev,  # read + write-back
        }
    out["total"] = sum(out.values())
    return out
