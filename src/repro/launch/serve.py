"""Batched serving driver: prefill + decode with slot-based batching.

A minimal continuous-batching loop: fixed B decode slots; finished sequences
(EOS or length) are refilled from the request queue; every slot shares one
jitted decode step (the same program the dry-run lowers for decode_32k).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.launch.mesh import smoke_mesh


@dataclasses.dataclass
class Request:
    prompt: np.ndarray        # (P,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def serve(cfg, requests: list[Request], batch_slots: int = 4,
          max_seq: int = 128, mesh=None, greedy: bool = True, seed: int = 0):
    mesh = mesh or smoke_mesh()
    params = api.init_params(jax.random.PRNGKey(seed), cfg)
    step = jax.jit(lambda p, c, t, pos: api.decode_step(p, c, t, pos, cfg))

    queue = list(requests)
    active: list[Request | None] = [None] * batch_slots
    cache = api.init_cache(cfg, batch_slots, max_seq)
    tok = np.zeros((batch_slots, 1), np.int32)
    served = []
    pos = 0
    t0 = time.perf_counter()
    n_tokens = 0
    while queue or any(a is not None for a in active):
        for i in range(batch_slots):
            if active[i] is None and queue:
                req = queue.pop(0)
                active[i] = req
                # teacher-force the prompt through decode steps (simple
                # prefill; production uses the prefill program)
                for t in req.prompt:
                    tok[i, 0] = t
            if active[i] is None:
                tok[i, 0] = 0
        logits, cache = step(params, cache, jnp.asarray(tok), jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        for i, req in enumerate(active):
            if req is None:
                continue
            req.out.append(int(nxt[i]))
            n_tokens += 1
            if len(req.out) >= req.max_new:
                req.done = True
                served.append(req)
                active[i] = None
        tok = nxt[:, None]
        pos += 1
        if pos >= max_seq - 1:
            break
    dt = time.perf_counter() - t0
    return served, {"tokens": n_tokens, "seconds": dt,
                    "tok_per_s": n_tokens / max(dt, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    cfg = get_config(args.arch, smoke=True)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=4)
                    .astype(np.int32), max_new=args.max_new)
            for _ in range(args.requests)]
    served, stats = serve(cfg, reqs)
    print(f"[serve {cfg.name}] {len(served)} requests, "
          f"{stats['tokens']} tokens, {stats['tok_per_s']:.1f} tok/s")
    for i, r in enumerate(served[:3]):
        print(f"  req{i}: {list(r.prompt)} -> {r.out[:8]}...")


if __name__ == "__main__":
    main()
