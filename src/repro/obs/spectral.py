"""Operator spectral-health monitor: "is the approximation still valid?"

The paper's §5 machinery makes the reduced-set approximation's error
QUANTIFIABLE — eigenvalue drift, the accumulated Theorem-5.x update bound,
and the windowed MMD against the substitute density are all closed-form or
cached.  This sampler lifts those quantities into scrapeable gauges, so a
production deployment watches the approximation's validity the same way it
watches queue depth:

  * ``spectral.eigval{k=...}`` — top-``rank`` eigenvalues of the served
    operator (plus ``spectral.gap``, the gap below the serving rank: a
    collapsing gap means the rank choice itself is going stale);
  * ``spectral.err_est`` / ``spectral.budget_ratio`` — the accumulated
    per-update perturbation bound and its fraction of the re-solve budget
    (ratio -> 1 means the next maintenance re-solves);
  * ``spectral.resid`` — the measured Rayleigh residual, the a-posteriori
    certificate of the patched eigensystem;
  * ``spectral.mmd`` / ``spectral.mmd_ratio`` — windowed MMD from a
    ``DriftDetector`` against its Theorem-5.1 trigger threshold;
  * ``spectral.quant_bound_max`` / ``spectral.budget_headroom`` — worst
    per-channel quantized-projector error bound of the PUBLISHED snapshot
    and the slack left once it and ``err_est`` are charged against the
    budget (same kappa currency, DESIGN.md §8).

Sampling costs a handful of host syncs of O(rank) scalars plus (optionally)
one jitted MMD evaluation, so it runs per maintenance interval or per
metrics scrape — never per request.  ``observe`` is a no-op while
observability is disabled; :meth:`SpectralHealth.install` hooks the sampler
into every ``metrics.dump()``/``snapshot()`` so scrapes self-refresh.
"""
from __future__ import annotations

import numpy as np

from repro.obs import metrics

#: Largest eigenvalue index exported individually; higher ranks would only
#: bloat series cardinality (the full spectrum lives in the state anyway).
MAX_EIGVAL_SERIES = 16


class SpectralHealth:
    """Pull-style sampler over a ``StreamingRSKPCA`` state (duck-typed: any
    object with ``eigvals/rank/err_est/budget/resid/m/n`` works).

    ``server`` (a ``swap.HotSwapServer``) adds the quantized-projector
    bound of the *published* snapshot; ``detector`` (a
    ``drift.DriftDetector``) adds the windowed MMD once its window fills.
    """

    def __init__(self, get_state=None, server=None, detector=None):
        self._get_state = get_state
        self.server = server
        self.detector = detector
        self._hook = None

    # -- one-shot sampling -------------------------------------------------

    def observe(self, state=None) -> None:
        if not metrics.enabled():
            return
        state = state if state is not None else (
            self._get_state() if self._get_state is not None else None)
        if state is None:
            return
        lam = np.asarray(state.eigvals, np.float64)
        rank = int(state.rank)
        for k in range(min(rank, MAX_EIGVAL_SERIES)):
            metrics.gauge("spectral.eigval", {"k": k}).set(float(lam[k]))
        if lam.shape[0] > rank:
            metrics.gauge("spectral.gap").set(
                float(lam[rank - 1] - lam[rank]))
        err = float(state.err_est)
        budget = float(state.budget)
        metrics.gauge("spectral.err_est").set(err)
        metrics.gauge("spectral.budget_ratio").set(
            err / budget if np.isfinite(budget) and budget > 0 else 0.0)
        metrics.gauge("spectral.resid").set(float(state.resid))
        metrics.gauge("spectral.n_patched").set(float(state.n_patched))
        metrics.gauge("spectral.m").set(float(state.m))
        metrics.gauge("spectral.n").set(float(state.n))

        if self.detector is not None and self.detector.full:
            mmd = float(self.detector.mmd(state))
            thr = float(self.detector.threshold)
            metrics.gauge("spectral.mmd").set(mmd)
            metrics.gauge("spectral.mmd_ratio").set(
                mmd / thr if thr > 0 else 0.0)

        if self.server is not None:
            self._observe_quant(err, budget)

    def _observe_quant(self, err: float, budget: float) -> None:
        """Error-bound headroom of the published (possibly quantized)
        serving snapshot, in the same currency as the update budget."""
        snap = getattr(self.server, "_snapshot", None)
        if snap is None:
            return
        _, projector, kernel, projector_q = snap
        qmax = 0.0
        if projector_q is not None:
            from repro.kernels import quantize

            qmax = float(np.max(np.asarray(quantize.projection_error_bound(
                projector, kernel.precision, kappa=kernel.kappa))))
            metrics.gauge("spectral.quant_bound_max").set(qmax)
        if np.isfinite(budget):
            metrics.gauge("spectral.budget_headroom").set(
                budget - err - qmax)

    # -- scrape integration ------------------------------------------------

    def install(self) -> "SpectralHealth":
        """Refresh the gauges at the start of every metrics dump/snapshot
        (requires a ``get_state`` provider)."""
        assert self._get_state is not None, \
            "install() needs SpectralHealth(get_state=...)"
        if self._hook is None:
            self._hook = self.observe
            metrics.add_hook(self._hook)
        return self

    def uninstall(self) -> None:
        if self._hook is not None:
            metrics.remove_hook(self._hook)
            self._hook = None
