"""Process-global metric registry: counters, gauges, histograms (§16).

One registry per process, keyed by ``(name, labels)``.  Metric objects are
created once (usually at module import of the instrumented subsystem) and
mutated on the hot path:

  * ``counter(name).inc(k)`` — monotone event counts;
  * ``gauge(name).set(v)`` — last-value signals (queue depth, overlap
    fraction, eigenvalues);
  * ``histogram(name).observe(v)`` — FIXED-bucket distributions.  The bucket
    bounds are chosen at creation; ``observe`` is a bisect plus two integer
    adds — no allocation, no unbounded reservoir — and p50/p99 are
    recovered from the bucket counts by linear interpolation, which is how
    the serving bench reads tail latency without recording every sample.

Every mutator checks the module-global ``_ENABLED`` flag first, so
instrumented code calls metrics UNCONDITIONALLY and pays one function call
plus one global load while observability is off (the ≤2%/~0% overhead
contract benchmarks/obs_overhead.py gates).  Mutations take the metric's own
lock only when enabled — exact under the threaded serving/ingest drivers.

Export:

  * :func:`dump` — Prometheus-style text exposition (names sanitized to
    ``[a-z0-9_]``, labels inline, histograms as cumulative ``_bucket``
    series plus interpolated ``{quantile=...}`` rows);
  * :func:`write` — atomic dump to a file;
  * :func:`start_reporter` — periodic snapshot thread re-dumping every
    ``interval_s``;
  * :func:`add_hook` — callbacks run at the START of every dump/snapshot;
    pull-style samplers (obs.spectral.SpectralHealth) refresh their gauges
    here so scrapes always see current derived state.
"""
from __future__ import annotations

import bisect
import os
import threading

_ENABLED = False

_LOCK = threading.Lock()          # registry structure only, never hot-path
_REGISTRY: dict[tuple, object] = {}
_HOOKS: list = []

#: Default histogram bounds: exponential grid covering 50us .. 30s — wide
#: enough for per-dispatch service times and whole-chunk ingest rounds.
TIME_BUCKETS_MS = (
    0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
    100.0, 200.0, 500.0, 1000.0, 2000.0, 5000.0, 10000.0, 30000.0,
)
#: Default bounds for size-shaped histograms (batch rows, coalesce counts).
SIZE_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096,
                8192, 16384)


def enabled() -> bool:
    return _ENABLED


def _labels_key(labels: dict | None) -> tuple:
    return () if not labels else tuple(sorted(labels.items()))


class Counter:
    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, v: int | float = 1) -> None:
        if not _ENABLED:
            return
        with self._lock:
            self.value += v


class Gauge:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        if not _ENABLED:
            return
        self.value = float(v)  # single attribute store: atomic under the GIL


class Histogram:
    """Fixed-bucket histogram; ``bounds`` are the inclusive upper edges of
    the finite buckets (one implicit +inf bucket follows)."""

    __slots__ = ("name", "labels", "bounds", "counts", "sum", "count",
                 "_lock")

    def __init__(self, name: str, labels: tuple, bounds):
        self.name = name
        self.labels = labels
        self.bounds = tuple(float(b) for b in bounds)
        assert self.bounds == tuple(sorted(self.bounds)), "bounds must sort"
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        if not _ENABLED:
            return
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def quantile(self, q: float) -> float:
        """Approximate q-quantile by linear interpolation inside the bucket
        holding rank ``q * count`` (0.0 when empty)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= target and c > 0:
                lo = 0.0 if i == 0 else self.bounds[i - 1]
                hi = self.bounds[i] if i < len(self.bounds) \
                    else self.bounds[-1]
                return lo + (hi - lo) * max(0.0, target - cum) / c
            cum += c
        return self.bounds[-1]


def _get(cls, name: str, labels: dict | None, *args):
    key = (cls.__name__, name, _labels_key(labels))
    with _LOCK:
        m = _REGISTRY.get(key)
        if m is None:
            m = cls(name, _labels_key(labels), *args)
            _REGISTRY[key] = m
        return m


def counter(name: str, labels: dict | None = None) -> Counter:
    return _get(Counter, name, labels)


def gauge(name: str, labels: dict | None = None) -> Gauge:
    return _get(Gauge, name, labels)


def histogram(name: str, labels: dict | None = None,
              bounds=TIME_BUCKETS_MS) -> Histogram:
    return _get(Histogram, name, labels, bounds)


def add_hook(fn) -> None:
    """Register a pre-dump sampler (idempotent per function object)."""
    with _LOCK:
        if fn not in _HOOKS:
            _HOOKS.append(fn)


def remove_hook(fn) -> None:
    with _LOCK:
        if fn in _HOOKS:
            _HOOKS.remove(fn)


def clear() -> None:
    """Zero every registered metric IN PLACE and drop hooks (tests).

    The registry entries themselves survive: instrumented modules hold
    their metric handles from import time (``_M_REQS`` etc.), and emptying
    the registry would orphan those handles from every later dump while
    they kept counting into the void.  Resetting values keeps handle
    identity — a metric object obtained before ``clear`` is the same
    object (still registered) after."""
    with _LOCK:
        metrics = list(_REGISTRY.values())
        _HOOKS.clear()
    for m in metrics:
        if isinstance(m, Counter):
            with m._lock:
                m.value = 0
        elif isinstance(m, Histogram):
            with m._lock:
                m.counts = [0] * (len(m.bounds) + 1)
                m.sum = 0.0
                m.count = 0
        else:
            m.value = 0.0


def _san(name: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


def _fmt_labels(labels: tuple, extra: tuple = ()) -> str:
    items = labels + extra
    if not items:
        return ""
    return "{" + ",".join(f'{_san(str(k))}="{v}"' for k, v in items) + "}"


def _run_hooks() -> None:
    with _LOCK:
        hooks = list(_HOOKS)
    for fn in hooks:
        try:
            fn()
        except Exception:  # a broken sampler must not kill the scrape
            pass


def snapshot() -> dict:
    """Hook-refreshed point-in-time dict of every metric series."""
    _run_hooks()
    out: dict = {}
    with _LOCK:
        metrics = list(_REGISTRY.values())
    for m in metrics:
        key = _san(m.name) + _fmt_labels(m.labels)
        if isinstance(m, Histogram):
            out[key] = {"count": m.count, "sum": round(m.sum, 6),
                        "p50": round(m.quantile(0.5), 6),
                        "p99": round(m.quantile(0.99), 6)}
        else:
            out[key] = m.value
    return out


def dump() -> str:
    """Prometheus-style text exposition of the whole registry."""
    _run_hooks()
    with _LOCK:
        metrics = sorted(_REGISTRY.values(),
                         key=lambda m: (m.name, m.labels))
    lines: list[str] = []
    seen_type: set[str] = set()
    for m in metrics:
        name = _san(m.name)
        kind = ("counter" if isinstance(m, Counter)
                else "histogram" if isinstance(m, Histogram) else "gauge")
        if name not in seen_type:
            lines.append(f"# TYPE {name} {kind}")
            seen_type.add(name)
        if isinstance(m, Histogram):
            cum = 0
            for b, c in zip(m.bounds, m.counts):
                cum += c
                lines.append(
                    f"{name}_bucket{_fmt_labels(m.labels, (('le', b),))}"
                    f" {cum}")
            lines.append(
                f"{name}_bucket{_fmt_labels(m.labels, (('le', '+Inf'),))}"
                f" {m.count}")
            lines.append(f"{name}_sum{_fmt_labels(m.labels)} {m.sum:.6g}")
            lines.append(f"{name}_count{_fmt_labels(m.labels)} {m.count}")
            for q in (0.5, 0.99):
                lines.append(
                    f"{name}{_fmt_labels(m.labels, (('quantile', q),))}"
                    f" {m.quantile(q):.6g}")
        else:
            v = m.value
            vs = f"{v:.6g}" if isinstance(v, float) else str(v)
            lines.append(f"{name}{_fmt_labels(m.labels)} {vs}")
    return "\n".join(lines) + "\n"


def write(path: str) -> None:
    """Atomic text dump to ``path``."""
    text = dump()
    tmp = f"{path}.tmp{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


class Reporter:
    """Periodic snapshot thread: re-dumps the registry to ``path`` every
    ``interval_s`` until :meth:`stop`."""

    def __init__(self, path: str, interval_s: float):
        self.path = path
        self.interval_s = float(interval_s)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True,
                                   name="repro-obs-reporter")
        self._t.start()

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            write(self.path)

    def stop(self) -> None:
        """Final dump, then join."""
        self._stop.set()
        self._t.join()
        write(self.path)


def start_reporter(path: str, interval_s: float = 10.0) -> Reporter:
    return Reporter(path, interval_s)
