"""Nestable tracing spans with a lock-free ring buffer (DESIGN.md §16).

A span is one timed region of a hot path::

    with span("ingest.select_chunk", chunk=i, rows=n_valid):
        ...

Spans NEST: each thread keeps a depth counter, so a Chrome-trace viewer
renders ``serve.batch`` containing ``swap.transform`` containing the kernel
dispatch as stacked bars.  Completed spans land in a bounded ``deque``
(``maxlen`` ring semantics: CPython's deque append/popleft are atomic under
the GIL, so producers on the dispatcher, producer-feed, and client threads
never take a lock on the hot path and the buffer can never grow without
bound).

Timing is wall-clock (``time.perf_counter``) by default.  JAX dispatch is
asynchronous — a wall-clock exit can close a span whose device work is still
in flight — so a span whose duration must include device completion passes
its result through :meth:`Span.sync`, which blocks until the arrays are
ready and records the synced fraction of the span separately::

    with span("serve.transform", rows=r) as sp:
        z = sp.sync(server.transform(x))   # dur now covers device work

Everything is OFF by default: ``span()`` returns a shared no-op object
(one module-global check, no allocation beyond the kwargs dict) until
``repro.obs.enable()`` flips the flag.  Exporters:

  * :func:`export_chrome` — ``chrome://tracing`` / Perfetto "X" complete
    events, one track per thread;
  * :func:`export_jsonl` — one flat JSON object per line, for ad-hoc
    ``jq``/pandas digestion.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque

#: Flipped by repro.obs.enable()/disable(); every hot-path check reads this
#: module global directly (one dict lookup — the disabled-mode cost).
_ENABLED = False

_DEFAULT_RING = 65536
_EVENTS: deque = deque(maxlen=_DEFAULT_RING)
_TLS = threading.local()

#: Process-epoch for relative timestamps: every event shares this origin so
#: cross-thread ordering in the exported trace is meaningful.
_T0 = time.perf_counter()


def _depth() -> int:
    return getattr(_TLS, "depth", 0)


class Span:
    """One live timed region; use via the :func:`span` factory."""

    __slots__ = ("name", "attrs", "t0", "sync_s")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.sync_s = 0.0

    def __enter__(self) -> "Span":
        _TLS.depth = _depth() + 1
        self.t0 = time.perf_counter()
        return self

    def sync(self, value):
        """Block until ``value``'s device work is done; the blocked wall time
        accrues to the span (reported as ``sync_s``).  Returns ``value``."""
        import jax

        t0 = time.perf_counter()
        jax.block_until_ready(value)
        self.sync_s += time.perf_counter() - t0
        return value

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (e.g. an output shape)."""
        self.attrs.update(attrs)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        depth = _depth()
        _TLS.depth = depth - 1
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        # attrs are flattened to a tuple of pairs: a ring of dicts keeps
        # 64k tracked containers alive and every GC pass pays for them,
        # whereas tuples of atoms get UNTRACKED after one young-gen scan —
        # the buffered trace then costs the collector nothing (this is
        # measurable: the serve-dispatch overhead in benchmarks/
        # obs_overhead.py was ~3% GC amplification before the flattening)
        _EVENTS.append((
            self.name, threading.get_ident(), depth - 1,
            self.t0 - _T0, t1 - self.t0, self.sync_s,
            tuple(self.attrs.items()),
        ))
        return False


class _NullSpan:
    """Shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def sync(self, value):
        return value

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


def span(name: str, **attrs):
    """A nestable timed region; no-op (shared null object) while disabled."""
    if not _ENABLED:
        return _NULL
    return Span(name, attrs)


def enabled() -> bool:
    return _ENABLED


def set_ring(maxlen: int) -> None:
    """Resize the event ring (drops buffered events)."""
    global _EVENTS
    _EVENTS = deque(maxlen=int(maxlen))


def clear() -> None:
    _EVENTS.clear()


def events() -> list[dict]:
    """Snapshot of the buffered spans, oldest first, as plain dicts."""
    return [
        {"name": n, "tid": tid, "depth": depth, "t_s": round(t, 6),
         "dur_s": round(dur, 6), "sync_s": round(sync_s, 6), **dict(attrs)}
        for n, tid, depth, t, dur, sync_s, attrs in list(_EVENTS)
    ]


def _atomic_write(path: str, text: str) -> None:
    tmp = f"{path}.tmp{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


def export_chrome(path: str) -> int:
    """Write the buffered spans as Chrome-trace JSON ("X" complete events,
    one track per thread); returns the number of events written."""
    evs = list(_EVENTS)
    out = []
    for name, tid, depth, t, dur, sync_s, attrs in evs:
        args = dict(attrs)  # ring stores flattened (k, v) pairs
        if sync_s:
            args["sync_ms"] = round(sync_s * 1e3, 3)
        out.append({
            "name": name, "ph": "X", "pid": 0, "tid": tid,
            "ts": round(t * 1e6, 1), "dur": round(dur * 1e6, 1),
            "args": args,
        })
    _atomic_write(path, json.dumps(
        {"traceEvents": out, "displayTimeUnit": "ms"}, indent=1))
    return len(out)


def export_jsonl(path: str) -> int:
    """Write the buffered spans as one flat JSON object per line."""
    evs = events()
    _atomic_write(path, "".join(json.dumps(e) + "\n" for e in evs))
    return len(evs)
