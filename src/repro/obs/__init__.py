"""Unified telemetry layer (DESIGN.md §16): tracing + metrics + health.

One switch governs everything::

    from repro import obs
    obs.enable()                  # or REPRO_OBS=1 in the environment

    with obs.span("serve.batch", rows=64):
        ...
    obs.metrics.counter("serve.requests").inc()

    obs.trace.export_chrome("trace.json")     # chrome://tracing / Perfetto
    print(obs.metrics.dump())                 # Prometheus-style text

Disabled (the default), every instrumentation site costs one function call
plus one module-global load — no locks, no allocation, no host syncs — so
the hot paths keep their benchmarked numbers (gated ~0% by
benchmarks/obs_overhead.py; enabled mode is gated <= 2%).  The flag is
process-wide and can be toggled at runtime; jitted code is never touched
(all instrumentation lives on the host driver side), so toggling never
retraces anything.

Naming conventions (§16): spans are ``subsystem.verb_noun``
(``ingest.select_chunk``), metrics are ``subsystem.noun``
(``serve.queue_depth``) with low-cardinality labels (pow2 ``bucket``,
eigenvalue index ``k``).
"""
from __future__ import annotations

import os

from repro.obs import metrics, trace
from repro.obs.spectral import SpectralHealth
from repro.obs.trace import span

__all__ = ["enable", "disable", "enabled", "span", "metrics", "trace",
           "SpectralHealth"]

_ENABLED = False


def enabled() -> bool:
    """The single flag every instrumentation site consults (via its local
    module's mirror — one global load on the disabled hot path)."""
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True
    trace._ENABLED = True
    metrics._ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False
    trace._ENABLED = False
    metrics._ENABLED = False


def _enable_from_env() -> None:
    """``REPRO_OBS=1`` turns observability on at import (how the demo and
    the overhead bench's enabled mode run without code changes)."""
    if os.environ.get("REPRO_OBS", "0") not in ("", "0"):
        enable()


_enable_from_env()
