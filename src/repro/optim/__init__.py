from repro.optim.adamw import (  # noqa: F401
    OptState, adamw_init, adamw_update, global_norm, clip_by_global_norm,
)
from repro.optim.schedules import warmup_cosine, constant  # noqa: F401
