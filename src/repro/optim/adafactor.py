"""Adafactor (Shazeer & Stern 2018) — factored second moment, no momentum.

Memory-critical for kimi-k2 (1T params): f32 AdamW needs ~12 TB of optimizer
+ master state; Adafactor's row/col factors are O(n+m) per matrix.  With bf16
params this brings the 1T-param train step inside a 256-chip v5e pod
(DESIGN.md §14).  Matrices (and the trailing two dims of stacked/3D+ leaves)
are factored; vectors keep a full second moment.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class FactoredState(NamedTuple):
    step: jax.Array
    vr: PyTree   # row factors (or full v for <2D leaves)
    vc: PyTree   # col factors (None placeholder for <2D leaves)


def _is_factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 8 and shape[-2] >= 8


def adafactor_init(params: PyTree) -> FactoredState:
    def vr_init(p):
        if _is_factored(p.shape):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc_init(p):
        if _is_factored(p.shape):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return FactoredState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(vr_init, params),
        vc=jax.tree.map(vc_init, params),
    )


def adafactor_update(grads: PyTree, state: FactoredState, params: PyTree, *,
                     lr, eps: float = 1e-30, clip_threshold: float = 1.0,
                     decay_exponent: float = 0.8,
                     weight_decay: float = 0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-decay_exponent)

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _is_factored(p.shape):
            vr_new = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
            vc_new = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
            # rank-1 reconstruction of the second moment
            denom = vr_new[..., None] * vc_new[..., None, :] / jnp.maximum(
                vr_new.mean(axis=-1)[..., None, None], eps)
            u = g / jnp.sqrt(jnp.maximum(denom, eps))
        else:
            vr_new = beta2 * vr + (1 - beta2) * g2
            vc_new = vc
            u = g / jnp.sqrt(jnp.maximum(vr_new, eps))
        # update clipping: rms(u) <= clip_threshold
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        new_p = p.astype(jnp.float32) - lr * u
        if weight_decay:
            new_p = new_p - lr * weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), vr_new, vc_new

    out = jax.tree.map(upd, params, grads, state.vr, state.vc)
    is3 = lambda x: isinstance(x, tuple)
    new_params = jax.tree.map(lambda t3: t3[0], out, is_leaf=is3)
    new_vr = jax.tree.map(lambda t3: t3[1], out, is_leaf=is3)
    new_vc = jax.tree.map(lambda t3: t3[2], out, is_leaf=is3)
    return new_params, FactoredState(step, new_vr, new_vc), {}
