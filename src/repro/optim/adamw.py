"""Native AdamW (no optax in this container) with pytree states.

Moments inherit each parameter's sharding automatically under pjit (they are
elementwise functions of the params), so FSDP shards optimizer state for free.
Master params stay f32; the model may cast to bf16 at use sites.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array   # ()
    mu: PyTree        # first moment
    nu: PyTree        # second moment


def adamw_init(params: PyTree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(grads: PyTree, state: OptState, params: PyTree, *,
                 lr: jax.Array | float, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 max_grad_norm: float | None = 1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1**t
    c2 = 1.0 - b2**t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1.0 - b1) * g
        nu = b2 * nu + (1.0 - b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        delta = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t3: t3[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t3: t3[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t3: t3[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_mu, new_nu), {"grad_norm": gnorm}
