"""Sharded, atomic, async checkpointing (no orbax in this container).

Layout:
    <dir>/step_<N>/
        meta.json            tree structure + per-leaf shape/dtype/sharding
        shard_<host>.npz     every leaf-shard owned by this host, keyed
                             "<leaf_idx>/<shard_idx>" with index metadata
    <dir>/LATEST             published last -> restart never sees a torn ckpt

Fault-tolerance contract (DESIGN.md §11/§17):
  * atomic publish: write into step_<N>.tmp, fsync, rename, then update LATEST;
  * every shard file carries a crc32 in meta.json, verified on restore —
    bit-rot or a torn write raises :class:`CheckpointCorrupt` instead of
    silently restoring garbage (chaos-tested via the ``checkpoint.shard``
    corrupt site); resumers fall back through :func:`available_steps`;
  * restore is sharding-agnostic: leaves are reassembled on the host and
    re-placed under ANY target mesh/sharding -> elastic restarts onto a
    smaller/larger mesh work (tested in tests/test_checkpoint.py); a leaf
    whose template in ``tree_like`` is a NUMPY array restores as numpy with
    its saved dtype intact (float64 ingest masses must not round through
    jnp's default f32);
  * async: a single worker thread serializes saves; `wait()` joins before
    the next save, and an ``atexit`` hook joins any in-flight save on
    interpreter exit — a daemon worker must never be killed mid-write.

Chaos injection sites (runtime/chaos.py): ``checkpoint.save`` fires before
the step-directory rename (a crash mid-publish: tmp left behind, LATEST
untouched), ``checkpoint.latest`` before the LATEST pointer swap (step
published but not pointed at), ``checkpoint.shard`` corrupts shard bytes
after the crc is recorded (bit-rot the crc check must catch).
"""
from __future__ import annotations

import atexit
import json
import os
import shutil
import threading
import weakref
import zlib
from typing import Any

import jax
import ml_dtypes
import numpy as np

from repro.runtime import chaos

PyTree = Any


class CheckpointCorrupt(RuntimeError):
    """A shard file's bytes do not match the crc recorded at save time."""

# numpy .npz cannot store ml_dtypes (bfloat16, float8_*): serialize them as
# a same-width integer view and restore via the recorded dtype string.
_VIEW_CODECS = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _encode(arr: np.ndarray) -> np.ndarray:
    name = arr.dtype.name
    if name in _VIEW_CODECS:
        return arr.view(_VIEW_CODECS[name][0])
    return arr


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_CODECS:
        return arr.view(_VIEW_CODECS[dtype_name][1])
    return arr


def _tree_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    extra_meta: dict | None = None) -> str:
    """Blocking sharded save. Returns the published step directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves, _ = _tree_paths(tree)
    meta = {"step": step, "leaves": [], "extra": extra_meta or {}}
    shards: dict[str, np.ndarray] = {}
    for li, (path, leaf) in enumerate(zip(paths, leaves)):
        leaf = jax.numpy.asarray(leaf) if np.isscalar(leaf) else leaf
        entry = {"path": path, "shape": list(np.shape(leaf)),
                 "dtype": str(np.asarray(jax.tree.leaves(leaf)[0]).dtype
                              if not hasattr(leaf, "dtype") else leaf.dtype)}
        if isinstance(leaf, jax.Array) and len(leaf.addressable_shards) > 1:
            entry["sharded"] = True
            for si, shard in enumerate(leaf.addressable_shards):
                shards[f"{li}/{si}"] = _encode(np.asarray(shard.data))
                meta.setdefault("indices", {})[f"{li}/{si}"] = [
                    [s.start or 0, s.stop if s.stop is not None else dim]
                    for s, dim in zip(shard.index, np.shape(leaf))
                ]
        else:
            entry["sharded"] = False
            shards[f"{li}/0"] = _encode(np.asarray(leaf))
        meta["leaves"].append(entry)

    host = jax.process_index() if jax.process_count() > 1 else 0
    shard_name = f"shard_{host}.npz"
    shard_path = os.path.join(tmp, shard_name)
    np.savez(shard_path, **shards)
    # crc the exact bytes just written; restore refuses a mismatch.  The
    # chaos corrupt site fires AFTER the crc is recorded — modelling rot
    # between write and read, which is precisely what the crc must catch.
    with open(shard_path, "rb") as f:
        raw = np.frombuffer(f.read(), np.uint8)
    meta["crc"] = {shard_name: zlib.crc32(raw.tobytes())}
    rotted = chaos.corrupt("checkpoint.shard", raw)
    if rotted is not raw:
        with open(shard_path, "wb") as f:
            f.write(rotted.tobytes())
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    chaos.inject("checkpoint.save")   # crash before publish: tmp left over
    if os.path.exists(final):  # idempotent same-step re-save
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on POSIX
    chaos.inject("checkpoint.latest")  # crash between publish and pointer
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> int | None:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def available_steps(directory: str) -> list[int]:
    """All PUBLISHED step numbers under ``directory``, ascending.

    ``step_<N>.tmp`` leftovers (a save that crashed before its rename) are
    by construction excluded — a resumer walking this list newest-first and
    falling back on :class:`CheckpointCorrupt` always lands on the newest
    intact checkpoint, even when LATEST points at a rotted one.
    """
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.isfile(os.path.join(directory, name,
                                                "meta.json")):
            steps.append(int(name[len("step_"):]))
    return sorted(steps)


def restore_checkpoint(directory: str, tree_like: PyTree,
                       step: int | None = None,
                       shardings: PyTree | None = None) -> tuple[PyTree, int]:
    """Restore into the structure of ``tree_like``; optionally re-place each
    leaf under ``shardings`` (same treedef) — this is the elastic-restart path.
    """
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint under {directory}"
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, "meta.json")) as f:
        meta = json.load(f)

    buffers: dict[str, np.ndarray] = {}
    for fname in sorted(os.listdir(final)):
        if fname.startswith("shard_") and fname.endswith(".npz"):
            path = os.path.join(final, fname)
            want = meta.get("crc", {}).get(fname)
            if want is not None:
                with open(path, "rb") as f:
                    got = zlib.crc32(f.read())
                if got != want:
                    raise CheckpointCorrupt(
                        f"{path}: crc {got:#x} != recorded {want:#x} — "
                        f"torn or rotted shard; fall back via "
                        f"available_steps()")
            with np.load(path) as z:
                buffers.update({k: z[k] for k in z.files})

    paths, leaves, treedef = _tree_paths(tree_like)
    assert len(meta["leaves"]) == len(leaves), \
        f"checkpoint has {len(meta['leaves'])} leaves, target {len(leaves)}"
    out = []
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    for li, entry in enumerate(meta["leaves"]):
        shape = tuple(entry["shape"])
        if entry["sharded"]:
            np_dtype = (_VIEW_CODECS[entry["dtype"]][1]
                        if entry["dtype"] in _VIEW_CODECS else entry["dtype"])
            full = np.zeros(shape, dtype=np_dtype)
            for key, idx in meta.get("indices", {}).items():
                if key.startswith(f"{li}/"):
                    sl = tuple(slice(a, b) for a, b in idx)
                    full[sl] = _decode(buffers[key], entry["dtype"])
        else:
            full = _decode(buffers[f"{li}/0"], entry["dtype"])
        if shard_leaves[li] is not None:
            out.append(jax.device_put(full, shard_leaves[li]))
        elif isinstance(leaves[li], np.ndarray):
            # numpy template -> numpy restore, saved dtype INTACT: routing
            # float64 through jnp.asarray would silently round the ingest
            # pipeline's weight-exact f64 masses to f32 (x64 is off)
            out.append(full)
        else:
            out.append(jax.numpy.asarray(full))
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """One background save in flight at a time; device->host copy happens on
    the caller thread (cheap), serialization/IO on the worker.

    The worker is a daemon thread, and daemon threads are KILLED mid-write
    when the interpreter exits — a save racing process exit would leave a
    truncated ``step_<N>.tmp`` (never published, but the work is lost) or,
    worse, die between its fsync and rename.  Every live checkpointer
    therefore registers in a module-level WeakSet joined by an ``atexit``
    hook: atexit runs BEFORE daemon threads are reaped, so an in-flight
    save always completes its atomic publish (tests/test_checkpoint.py
    races a save against ``sys.exit`` in a subprocess)."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None
        _LIVE_CHECKPOINTERS.add(self)

    def save(self, step: int, tree: PyTree, extra_meta: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before mutation
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, host_tree, extra_meta),
            daemon=True,
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


_LIVE_CHECKPOINTERS: "weakref.WeakSet[AsyncCheckpointer]" = weakref.WeakSet()


@atexit.register
def _join_in_flight_saves() -> None:
    for ckpt in list(_LIVE_CHECKPOINTERS):
        try:
            ckpt.wait()
        except Exception:  # joining must never turn exit into a traceback
            pass
