"""Single-pass select->fit pipeline (DESIGN.md §6).

``fit_shadow_fused`` runs blocked shadow selection (Algorithm 2, §3) and
Algorithm 1's fit as one device-resident dataflow:

  * selection runs to exhaustion inside ONE jitted while_loop
    (``shadow._blocked_select_device`` with ``stop_count=0``) — the accepted
    centers scatter straight into a preallocated (n, d) device buffer;
  * the ONLY host synchronization between the stages is the scalar center
    count m (needed to pick the power-of-two capacity bucket the fit
    compiles against — the same bucketing contract as streaming/serving);
  * the fit consumes a ``cap``-row slice of the selection output directly:
    no host round-trip of the center data, no re-padding — rows beyond m
    carry zero weight, which zeroes their K-tilde rows/columns and their
    projector rows (the established zero-weight-padding invariant);
  * the sliced center/weight buffers are donated into the jitted fit
    (``_fit_rskpca_device``) and XLA reuses their storage (the model's
    center rows are materialized to host BEFORE the donation, since a
    cap == n slice is the selection buffer itself);
  * above the matrix-free crossover (kernels.ops.matfree_fit) the fit's
    eigensolve streams Gram tiles through the fused ``gram_matvec`` kernel —
    the select->fit pipeline then never materializes ANY m x m buffer.

Tradeoff vs ``shadow_select_blocked``: the host-compaction cascade (§3)
halves late-round absorption work but pays a host sync + re-upload per
phase; the fused loop keeps everything device-resident at full-n absorption
cost per round.  At large n/m — exactly where the matrix-free fit engages —
the removed host traffic wins; below it ``selector="blocked"`` remains the
default.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_math import Kernel
from repro.core import shadow as shadow_mod
from repro.core.shadow import _pow2_ceil
from repro.core.rskpca import KPCAModel, _fit_rskpca_device, _use_matfree


def fit_centers(centers, weights, n: int, kernel: Kernel, rank: int, *,
                m: int | None = None, matfree: bool | None = None,
                method: str = "rskpca") -> KPCAModel:
    """Capacity-bucketed Algorithm 1 fit of a selected center set — the
    shared fit tail of the fused (``fit_shadow_fused``) and out-of-core
    (``ingest_pipeline.ingest_fit``) pipelines.

    ``centers``/``weights`` may be a device buffer with ``m`` live rows (the
    fused selector's preallocated (n, d) output, sliced here without a host
    round-trip) or an exact host (m, d) set (the streaming merge's).  Either
    way they are sliced/zero-padded to the power-of-two capacity bucket —
    zero-weight rows contribute zero K-tilde rows/columns and zero projector
    rows — so re-jit count stays logarithmic across m.  The cap slices are
    donated into the jitted device fit; ``matfree=None`` consults the
    bytes-budget crossover (above it no m x m buffer ever materializes).
    """
    c = jnp.asarray(centers, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    m = c.shape[0] if m is None else int(m)
    rank = min(rank, m)
    cap = min(max(c.shape[0], 128), _pow2_ceil(max(m, 128)))
    # materialize the model's center rows BEFORE the fit: the cap slices are
    # donated into it, and when cap == c.shape[0] jax's full-slice fast path
    # returns `c` ITSELF — reading it after donation would hit a deleted array
    centers_host = np.asarray(c[:m], np.float32)
    if c.shape[0] < cap:  # host center sets arrive exactly (m, d): pad
        c = jnp.concatenate(
            [c, jnp.zeros((cap - c.shape[0], c.shape[1]), jnp.float32)])
        w = jnp.concatenate([w, jnp.zeros((cap - w.shape[0],), jnp.float32)])
    use_mf = _use_matfree(kernel, cap, rank, matfree)
    lam, proj = _fit_rskpca_device(c[:cap], w[:cap], jnp.float32(n), kernel,
                                   rank, matfree=use_mf)
    return KPCAModel(
        kernel=kernel,
        centers=centers_host,
        projector=np.asarray(proj[:m]),
        eigvals=np.asarray(lam),
        method=method,
    )


def fit_shadow_fused(x, kernel: Kernel, rank: int, *, ell: float,
                     block: int | None = None,
                     matfree: bool | None = None) -> KPCAModel:
    """ShDE selection + RSKPCA fit with the centers never leaving device.

    Equivalent to ``fit(x, ..., method="shadow", selector="blocked")``
    followed by ``fit_rskpca`` — same cover invariants, same operator — but
    with the intermediate RSDE elided.  ``matfree=None`` consults the
    bytes-budget crossover; the model is materialized to host only at the
    very end (sliced to the true m).
    """
    xf = jnp.asarray(x, jnp.float32)
    n, d = xf.shape
    eps2 = jnp.float32(kernel.epsilon(ell)) ** 2
    b = max(1, min(256 if block is None else block, n))
    _, centers, weights, _, m_dev = shadow_mod._blocked_select_device(
        xf, eps2, b, jnp.ones((n,), bool), jnp.asarray(0, jnp.int32))
    m = int(m_dev)  # the pipeline's single host sync: one scalar
    return fit_centers(centers, weights, n, kernel, rank, m=m,
                       matfree=matfree, method="rskpca+shadow-fused")
