"""Shadow set selection (paper Algorithm 2) — the ShDE center selector.

Greedy single-pass epsilon-cover: take the first remaining point ``c``, absorb
every point within ``eps = sigma / ell`` (the *shadow* of ``c``), weight ``c``
by the shadow size, repeat until the dataset is exhausted.  Cost O(mn).

Two implementations:
  * ``shadow_select_np``  — numpy oracle, literal transcription of Algorithm 2.
  * ``shadow_select``     — jittable ``lax.while_loop`` version with static
    padding (``max_centers``); returns (centers, weights, assign, m).

Invariants (property-tested in tests/test_shadow.py):
  * every data point lies strictly within eps of its assigned center;
  * shadow sets partition the data: weights sum to n;
  * centers are pairwise >= eps apart ... for the *sequential* algorithm
    (each new center was not absorbed by any earlier one);
  * m is monotonically non-increasing in eps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def shadow_select_np(x: np.ndarray, eps: float):
    """Literal Algorithm 2 (numpy oracle). Returns (centers, weights, assign)."""
    n = x.shape[0]
    alive = np.ones(n, dtype=bool)
    assign = np.full(n, -1, dtype=np.int64)
    centers, weights = [], []
    eps2 = eps * eps
    while alive.any():
        i = int(np.argmax(alive))  # first element of the remaining set
        c = x[i]
        d2 = ((x - c) ** 2).sum(axis=1)
        shadow = alive & (d2 < eps2)  # strict inequality, per Algorithm 2
        assign[shadow] = len(centers)
        centers.append(c)
        weights.append(int(shadow.sum()))
        alive &= ~shadow
    return np.asarray(centers), np.asarray(weights, dtype=np.float64), assign


@partial(jax.jit, static_argnames=("max_centers",))
def shadow_select(x: Array, eps: Array, max_centers: int):
    """Jittable Algorithm 2.

    Args:
      x: (n, d) data.
      eps: shadow radius sigma/ell.
      max_centers: static bound on m (use n for exactness).

    Returns:
      centers: (max_centers, d), zero-padded beyond m.
      weights: (max_centers,) float32, zero beyond m.  sum == n.
      assign:  (n,) int32 data->center map (alpha in §5).
      m:       int32 number of centers actually selected.
    """
    n, d = x.shape
    xf = x.astype(jnp.float32)
    eps2 = jnp.asarray(eps, jnp.float32) ** 2

    def cond(state):
        alive, *_ = state
        return alive.any()

    def body(state):
        alive, centers, weights, assign, m = state
        i = jnp.argmax(alive)  # first alive index
        c = xf[i]
        d2 = jnp.sum((xf - c[None, :]) ** 2, axis=1)
        shadow = alive & (d2 < eps2)
        centers = centers.at[m].set(c)
        weights = weights.at[m].set(shadow.sum().astype(jnp.float32))
        assign = jnp.where(shadow, m, assign)
        # Guard: if m hits max_centers, absorb everything remaining into the
        # last center so the loop terminates (only possible if max_centers < n
        # and eps is tiny; callers use max_centers = n for exactness).
        overflow = m >= max_centers - 1
        shadow = jnp.where(overflow, alive, shadow)
        assign = jnp.where(overflow & alive, m, assign)
        weights = jnp.where(
            overflow,
            weights.at[m].set(alive.sum().astype(jnp.float32)),
            weights,
        )
        alive = alive & ~shadow
        return alive, centers, weights, assign, m + 1

    state = (
        jnp.ones(n, dtype=bool),
        jnp.zeros((max_centers, d), jnp.float32),
        jnp.zeros((max_centers,), jnp.float32),
        jnp.full((n,), -1, jnp.int32),
        jnp.asarray(0, jnp.int32),
    )
    alive, centers, weights, assign, m = jax.lax.while_loop(cond, body, state)
    return centers, weights, assign.astype(jnp.int32), m


def shadow_select_host(x, eps: float):
    """Convenience host wrapper: jitted select, then slice to the true m."""
    x = jnp.asarray(x)
    centers, weights, assign, m = shadow_select(x, eps, max_centers=x.shape[0])
    m = int(m)
    return np.asarray(centers[:m]), np.asarray(weights[:m]), np.asarray(assign), m


def two_level_merge(centers: Array, weights: Array, eps: Array,
                    max_centers: int):
    """Second-level shadow pass over candidate centers (distributed variant).

    Runs Algorithm 2 on the *centers* themselves, summing absorbed weights
    instead of counting points.  Quantization error of the two-level scheme is
    at most 2*eps (triangle inequality), i.e. the paper's bounds hold with
    ell -> ell/2 in the worst case (DESIGN.md §3).
    """
    n, d = centers.shape
    cf = centers.astype(jnp.float32)
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    alive0 = weights > 0  # padded slots carry zero weight

    def cond(state):
        alive, *_ = state
        return alive.any()

    def body(state):
        alive, out_c, out_w, m = state
        i = jnp.argmax(alive)
        c = cf[i]
        d2 = jnp.sum((cf - c[None, :]) ** 2, axis=1)
        shadow = alive & (d2 < eps2)
        out_c = out_c.at[m].set(c)
        out_w = out_w.at[m].set(jnp.where(shadow, weights, 0.0).sum())
        overflow = m >= max_centers - 1
        shadow = jnp.where(overflow, alive, shadow)
        out_w = jnp.where(
            overflow, out_w.at[m].set(jnp.where(alive, weights, 0.0).sum()), out_w
        )
        alive = alive & ~shadow
        return alive, out_c, out_w, m + 1

    state = (
        alive0,
        jnp.zeros((max_centers, d), jnp.float32),
        jnp.zeros((max_centers,), jnp.float32),
        jnp.asarray(0, jnp.int32),
    )
    _, out_c, out_w, m = jax.lax.while_loop(cond, body, state)
    return out_c, out_w, m
