"""Shadow set selection (paper Algorithm 2) — the ShDE center selector.

Greedy single-pass epsilon-cover: take the first remaining point ``c``, absorb
every point within ``eps = sigma / ell`` (the *shadow* of ``c``), weight ``c``
by the shadow size, repeat until the dataset is exhausted.  Cost O(mn).

Implementations (DESIGN.md §3):
  * ``shadow_select_np``      — numpy oracle, literal Algorithm 2.
  * ``shadow_select``         — jittable ``lax.while_loop`` version with
    static padding (``max_centers``); sequential depth m.
  * ``shadow_select_blocked`` — blocked selector: each round keeps a batch of
    up to B mutually-eps-separated candidates and absorbs all their shadows
    in ONE Pallas assignment pass, cutting sequential depth from m to ~m/B.
  * ``shadow_select_streaming`` — two-level path for data that doesn't fit in
    device memory: per-chunk blocked selection + a ``StreamingMerge`` fold
    (cover radius degrades to 2*eps; the §5 bounds hold with ell -> ell/2).
  * ``StreamingMerge``     — weight-exact streaming reconciliation of
    candidate-center batches (the level-2 merge of the out-of-core ingest
    pipeline, core/ingest_pipeline.py), with center-budget spill handling;
    ``two_level_merge`` remains the one-shot replicated-merge variant the
    sharded selector uses.

Invariants (property-tested in tests/test_shadow.py):
  * every data point lies strictly within eps of its assigned center;
  * shadow sets partition the data: weights sum to n;
  * centers are pairwise >= eps apart (blocked selection preserves this: the
    batch is pruned to a mutually-separated prefix subset, and later rounds
    only see points no earlier center absorbed);
  * m is monotonically non-increasing in eps ... for the sequential order.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops

Array = jax.Array


def shadow_select_np(x: np.ndarray, eps: float):
    """Literal Algorithm 2 (numpy oracle). Returns (centers, weights, assign)."""
    n = x.shape[0]
    alive = np.ones(n, dtype=bool)
    assign = np.full(n, -1, dtype=np.int64)
    centers, weights = [], []
    eps2 = eps * eps
    while alive.any():
        i = int(np.argmax(alive))  # first element of the remaining set
        c = x[i]
        d2 = ((x - c) ** 2).sum(axis=1)
        shadow = alive & (d2 < eps2)  # strict inequality, per Algorithm 2
        assign[shadow] = len(centers)
        centers.append(c)
        weights.append(int(shadow.sum()))
        alive &= ~shadow
    return np.asarray(centers), np.asarray(weights, dtype=np.float64), assign


@partial(jax.jit, static_argnames=("max_centers",))
def shadow_select(x: Array, eps: Array, max_centers: int, valid=None):
    """Jittable Algorithm 2.

    Args:
      x: (n, d) data.
      eps: shadow radius sigma/ell.
      max_centers: static bound on m (use n for exactness).
      valid: optional (n,) bool mask — False rows are padding: never
        selected, never counted (the distributed path pads n to a device
        multiple and masks the tail).

    Returns:
      centers: (max_centers, d), zero-padded beyond m.
      weights: (max_centers,) float32, zero beyond m.  sum == #valid.
      assign:  (n,) int32 data->center map (alpha in §5); -1 on padding.
      m:       int32 number of centers actually selected.
    """
    n, d = x.shape
    xf = x.astype(jnp.float32)
    eps2 = jnp.asarray(eps, jnp.float32) ** 2

    def cond(state):
        alive, *_ = state
        return alive.any()

    def body(state):
        alive, centers, weights, assign, m = state
        i = jnp.argmax(alive)  # first alive index
        c = xf[i]
        d2 = jnp.sum((xf - c[None, :]) ** 2, axis=1)
        shadow = alive & (d2 < eps2)
        centers = centers.at[m].set(c)
        weights = weights.at[m].set(shadow.sum().astype(jnp.float32))
        assign = jnp.where(shadow, m, assign)
        # Guard: if m hits max_centers, absorb everything remaining into the
        # last center so the loop terminates (only possible if max_centers < n
        # and eps is tiny; callers use max_centers = n for exactness).
        overflow = m >= max_centers - 1
        shadow = jnp.where(overflow, alive, shadow)
        assign = jnp.where(overflow & alive, m, assign)
        weights = jnp.where(
            overflow,
            weights.at[m].set(alive.sum().astype(jnp.float32)),
            weights,
        )
        alive = alive & ~shadow
        return alive, centers, weights, assign, m + 1

    state = (
        jnp.ones(n, dtype=bool) if valid is None else valid.astype(bool),
        jnp.zeros((max_centers, d), jnp.float32),
        jnp.zeros((max_centers,), jnp.float32),
        jnp.full((n,), -1, jnp.int32),
        jnp.asarray(0, jnp.int32),
    )
    alive, centers, weights, assign, m = jax.lax.while_loop(cond, body, state)
    return centers, weights, assign.astype(jnp.int32), m


def shadow_select_host(x, eps: float):
    """Convenience host wrapper: jitted select, then slice to the true m."""
    x = jnp.asarray(x)
    centers, weights, assign, m = shadow_select(x, eps, max_centers=x.shape[0])
    m = int(m)
    return np.asarray(centers[:m]), np.asarray(weights[:m]), np.asarray(assign), m


@partial(jax.jit, static_argnames=("block",))
def _blocked_select_device(xf: Array, eps2: Array, block: int,
                           alive0: Array, stop_count: Array, w0=None):
    """Blocked-selection rounds fused in ONE device while_loop, running
    until the alive set drops to ``stop_count`` (0 = exhaust it).

    ``alive0`` lets the caller mark padding rows dead up front (the
    compaction cascade in ``shadow_select_blocked`` pads the shrunken alive
    set to a power of two so re-jits stay bounded).  ``w0`` (optional (n,)
    f32) gives each point a MASS instead of unit count — the streaming
    merge runs selection over weighted candidate centers, and a keeper's
    weight is then the sum of absorbed masses rather than a point count.

    Per round (the old per-round host loop paid a host sync + numpy
    conversion per round — fusing the loop cut n=32k selection ~2x):

    1. Gather the first ``block`` still-alive points (index order) as the
       candidate batch.
    2. Prune the batch to the greedy prefix-independent subset: candidate j
       is KEPT iff it is >= eps from every kept candidate before it — the
       same rule sequential Algorithm 2 applies, restricted to the batch.
    3. Absorb: one Pallas nearest-center pass of ALL points against the kept
       candidates; any alive point strictly within eps joins the shadow of
       its nearest kept candidate.  Keepers scatter into the preallocated
       (n, d) center buffer at positions m + rank (invalid slots dropped).

    Every alive candidate leaves the alive set each round (kept ones absorb
    themselves; dropped ones are within eps of the keeper that shadowed
    them), so the round count is <= ceil(m/1) and typically ~m/B.
    """
    n, d = xf.shape
    iota = jnp.arange(n)

    def round_core(alive):
        # indices of the first `block` alive points (dead points sort last)
        order = jnp.argsort(jnp.where(alive, iota, n + iota))
        cand_idx = order[:block]
        cand_alive = alive[cand_idx]
        cand = xf[cand_idx]                                # (B, d)
        d2c = jnp.sum((cand[:, None, :] - cand[None, :, :]) ** 2, axis=-1)

        def pick(j, keep):
            sep = jnp.all(jnp.where(keep, d2c[:, j] >= eps2, True))
            return keep.at[j].set(cand_alive[j] & sep)

        keep = jax.lax.fori_loop(0, block, pick, jnp.zeros((block,), bool))

        idx, d2min = kernel_ops.shadow_assign(
            xf, cand, valid=keep.astype(jnp.float32))
        # Candidate rows must resolve against the batch via the
        # direct-difference d2c, which is exact at zero distance: the assign
        # kernel's expansion form rounds off near zero, and at tiny eps a
        # keeper could then fail to absorb even itself and the round would
        # never make progress.  This also guarantees every alive candidate
        # leaves the alive set each round (a dropped candidate is, by the
        # pick rule, within eps of some keeper).
        d2c_kept = jnp.where(keep[:, None], d2c, jnp.inf)  # (B, B)
        idx = idx.at[cand_idx].set(
            jnp.argmin(d2c_kept, axis=0).astype(idx.dtype))
        d2min = d2min.at[cand_idx].set(jnp.min(d2c_kept, axis=0))
        absorbed = alive & (d2min < eps2)
        mass = jnp.where(absorbed, 1.0, 0.0) if w0 is None \
            else jnp.where(absorbed, w0, 0.0)
        counts = jnp.zeros((block,), jnp.float32).at[idx].add(mass)
        kept_rank = jnp.cumsum(keep) - 1                   # rank among kept
        return cand, keep, counts, idx, absorbed, kept_rank

    def cond(state):
        alive = state[0]
        return alive.any() & (alive.sum(dtype=jnp.int32) > stop_count)

    def body(state):
        alive, centers, weights, assign, m = state
        cand, keep, counts, idx, absorbed, kept_rank = round_core(alive)
        pos = jnp.where(keep, m + kept_rank, n)  # n = out-of-bounds: dropped
        centers = centers.at[pos].set(cand, mode="drop")
        weights = weights.at[pos].set(counts, mode="drop")
        assign = jnp.where(absorbed,
                           (m + kept_rank[idx]).astype(jnp.int32), assign)
        alive = alive & ~absorbed
        return alive, centers, weights, assign, \
            m + keep.sum(dtype=jnp.int32)

    state = (
        alive0,
        jnp.zeros((n, d), jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.full((n,), -1, jnp.int32),
        jnp.asarray(0, jnp.int32),
    )
    alive, centers, weights, assign, m = jax.lax.while_loop(cond, body, state)
    return alive, centers, weights, assign, m


def _pow2_ceil(v: int) -> int:
    return 1 << max(v - 1, 0).bit_length()


def shadow_select_blocked(x, eps: float, block: int | None = None,
                          weights=None):
    """Blocked Algorithm 2: ~m/B sequential rounds instead of m iterations,
    fused in device while_loops (no per-round host sync).

    Work efficiency: every round's absorption pass costs O(alive_now * B),
    but late rounds mostly revisit dead points if the loop keeps the full
    array.  So the device loop runs until the alive set HALVES, the host
    compacts the survivors (padded to a power of two so re-jit count stays
    logarithmic), and selection resumes on the smaller array — total
    absorption work drops from rounds*n to ~2x the first phase.

    Returns (centers (m, d), weights (m,), assign (n,), m) exactly like
    ``shadow_select_host``.  The center SET differs from the sequential order
    (points absorb to their NEAREST keeper, not the first), but all cover
    invariants hold: strict eps-cover, weights partition n, centers pairwise
    >= eps apart (a later-phase candidate was, by construction, never within
    eps of any earlier keeper).

    ``weights`` (optional (n,) masses) runs the WEIGHTED variant the
    streaming merge needs: each input point carries a mass and a keeper's
    output weight is the sum of absorbed masses (== point count when every
    mass is 1).  Output weights then partition ``sum(weights)`` instead
    of ``n``.
    """
    x_np = np.asarray(x, np.float32)
    n = x_np.shape[0]
    block = 256 if block is None else block
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    assign = np.full((n,), -1, np.int64)
    centers_out, weights_out = [], []
    m = 0
    cur_x = x_np                    # padded working set
    cur_orig = np.arange(n)         # padded-row -> original-row map
    cur_alive = np.ones((n,), bool)
    cur_w = None if weights is None else np.asarray(weights, np.float32)
    while cur_alive.any():
        b = max(1, min(block, cur_x.shape[0]))
        n_alive = int(cur_alive.sum())
        alive, c, w, a, mm = _blocked_select_device(
            jnp.asarray(cur_x), eps2, b, jnp.asarray(cur_alive),
            jnp.asarray(n_alive // 2, jnp.int32),
            None if cur_w is None else jnp.asarray(cur_w))
        mm = int(mm)
        a = np.asarray(a)
        absorbed = a >= 0
        assign[cur_orig[absorbed]] = m + a[absorbed]
        centers_out.append(np.asarray(c[:mm]))
        weights_out.append(np.asarray(w[:mm]))
        m += mm
        still = np.flatnonzero(np.asarray(alive))
        if still.size == 0:
            break
        # compact survivors; pad to a power of two with dead zero rows so
        # the number of distinct jit shapes stays logarithmic
        npad = _pow2_ceil(still.size)
        nxt = np.zeros((npad, x_np.shape[1]), np.float32)
        nxt[: still.size] = cur_x[still]
        cur_x = nxt
        nxt_orig = np.zeros((npad,), np.int64)
        nxt_orig[: still.size] = cur_orig[still]
        cur_orig = nxt_orig
        cur_alive = np.zeros((npad,), bool)
        cur_alive[: still.size] = True
        if cur_w is not None:
            nxt_w = np.zeros((npad,), np.float32)
            nxt_w[: still.size] = cur_w[still]
            cur_w = nxt_w
    return (np.concatenate(centers_out),
            np.concatenate(weights_out).astype(np.float64),
            assign, m)


class StreamingMerge:
    """Weight-exact streaming extension of ``two_level_merge`` (DESIGN.md
    §9): reconcile candidate-center batches ONE BATCH AT A TIME instead of
    requiring every level-1 center in memory at once.

    Per ``update(cand_c, cand_w)``:

    1. **Absorb** — one assignment pass of the candidates against the
       current merged set; any candidate strictly within eps of a merged
       center hands its mass to that center.  Duplicate centers across
       chunk/shard boundaries land here (d2 == 0 < eps^2), so they merge
       instead of accumulating.
    2. **Select** — survivors (all >= eps from every merged center) run
       WEIGHTED blocked selection among themselves, restoring pairwise
       eps-separation; the kept centers append to the merged set.
    3. **Spill** — if appending would exceed ``budget`` centers, the
       over-budget keepers are instead absorbed into their nearest
       retained center (merged set + kept prefix) regardless of distance;
       ``spilled``/``max_spill_dist`` record how much cover quality the
       budget cost.

    Mass bookkeeping is float64 on host, so for integer point masses the
    invariant ``weights.sum() == total ingested mass`` holds EXACTLY up to
    2^53 (the one-shot device merge is only exact to f32's 2^24).  Cover
    radius of the merged set is 2*eps (triangle inequality), exactly like
    ``two_level_merge`` — the §5 bounds hold with ell -> ell/2.
    """

    def __init__(self, d: int, eps: float, budget: int | None = None,
                 block: int | None = 256):
        self.d = int(d)
        self.eps = float(eps)
        self.budget = None if budget is None else int(budget)
        self.block = 256 if block is None else int(block)
        self._c = np.zeros((0, self.d), np.float32)
        self._w = np.zeros((0,), np.float64)
        self.spilled = 0
        self.max_spill_dist = 0.0

    @property
    def m(self) -> int:
        return self._c.shape[0]

    @property
    def centers(self) -> np.ndarray:
        return self._c

    @property
    def weights(self) -> np.ndarray:
        return self._w

    # -- crash-consistent state (DESIGN.md §17) ---------------------------
    # The merge IS the only cross-chunk ingest state, so these two methods
    # are the whole checkpoint/resume contract: state() -> a flat numpy
    # tree checkpoint/store can publish atomically, load_state() -> the
    # bit-identical merge (f32 centers and f64 masses round-trip exactly;
    # the store's numpy-template restore preserves the f64 dtype).

    def state(self) -> dict:
        return {
            "centers": np.array(self._c, np.float32),
            "weights": np.array(self._w, np.float64),
            "spilled": np.asarray(self.spilled, np.int64),
            "max_spill_dist": np.asarray(self.max_spill_dist, np.float64),
        }

    def state_template(self) -> dict:
        """Zero-row tree with the same structure/dtypes as :meth:`state`
        (restore takes shapes from the checkpoint meta, not the template)."""
        return {
            "centers": np.zeros((0, self.d), np.float32),
            "weights": np.zeros((0,), np.float64),
            "spilled": np.asarray(0, np.int64),
            "max_spill_dist": np.asarray(0.0, np.float64),
        }

    def load_state(self, tree: dict) -> None:
        self._c = np.asarray(tree["centers"], np.float32)
        self._w = np.asarray(tree["weights"], np.float64)
        assert self._c.shape[1] == self.d and \
            self._c.shape[0] == self._w.shape[0]
        self.spilled = int(tree["spilled"])
        self.max_spill_dist = float(tree["max_spill_dist"])

    def _absorb_into(self, target_c, target_w, cand_c, cand_w, spill: bool):
        """Assign candidates to nearest target center; within-eps (or ALL,
        when ``spill``) hand over their mass.  Returns the survivor mask."""
        idx, d2 = kernel_ops.shadow_assign(cand_c, target_c, tag="ingest")
        idx, d2 = np.asarray(idx), np.asarray(d2)
        hit = np.ones_like(idx, dtype=bool) if spill \
            else d2 < np.float32(self.eps) ** 2
        np.add.at(target_w, idx[hit], cand_w[hit])
        if spill and hit.any():
            self.spilled += int(hit.sum())
            self.max_spill_dist = max(self.max_spill_dist,
                                      float(np.sqrt(d2[hit].max())))
        return ~hit

    def update(self, cand_c, cand_w) -> None:
        """Fold one batch of candidate centers (zero-weight rows are
        padding and ignored) into the merged set."""
        cand_c = np.asarray(cand_c, np.float32)
        cand_w = np.asarray(cand_w, np.float64)
        live = cand_w > 0
        cand_c, cand_w = cand_c[live], cand_w[live]
        if cand_c.shape[0] == 0:          # empty shard / all-padding batch
            return
        if self.m:
            keep = self._absorb_into(self._c, self._w, cand_c, cand_w,
                                     spill=False)
            cand_c, cand_w = cand_c[keep], cand_w[keep]
            if cand_c.shape[0] == 0:
                return
        c_new, w_new, _, m_new = shadow_select_blocked(
            cand_c, self.eps, block=self.block, weights=cand_w)
        room = m_new if self.budget is None else max(0, self.budget - self.m)
        kept = min(m_new, room)
        kept_c = np.asarray(c_new[:kept], np.float32)
        kept_w = np.asarray(w_new[:kept], np.float64)
        if kept < m_new:                  # center-budget spill
            target_c = np.concatenate([self._c, kept_c]) if self.m else kept_c
            target_w = np.concatenate([self._w, kept_w]) if self.m else kept_w
            if target_c.shape[0] == 0:
                raise ValueError("center budget is 0: nowhere to spill")
            self._absorb_into(target_c, target_w, c_new[kept:], w_new[kept:],
                              spill=True)
            self._c, self._w = target_c, target_w
        else:
            self._c = np.concatenate([self._c, kept_c]) if self.m else kept_c
            self._w = np.concatenate([self._w, kept_w]) if self.m else kept_w


def shadow_select_streaming(x, eps: float, chunk: int = 8192,
                            block: int = 256, budget: int | None = None):
    """Two-level streaming selection for out-of-memory datasets.

    Level 1 runs blocked selection per fixed-size chunk (only one chunk is
    device-resident at a time); level 2 folds each chunk's centers into a
    ``StreamingMerge`` — the merged set is the ONLY cross-chunk state, so
    peak memory is O(chunk + m) however large n grows.  Cover radius is
    2*eps (triangle inequality), i.e. the §5 bounds hold with ell -> ell/2;
    the final assign map is recovered with one Pallas assignment pass per
    chunk.  ``budget`` caps the merged center count (over-budget candidates
    spill weight-exactly into their nearest retained center).

    Returns (centers, weights, assign, m).  Unlike the one-level selectors,
    ``weights`` are the MERGED level-1 shadow masses while ``assign`` maps
    each point to its NEAREST merged center, so ``bincount(assign)`` need
    not equal ``weights`` — both are valid 2*eps quantizations, they just
    answer different questions (density mass vs. nearest-cover membership).
    ``weights.sum() == n`` holds exactly (float64 mass bookkeeping).
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    merge = StreamingMerge(x.shape[1], eps, budget=budget, block=block)
    for s in range(0, n, chunk):
        c, w, _, _ = shadow_select_blocked(x[s : s + chunk], eps, block=block)
        merge.update(c, w)
    m = merge.m
    centers = merge.centers
    assign = np.empty((n,), np.int64)
    for s in range(0, n, chunk):
        idx, _ = kernel_ops.shadow_assign(x[s : s + chunk], centers,
                                          tag="ingest")
        assign[s : s + chunk] = np.asarray(idx)
    return centers, merge.weights, assign, m


def two_level_merge(centers: Array, weights: Array, eps: Array,
                    max_centers: int):
    """Second-level shadow pass over candidate centers (distributed variant).

    Runs Algorithm 2 on the *centers* themselves, summing absorbed weights
    instead of counting points.  Quantization error of the two-level scheme is
    at most 2*eps (triangle inequality), i.e. the paper's bounds hold with
    ell -> ell/2 in the worst case (DESIGN.md §3).
    """
    n, d = centers.shape
    cf = centers.astype(jnp.float32)
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    alive0 = weights > 0  # padded slots carry zero weight

    def cond(state):
        alive, *_ = state
        return alive.any()

    def body(state):
        alive, out_c, out_w, m = state
        i = jnp.argmax(alive)
        c = cf[i]
        d2 = jnp.sum((cf - c[None, :]) ** 2, axis=1)
        shadow = alive & (d2 < eps2)
        out_c = out_c.at[m].set(c)
        out_w = out_w.at[m].set(jnp.where(shadow, weights, 0.0).sum())
        overflow = m >= max_centers - 1
        shadow = jnp.where(overflow, alive, shadow)
        out_w = jnp.where(
            overflow, out_w.at[m].set(jnp.where(alive, weights, 0.0).sum()), out_w
        )
        alive = alive & ~shadow
        return alive, out_c, out_w, m + 1

    state = (
        alive0,
        jnp.zeros((max_centers, d), jnp.float32),
        jnp.zeros((max_centers,), jnp.float32),
        jnp.asarray(0, jnp.int32),
    )
    _, out_c, out_w, m = jax.lax.while_loop(cond, body, state)
    return out_c, out_w, m
