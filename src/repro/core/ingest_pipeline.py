"""Out-of-core distributed ingestion: select -> fit at n = 10M+ (DESIGN.md §9).

The paper's Algorithm 2 is what makes huge-n KPCA *possible* (m ~ eps-cover
size, not n), but the seed implementations still assumed the (n, d) array was
resident.  This pipeline removes that assumption end to end:

  * the data source yields fixed-shape HOST chunks (only one-in-flight plus a
    prefetch window ever exists — peak host memory is O(chunk * depth), not
    O(n));
  * a producer thread generates the next chunk and stages it onto the
    device(s) (``jax.device_put``) while the consumer runs blocked selection
    on the current one — the async double-buffered feed.  ``IngestStats``
    records the measured copy/compute overlap fraction;
  * per chunk, selection runs the fused ``_blocked_select_device`` rounds —
    on a mesh, per device shard via ``distributed._chunk_select_sharded`` —
    and the resulting candidate centers fold into a ``StreamingMerge``
    (weight-exact, center-budget spill; cover radius 2*eps, so the §5 bounds
    hold with ell -> ell/2);
  * the merged center set feeds Algorithm 1 directly (``pipeline.fit_centers``
    single-device, ``fit_rskpca_sharded`` via ``fit_rskpca(mesh=...)``) — the
    dataset is touched exactly once.

This module deliberately takes ANY chunk source (``.chunks()`` method or a
bare iterable of ``(x, n_valid)``) so it never imports ``repro.data``; the
deterministic synthetic source lives in ``data.kpca_datasets.ChunkedDataset``.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import shadow as shadow_mod
from repro.core.rsde import RSDE
from repro.core.shadow import StreamingMerge
from repro.obs import metrics as _om
from repro.obs.trace import span as _span
from repro.runtime import chaos
from repro.runtime.fault import Preempted, RetryPolicy, retry_call

Array = jax.Array

# fault-path telemetry (DESIGN.md §17): how often the pipeline had to
# recover, and what a preemption/resume cost.
_M_CKPTS = _om.counter("ingest.checkpoints")
_M_RESUMES = _om.counter("ingest.resumes")
_M_STRAGGLERS = _om.counter("ingest.stragglers")

# pipeline telemetry (DESIGN.md §16): the IngestStats fields double as LIVE
# gauges, refreshed per chunk — a 10M-row run is observable while it runs,
# not only from the end-of-run stats object.
_M_CHUNKS = _om.counter("ingest.chunks")
_M_ROWS = _om.counter("ingest.rows")
_M_CHUNK_MS = _om.histogram("ingest.chunk_ms")


def _observe_chunk(stats: "IngestStats") -> None:
    _om.gauge("ingest.feed_s").set(stats.feed_s)
    _om.gauge("ingest.stall_s").set(stats.stall_s)
    _om.gauge("ingest.compute_s").set(stats.compute_s)
    _om.gauge("ingest.overlap_fraction").set(stats.overlap_fraction)
    _om.gauge("ingest.m").set(stats.m)
    _om.gauge("ingest.spilled").set(stats.spilled)


def pad_block(x, rows: int):
    """Zero-pad a ragged (k, d) host block to fixed (rows, d) + valid mask.

    The fixed-shape contract shared by streaming ingest batches and ingest
    chunks: padding rows are masked (never selected, never counted), so one
    compiled program serves every block of a ragged stream.
    """
    x = np.asarray(x, np.float32)
    k = x.shape[0]
    if k == rows:
        return x, np.ones((rows,), bool)
    assert k < rows, f"block of {k} rows exceeds the fixed size {rows}"
    xp = np.zeros((rows, x.shape[1]), np.float32)
    xp[:k] = x
    ok = np.zeros((rows,), bool)
    ok[:k] = True
    return xp, ok


@dataclasses.dataclass
class IngestStats:
    """Measured pipeline counters (the numbers BENCH_rskpca.json records).

    ``feed_s`` is producer busy time (host chunk generation + device staging);
    ``stall_s`` is consumer time blocked waiting on the feed queue.  When the
    feed hides fully behind selection compute, stall collapses to the
    pipeline-fill latency of the first chunk and ``overlap_fraction`` -> 1;
    a transfer-bound pipeline drives it toward 0.
    """
    chunks: int = 0
    rows: int = 0
    m: int = 0
    feed_s: float = 0.0
    stall_s: float = 0.0
    compute_s: float = 0.0
    select_s: float = 0.0     # select+merge wall (includes stalls)
    fit_s: float = 0.0
    wall_s: float = 0.0       # end-to-end select -> fit
    spilled: int = 0
    max_spill_dist: float = 0.0

    @property
    def overlap_fraction(self) -> float:
        """Fraction of feed work hidden behind selection compute."""
        if self.feed_s <= 0:
            return 1.0
        return float(np.clip((self.feed_s - self.stall_s) / self.feed_s,
                             0.0, 1.0))

    @property
    def rows_per_s(self) -> float:
        wall = self.wall_s or self.select_s
        return self.rows / wall if wall > 0 else 0.0


_END = object()


class _PrefetchFeed:
    """Producer-thread double buffer: generate + stage chunk i+1 while the
    consumer computes on chunk i.

    The queue holds at most ``depth - 1`` staged chunks (plus the one the
    producer is building), bounding host memory at ``depth`` chunks.  The
    producer's busy time accrues to ``feed_s`` (queue blocking excluded — a
    full queue means the feed is AHEAD, not working); consumer blocking on
    ``get`` accrues to ``stall_s``.  Producer exceptions re-raise at the
    consumer's next pull, so a failing source can't hang the pipeline.

    Fault model (DESIGN.md §17): ``ingest.feed`` is the chaos injection
    site for the staging step — transient faults are retried in place
    (``place`` is a pure device_put of an already-generated host chunk),
    delays model a straggling feed thread (what the consumer-side watchdog
    flags), permanent faults propagate as before.  ``close()`` gives the
    consumer a CLEAN early exit (preemption drain, consumer-side error):
    the producer stops at its next chunk boundary and the thread is
    joined, so no orphan thread keeps staging chunks onto a device the
    resumed process wants.
    """

    def __init__(self, it, place, stats: IngestStats, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth - 1))
        self._stats = stats
        self._err: BaseException | None = None
        self._stop = threading.Event()
        self._t = threading.Thread(
            target=self._run, args=(iter(it), place), daemon=True)
        self._t.start()

    def _run(self, it, place):
        try:
            k = 0
            while not self._stop.is_set():
                t0 = time.perf_counter()
                with _span("ingest.feed_chunk", chunk=k):
                    try:
                        item = next(it)
                    except StopIteration:
                        break

                    def stage():
                        chaos.inject("ingest.feed")
                        return place(*item)

                    staged = retry_call(stage, key=f"feed{k}")
                # feed_s stops HERE: time blocked on a full queue below is
                # the feed being AHEAD of compute, not the feed working
                # (asserted by the slow-consumer test in tests/test_ingest)
                self._stats.feed_s += time.perf_counter() - t0
                k += 1
                while not self._stop.is_set():
                    try:
                        self._q.put(staged, timeout=0.05)
                        break
                    except queue.Full:  # consumer slow or gone: re-check stop
                        continue
        except BaseException as e:  # re-raised on the consumer side
            self._err = e
        finally:
            self._q.put(_END)

    def close(self) -> None:
        """Stop the producer at its next boundary and join it (drains the
        queue so a producer blocked on put can finish)."""
        self._stop.set()
        while True:
            try:
                if self._q.get_nowait() is _END:
                    break
            except queue.Empty:
                if not self._t.is_alive():
                    break
                time.sleep(0.005)
        self._t.join()

    def __iter__(self):
        while True:
            t0 = time.perf_counter()
            item = self._q.get()
            self._stats.stall_s += time.perf_counter() - t0
            if item is _END:
                self._t.join()
                if self._err is not None:
                    raise self._err
                return
            yield item


def _chunk_iter(source, start: int = 0):
    """``.chunks()`` protocol or a bare iterable of (x, n_valid).

    ``start`` is the resume cursor: sources implementing ``chunks(start=)``
    (``data.ChunkedDataset``) seek for free; bare iterables are skipped
    item-by-item (correct, just not cheap — a resumable source should seek).
    """
    if hasattr(source, "chunks"):
        try:
            return source.chunks(start=start)
        except TypeError:  # a chunks() without resume support
            it = source.chunks()
    else:
        it = iter(source)
    import itertools
    return itertools.islice(it, start, None)


#: Ingest-checkpoint schema: what select_streaming persists per cursor.
#: The chunk source contributes NOTHING to the checkpoint — a
#: ``ChunkedDataset`` is a seed, so its "RNG state" is exactly the cursor.
def _ckpt_template(d: int) -> dict:
    return {
        "merge": StreamingMerge(d, 1.0).state_template(),
        "cursor": np.asarray(0, np.int64),      # chunks fully ingested
        "rows": np.asarray(0, np.int64),        # valid rows ingested
        "chunks": np.asarray(0, np.int64),      # == cursor (stats mirror)
    }


def _ckpt_restore(checkpoint_dir: str, d: int):
    """Newest intact ingest checkpoint, walking back over corrupt/torn
    steps (store.CheckpointCorrupt) — the graceful-degradation path of the
    checkpoint stack itself.  Returns ``(tree, step)`` or ``(None, None)``."""
    from repro.checkpoint import store

    for step in reversed(store.available_steps(checkpoint_dir)):
        try:
            tree, _ = store.restore_checkpoint(
                checkpoint_dir, _ckpt_template(d), step=step)
            return tree, step
        except store.CheckpointCorrupt:
            continue
    return None, None


def select_streaming(source, eps: float, *, block: int = 256,
                     budget: int | None = None, mesh=None,
                     axis: str = "data", prefetch: int = 2,
                     checkpoint_dir: str | None = None,
                     checkpoint_every: int = 0, resume: bool = False,
                     guard=None, watchdog=None):
    """Distributed out-of-core shadow selection over a chunk stream.

    Args:
      source: ``.chunks()`` object (e.g. ``data.ChunkedDataset``) or an
        iterable of ``(x (chunk, d) f32, n_valid)`` fixed-shape host chunks.
      eps: shadow radius sigma/ell.
      block: candidate batch size of the blocked selector.
      budget: cap on merged centers (over-budget mass spills weight-exactly
        to the nearest retained center; see ``StreamingMerge``).
      mesh: optional device mesh — each chunk's rows shard over ``axis`` and
        every device runs selection on its local rows; chunk size must then
        divide the axis size.
      prefetch: feed depth (chunks of host memory the pipeline may hold).
      checkpoint_dir: enable crash consistency — every ``checkpoint_every``
        chunks the (merge state, chunk cursor) pair publishes atomically
        via ``checkpoint/store``; because the merge is the ONLY cross-chunk
        state and a ``ChunkedDataset`` regenerates any chunk from its seed,
        a resumed run is BIT-EXACT equal to an uninterrupted one (SIGKILL
        subprocess test in tests/test_chaos.py).
      checkpoint_every: checkpoint cadence in chunks (0 with a
        ``checkpoint_dir`` still checkpoints on preemption).
      resume: restore the newest intact checkpoint under ``checkpoint_dir``
        (corrupt/torn steps are skipped) and continue from its cursor.
      guard: optional ``runtime.PreemptionGuard`` — polled per chunk; on
        SIGTERM the loop drains cleanly: final checkpoint, producer thread
        joined, then raises ``runtime.Preempted`` with the resume step.
      watchdog: optional ``runtime.StepWatchdog`` wrapping each chunk's
        pull+select+merge — a straggling feed (slow disk, injected delay)
        flags here and counts into ``ingest.stragglers``.

    Returns ``(RSDE(scheme="shadow-ingest"), IngestStats)``.  Weights are
    float64 and sum EXACTLY to the number of ingested rows; cover radius is
    2*eps like every two-level path.
    """
    stats = IngestStats()
    t_start = time.perf_counter()
    eps2 = jnp.float32(eps) ** 2
    stop0 = jnp.asarray(0, jnp.int32)
    ndev = 1 if mesh is None else mesh.shape[axis]
    if mesh is not None:
        x_shard = NamedSharding(mesh, P(axis, None))
        v_shard = NamedSharding(mesh, P(axis))

        def place(x, n_valid):
            assert x.shape[0] % ndev == 0, \
                f"chunk {x.shape[0]} must divide the '{axis}' axis ({ndev})"
            ok = np.arange(x.shape[0]) < n_valid
            return (jax.device_put(x, x_shard),
                    jax.device_put(ok, v_shard), int(n_valid))
    else:
        def place(x, n_valid):
            ok = np.arange(x.shape[0]) < n_valid
            return jax.device_put(x), jax.device_put(ok), int(n_valid)

    merge: StreamingMerge | None = None
    cursor = 0  # chunks FULLY ingested == resume start == checkpoint step
    if resume and checkpoint_dir is not None:
        d = getattr(source, "d", None)
        assert d is not None, \
            "resume requires a source exposing .d (e.g. ChunkedDataset) — " \
            "a bare iterable cannot be replayed from a cursor"
        tree, ck_step = _ckpt_restore(checkpoint_dir, int(d))
        if tree is not None:
            merge = StreamingMerge(int(d), eps, budget=budget, block=block)
            merge.load_state(tree["merge"])
            cursor = int(tree["cursor"])
            stats.chunks = int(tree["chunks"])
            stats.rows = int(tree["rows"])
            stats.m = merge.m
            if _om.enabled():
                _M_RESUMES.inc()

    def _save_ckpt() -> None:
        """Atomic-publish the full cross-chunk state at the current cursor.

        The merge state is the ONLY accumulator and ``cursor`` replays the
        source (row i of a ChunkedDataset depends only on (name, seed, i)),
        so this pair IS crash consistency: resume == uninterrupted, bitwise.
        """
        from repro.checkpoint import store
        tree = {"merge": merge.state(),
                "cursor": np.asarray(cursor, np.int64),
                "rows": np.asarray(stats.rows, np.int64),
                "chunks": np.asarray(stats.chunks, np.int64)}
        store.save_checkpoint(
            checkpoint_dir, cursor, tree,
            extra_meta={"eps": float(eps), "budget": budget, "block": block})
        if _om.enabled():
            _M_CKPTS.inc()

    feed = _PrefetchFeed(_chunk_iter(source, start=cursor), place, stats,
                         depth=prefetch)
    for xd, okd, n_valid in feed:
        if guard is not None and guard.should_stop:
            # drain: persist at the last FULLY ingested chunk, stop the
            # producer thread, and hand the resume step to the caller —
            # the pulled-but-unprocessed chunk is regenerated on resume.
            if checkpoint_dir is not None and merge is not None:
                _save_ckpt()
            feed.close()
            raise Preempted(f"preempted at chunk {cursor}", step=cursor)
        if watchdog is not None:
            watchdog.start()
            flags0 = len(watchdog.flags)
        t0 = time.perf_counter()
        with _span("ingest.select_chunk", chunk=stats.chunks,
                   rows=int(n_valid)):
            if merge is None:
                merge = StreamingMerge(xd.shape[1], eps, budget=budget,
                                       block=block)
            b = max(1, min(block, xd.shape[0] // ndev))
            if mesh is not None:
                from repro.core.distributed import _chunk_select_sharded
                c, w = _chunk_select_sharded(xd, okd, eps2, mesh, axis, b)
            else:
                _, c, w, _, _ = shadow_mod._blocked_select_device(
                    xd, eps2, b, okd, stop0)
            # np.asarray blocks until the device round finishes — compute_s
            # is true select+merge time, which is what overlap compares
            # feed_s to
            ch, wh = np.asarray(c), np.asarray(w)

            def fold():
                # inject BEFORE the non-idempotent merge.update: a
                # transient here retries safely because the mutation has
                # not happened yet on the failed attempt
                chaos.inject("ingest.merge")
                with _span("ingest.merge"):
                    merge.update(ch, wh)

            retry_call(fold, key=f"merge{cursor}")
        cursor += 1
        stats.chunks += 1
        stats.rows += n_valid
        stats.compute_s += time.perf_counter() - t0
        stats.m = merge.m
        if watchdog is not None:
            watchdog.stop(cursor - 1)
            if _om.enabled() and len(watchdog.flags) > flags0:
                _M_STRAGGLERS.inc(len(watchdog.flags) - flags0)
        if _om.enabled():
            _M_CHUNKS.inc()
            _M_ROWS.inc(n_valid)
            _M_CHUNK_MS.observe((time.perf_counter() - t0) * 1e3)
            stats.spilled = merge.spilled
            _observe_chunk(stats)
        if checkpoint_dir is not None and checkpoint_every \
                and cursor % checkpoint_every == 0:
            _save_ckpt()
    if merge is None:
        raise ValueError("empty source: no chunks to ingest")
    if checkpoint_dir is not None:
        _save_ckpt()  # final: a resume of a finished run is a no-op replay
    stats.select_s = time.perf_counter() - t_start
    stats.m = merge.m
    stats.spilled = merge.spilled
    stats.max_spill_dist = merge.max_spill_dist
    rsde = RSDE(centers=merge.centers, weights=merge.weights, n=stats.rows,
                assign=None, scheme="shadow-ingest")
    return rsde, stats


def ingest_fit(source, kernel, rank: int, *, ell: float = 4.0,
               block: int = 256, budget: int | None = None, mesh=None,
               axis: str = "data", prefetch: int = 2,
               matfree: bool | None = None,
               checkpoint_dir: str | None = None,
               checkpoint_every: int = 0, resume: bool = False,
               guard=None, watchdog=None):
    """Single-pass out-of-core select -> fit: the n=10M front door.

    Streams ``source`` through ``select_streaming`` (eps = sigma/ell via
    ``kernel.epsilon``), then fits Algorithm 1 on the merged centers —
    ``pipeline.fit_centers`` on one device, the sharded/matrix-free fit when
    ``mesh`` is given.  Returns ``(KPCAModel, IngestStats)``; the dataset is
    generated, staged, and read exactly once.  The fault-tolerance knobs
    (``checkpoint_dir``/``checkpoint_every``/``resume``/``guard``/
    ``watchdog``) pass straight through to ``select_streaming`` — the fit
    itself is a pure function of the selected centers, so select-phase
    crash consistency covers the whole front door.
    """
    from repro.core.pipeline import fit_centers
    from repro.core.rskpca import fit_rskpca

    t0 = time.perf_counter()
    with _span("ingest.select"):
        rsde, stats = select_streaming(
            source, kernel.epsilon(ell), block=block, budget=budget,
            mesh=mesh, axis=axis, prefetch=prefetch,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every, resume=resume,
            guard=guard, watchdog=watchdog)
    t1 = time.perf_counter()
    with _span("ingest.fit", m=rsde.m) as sp:
        if mesh is None:
            model = fit_centers(rsde.centers, rsde.weights, rsde.n, kernel,
                                rank, matfree=matfree,
                                method="rskpca+shadow-ingest")
        else:
            model = fit_rskpca(rsde, kernel, rank, mesh=mesh, axis=axis,
                               matfree=matfree)
            model = dataclasses.replace(model, method="rskpca+shadow-ingest")
        sp.sync(model.projector)
    stats.fit_s = time.perf_counter() - t1
    stats.wall_s = time.perf_counter() - t0
    if _om.enabled():
        _om.gauge("ingest.fit_s").set(stats.fit_s)
        _om.gauge("ingest.rows_per_s").set(stats.rows_per_s)
    return model, stats
