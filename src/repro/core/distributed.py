"""Distributed ShDE + RSKPCA (DESIGN.md §3 selection, §5 sharded
fit/transform — the TPU-pod adaptation).

The paper's Algorithm 2 is a greedy sequential scan — fine on one host,
hostile to a 256-chip pod.  We adapt it as a two-level blocked selection:

  level 1: each device runs Algorithm 2 on its local shard (shard_map);
  level 2: candidate centers are all-gathered and a single merge pass runs
           Algorithm 2 *on the centers*, summing absorbed weights.

Correctness: every data point is within eps of its level-1 center, and every
level-1 center is within eps of its level-2 center, so the two-level
quantization error is <= 2*eps (triangle inequality) — the paper's bounds hold
with ell -> ell/2 in the worst case.  Empirically the measured MMD sits far
below even the one-level bound (tests/test_distributed.py).

The Gram assembly and projection are embarrassingly parallel over ROWS: each
device computes the k(x_shard, C) block against the replicated (small) center
set — this is the O(mn) term and parallelizes perfectly, which is what makes
the probe (core/probe.py) cheap at pod scale.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.kernels_math import Kernel, gram_matrix, gram_matrix_dense
from repro.core.rsde import RSDE
from repro.core import shadow as shadow_mod
from repro.kernels import ops as kernel_ops
from repro.kernels.ops import _pad_rows

Array = jax.Array


def _local_shadow(x_loc: Array, eps: Array, max_centers: int,
                  valid_loc: Array):
    """Level-1 selection on one device's shard. Returns padded (c, w)."""
    centers, weights, _, _ = shadow_mod.shadow_select(
        x_loc, eps, max_centers=max_centers, valid=valid_loc
    )
    return centers, weights


@partial(jax.jit, static_argnames=("mesh", "axis", "max_local", "max_global"))
def _two_level_select(x: Array, valid: Array, eps: Array, mesh: Mesh,
                      axis: str, max_local: int, max_global: int):
    """shard_map level-1 + all-gather + replicated level-2 merge."""

    def level1(x_loc, valid_loc):
        c, w = _local_shadow(x_loc, eps, max_centers=max_local,
                             valid_loc=valid_loc)
        # gather every device's candidates (m_loc is data-dependent; padded)
        all_c = jax.lax.all_gather(c, axis, tiled=True)   # (ndev*max_local, d)
        all_w = jax.lax.all_gather(w, axis, tiled=True)   # (ndev*max_local,)
        return all_c, all_w

    all_c, all_w = shard_map(
        level1, mesh=mesh, in_specs=(P(axis, None), P(axis)),
        out_specs=(P(None, None), P(None)), check_vma=False,
    )(x, valid)
    # level-2 merge is replicated (centers are tiny); weights>0 masks padding
    out_c, out_w, m = shadow_mod.two_level_merge(
        all_c, all_w, eps, max_centers=max_global
    )
    return out_c, out_w, m


@partial(jax.jit, static_argnames=("mesh", "axis", "block"))
def _chunk_select_sharded(xp: Array, valid: Array, eps2: Array, mesh: Mesh,
                          axis: str, block: int):
    """Level-1 BLOCKED selection on one ingest chunk, rows sharded over
    ``axis`` — the per-chunk device step of the out-of-core pipeline
    (core/ingest_pipeline.py, DESIGN.md §9).

    Unlike ``_two_level_select`` this neither all-gathers nor merges: each
    device runs the fused blocked-selection while_loop on its local rows and
    the padded per-device (c, w) buffers come back still row-sharded (only
    selected rows carry weight; the host-side ``StreamingMerge`` is the
    level 2, shared across every chunk of the stream).  An all-invalid shard
    (ragged final chunk confined to few devices) exits its loop immediately
    with zero survivors — zero-weight rows are the merge's padding contract.
    """

    def level1(x_loc, v_loc):
        _, c, w, _, _ = shadow_mod._blocked_select_device(
            x_loc, eps2, block, v_loc, jnp.asarray(0, jnp.int32))
        return c, w

    return shard_map(
        level1, mesh=mesh, in_specs=(P(axis, None), P(axis)),
        out_specs=(P(axis, None), P(axis)), check_vma=False,
    )(xp, valid)


def distributed_shadow_rsde(x, kernel: Kernel, ell: float, mesh: Mesh,
                            axis: str = "data",
                            max_local: int | None = None,
                            max_global: int | None = None) -> RSDE:
    """Two-level distributed ShDE over a device mesh axis.

    n need not divide the axis: rows are padded to a device multiple and
    masked out of selection (they are never centers and carry no weight)."""
    ndev = mesh.shape[axis]
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    xp = _pad_rows(x, ndev)
    valid = (jnp.arange(xp.shape[0]) < n)
    n_loc = xp.shape[0] // ndev
    max_local = max_local or n_loc
    max_global = max_global or min(xp.shape[0], ndev * max_local)
    sharding = NamedSharding(mesh, P(axis, None))
    xp = jax.device_put(xp, sharding)
    c, w, m = _two_level_select(
        xp, valid, jnp.float32(kernel.epsilon(ell)), mesh, axis, max_local,
        max_global
    )
    m = int(m)
    return RSDE(
        centers=np.asarray(c[:m]),
        weights=np.asarray(w[:m], np.float64),
        n=n,
        assign=None,  # assignment is recomputable in one blocked pass if needed
        scheme="shadow2",
    )


def blocked_gram_rows(x, centers, kernel: Kernel, mesh: Mesh,
                      axis: str = "data") -> Array:
    """k(x, C) with rows sharded over ``axis`` and C replicated — the O(mn)
    Gram-block assembly used by both training-side MMD checks and the probe.

    On TPU the per-device block is computed by the Pallas kernel
    (repro.kernels.gram); here sharding is expressed with explicit specs so
    XLA partitions it without any gather of x.
    """
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(centers, jnp.float32)

    def block(x_loc, c_rep):
        return gram_matrix(kernel, x_loc, c_rep)

    return shard_map(
        block, mesh=mesh, in_specs=(P(axis, None), P(None, None)),
        out_specs=P(axis, None), check_vma=False,
    )(x, c)


@partial(jax.jit, static_argnames=("mesh", "axis"))
def _sharded_assign_jit(xp, c, v, mesh: Mesh, axis: str):
    def block(x_loc, c_rep, v_rep):
        return kernel_ops.shadow_assign(x_loc, c_rep, valid=v_rep)

    return shard_map(
        block, mesh=mesh,
        in_specs=(P(axis, None), P(None, None), P(None)),
        out_specs=(P(axis), P(axis)), check_vma=False,
    )(xp, c, v)


def sharded_shadow_assign(x, centers, mesh: Mesh, axis: str = "data",
                          valid=None):
    """Nearest-valid-center pass with x ROWS sharded over ``axis`` and the
    center set replicated: each device runs the Pallas assignment kernel
    (repro.kernels.shadow_assign) on its shard.  Returns (idx, d2min) like
    ``kernel_ops.shadow_assign``; x is padded to a device multiple and
    stripped on the way out.  Jitted (mesh/axis static) so repeated serving
    calls at one shape reuse the compiled sharded program.
    """
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(centers, jnp.float32)
    n, m = x.shape[0], c.shape[0]
    ndev = mesh.shape[axis]
    xp = _pad_rows(x, ndev)
    v = jnp.ones((m,), jnp.float32) if valid is None \
        else jnp.asarray(valid, jnp.float32)
    idx, d2 = _sharded_assign_jit(xp, c, v, mesh, axis)
    return idx[:n], d2[:n]


def distributed_assign(x, centers, mesh: Mesh, axis: str = "data") -> Array:
    """Recover the data->center map alpha in one sharded pass (O(mn/devices)),
    routed through the Pallas assignment kernel per shard."""
    idx, _ = sharded_shadow_assign(x, centers, mesh, axis=axis)
    return idx


def sharded_weighted_gram(centers, weights, kernel: Kernel, mesh: Mesh,
                          axis: str = "data") -> Array:
    """Algorithm 1's K-tilde = W K^C W with center ROWS sharded over ``axis``
    and the center set replicated as columns — the fit-side O(m^2) assembly
    of DESIGN.md §5.  Callers pad (centers, weights) to a device multiple
    with zero-weight rows (sqrt(0) zeroes the padded rows/columns, so the
    padded spectrum gains only zeros)."""
    c = jnp.asarray(centers, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)

    def block(c_loc, w_loc, c_rep, w_rep):
        if kernel.backend == "pallas":
            return kernel_ops.gram(c_loc, c_rep, sigma=kernel.sigma,
                                   p=kernel.p, wx=w_loc, wy=w_rep,
                                   precision=kernel.precision)
        g = gram_matrix_dense(kernel, c_loc, c_rep)
        return g * jnp.sqrt(w_loc)[:, None] * jnp.sqrt(w_rep)[None, :]

    return shard_map(
        block, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(None, None), P(None)),
        out_specs=P(axis, None), check_vma=False,
    )(c, w, c, w)


@partial(jax.jit, static_argnames=("kernel", "mesh", "axis"))
def _sharded_wgram_jit(c, w, kernel: Kernel, mesh: Mesh, axis: str):
    return sharded_weighted_gram(c, w, kernel, mesh, axis=axis)


@partial(jax.jit, static_argnames=("kernel", "mesh", "axis", "chunk"))
def _sharded_project_jit(xp, c, a, kernel: Kernel, mesh: Mesh, axis: str,
                         chunk: int | None):
    def block(x_loc, c_rep, a_rep):
        if kernel.backend == "pallas":
            return kernel_ops.kpca_project(
                x_loc, c_rep, a_rep, sigma=kernel.sigma, p=kernel.p,
                chunk=chunk, precision=kernel.precision)
        return gram_matrix_dense(kernel, x_loc, c_rep) @ a_rep

    return shard_map(
        block, mesh=mesh,
        in_specs=(P(axis, None), P(None, None), P(None, None)),
        out_specs=P(axis, None), check_vma=False,
    )(xp, c, a)


def sharded_kpca_project(x, centers, projector, kernel: Kernel, mesh: Mesh,
                         axis: str = "data", chunk: int | None = None):
    """Fused z = k(x, C) @ A with query ROWS sharded over ``axis`` and the
    (m, d) centers + (m, r) projector replicated (DESIGN.md §5).  Per device
    the fused Pallas projection kernel runs on the local shard (streamed in
    ``chunk`` rows if given); only the (n/ndev, r) embeddings travel back.
    Jitted (kernel/mesh/axis/chunk static) so repeated serving calls at one
    shape reuse the compiled sharded program.
    """
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(centers, jnp.float32)
    a = jnp.asarray(projector, jnp.float32)
    n = x.shape[0]
    ndev = mesh.shape[axis]
    # pad rows to a shape BUCKET, not just a device multiple: a ragged
    # serving stream then re-traces the sharded program once per
    # (chunk * ndev) bucket instead of once per distinct query size — the
    # mesh-side analogue of the single-device tail-chunk padding contract
    if chunk is not None and n > chunk * ndev:
        xp = _pad_rows(x, ndev * chunk)
        eff_chunk = chunk  # per-device rows are an exact chunk multiple
    else:
        xp = _pad_rows(x, ndev * 128)
        eff_chunk = None
    z = _sharded_project_jit(xp, c, a, kernel, mesh, axis, eff_chunk)
    return z[:n]


@partial(jax.jit,
         static_argnames=("kernel", "rank", "mesh", "axis", "lobpcg_min_m",
                          "matfree"))
def _fit_rskpca_sharded(c: Array, w: Array, n: Array, kernel: Kernel,
                        rank: int, mesh: Mesh, axis: str,
                        lobpcg_min_m: int, matfree: bool = False):
    """Algorithm 1 with the Gram assembly sharded over center rows and, for
    large m, the LOBPCG matvec distributed the same way — the m x m operator
    never needs to be replicated; only the (m, r) projector is.

    ``matfree=True`` (DESIGN.md §6) goes one step further: the sharded m x m
    operator is never ASSEMBLED either.  Each device runs the fused
    ``gram_matvec`` Pallas kernel on its row-tile of centers against the
    replicated center set, so per-device peak memory is O(m_loc * r + tiles)
    instead of O(m_loc * m) — the pod-scale analogue of the single-device
    matrix-free fit.
    """
    from repro.core.rskpca import _canonicalize_signs, _lobpcg_topk

    sw = jnp.sqrt(w)
    m_pad = c.shape[0]
    if matfree:
        # honored UNCONDITIONALLY: the caller asked for the memory contract,
        # so the sharded Gram is never assembled regardless of the wall-clock
        # crossover (the single-device matfree branch behaves the same way)
        def matvec(v):
            def blk(c_loc, w_loc, c_rep, w_rep, v_rep):
                return kernel_ops.gram_matvec(
                    c_loc, c_rep, v_rep, wx=w_loc, wy=w_rep,
                    sigma=kernel.sigma, p=kernel.p,
                    precision=kernel.precision, allow_dense=False)
            out = shard_map(
                blk, mesh=mesh,
                in_specs=(P(axis, None), P(axis), P(None, None), P(None),
                          P(None, None)),
                out_specs=P(axis, None), check_vma=False,
            )(c, w, c, w, v)
            return out / n

        lam, u = _lobpcg_topk(matvec, m_pad, rank)
    else:
        kt = sharded_weighted_gram(c, w, kernel, mesh, axis=axis) / n
        if m_pad > lobpcg_min_m and 5 * rank < m_pad:
            def matvec(v):
                def blk(k_loc, v_rep):
                    return jnp.dot(k_loc, v_rep,
                                   preferred_element_type=jnp.float32)
                return shard_map(
                    blk, mesh=mesh, in_specs=(P(axis, None), P(None, None)),
                    out_specs=P(axis, None), check_vma=False,
                )(kt, v)

            lam, u = _lobpcg_topk(matvec, m_pad, rank)
        else:
            lam, u = jnp.linalg.eigh(kt)  # ascending
            lam = lam[::-1][:rank]
            u = _canonicalize_signs(u[:, ::-1][:, :rank])
    lam = jnp.maximum(lam, 1e-12)
    proj = (sw[:, None] * u) / jnp.sqrt(lam)[None, :] / jnp.sqrt(n)
    return lam, proj


def fit_rskpca_sharded(centers, weights, n: int, kernel: Kernel, rank: int,
                       mesh: Mesh, axis: str = "data",
                       lobpcg_min_m: int | None = None,
                       matfree: bool | None = None):
    """Sharded Algorithm 1 core: returns (eigvals (rank,), projector (m, r)).

    Centers are padded to a device multiple with zero-weight rows (harmless:
    they contribute zero rows/columns to K-tilde and zero projector rows)
    and the padding is stripped before returning.  ``lobpcg_min_m`` is a
    test hook to force the distributed-matvec eigensolve at small m.
    ``matfree`` (None = the bytes-budget policy of kernels.ops.matfree_fit)
    skips the sharded Gram assembly entirely and streams matvec row-tiles
    through the fused Pallas kernel per device (DESIGN.md §6).

    On CPU, small-m eigensolves hop to the same LAPACK subset driver the
    single-device fit uses (rskpca._host_subset_eigh) — same solver on both
    paths is what makes the 1e-5 sharded-vs-single parity hold.
    """
    from repro.core.rskpca import (_LOBPCG_MIN_M, _fold_projector,
                                   _host_subset_eigh, _use_matfree)

    c = jnp.asarray(centers, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    m = c.shape[0]
    ndev = mesh.shape[axis]
    cp = _pad_rows(c, ndev)
    wp = _pad_rows(w, ndev)
    min_m = _LOBPCG_MIN_M if lobpcg_min_m is None else int(lobpcg_min_m)
    use_mf = _use_matfree(kernel, cp.shape[0], rank, matfree)
    if (not use_mf and jax.default_backend() == "cpu"
            and cp.shape[0] <= min_m):
        kt = np.asarray(_sharded_wgram_jit(cp, wp, kernel, mesh, axis)) \
            / np.float32(n)
        top = _host_subset_eigh(kt, rank)
        if top is not None:
            lam, proj = _fold_projector(*top, np.asarray(wp), n)
            return jnp.asarray(lam), jnp.asarray(proj[:m])
    lam, proj = _fit_rskpca_sharded(
        cp, wp, jnp.float32(n), kernel, rank, mesh, axis, min_m,
        matfree=use_mf)
    return lam, proj[:m]


# --------------------------------------------------------------------------
# method zoo: sharded Nystrom extension + RFF covariance / projection
# (DESIGN.md §15 — the mesh= paths of fit_nystrom / fit_rff)
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("kernel", "mesh", "axis"))
def _sharded_extend_jit(xp, lmk, bmat, kernel: Kernel, mesh: Mesh,
                        axis: str):
    def block(x_loc, l_rep, b_rep):
        if kernel.backend == "pallas":
            return kernel_ops.gram_matvec(
                x_loc, l_rep, b_rep, sigma=kernel.sigma, p=kernel.p,
                precision=kernel.precision)
        return gram_matrix_dense(kernel, x_loc, l_rep) @ b_rep

    return shard_map(
        block, mesh=mesh,
        in_specs=(P(axis, None), P(None, None), P(None, None)),
        out_specs=P(axis, None), check_vma=False,
    )(xp, lmk, bmat)


def sharded_nystrom_extend(x, landmarks, bmat, kernel: Kernel, mesh: Mesh,
                           axis: str = "data") -> Array:
    """One chunk of the Nystrom extension proj = K_nm @ B with data ROWS
    sharded over ``axis`` and the (m, d) landmarks + (m, r) fold matrix
    replicated.  Per device the fused ``gram_matvec`` kernel streams K
    tiles through VMEM — the local rows x m Gram block never materializes
    (same contract as the single-device chunked extension)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    ndev = mesh.shape[axis]
    xp = _pad_rows(x, ndev * 128)
    out = _sharded_extend_jit(xp, jnp.asarray(landmarks, jnp.float32),
                              jnp.asarray(bmat, jnp.float32), kernel, mesh,
                              axis)
    return out[:n]


@partial(jax.jit, static_argnames=("mesh", "axis", "scale", "precision"))
def sharded_rff_cov(xd, ok, omega, phase, mesh: Mesh, axis: str = "data", *,
                    scale: float, precision: str = "f32") -> Array:
    """One chunk's feature-covariance contribution sum_i phi(x_i) phi(x_i)^T
    with the chunk's rows sharded over ``axis``: each device computes its
    local phi^T phi partial and a psum replicates the (D, D) result —
    only O(D^2) crosses the interconnect per chunk, never features."""
    def block(x_loc, ok_loc, w_rep, b_rep):
        z = kernel_ops.rff_features(x_loc, w_rep, b_rep, scale=scale,
                                    precision=precision)
        z = jnp.where(ok_loc[:, None], z, 0.0)
        cd = jnp.float32 if precision == "f32" else jnp.bfloat16
        part = jax.lax.dot_general(
            z.astype(cd), z.astype(cd), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return jax.lax.psum(part, axis)

    return shard_map(
        block, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(None, None), P(None)),
        out_specs=P(None, None), check_vma=False,
    )(xd, ok, omega, phase)


@partial(jax.jit,
         static_argnames=("mesh", "axis", "chunk", "scale", "precision"))
def _sharded_rff_project_jit(xp, omega, phase, u, mesh: Mesh, axis: str,
                             chunk: int | None, scale: float,
                             precision: str):
    def block(x_loc, w_rep, b_rep, u_rep):
        return kernel_ops.rff_project(
            x_loc, w_rep, b_rep, u_rep, scale=scale, chunk=chunk,
            precision=precision)

    return shard_map(
        block, mesh=mesh,
        in_specs=(P(axis, None), P(None, None), P(None), P(None, None)),
        out_specs=P(axis, None), check_vma=False,
    )(xp, omega, phase, u)


def sharded_rff_project(x, omega, phase, u, mesh: Mesh, axis: str = "data",
                        chunk: int | None = None,
                        precision: str = "f32") -> Array:
    """z = sqrt(2/D) cos(x Omega^T + b) @ U with query ROWS sharded and
    (Omega, b, U) replicated — the RFF analogue of sharded_kpca_project,
    with the same shape-bucket padding so ragged serving streams retrace
    once per (chunk * ndev) bucket."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    ndev = mesh.shape[axis]
    scale = float(np.sqrt(2.0 / omega.shape[0]))
    if chunk is not None and n > chunk * ndev:
        xp = _pad_rows(x, ndev * chunk)
        eff_chunk = chunk
    else:
        xp = _pad_rows(x, ndev * 128)
        eff_chunk = None
    z = _sharded_rff_project_jit(
        xp, jnp.asarray(omega, jnp.float32), jnp.asarray(phase, jnp.float32),
        jnp.asarray(u, jnp.float32), mesh, axis, eff_chunk, scale, precision)
    return z[:n]
