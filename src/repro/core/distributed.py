"""Distributed ShDE + RSKPCA (DESIGN.md §3 — the TPU-pod adaptation).

The paper's Algorithm 2 is a greedy sequential scan — fine on one host,
hostile to a 256-chip pod.  We adapt it as a two-level blocked selection:

  level 1: each device runs Algorithm 2 on its local shard (shard_map);
  level 2: candidate centers are all-gathered and a single merge pass runs
           Algorithm 2 *on the centers*, summing absorbed weights.

Correctness: every data point is within eps of its level-1 center, and every
level-1 center is within eps of its level-2 center, so the two-level
quantization error is <= 2*eps (triangle inequality) — the paper's bounds hold
with ell -> ell/2 in the worst case.  Empirically the measured MMD sits far
below even the one-level bound (tests/test_distributed.py).

The Gram assembly and projection are embarrassingly parallel over ROWS: each
device computes the k(x_shard, C) block against the replicated (small) center
set — this is the O(mn) term and parallelizes perfectly, which is what makes
the probe (core/probe.py) cheap at pod scale.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.kernels_math import Kernel, gram_matrix
from repro.core.rsde import RSDE
from repro.core import shadow as shadow_mod

Array = jax.Array


def _local_shadow(x_loc: Array, eps: Array, max_centers: int):
    """Level-1 selection on one device's shard. Returns padded (c, w)."""
    centers, weights, _, _ = shadow_mod.shadow_select(
        x_loc, eps, max_centers=max_centers
    )
    return centers, weights


@partial(jax.jit, static_argnames=("mesh", "axis", "max_local", "max_global"))
def _two_level_select(x: Array, eps: Array, mesh: Mesh, axis: str,
                      max_local: int, max_global: int):
    """shard_map level-1 + all-gather + replicated level-2 merge."""

    def level1(x_loc):
        c, w = _local_shadow(x_loc, eps, max_centers=max_local)
        # gather every device's candidates (m_loc is data-dependent; padded)
        all_c = jax.lax.all_gather(c, axis, tiled=True)   # (ndev*max_local, d)
        all_w = jax.lax.all_gather(w, axis, tiled=True)   # (ndev*max_local,)
        return all_c, all_w

    spec_in = P(axis, None)
    all_c, all_w = shard_map(
        level1, mesh=mesh, in_specs=(spec_in,),
        out_specs=(P(None, None), P(None)), check_vma=False,
    )(x)
    # level-2 merge is replicated (centers are tiny); weights>0 masks padding
    out_c, out_w, m = shadow_mod.two_level_merge(
        all_c, all_w, eps, max_centers=max_global
    )
    return out_c, out_w, m


def distributed_shadow_rsde(x, kernel: Kernel, ell: float, mesh: Mesh,
                            axis: str = "data",
                            max_local: int | None = None,
                            max_global: int | None = None) -> RSDE:
    """Two-level distributed ShDE over a device mesh axis."""
    ndev = mesh.shape[axis]
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    assert n % ndev == 0, f"n={n} must divide over {axis}={ndev} (pad upstream)"
    n_loc = n // ndev
    max_local = max_local or n_loc
    max_global = max_global or min(n, ndev * max_local)
    sharding = NamedSharding(mesh, P(axis, None))
    x = jax.device_put(x, sharding)
    c, w, m = _two_level_select(
        x, jnp.float32(kernel.epsilon(ell)), mesh, axis, max_local, max_global
    )
    m = int(m)
    return RSDE(
        centers=np.asarray(c[:m]),
        weights=np.asarray(w[:m], np.float64),
        n=n,
        assign=None,  # assignment is recomputable in one blocked pass if needed
        scheme="shadow2",
    )


def blocked_gram_rows(x, centers, kernel: Kernel, mesh: Mesh,
                      axis: str = "data") -> Array:
    """k(x, C) with rows sharded over ``axis`` and C replicated — the O(mn)
    Gram-block assembly used by both training-side MMD checks and the probe.

    On TPU the per-device block is computed by the Pallas kernel
    (repro.kernels.gram); here sharding is expressed with explicit specs so
    XLA partitions it without any gather of x.
    """
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(centers, jnp.float32)

    def block(x_loc, c_rep):
        return gram_matrix(kernel, x_loc, c_rep)

    return shard_map(
        block, mesh=mesh, in_specs=(P(axis, None), P(None, None)),
        out_specs=P(axis, None), check_vma=False,
    )(x, c)


def distributed_assign(x, centers, mesh: Mesh, axis: str = "data") -> Array:
    """Recover the data->center map alpha in one sharded pass (O(mn/devices))."""
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(centers, jnp.float32)

    def block(x_loc, c_rep):
        d2 = (
            jnp.sum(x_loc * x_loc, 1)[:, None]
            + jnp.sum(c_rep * c_rep, 1)[None, :]
            - 2.0 * x_loc @ c_rep.T
        )
        return jnp.argmin(d2, axis=1).astype(jnp.int32)

    return shard_map(
        block, mesh=mesh, in_specs=(P(axis, None), P(None, None)),
        out_specs=P(axis), check_vma=False,
    )(x, c)
