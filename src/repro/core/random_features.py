"""Random-Fourier-feature KPCA (Sriperumbudur & Sterge; DESIGN.md §15).

Bochner's theorem: a shift-invariant kernel is the Fourier transform of a
probability measure, so with omega_j ~ spectral measure and b_j ~ U[0, 2pi)

    phi_D(x) = sqrt(2/D) cos(x Omega^T + b),   E[phi_D(x)^T phi_D(y)] = k(x,y).

KPCA in the D-dimensional feature space needs only the feature covariance
C = Z^T Z / n (D x D): its nonzero spectrum equals that of the RFF Gram
Z Z^T / n ~ K / n, and for an eigenpair (lam, u) of C the repo's KPCA-scaled
embedding z(x) = k(x, X) v / sqrt(lam) / sqrt(n) collapses EXACTLY to

    z(x) = phi_D(x) @ u

— no eigenvalue folding at all (substitute v = Z u / sqrt(n lam)).  So the
model stores (Omega, b, U): O(D(d+k)) space and test cost, independent of n,
with accuracy controlled by D (the hypothesis convergence property in
tests/test_methods.py).

Spectral measures for the repo's kernels (kernels_math: k = exp(-||delta||^p
/ sigma^p)):

  * Gaussian p=2: exp(-||delta||^2/sigma^2) has omega ~ N(0, (2/sigma^2) I).
  * Laplacian p=1: exp(-||delta||/sigma) has the multivariate Cauchy measure
    (t distribution with nu=1): omega = z / (|u| sigma), z ~ N(0, I_d),
    u ~ N(0, 1) — its characteristic function is exp(-||t|| sigma^{-1}...).

The fit streams the data in fixed-shape chunks and accumulates C chunk by
chunk (f32 accumulation; bf16 operands under precision="bf16"), so the
(n, D) feature matrix never materializes — the same out-of-core contract as
the ingest pipeline, and ``fit_rff_stream`` takes the same chunk sources.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ingest_pipeline import (IngestStats, _PrefetchFeed,
                                        _chunk_iter, pad_block)
from repro.core.kernels_math import Kernel
from repro.core.rskpca import (KPCAModel, TRANSFORM_CHUNK, _LOBPCG_MIN_M,
                               _host_subset_eigh, _top_eigh)
from repro.kernels import ops as kernel_ops

Array = jax.Array

#: Default feature count: enough for ~1e-2 relative spectral error on the
#: paper-scale datasets (tests/test_methods.py convergence property) while
#: keeping the D x D covariance well under the bytes budget.
DEFAULT_FEATURES = 1024


def sample_rff(kernel: Kernel, d: int, n_features: int, seed: int = 0):
    """Sample (Omega (D, d), phase (D,)) from the kernel's spectral measure.

    ``jax.random`` keyed off ``seed`` — deterministic across hosts and
    backends (the same satellite contract as the Nystrom landmark fix; no
    host-side np.random state involved).
    """
    key = jax.random.PRNGKey(seed)
    kw, kb, ku = jax.random.split(key, 3)
    z = jax.random.normal(kw, (n_features, d), jnp.float32)
    if kernel.p == 2:
        omega = z * (np.sqrt(2.0) / kernel.sigma)
    elif kernel.p == 1:
        u = jax.random.normal(ku, (n_features, 1), jnp.float32)
        omega = z / (jnp.abs(u) * kernel.sigma)
    else:
        raise ValueError(
            f"no spectral measure implemented for p={kernel.p}")
    phase = jax.random.uniform(kb, (n_features,), jnp.float32,
                               maxval=2.0 * np.pi)
    return np.asarray(omega), np.asarray(phase)


@dataclasses.dataclass
class RFFKPCAModel(KPCAModel):
    """RFF-KPCA model behind the KPCAModel interface.

    ``centers`` holds Omega (D, d) and ``projector`` the covariance
    eigenvectors U (D, r), so the base class's storage accounting
    (centers.size + projector.size) reports the honest O(D(d+k)) model size;
    ``phase`` carries the D Fourier phases.  ``eigvals`` approximate the
    spectrum of K/n (same normalization as every other method).
    """

    phase: np.ndarray | None = None

    @property
    def n_features(self) -> int:
        return self.centers.shape[0]

    def transform(self, x, chunk: int = TRANSFORM_CHUNK,
                  mesh=None, axis: str = "data") -> np.ndarray:
        """z = sqrt(2/D) cos(x Omega^T + b) @ U — O(q * D * (d + r)).

        Pallas backend runs the fused kernel (kernels/rff.py: the (chunk, D)
        feature block never leaves VMEM); the dense backend is the jnp
        oracle; ``mesh`` shards query rows with (Omega, b, U) replicated.
        """
        if mesh is not None:
            from repro.core import distributed as dist
            z = dist.sharded_rff_project(
                x, self.centers, self.phase, self.projector, mesh,
                axis=axis, chunk=chunk, precision=self.kernel.precision)
            return np.asarray(z)
        plan = "dense" if self.kernel.backend == "dense" else None
        z = kernel_ops.rff_project(
            x, self.centers, self.phase, self.projector, chunk=chunk,
            precision=self.kernel.precision, plan=plan)
        return np.asarray(z)


@partial(jax.jit, static_argnames=("scale", "precision"),
         donate_argnums=(0,))
def _cov_chunk(cacc, xc, ok, omega, phase, *, scale, precision):
    """cacc += phi(xc)^T phi(xc) over the chunk's VALID rows.

    Padding rows are masked to zero features (cos(b) != 0, so the mask is
    load-bearing); the accumulator is donated — one (D, D) buffer lives for
    the whole pass.  bf16 runs both matmuls on bf16 operands with f32
    accumulation, matching the fit-side gram convention.
    """
    cd = jnp.float32 if precision == "f32" else jnp.bfloat16
    z = kernel_ops.rff_features(xc, omega, phase, scale=scale,
                                precision=precision)
    z = jnp.where(ok[:, None], z, 0.0)
    return cacc + jax.lax.dot_general(
        z.astype(cd), z.astype(cd), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _solve_cov(cov: np.ndarray, rank: int):
    """Top-``rank`` eigenpairs of the (D, D) feature covariance, through the
    same solver ladder as the Gram fits: LAPACK subset driver on CPU at
    small D, else _top_eigh (full eigh below _LOBPCG_MIN_M, LOBPCG above)."""
    nfeat = cov.shape[0]
    if jax.default_backend() == "cpu" and nfeat <= _LOBPCG_MIN_M:
        top = _host_subset_eigh(cov, rank)
        if top is not None:
            lam, u = top
            return np.maximum(lam, 1e-12), u
    lam, u = _top_eigh(jnp.asarray(cov), rank)
    return np.maximum(np.asarray(lam), 1e-12), np.asarray(u)


def _chunk_slices(x: np.ndarray, rows: int):
    """Fixed-shape (rows, d) chunk view of a resident array."""
    for s in range(0, x.shape[0], rows):
        blk = x[s : s + rows]
        yield blk, blk.shape[0]


def fit_rff_stream(source, kernel: Kernel, rank: int, *,
                   n_features: int = DEFAULT_FEATURES, seed: int = 0,
                   mesh=None, axis: str = "data",
                   prefetch: int = 2):
    """Single-pass out-of-core RFF-KPCA over a chunk source.

    Accumulates the (D, D) feature covariance chunk by chunk behind the same
    prefetch double buffer as the ingest pipeline — peak residency is one
    chunk plus the covariance, never the dataset.  Returns
    ``(RFFKPCAModel, IngestStats)`` (``stats.m`` reports D).
    """
    stats = IngestStats()
    t_start = time.perf_counter()
    omega = phase = None
    scale = float(np.sqrt(2.0 / n_features))
    cov = jnp.zeros((n_features, n_features), jnp.float32)
    ndev = 1 if mesh is None else mesh.shape[axis]
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        x_shard = NamedSharding(mesh, P(axis, None))
        v_shard = NamedSharding(mesh, P(axis))

        def place(x, n_valid):
            assert x.shape[0] % ndev == 0, \
                f"chunk {x.shape[0]} must divide the '{axis}' axis ({ndev})"
            ok = np.arange(x.shape[0]) < n_valid
            return (jax.device_put(x, x_shard),
                    jax.device_put(ok, v_shard), int(n_valid))
    else:
        def place(x, n_valid):
            ok = np.arange(x.shape[0]) < n_valid
            return jax.device_put(x), jax.device_put(ok), int(n_valid)

    for xd, okd, n_valid in _PrefetchFeed(_chunk_iter(source), place, stats,
                                          depth=prefetch):
        t0 = time.perf_counter()
        if omega is None:
            omega, phase = sample_rff(kernel, xd.shape[1], n_features, seed)
            omega_j, phase_j = jnp.asarray(omega), jnp.asarray(phase)
        if mesh is not None:
            from repro.core import distributed as dist
            cov = cov + dist.sharded_rff_cov(
                xd, okd, omega_j, phase_j, mesh, axis=axis, scale=scale,
                precision=kernel.precision)
        else:
            cov = _cov_chunk(cov, xd, okd, omega_j, phase_j, scale=scale,
                             precision=kernel.precision)
        stats.chunks += 1
        stats.rows += n_valid
        stats.compute_s += time.perf_counter() - t0
    if omega is None:
        raise ValueError("empty source: no chunks to ingest")
    stats.select_s = time.perf_counter() - t_start
    stats.m = n_features
    t1 = time.perf_counter()
    cov_np = np.asarray(cov) / np.float32(stats.rows)
    lam, u = _solve_cov(cov_np, rank)
    stats.fit_s = time.perf_counter() - t1
    stats.wall_s = time.perf_counter() - t_start
    model = RFFKPCAModel(
        kernel=kernel, centers=omega, projector=u, eigvals=lam,
        method="rff", phase=phase)
    return model, stats


def fit_rff(x, kernel: Kernel, rank: int, *,
            n_features: int = DEFAULT_FEATURES, seed: int = 0,
            chunk: int = 65536, mesh=None, axis: str = "data"
            ) -> RFFKPCAModel:
    """RFF-KPCA on a resident array: O(n D (d + D)) train (streamed in
    ``chunk``-row slices, so peak memory is O(chunk * D + D^2), never n x D),
    O(D^3)-capped eigensolve, O(D(d+k)) model.  ``mesh`` shards each chunk's
    rows with a per-device partial covariance psum."""
    x = np.asarray(x, np.float32)
    rows = min(chunk, x.shape[0])
    if mesh is not None:
        ndev = mesh.shape[axis]
        rows = -(-rows // ndev) * ndev
    src = (pad_block(blk, rows) for blk, _ in _chunk_slices(x, rows))
    model, _ = fit_rff_stream(
        ((xb, nv.sum()) for xb, nv in src), kernel, rank,
        n_features=n_features, seed=seed, mesh=mesh, axis=axis, prefetch=2)
    return model
