"""Kernel manifold learning algorithms on the reduced set (paper §3, Eqs. 14-15).

The generic KMLA eigenproblem (G f)(x) = int g(x,y) k(x,y) f(y) p(y) dy admits
the same reduced-set treatment as KPCA: replace p by the RSDE and solve the
weighted m x m problem.  We instantiate the two examples the paper names:

* Laplacian eigenmaps  — g(x,y) = 1/sqrt(d(x) d(y)) (normalized graph Laplacian)
* Diffusion maps       — anisotropic alpha-normalization then row-stochastic
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_math import Kernel, gram_matrix
from repro.core.rsde import RSDE

Array = jnp.ndarray


@dataclasses.dataclass
class KMLAModel:
    kernel: Kernel
    centers: np.ndarray
    embedding: np.ndarray   # (m, r) embedding of the centers
    eigvals: np.ndarray
    method: str


def reduced_laplacian_eigenmaps(rsde: RSDE, kernel: Kernel, rank: int) -> KMLAModel:
    """Normalized-Laplacian spectral embedding of the reduced set.

    Weighted adjacency A_ij = w_i k(c_i,c_j) w_j (each center stands for w_i
    data points); embedding = bottom non-trivial eigenvectors of
    I - D^{-1/2} A D^{-1/2}, equivalently top of the normalized affinity.
    """
    c = jnp.asarray(rsde.centers, jnp.float32)
    w = jnp.asarray(rsde.weights, jnp.float32)
    a = gram_matrix(kernel, c, c) * w[:, None] * w[None, :]
    d = a.sum(axis=1)
    d_is = 1.0 / jnp.sqrt(jnp.maximum(d, 1e-12))
    norm_a = a * d_is[:, None] * d_is[None, :]
    lam, v = jnp.linalg.eigh(norm_a)
    lam = lam[::-1][: rank + 1]
    v = v[:, ::-1][:, : rank + 1]
    # drop the trivial top eigenvector (constant direction)
    return KMLAModel(
        kernel=kernel,
        centers=np.asarray(rsde.centers),
        embedding=np.asarray(v[:, 1:]),
        eigvals=np.asarray(lam[1:]),
        method="laplacian_eigenmaps",
    )


def reduced_diffusion_maps(rsde: RSDE, kernel: Kernel, rank: int,
                           alpha: float = 1.0, t: int = 1) -> KMLAModel:
    """Diffusion maps [Coifman & Lafon] on the reduced set.

    alpha-normalize the weighted affinity to correct for sampling density
    (the RSDE weights ARE the density estimate), build the diffusion operator,
    embed with lambda^t-scaled right eigenvectors.
    """
    c = jnp.asarray(rsde.centers, jnp.float32)
    w = jnp.asarray(rsde.weights, jnp.float32)
    a = gram_matrix(kernel, c, c) * w[:, None] * w[None, :]
    q = a.sum(axis=1)
    q_a = jnp.power(jnp.maximum(q, 1e-12), -alpha)
    a = a * q_a[:, None] * q_a[None, :]
    d = a.sum(axis=1)
    d_is = 1.0 / jnp.sqrt(jnp.maximum(d, 1e-12))
    s = a * d_is[:, None] * d_is[None, :]  # symmetric conjugate of the Markov op
    lam, v = jnp.linalg.eigh(s)
    lam = lam[::-1][: rank + 1]
    v = v[:, ::-1][:, : rank + 1]
    psi = v * d_is[:, None]  # right eigenvectors of the Markov operator
    emb = psi[:, 1:] * (lam[1:] ** t)[None, :]
    return KMLAModel(
        kernel=kernel,
        centers=np.asarray(rsde.centers),
        embedding=np.asarray(emb),
        eigvals=np.asarray(lam[1:]),
        method="diffusion_maps",
    )
