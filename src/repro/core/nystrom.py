"""Nystrom-family KPCA on the optimized stack (paper §6; DESIGN.md §15).

* ``fit_nystrom``   — classical Nystrom KPCA with uniformly sampled landmarks
  [Drineas & Mahoney 2005; Williams & Seeger].  Approximate eigensystem of the
  full n x n Gram from the (n x m, m x m) blocks.  NOTE: the extension
  eigenvectors live on the FULL dataset, so the model must retain all n points
  — O(nr) storage and O(kn) test cost (paper Table 2).  This is exactly the
  asymmetry RSKPCA removes.

* ``fit_weighted_nystrom`` — density-weighted Nystrom [Zhang & Kwok 2010]:
  k-means centers c_j with cluster masses w_j define the weighted Gram
  W K^C W / n whose eigensystem extends through k(x, C) — but training still
  requires the k-means passes over all data.

Both now ride the same machinery as the RSKPCA path (ISSUE 8):

  * landmark sampling via ``jax.random`` keyed off ``seed`` — deterministic
    across hosts, no host-side RNG state;
  * the m x m eigensolve follows the repo's solver ladder (LAPACK subset on
    CPU small-m, eigh, LOBPCG) and goes MATRIX-FREE through the fused
    ``gram_matvec`` Pallas kernel above the bytes-budget crossover — the
    m x m landmark Gram never materializes there;
  * the O(nm) extension folds every Nystrom constant into one (m, r) matrix
    B, so proj = K_nm @ B streams through ``gram_matvec`` in fixed-size row
    chunks — the n x m cross-Gram NEVER materializes (each chunk's working
    set is capped at half the bytes budget, and on the Pallas plan the
    chunk x m block stays in VMEM too);
  * ``mesh=`` shards the extension rows (``distributed.sharded_nystrom_extend``)
    and, for wnystrom, the Algorithm-1 fit;
  * ``fit_nystrom_stream`` / ``fit_weighted_nystrom_stream`` take the same
    chunk sources as the ingest pipeline, so both fit out-of-core: device
    residency stays O(chunk + m) while the nystrom model's O(nd) retained
    data fills a host buffer (that buffer IS the model — paper Table 2's
    storage row, measured honestly in benchmarks/methods_bench.py).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ingest_pipeline import IngestStats, _chunk_iter
from repro.core.kernels_math import Kernel, gram_matrix_dense
from repro.core.rskpca import (KPCAModel, _LOBPCG_MIN_M, _host_subset_eigh,
                               _lobpcg_topk, _top_eigh, _use_matfree)
from repro.core.rsde import kmeans_rsde, kmeans_rsde_stream
from repro.kernels import ops as kernel_ops


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _landmark_idx(n: int, m: int, seed: int) -> np.ndarray:
    """Uniform landmark indices without replacement via ``jax.random`` —
    deterministic across hosts/backends for a given seed (the satellite fix:
    no ``np.random`` state, no host-resident dataset required to sample)."""
    idx = jax.random.choice(jax.random.PRNGKey(seed), n, shape=(m,),
                            replace=False)
    return np.sort(np.asarray(idx))


@partial(jax.jit, static_argnames=("kernel", "rank"))
def _landmark_eigs_matfree(lmk, kernel: Kernel, rank: int):
    """Matrix-free top-``rank`` eigensolve of K_mm / m: LOBPCG's matvec
    recomputes landmark-Gram tiles in VMEM through the fused ``gram_matvec``
    kernel (allow_dense=False — the O(m^2)-free contract is load-bearing;
    the no-m x m certificate is checked on this function's lowered HLO in
    benchmarks/methods_bench.py, PR-5 style)."""
    mm = lmk.shape[0]

    def matvec(v):
        return kernel_ops.gram_matvec(
            lmk, lmk, v, sigma=kernel.sigma, p=kernel.p,
            precision=kernel.precision, allow_dense=False) / mm

    return _lobpcg_topk(matvec, mm, rank)


def _landmark_eigs(landmarks: np.ndarray, kernel: Kernel, rank: int,
                   matfree: bool | None):
    """Top-``rank`` eigenpairs of K_mm / m through the repo's solver ladder:
    matrix-free LOBPCG above the bytes-budget crossover, LAPACK subset
    driver on CPU at small m, _top_eigh otherwise."""
    from repro.core.kernels_math import gram_matrix

    mm = landmarks.shape[0]
    if _use_matfree(kernel, mm, rank, matfree):
        lam, u = _landmark_eigs_matfree(jnp.asarray(landmarks), kernel, rank)
        return np.asarray(lam), np.asarray(u)
    if jax.default_backend() == "cpu" and mm <= _LOBPCG_MIN_M:
        kt = np.asarray(gram_matrix(kernel, landmarks, landmarks),
                        np.float32) / np.float32(mm)
        top = _host_subset_eigh(kt, rank)
        if top is not None:
            return top
    lam, u = _top_eigh(gram_matrix(kernel, landmarks, landmarks) / mm, rank)
    return np.asarray(lam), np.asarray(u)


def _fold_extension(lam: np.ndarray, u: np.ndarray, n: int,
                    m: int) -> np.ndarray:
    """Fold every Nystrom constant into one (m, r) matrix B so the extension
    is a single cross-Gram matvec:

        v    = sqrt(m/n) (K_nm / m) (u / lam)        [eigenvector extension]
        proj = v / sqrt(lam) / sqrt(n)               [KPCA scaling]
              = K_nm @ B,   B = u * sqrt(m/n) / (m lam^{3/2} sqrt(n))

    which is what lets the n x m block stream through ``gram_matvec``
    without ever materializing."""
    lam = np.maximum(np.asarray(lam, np.float32), 1e-12)
    scale = np.sqrt(m / n) / (m * lam * np.sqrt(lam) * np.sqrt(np.float32(n)))
    return np.asarray(u, np.float32) * scale[None, :].astype(np.float32)


def _extension_rows(m: int, n: int) -> int:
    """Row-chunk size for the streamed extension: the per-chunk chunk x m
    working set stays under HALF the Gram bytes budget, so even the dense
    per-chunk plan (below the autotune crossover) can never approach an
    n x m materialization."""
    budget = kernel_ops.gram_bytes_budget()
    rows = budget // (8 * max(m, 1))
    rows = max(1024, min(65536, rows))
    return min(_round_up(rows, 128), _round_up(n, 128))


def _extend_projector(x, landmarks, bmat, kernel: Kernel, *, mesh=None,
                      axis: str = "data", rows: int | None = None
                      ) -> np.ndarray:
    """proj = K_nm @ B in fixed-shape row chunks — compile once, stream all
    of x.  Pallas backend: fused ``gram_matvec`` per chunk (K tiles stay in
    VMEM); dense backend: the chunked jnp oracle; ``mesh``: rows sharded per
    chunk with landmarks/B replicated."""
    x = np.asarray(x, np.float32)
    n, r = x.shape[0], bmat.shape[1]
    rows = rows or _extension_rows(landmarks.shape[0], n)
    if mesh is not None:
        rows = _round_up(rows, mesh.shape[axis] * 128)
    lj = jnp.asarray(landmarks, jnp.float32)
    bj = jnp.asarray(bmat, jnp.float32)
    out = np.empty((n, r), np.float32)
    for s in range(0, n, rows):
        blk = x[s : s + rows]
        k = blk.shape[0]
        if k < rows:  # zero-pad the ragged tail: one compiled shape
            blk = np.concatenate(
                [blk, np.zeros((rows - k, x.shape[1]), np.float32)])
        if mesh is not None:
            from repro.core import distributed as dist
            z = dist.sharded_nystrom_extend(blk, lj, bj, kernel, mesh,
                                            axis=axis)
        elif kernel.backend == "pallas":
            z = kernel_ops.gram_matvec(blk, lj, bj, sigma=kernel.sigma,
                                       p=kernel.p,
                                       precision=kernel.precision)
        else:
            z = gram_matrix_dense(kernel, jnp.asarray(blk), lj) @ bj
        out[s : s + k] = np.asarray(z)[:k]
    return out


def fit_nystrom(x, kernel: Kernel, rank: int, m: int, seed: int = 0, *,
                mesh=None, axis: str = "data", matfree: bool | None = None,
                rows: int | None = None) -> KPCAModel:
    """Classical Nystrom approximation to KPCA.

    lam_full ~ (n/m) lam_mm;  v_full ~ sqrt(m/n) K_nm u_mm / lam_mm.
    The returned model's ``centers`` are the FULL dataset (test cost O(kn)).

    ``matfree`` (None = bytes-budget policy) controls the m x m eigensolve;
    the n x m extension always streams in row chunks (``rows`` overrides the
    chunk size); ``mesh`` shards the extension rows over ``axis``.
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    idx = _landmark_idx(n, m, seed)
    landmarks = x[idx]
    lam, u = _landmark_eigs(landmarks, kernel, rank, matfree)
    lam = np.maximum(np.asarray(lam, np.float32), 1e-12)
    bmat = _fold_extension(lam, u, n, m)
    proj = _extend_projector(x, landmarks, bmat, kernel, mesh=mesh,
                             axis=axis, rows=rows)
    return KPCAModel(
        kernel=kernel,
        centers=x,                        # full data retained — the point!
        projector=proj,
        eigvals=lam,
        method="nystrom",
    )


def fit_nystrom_stream(source, kernel: Kernel, rank: int, m: int, *,
                       seed: int = 0, mesh=None, axis: str = "data",
                       matfree: bool | None = None, rows: int | None = None):
    """Out-of-core Nystrom over a chunk source (``.chunks()`` protocol or an
    iterable of ``(x, n_valid)``).

    Pass A drains the source into a host (n, d) buffer — which IS the
    model's O(nd) retained data (paper Table 2), not a working-set leak —
    gathering nothing onto device.  Landmarks are then gathered by global
    index (same ``jax.random`` draw as the resident fit, so stream and
    resident fits are bit-identical for one seed), and pass B streams the
    extension in fixed row chunks.  Device residency stays O(chunk + m)
    throughout (the out-of-core certificate measured by methods_bench).
    Returns ``(KPCAModel, IngestStats)``.
    """
    stats = IngestStats()
    t0 = time.perf_counter()
    n_hint = getattr(source, "n", None)
    buf, blocks, seen = None, [], 0
    for xb, nv in _chunk_iter(source):
        xb = np.asarray(xb, np.float32)[: int(nv)]
        if n_hint and buf is None:
            buf = np.empty((int(n_hint), xb.shape[1]), np.float32)
        if buf is not None:
            buf[seen : seen + xb.shape[0]] = xb
        else:
            blocks.append(xb.copy())
        seen += xb.shape[0]
        stats.chunks += 1
    if seen == 0:
        raise ValueError("empty source: no chunks to ingest")
    x_host = buf[:seen] if buf is not None else np.concatenate(blocks)
    del blocks
    stats.rows = seen
    stats.select_s = time.perf_counter() - t0
    t1 = time.perf_counter()
    idx = _landmark_idx(seen, m, seed)
    landmarks = x_host[idx]
    lam, u = _landmark_eigs(landmarks, kernel, rank, matfree)
    lam = np.maximum(np.asarray(lam, np.float32), 1e-12)
    bmat = _fold_extension(lam, u, seen, m)
    proj = _extend_projector(x_host, landmarks, bmat, kernel, mesh=mesh,
                             axis=axis, rows=rows)
    stats.fit_s = time.perf_counter() - t1
    stats.wall_s = time.perf_counter() - t0
    stats.m = m
    model = KPCAModel(kernel=kernel, centers=x_host, projector=proj,
                      eigvals=lam, method="nystrom")
    return model, stats


def fit_weighted_nystrom(x, kernel: Kernel, rank: int, m: int,
                         iters: int = 10, seed: int = 0, *, mesh=None,
                         axis: str = "data",
                         matfree: bool | None = None) -> KPCAModel:
    """Density-weighted Nystrom [20]: k-means RSDE + weighted Gram eigensystem.

    Structurally an RSKPCA with the k-means selector; the difference from the
    paper's ShDE path is the selector cost (iterative k-means over all data)
    and that m must be supplied by the user.  ``mesh``/``matfree`` thread
    into the Algorithm-1 fit exactly as for ``fit_rskpca``.
    """
    from repro.core.rskpca import fit_rskpca

    rsde = kmeans_rsde(x, kernel, m=m, iters=iters, seed=seed)
    model = fit_rskpca(rsde, kernel, rank, mesh=mesh, axis=axis,
                       matfree=matfree)
    return dataclasses.replace(model, method="wnystrom")


def fit_weighted_nystrom_stream(source, kernel: Kernel, rank: int, m: int, *,
                                seed: int = 0, mesh=None,
                                axis: str = "data",
                                matfree: bool | None = None):
    """Out-of-core density-weighted Nystrom: one-pass mini-batch k-means
    over the chunk source (``rsde.kmeans_rsde_stream`` — assignment through
    the Pallas ``shadow_assign`` kernel), then Algorithm 1 on the (m, d)
    centers.  Returns ``(KPCAModel, IngestStats)``."""
    from repro.core.pipeline import fit_centers
    from repro.core.rskpca import fit_rskpca

    t0 = time.perf_counter()
    rsde, stats = kmeans_rsde_stream(source, kernel, m, seed=seed)
    t1 = time.perf_counter()
    if mesh is None:
        model = fit_centers(rsde.centers, rsde.weights, rsde.n, kernel, rank,
                            matfree=matfree, method="wnystrom")
    else:
        model = fit_rskpca(rsde, kernel, rank, mesh=mesh, axis=axis,
                           matfree=matfree)
        model = dataclasses.replace(model, method="wnystrom")
    stats.fit_s = time.perf_counter() - t1
    stats.wall_s = time.perf_counter() - t0
    return model, stats
