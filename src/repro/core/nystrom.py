"""Nystrom-family baselines the paper compares against (§6).

* ``fit_nystrom``   — classical Nystrom KPCA with uniformly sampled landmarks
  [Drineas & Mahoney 2005; Williams & Seeger].  Approximate eigensystem of the
  full n x n Gram from the (n x m, m x m) blocks.  NOTE: the extension
  eigenvectors live on the FULL dataset, so the model must retain all n points
  — O(nr) storage and O(kn) test cost (paper Table 2).  This is exactly the
  asymmetry RSKPCA removes.

* ``fit_weighted_nystrom`` — density-weighted Nystrom [Zhang & Kwok 2010]:
  k-means centers c_j with cluster masses w_j define the weighted Gram
  W K^C W / n whose eigensystem extends through k(x, C) — but training still
  requires the k-means passes over all data.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_math import Kernel, gram_matrix
from repro.core.rskpca import KPCAModel, _top_eigh
from repro.core.rsde import kmeans_rsde


def fit_nystrom(x, kernel: Kernel, rank: int, m: int, seed: int = 0) -> KPCAModel:
    """Classical Nystrom approximation to KPCA.

    lam_full ~ (n/m) lam_mm;  v_full ~ sqrt(m/n) K_nm u_mm / lam_mm.
    The returned model's ``centers`` are the FULL dataset (test cost O(kn)).
    """
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.choice(n, size=m, replace=False))
    landmarks = x[idx]
    k_nm = gram_matrix(kernel, x, landmarks)          # (n, m)
    k_mm = gram_matrix(kernel, landmarks, landmarks)  # (m, m)
    lam_m, u_m = _top_eigh(k_mm / m, rank)            # normalized m x m problem
    lam_m = jnp.maximum(lam_m, 1e-12)
    # Approximate eigenvectors of K/n on the full data (orthonormal columns up
    # to Nystrom error):
    v = jnp.sqrt(m / n) * (k_nm / m) @ (u_m / lam_m[None, :])
    lam = lam_m  # normalized eigenvalues approximate those of K/n
    proj = v / jnp.sqrt(lam)[None, :] / np.sqrt(n)
    return KPCAModel(
        kernel=kernel,
        centers=np.asarray(x),            # full data retained — the point!
        projector=np.asarray(proj),
        eigvals=np.asarray(lam),
        method="nystrom",
    )


def fit_weighted_nystrom(x, kernel: Kernel, rank: int, m: int,
                         iters: int = 10, seed: int = 0) -> KPCAModel:
    """Density-weighted Nystrom [20]: k-means RSDE + weighted Gram eigensystem.

    Structurally an RSKPCA with the k-means selector; the difference from the
    paper's ShDE path is the selector cost (iterative k-means over all data)
    and that m must be supplied by the user.
    """
    from repro.core.rskpca import fit_rskpca

    rsde = kmeans_rsde(x, kernel, m=m, iters=iters, seed=seed)
    model = fit_rskpca(rsde, kernel, rank)
    return dataclasses.replace(model, method="wnystrom")
