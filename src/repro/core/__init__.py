# The paper's primary contribution: Reduced-Set KPCA (Algorithm 1) driven by
# the shadow density estimate (Algorithm 2), plus every baseline the paper
# compares against and the §5 error-bound machinery.
from repro.core.kernels_math import (  # noqa: F401
    DEFAULT_BACKEND, DEFAULT_PRECISION, Kernel, gaussian, laplacian,
    make_kernel, gram_matrix, gram_matrix_dense, weighted_gram,
    pairwise_sq_dists, kde, rsde_eval,
)
from repro.core.shadow import (  # noqa: F401
    StreamingMerge, shadow_select, shadow_select_np, shadow_select_host,
    shadow_select_blocked, shadow_select_streaming, two_level_merge,
)
from repro.core.rsde import (  # noqa: F401
    RSDE, make_rsde, shadow_rsde, kmeans_rsde, kmeans_rsde_stream,
    paring_rsde, herding_rsde,
)
from repro.core.rskpca import (  # noqa: F401
    KPCAModel, fit, fit_rskpca, fit_kpca, fit_subsampled_kpca,
    embedding_alignment_error, eigenvalue_error,
)
from repro.core.pipeline import fit_centers, fit_shadow_fused  # noqa: F401
from repro.core.ingest_pipeline import (  # noqa: F401
    IngestStats, ingest_fit, pad_block, select_streaming,
)
from repro.core.nystrom import (  # noqa: F401
    fit_nystrom, fit_nystrom_stream, fit_weighted_nystrom,
    fit_weighted_nystrom_stream,
)
from repro.core.random_features import (  # noqa: F401
    RFFKPCAModel, fit_rff, fit_rff_stream, sample_rff,
)
from repro.core.methods import (  # noqa: F401
    METHODS, MethodSpec, fit_stream, select_method,
)
from repro.core import mmd  # noqa: F401
from repro.core.mmd import (  # noqa: F401
    weight_update_bound, absorb_bound, insert_bound, remove_bound,
)
from repro.core.kmla import (  # noqa: F401
    reduced_laplacian_eigenmaps, reduced_diffusion_maps,
)
