"""Kernel functions and pairwise-distance machinery (paper §2, Eq. 19).

The paper considers bounded, radially-symmetric kernels of the form

    k(x, y) = phi(||x - y||^p / sigma^p),      phi(s) = exp(-s)

with p = 2 (Gaussian) and p = 1 (Laplacian).  kappa = k(c, c) = phi(0) = 1.
The Lipschitz-type constant of Eq. (18) is C_X^k = 1/(2 sigma^2) for the
Gaussian and 1/sigma^2 for the Laplacian (Zhang & Kwok 2008).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops as _pallas_ops

Array = jax.Array

#: Default compute backend for every Gram-shaped op.  "pallas" routes through
#: the fused kernels in repro.kernels.ops (real Pallas on TPU, interpret
#: elsewhere); "dense" is the pure-jnp oracle path kept for parity testing.
DEFAULT_BACKEND = "pallas"
_BACKENDS = ("pallas", "dense")

#: Compute precision of the Gram-shaped matmuls: "f32" everywhere, or "bf16"
#: operands on the MXU with f32 accumulation and an f32 exp nonlinearity
#: (DESIGN.md §3; parity tolerances in tests/test_precision.py).  "int8" /
#: "fp8" are the quantized SERVING tiers (DESIGN.md §8): they drop precision
#: only in the kpca_project projector contraction (per-channel scales from
#: kernels/quantize.py, f32 accumulation, error bounds property-tested in
#: tests/test_quantized.py); every other Gram-shaped op runs them as bf16.
DEFAULT_PRECISION = "f32"
_PRECISIONS = ("f32", "bf16", "int8", "fp8")


@dataclasses.dataclass(frozen=True)
class Kernel:
    """A radially symmetric kernel k(x,y) = phi(||x-y||^p / sigma^p).

    ``backend`` selects the compute path for all Gram-shaped ops made with
    this kernel (DESIGN.md §3): the fused Pallas kernels (default) or the
    dense jnp oracle.  Both are numerically interchangeable (parity-tested to
    1e-5 in tests/test_kernels.py).

    ``precision`` selects the MXU operand dtype for those same ops: "f32"
    (default) or "bf16" (half the operand bandwidth; accumulation and the
    exp nonlinearity stay f32 — bf16-vs-f32 parity is tested with documented
    tolerances in tests/test_precision.py).  "int8"/"fp8" additionally
    quantize the serving projector contraction with per-channel scales
    (kernels/quantize.py) — the low-latency transform tier.
    """

    name: str
    sigma: float
    p: int  # exponent of the norm (2 = Gaussian, 1 = Laplacian)
    backend: str = DEFAULT_BACKEND
    precision: str = DEFAULT_PRECISION

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of {_BACKENDS}")
        if self.precision not in _PRECISIONS:
            raise ValueError(
                f"unknown precision {self.precision!r}; "
                f"expected one of {_PRECISIONS}")
        if self.backend == "dense" and self.precision != "f32":
            raise ValueError(
                "the dense backend is the f32 parity oracle and does not "
                "honor reduced precision; use backend='pallas' for "
                "bf16/int8/fp8")

    def with_backend(self, backend: str) -> "Kernel":
        return dataclasses.replace(self, backend=backend)

    def with_precision(self, precision: str) -> "Kernel":
        return dataclasses.replace(self, precision=precision)

    @property
    def kappa(self) -> float:
        """Maximum kernel value k(c, c) = phi(0)."""
        return 1.0

    @property
    def lipschitz_const(self) -> float:
        """C_X^k of Eq. (18)."""
        if self.p == 2:
            return 1.0 / (2.0 * self.sigma**2)
        return 1.0 / self.sigma**2

    def phi(self, s: Array) -> Array:
        """The profile function phi(s) = exp(-s)."""
        return jnp.exp(-s)

    def __call__(self, x: Array, y: Array) -> Array:
        """Gram matrix k(x_i, y_j) for x: (n, d), y: (m, d) -> (n, m)."""
        return gram_matrix(self, x, y)

    def mmd_bound(self, ell: float) -> float:
        """Theorem 5.1 worst-case MMD bound: sqrt(2 (kappa - phi(1/ell^p)))."""
        return float(jnp.sqrt(2.0 * (self.kappa - jnp.exp(-(1.0 / ell**self.p)))))

    def eigenvalue_bound(self, ell: float) -> float:
        """Theorem 5.2 bound on sum_i (lambda_i - lbar_i)^2 for *normalized*
        (divided by n) Gram matrices: 2 C_X^k (sigma/ell)^2."""
        return float(2.0 * self.lipschitz_const * (self.sigma / ell) ** 2)

    def hs_bound(self, ell: float) -> float:
        """Theorem 5.3 Hilbert-Schmidt operator bound."""
        return float(2.0 * self.kappa * self.mmd_bound(ell))

    def epsilon(self, ell: float) -> float:
        """Shadow radius eps(ell) = sigma / ell (§4)."""
        return self.sigma / ell


def gaussian(sigma: float, backend: str = DEFAULT_BACKEND,
             precision: str = DEFAULT_PRECISION) -> Kernel:
    return Kernel(name="gaussian", sigma=float(sigma), p=2, backend=backend,
                  precision=precision)


def laplacian(sigma: float, backend: str = DEFAULT_BACKEND,
              precision: str = DEFAULT_PRECISION) -> Kernel:
    return Kernel(name="laplacian", sigma=float(sigma), p=1, backend=backend,
                  precision=precision)


def make_kernel(name: str, sigma: float, backend: str = DEFAULT_BACKEND,
                precision: str = DEFAULT_PRECISION) -> Kernel:
    if name == "gaussian":
        return gaussian(sigma, backend, precision)
    if name == "laplacian":
        return laplacian(sigma, backend, precision)
    raise ValueError(f"unknown kernel {name!r}")


@partial(jax.jit, static_argnames=())
def pairwise_sq_dists(x: Array, y: Array) -> Array:
    """||x_i - y_j||^2 via the MXU-friendly expansion (n,d),(m,d) -> (n,m).

    Uses ||x||^2 + ||y||^2 - 2<x,y>; clamped at 0 against roundoff.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1, keepdims=True)  # (n, 1)
    yy = jnp.sum(y * y, axis=-1, keepdims=True).T  # (1, m)
    cross = x @ y.T  # (n, m) — the MXU matmul
    return jnp.maximum(xx + yy - 2.0 * cross, 0.0)


def _dist_pow(sq: Array, p: int) -> Array:
    if p == 2:
        return sq
    if p == 1:
        return jnp.sqrt(sq)
    return jnp.power(sq, p / 2.0)


def gram_matrix_dense(kernel: Kernel, x: Array, y: Array | None = None) -> Array:
    """Dense Gram matrix K_ij = k(x_i, y_j). Pure-jnp reference path.

    The Pallas kernel in ``repro.kernels.gram`` computes the same quantity
    blockwise; this function is the numerical oracle the Pallas path is
    parity-tested against.
    """
    if y is None:
        y = x
    sq = pairwise_sq_dists(x, y)
    return jnp.exp(-_dist_pow(sq, kernel.p) / (kernel.sigma**kernel.p))


def gram_matrix(kernel: Kernel, x: Array, y: Array | None = None) -> Array:
    """Gram matrix K_ij = k(x_i, y_j), dispatched on ``kernel.backend``.

    Every Gram-shaped computation in the repo funnels through here (or the
    fused variants below), so the backend switch covers fit, transform, MMD
    checks, and the RSDE schemes uniformly (DESIGN.md §3).
    """
    if kernel.backend == "pallas":
        return _pallas_ops.gram(x, x if y is None else y,
                                sigma=kernel.sigma, p=kernel.p,
                                precision=kernel.precision)
    return gram_matrix_dense(kernel, x, y)


def weighted_gram(kernel: Kernel, centers: Array, weights: Array) -> Array:
    """K-tilde = W K^C W with W = diag(sqrt(w)) (Algorithm 1 / Eq. 13).

    On the Pallas backend the weighting is fused into the Gram block pass —
    the unweighted m x m matrix never materializes.
    """
    if kernel.backend == "pallas":
        return _pallas_ops.weighted_gram(centers, weights,
                                         sigma=kernel.sigma, p=kernel.p,
                                         precision=kernel.precision)
    kc = gram_matrix_dense(kernel, centers, centers)
    sw = jnp.sqrt(weights.astype(kc.dtype))
    return kc * sw[:, None] * sw[None, :]


def kde(kernel: Kernel, data: Array, query: Array) -> Array:
    """Kernel density estimate p-hat(query) = (1/n) sum_i k(x_i, q). Eq. (8)."""
    n = data.shape[0]
    return gram_matrix(kernel, query, data).sum(axis=1) / n


def rsde_eval(kernel: Kernel, centers: Array, weights: Array, query: Array,
              n: int) -> Array:
    """Reduced-set density estimate p-tilde(query) = (1/n) sum_j w_j k(c_j, q).

    Eq. (9) — note the 1/n (not 1/m) normalization: weights sum to n.
    """
    return (gram_matrix(kernel, query, centers) * weights[None, :]).sum(axis=1) / n
