"""Reduced-set density estimates (paper §3 Eq. 9 and §6 'different RSDE schemes').

Every scheme produces an ``RSDE(centers, weights, n)`` with weights summing to
``n`` so that p-tilde(x) = (1/n) sum_j w_j k(c_j, x) approximates the KDE.

Schemes (paper §6, Figs. 7-8):
  * shadow   — Algorithm 2 (ShDE), O(mn), m derived from ell.     [this paper]
  * kmeans   — Lloyd centers, weights = cluster sizes, O(mn) per iter.  [20]
  * paring   — uniform subsample, uniform weights n/m, O(m).      [8]
  * herding  — greedy MMD-descent sample from the KDE, O(n^2 m).  [5]
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_math import Kernel, gram_matrix
from repro.core import shadow as shadow_mod

Array = jax.Array


@dataclasses.dataclass
class RSDE:
    centers: np.ndarray  # (m, d)
    weights: np.ndarray  # (m,), sums to n
    n: int               # cardinality of the originating dataset
    assign: np.ndarray | None = None  # (n,) data->center map when available
    scheme: str = "shadow"

    @property
    def m(self) -> int:
        return self.centers.shape[0]

    @property
    def retention(self) -> float:
        """Fraction of the data retained (Fig. 6)."""
        return self.m / self.n


#: "auto" selector crossover: below this n the sequential while_loop beats
#: blocked selection (the per-round assign/prune overhead only amortizes once
#: m is large — measured 2x either way at n=2048 vs n=8192).
_BLOCKED_MIN_N = 4096


def shadow_rsde(x, kernel: Kernel, ell: float, *,
                selector: str = "auto", block: int | None = None,
                chunk: int = 8192) -> RSDE:
    """ShDE via Algorithm 2 with eps = sigma/ell.

    ``selector`` picks the implementation (DESIGN.md §3):
      * "auto"       — sequential below ``_BLOCKED_MIN_N`` rows, blocked
        above (default; both sides of the crossover are exact eps-covers);
      * "blocked"    — batched selection, ~m/B sequential rounds;
      * "sequential" — the paper's literal one-center-per-iteration scan;
      * "streaming"  — per-chunk blocked selection + two-level merge (2*eps
        cover) for datasets that don't fit in device memory.
    All produce a valid eps-cover whose weights sum to n.
    """
    eps = kernel.epsilon(ell)
    if selector == "auto":
        selector = "sequential" if np.shape(x)[0] <= _BLOCKED_MIN_N \
            else "blocked"
    if selector == "blocked":
        centers, weights, assign, m = shadow_mod.shadow_select_blocked(
            x, eps, block=block)
    elif selector == "sequential":
        centers, weights, assign, m = shadow_mod.shadow_select_host(x, eps)
    elif selector == "streaming":
        centers, weights, assign, m = shadow_mod.shadow_select_streaming(
            x, eps, chunk=chunk, block=block)
    else:
        raise ValueError(f"unknown selector {selector!r}")
    return RSDE(centers, weights, n=np.shape(x)[0], assign=assign, scheme="shadow")


@partial(jax.jit, static_argnames=("m", "iters"))
def _kmeans(x: Array, m: int, iters: int, seed: int):
    n, d = x.shape
    key = jax.random.PRNGKey(seed)
    idx = jax.random.choice(key, n, (m,), replace=False)
    centers = x[idx]

    def step(centers, _):
        d2 = (
            jnp.sum(x * x, 1)[:, None]
            + jnp.sum(centers * centers, 1)[None, :]
            - 2.0 * x @ centers.T
        )
        assign = jnp.argmin(d2, axis=1)
        one_hot = jax.nn.one_hot(assign, m, dtype=x.dtype)  # (n, m)
        counts = one_hot.sum(0)
        sums = one_hot.T @ x
        new_centers = sums / jnp.maximum(counts, 1.0)[:, None]
        # keep old center for empty clusters
        new_centers = jnp.where(counts[:, None] > 0, new_centers, centers)
        return new_centers, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    d2 = (
        jnp.sum(x * x, 1)[:, None]
        + jnp.sum(centers * centers, 1)[None, :]
        - 2.0 * x @ centers.T
    )
    assign = jnp.argmin(d2, axis=1)
    counts = jax.nn.one_hot(assign, m, dtype=x.dtype).sum(0)
    return centers, counts, assign


def kmeans_rsde(x, kernel: Kernel, m: int, iters: int = 10, seed: int = 0) -> RSDE:
    """k-means RSDE (density-weighted Nystrom's selector, [20])."""
    x = jnp.asarray(x, jnp.float32)
    centers, counts, assign = _kmeans(x, m, iters, seed)
    return RSDE(
        np.asarray(centers), np.asarray(counts, np.float64),
        n=x.shape[0], assign=np.asarray(assign), scheme="kmeans",
    )


def paring_rsde(x, kernel: Kernel, m: int, seed: int = 0) -> RSDE:
    """KDE paring [8] (simplified): uniform subsample, uniform weights n/m."""
    x = np.asarray(x)
    rng = np.random.default_rng(seed)
    idx = rng.choice(x.shape[0], size=m, replace=False)
    w = np.full(m, x.shape[0] / m, dtype=np.float64)
    return RSDE(x[idx].copy(), w, n=x.shape[0], scheme="paring")


def herding_rsde(x, kernel: Kernel, m: int) -> RSDE:
    """Kernel herding [5]: greedy samples maximizing the herding functional

        c_{t+1} = argmax_{x in X}  mu(x) - (1/(t+1)) sum_{s<=t} k(c_s, x)

    where mu(x) = (1/n) sum_i k(x_i, x) is the KDE.  O(n^2 + nm).
    """
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    k_full = gram_matrix(kernel, x, x)  # (n, n)
    mu = k_full.mean(axis=1)  # KDE at each candidate

    def step(carry, t):
        acc, chosen = carry  # acc = sum_{s<=t-1} k(c_s, .) over candidates
        score = mu - acc / (t + 1.0)
        score = jnp.where(chosen, -jnp.inf, score)
        i = jnp.argmax(score)
        acc = acc + k_full[i]
        chosen = chosen.at[i].set(True)
        return (acc, chosen), i

    (_, _), idx = jax.lax.scan(
        step,
        (jnp.zeros(n, jnp.float32), jnp.zeros(n, bool)),
        jnp.arange(m, dtype=jnp.float32),
    )
    centers = np.asarray(x[idx])
    w = np.full(m, n / m, dtype=np.float64)  # herding samples are equal-weight
    return RSDE(centers, w, n=int(n), scheme="herding")


_SCHEMES = {
    "shadow": shadow_rsde,
    "kmeans": kmeans_rsde,
    "paring": paring_rsde,
    "herding": herding_rsde,
}


def make_rsde(scheme: str, x, kernel: Kernel, *, ell: float | None = None,
              m: int | None = None, **kw) -> RSDE:
    """Factory. ``shadow`` takes ell; the others take an explicit m (as in the
    paper, where the average shadow m sets m for the competing schemes)."""
    if scheme == "shadow":
        assert ell is not None, "shadow RSDE is parameterized by ell"
        return shadow_rsde(x, kernel, ell, **kw)
    assert m is not None, f"{scheme} RSDE needs an explicit m"
    return _SCHEMES[scheme](x, kernel, m=m, **kw)
