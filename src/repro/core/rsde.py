"""Reduced-set density estimates (paper §3 Eq. 9 and §6 'different RSDE schemes').

Every scheme produces an ``RSDE(centers, weights, n)`` with weights summing to
``n`` so that p-tilde(x) = (1/n) sum_j w_j k(c_j, x) approximates the KDE.

Schemes (paper §6, Figs. 7-8):
  * shadow   — Algorithm 2 (ShDE), O(mn), m derived from ell.     [this paper]
  * kmeans   — Lloyd centers, weights = cluster sizes, O(mn) per iter.  [20]
  * paring   — uniform subsample, uniform weights n/m, O(m).      [8]
  * herding  — greedy MMD-descent sample from the KDE, O(n^2 m).  [5]
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_math import Kernel, gram_matrix
from repro.core import shadow as shadow_mod
from repro.kernels import ops as kernel_ops

Array = jax.Array


@dataclasses.dataclass
class RSDE:
    centers: np.ndarray  # (m, d)
    weights: np.ndarray  # (m,), sums to n
    n: int               # cardinality of the originating dataset
    assign: np.ndarray | None = None  # (n,) data->center map when available
    scheme: str = "shadow"

    @property
    def m(self) -> int:
        return self.centers.shape[0]

    @property
    def retention(self) -> float:
        """Fraction of the data retained (Fig. 6)."""
        return self.m / self.n


#: "auto" selector crossover: below this n the sequential while_loop beats
#: blocked selection (the per-round assign/prune overhead only amortizes once
#: m is large — measured 2x either way at n=2048 vs n=8192).
_BLOCKED_MIN_N = 4096


def shadow_rsde(x, kernel: Kernel, ell: float, *,
                selector: str = "auto", block: int | None = None,
                chunk: int = 8192) -> RSDE:
    """ShDE via Algorithm 2 with eps = sigma/ell.

    ``selector`` picks the implementation (DESIGN.md §3):
      * "auto"       — sequential below ``_BLOCKED_MIN_N`` rows, blocked
        above (default; both sides of the crossover are exact eps-covers);
      * "blocked"    — batched selection, ~m/B sequential rounds;
      * "sequential" — the paper's literal one-center-per-iteration scan;
      * "streaming"  — per-chunk blocked selection + two-level merge (2*eps
        cover) for datasets that don't fit in device memory.
    All produce a valid eps-cover whose weights sum to n.
    """
    eps = kernel.epsilon(ell)
    if selector == "auto":
        selector = "sequential" if np.shape(x)[0] <= _BLOCKED_MIN_N \
            else "blocked"
    if selector == "blocked":
        centers, weights, assign, m = shadow_mod.shadow_select_blocked(
            x, eps, block=block)
    elif selector == "sequential":
        centers, weights, assign, m = shadow_mod.shadow_select_host(x, eps)
    elif selector == "streaming":
        centers, weights, assign, m = shadow_mod.shadow_select_streaming(
            x, eps, chunk=chunk, block=block)
    else:
        raise ValueError(f"unknown selector {selector!r}")
    return RSDE(centers, weights, n=np.shape(x)[0], assign=assign, scheme="shadow")


@partial(jax.jit, static_argnames=("m", "iters"))
def _kmeans(x: Array, m: int, iters: int, seed: int):
    n, d = x.shape
    key = jax.random.PRNGKey(seed)
    idx = jax.random.choice(key, n, (m,), replace=False)
    centers = x[idx]

    def step(centers, _):
        d2 = (
            jnp.sum(x * x, 1)[:, None]
            + jnp.sum(centers * centers, 1)[None, :]
            - 2.0 * x @ centers.T
        )
        assign = jnp.argmin(d2, axis=1)
        one_hot = jax.nn.one_hot(assign, m, dtype=x.dtype)  # (n, m)
        counts = one_hot.sum(0)
        sums = one_hot.T @ x
        new_centers = sums / jnp.maximum(counts, 1.0)[:, None]
        # keep old center for empty clusters
        new_centers = jnp.where(counts[:, None] > 0, new_centers, centers)
        return new_centers, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    d2 = (
        jnp.sum(x * x, 1)[:, None]
        + jnp.sum(centers * centers, 1)[None, :]
        - 2.0 * x @ centers.T
    )
    assign = jnp.argmin(d2, axis=1)
    counts = jax.nn.one_hot(assign, m, dtype=x.dtype).sum(0)
    return centers, counts, assign


def kmeans_rsde(x, kernel: Kernel, m: int, iters: int = 10, seed: int = 0) -> RSDE:
    """k-means RSDE (density-weighted Nystrom's selector, [20])."""
    x = jnp.asarray(x, jnp.float32)
    centers, counts, assign = _kmeans(x, m, iters, seed)
    return RSDE(
        np.asarray(centers), np.asarray(counts, np.float64),
        n=x.shape[0], assign=np.asarray(assign), scheme="kmeans",
    )


def paring_rsde(x, kernel: Kernel, m: int, seed: int = 0) -> RSDE:
    """KDE paring [8] (simplified): uniform subsample, uniform weights n/m.

    Subsampling via ``jax.random`` keyed off ``seed`` — deterministic across
    hosts/backends, unlike the host ``np.random`` state it replaces.
    """
    x = np.asarray(x)
    idx = np.asarray(jax.random.choice(
        jax.random.PRNGKey(seed), x.shape[0], (m,), replace=False))
    w = np.full(m, x.shape[0] / m, dtype=np.float64)
    return RSDE(x[idx].copy(), w, n=x.shape[0], scheme="paring")


@partial(jax.jit, static_argnames=("m",))
def _kmeans_stream_update(sums, counts, x, ok, idx, m: int):
    """Accumulate per-center sums/counts over one chunk's VALID rows.

    Padding rows route to a discard bucket (segment m) instead of being
    masked by a (rows, m) one-hot — ``segment_sum`` keeps the chunk update
    O(rows * d), which is what lets the stream pass scale to 1M rows.
    """
    okf = ok.astype(x.dtype)
    idx_safe = jnp.where(ok, idx, m)
    sums = sums + jax.ops.segment_sum(
        x * okf[:, None], idx_safe, num_segments=m + 1)[:m]
    counts = counts + jax.ops.segment_sum(
        okf, idx_safe, num_segments=m + 1)[:m]
    return sums, counts


def kmeans_rsde_stream(source, kernel: Kernel, m: int, seed: int = 0):
    """One-pass streaming mini-batch k-means RSDE over a chunk source
    (``.chunks()`` protocol or an iterable of ``(x, n_valid)`` blocks).

    Centers seed from the first chunk (``jax.random`` keyed off ``seed``;
    the first chunk must hold at least m valid rows), each chunk assigns
    through the Pallas ``shadow_assign`` kernel, and centers refresh to the
    running means after every chunk (mini-batch Lloyd).  Weights are the
    final-pass cluster counts, summing exactly to n.  Device residency is
    O(chunk + m*d).  Returns ``(RSDE, IngestStats)``.
    """
    import time

    from repro.core.ingest_pipeline import IngestStats  # lazy: circular

    stats = IngestStats()
    t0 = time.perf_counter()
    chunks = source.chunks() if hasattr(source, "chunks") else iter(source)
    centers = sums = counts = None
    for xb, nv in chunks:
        t1 = time.perf_counter()
        nv = int(nv)
        x = jnp.asarray(np.asarray(xb, np.float32))
        ok = jnp.arange(x.shape[0]) < nv
        if centers is None:
            if nv < m:
                raise ValueError(
                    f"first chunk holds {nv} valid rows < m={m}")
            pick = jax.random.choice(jax.random.PRNGKey(seed), nv, (m,),
                                     replace=False)
            centers = x[pick]
            sums = jnp.zeros((m, x.shape[1]), jnp.float32)
            counts = jnp.zeros((m,), jnp.float32)
        idx, _ = kernel_ops.shadow_assign(x, centers, tag="kmeans")
        sums, counts = _kmeans_stream_update(sums, counts, x, ok, idx, m)
        centers = jnp.where(counts[:, None] > 0,
                            sums / jnp.maximum(counts, 1.0)[:, None],
                            centers)
        stats.chunks += 1
        stats.rows += nv
        stats.compute_s += time.perf_counter() - t1
    if centers is None:
        raise ValueError("empty source: no chunks to ingest")
    stats.m = m
    stats.select_s = time.perf_counter() - t0
    stats.wall_s = stats.select_s
    rsde = RSDE(
        np.asarray(centers), np.asarray(counts, np.float64),
        n=int(stats.rows), scheme="kmeans-stream",
    )
    return rsde, stats


def herding_rsde(x, kernel: Kernel, m: int) -> RSDE:
    """Kernel herding [5]: greedy samples maximizing the herding functional

        c_{t+1} = argmax_{x in X}  mu(x) - (1/(t+1)) sum_{s<=t} k(c_s, x)

    where mu(x) = (1/n) sum_i k(x_i, x) is the KDE.  O(n^2 + nm).
    """
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    k_full = gram_matrix(kernel, x, x)  # (n, n)
    mu = k_full.mean(axis=1)  # KDE at each candidate

    def step(carry, t):
        acc, chosen = carry  # acc = sum_{s<=t-1} k(c_s, .) over candidates
        score = mu - acc / (t + 1.0)
        score = jnp.where(chosen, -jnp.inf, score)
        i = jnp.argmax(score)
        acc = acc + k_full[i]
        chosen = chosen.at[i].set(True)
        return (acc, chosen), i

    (_, _), idx = jax.lax.scan(
        step,
        (jnp.zeros(n, jnp.float32), jnp.zeros(n, bool)),
        jnp.arange(m, dtype=jnp.float32),
    )
    centers = np.asarray(x[idx])
    w = np.full(m, n / m, dtype=np.float64)  # herding samples are equal-weight
    return RSDE(centers, w, n=int(n), scheme="herding")


_SCHEMES = {
    "shadow": shadow_rsde,
    "kmeans": kmeans_rsde,
    "paring": paring_rsde,
    "herding": herding_rsde,
}


def make_rsde(scheme: str, x, kernel: Kernel, *, ell: float | None = None,
              m: int | None = None, **kw) -> RSDE:
    """Factory. ``shadow`` takes ell; the others take an explicit m (as in the
    paper, where the average shadow m sets m for the competing schemes)."""
    if scheme == "shadow":
        assert ell is not None, "shadow RSDE is parameterized by ell"
        return shadow_rsde(x, kernel, ell, **kw)
    assert m is not None, f"{scheme} RSDE needs an explicit m"
    return _SCHEMES[scheme](x, kernel, m=m, **kw)
