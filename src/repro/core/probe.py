"""RSKPCA activation probe — the paper's technique as a first-class training
feature (DESIGN.md §4).

During LM training, pooled hidden states are reservoir-sampled into a host
buffer.  Every ``period`` steps the probe runs distributed ShDE + RSKPCA on
the buffer and reports:

  * the top-k kernel spectrum of the representation (effective dimensionality
    of the feature manifold — collapse shows up as spectral concentration);
  * retention m/n (how redundant the representation is at bandwidth sigma);
  * eigen-embedding drift vs the previous probe (aligned Frobenius distance —
    how fast the representation is rotating).

Cost per probe is O(mn/devices + m^3) instead of O(n^2) — this is exactly the
paper's speedup applied to a production monitoring loop.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.kernels_math import Kernel, gaussian
from repro.core.rskpca import fit_rskpca, embedding_alignment_error
from repro.core.rsde import shadow_rsde
from repro.data.kpca_datasets import median_sigma


@dataclasses.dataclass
class ProbeReport:
    step: int
    spectrum: np.ndarray       # top-k eigenvalues of the reduced operator
    retention: float           # m / n
    m: int
    drift: float | None        # aligned embedding drift vs previous probe
    sigma: float

    def summary(self) -> str:
        top = ", ".join(f"{v:.4f}" for v in self.spectrum[:5])
        drift = f"{self.drift:.4f}" if self.drift is not None else "n/a"
        return (f"[probe step {self.step}] m={self.m} "
                f"retention={self.retention:.3f} drift={drift} "
                f"spectrum=[{top}...]")


class ReservoirBuffer:
    """Classic reservoir sampling of activation rows (host-side, O(cap) mem)."""

    def __init__(self, capacity: int, dim: int, seed: int = 0):
        self.capacity = capacity
        self.buf = np.zeros((capacity, dim), np.float32)
        self.seen = 0
        self.rng = np.random.default_rng(seed)

    def add(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows, np.float32).reshape(-1, self.buf.shape[1])
        for r in rows:
            if self.seen < self.capacity:
                self.buf[self.seen] = r
            else:
                j = self.rng.integers(0, self.seen + 1)
                if j < self.capacity:
                    self.buf[j] = r
            self.seen += 1

    @property
    def data(self) -> np.ndarray:
        return self.buf[: min(self.seen, self.capacity)]


class RSKPCAProbe:
    """Attachable representation monitor for the training loop."""

    def __init__(self, dim: int, capacity: int = 2048, rank: int = 8,
                 ell: float = 4.0, period: int = 50, seed: int = 0,
                 mesh=None):
        self.buffer = ReservoirBuffer(capacity, dim, seed)
        self.rank = rank
        self.ell = ell
        self.period = period
        self.mesh = mesh
        self._prev_embedding: np.ndarray | None = None
        self._anchor: np.ndarray | None = None  # fixed query set for drift
        self.reports: list[ProbeReport] = []

    def observe(self, hidden: np.ndarray) -> None:
        """Feed pooled hidden states, shape (batch, dim)."""
        self.buffer.add(hidden)

    def maybe_probe(self, step: int) -> ProbeReport | None:
        if step % self.period or self.buffer.seen < 64:
            return None
        return self.probe(step)

    def probe(self, step: int) -> ProbeReport:
        x = self.buffer.data
        sigma = max(median_sigma(x), 1e-6)
        kernel = gaussian(sigma)
        if self.mesh is not None and np.prod(self.mesh.devices.shape) > 1:
            from repro.core.distributed import distributed_shadow_rsde
            ndev = self.mesh.shape["data"]
            n_fit = (x.shape[0] // ndev) * ndev
            rsde = distributed_shadow_rsde(x[:n_fit], kernel, self.ell, self.mesh)
        else:
            rsde = shadow_rsde(x, kernel, self.ell)
        rank = min(self.rank, rsde.m)
        model = fit_rskpca(rsde, kernel, rank=rank)
        if self._anchor is None:
            self._anchor = x[: min(256, x.shape[0])].copy()
        emb = model.transform(self._anchor)
        drift = None
        if self._prev_embedding is not None:
            k = min(emb.shape[1], self._prev_embedding.shape[1])
            denom = np.linalg.norm(self._prev_embedding[:, :k]) + 1e-12
            drift = embedding_alignment_error(
                self._prev_embedding[:, :k], emb[:, :k]
            ) / denom
        self._prev_embedding = emb
        report = ProbeReport(
            step=step, spectrum=model.eigvals, retention=rsde.retention,
            m=rsde.m, drift=drift, sigma=sigma,
        )
        self.reports.append(report)
        return report
