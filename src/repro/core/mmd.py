"""Maximum mean discrepancy + the paper's Theorem 5.1-5.4 quantities.

All quantities are defined exactly as in §5 so the property tests can check
the closed-form bounds directly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_math import Kernel, gram_matrix

Array = jnp.ndarray


def mmd_biased(kernel: Kernel, x, y) -> float:
    """Biased MMD (Eq. 20) between equal-cardinality sets X and Y."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    kxx = gram_matrix(kernel, x, x).mean()
    kyy = gram_matrix(kernel, y, y).mean()
    kxy = gram_matrix(kernel, x, y).mean()
    return float(jnp.sqrt(jnp.maximum(kxx + kyy - 2.0 * kxy, 0.0)))


def mmd_weighted(kernel: Kernel, x, centers, weights) -> float:
    """MMD(X, C-tilde) where C-tilde is the shadow-quantized dataset, computed
    in weighted form without materializing the n duplicated centers:

        || (1/n) sum_i psi(x_i) - (1/n) sum_j w_j psi(c_j) ||_H
    """
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(centers, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    n = x.shape[0]
    kxx = gram_matrix(kernel, x, x).sum() / n**2
    kcc = (w[:, None] * gram_matrix(kernel, c, c) * w[None, :]).sum() / n**2
    kxc = (gram_matrix(kernel, x, c) * w[None, :]).sum() / n**2
    return float(jnp.sqrt(jnp.maximum(kxx + kcc - 2.0 * kxc, 0.0)))


def quantized_dataset(x: np.ndarray, centers: np.ndarray,
                      assign: np.ndarray) -> np.ndarray:
    """C-tilde = {c_alpha(1), ..., c_alpha(n)} (§5)."""
    return centers[assign]


def eigenvalue_gap_sq(kernel: Kernel, x, x_quant) -> float:
    """sum_i (lambda_i - lbar_i)^2 for the NORMALIZED (K/n) Gram matrices of
    the data and its quantization (Theorem 5.2 LHS)."""
    x = jnp.asarray(x, jnp.float32)
    xq = jnp.asarray(x_quant, jnp.float32)
    n = x.shape[0]
    lam = jnp.linalg.eigvalsh(gram_matrix(kernel, x, x) / n)
    lam_q = jnp.linalg.eigvalsh(gram_matrix(kernel, xq, xq) / n)
    return float(jnp.sum((lam - lam_q) ** 2))


def hs_operator_distance(kernel: Kernel, x, x_quant) -> float:
    """||K_n - Kbar_n||_HS for the empirical operators (22).

    In the RKHS, <k_a, k_b> = k(a, b), so the HS norm of
    (1/n) sum_i <., k_xi> k_yi style operators reduces to Gram sums:

        ||K_n - Kbar_n||_HS^2 = (1/n^2) [ sum_ij k(x_i,x_j) k(x_i,x_j)
            - 2 sum_ij k(x_i, c_i') k(x_j, c_j') ... ]

    computed here exactly via the 4-block expansion with A_i = k_{x_i},
    B_i = k_{c_alpha(i)}:
        ||sum_i (A_i x A_i - B_i x B_i)/n||^2
      = (1/n^2) sum_ij [ K(x,x)_ij^2 - 2 K(x,c)_ij K(c,x... ) + K(c,c)_ij^2 ]
    where (A x A) denotes the rank-one operator <., A> A.
    """
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(x_quant, jnp.float32)
    n = x.shape[0]
    kxx = gram_matrix(kernel, x, x)
    kcc = gram_matrix(kernel, c, c)
    kxc = gram_matrix(kernel, x, c)
    # <A_i??A_i, A_j??A_j>_HS = k(x_i,x_j)^2 ; <A??A, B??B>_HS = k(x_i,c_j)^2
    val = (kxx**2).sum() - 2.0 * (kxc**2).sum() + (kcc**2).sum()
    return float(jnp.sqrt(jnp.maximum(val, 0.0)) / n)


def eigenspace_projection_distance(kernel: Kernel, x, x_quant, rank: int) -> float:
    """||P^D(K_n) - P^D(Kbar_n)||_HS (Theorem 5.4 LHS), computed in the span
    of the 2n mapped points.

    P^D(K_n) = sum_{i<=D} <., e_i> e_i with e_i the top unit eigenfunctions.
    Using the Gram of the joint set Z = [x; x_quant] we orthonormalize the
    span, express both projections as matrices in that basis, and take the
    Frobenius norm of the difference.
    """
    x = np.asarray(x, np.float64)
    c = np.asarray(x_quant, np.float64)
    n = x.shape[0]
    z = np.concatenate([x, c], axis=0)
    kzz = np.asarray(gram_matrix(kernel, jnp.asarray(z), jnp.asarray(z)),
                     np.float64)
    # Basis for span{psi(z_i)}: kzz = R^T R (Cholesky w/ jitter); column i of R
    # is psi(z_i) in an orthonormal basis.
    jitter = 1e-9 * np.eye(2 * n)
    rchol = np.linalg.cholesky(kzz + jitter).T  # (2n, 2n): psi(z_i) = R[:, i]
    phi_x, phi_c = rchol[:, :n], rchol[:, n:]
    proj = []
    for phi in (phi_x, phi_c):
        op = phi @ phi.T / n  # K_n as a matrix in the orthonormal basis
        lam, vec = np.linalg.eigh(op)
        top = vec[:, ::-1][:, :rank]
        proj.append(top @ top.T)
    return float(np.linalg.norm(proj[0] - proj[1]))


def weight_update_bound(n_old, n_new, w_old, w_new, kappa: float = 1.0):
    """Closed-form Frobenius bound on the normalized-operator perturbation
    caused by changing ONE center's weight (the §5 machinery applied to a
    single online update; jittable, returns a f32 scalar).

    The reduced operator is K-tilde/n = (s s^T ⊙ K) / n with s = sqrt(w),
    ||s||^2 = n and |K_ij| <= kappa.  Changing center j's weight w -> w'
    (and the total mass n -> n') changes the weight factor by the RANK-TWO
    matrix a a^T - b b^T with unit vectors a = s'/sqrt(n'), b = s/sqrt(n),
    so with t = a.b = (n - w + sqrt(w w')) / sqrt(n n'):

        || K-tilde'/n' - K-tilde/n ||_F  <=  kappa * sqrt(2 (1 - t^2))

    Special cases (the paper's Theorem 5.1/5.3 flavor, per update):
      * insert a fresh unit-mass center: w=0, w'=1  ->  kappa sqrt(2/(n+1))
      * absorb one sample into center j:  w'=w+1, n'=n+1
      * remove center j entirely:         w'=0, n'=n-w  ->  kappa sqrt(2w/n)
    """
    n_old = jnp.asarray(n_old, jnp.float32)
    n_new = jnp.asarray(n_new, jnp.float32)
    w_old = jnp.asarray(w_old, jnp.float32)
    w_new = jnp.asarray(w_new, jnp.float32)
    t = (n_old - w_old + jnp.sqrt(w_old * w_new)) / jnp.sqrt(
        jnp.maximum(n_old * n_new, 1e-12))
    return kappa * jnp.sqrt(jnp.maximum(2.0 * (1.0 - t * t), 0.0))


def absorb_bound(n, w_j, kappa: float = 1.0):
    """Perturbation bound for absorbing one sample into a center of weight
    w_j (Algorithm 2's absorption rule applied online)."""
    return weight_update_bound(n, n + 1.0, w_j, w_j + 1.0, kappa)


def insert_bound(n, kappa: float = 1.0):
    """Perturbation bound for inserting a fresh unit-mass center."""
    return weight_update_bound(n, n + 1.0, 0.0, 1.0, kappa)


def remove_bound(n, w_j, kappa: float = 1.0):
    """Perturbation bound for deleting a center of weight w_j — the paper's
    'remove samples with minimal effect on the empirical operator' (§5)."""
    return weight_update_bound(n, n - w_j, w_j, 0.0, kappa)


def staleness_bound(w_pub, w_cur, kappa: float = 1.0) -> float:
    """Operator-drift bound of a STALE published snapshot (DESIGN.md §17).

    The whole-vector generalization of :func:`weight_update_bound`: the
    published operator carries masses ``w_pub`` (per center slot) while the
    live state has drifted to ``w_cur``.  With s = sqrt(w) and unit vectors
    a = s_cur/||s_cur||, b = s_pub/||s_pub||, the weight factor changes by
    the rank-two matrix a a^T - b b^T, so with

        t = a . b = sum_j sqrt(w_pub_j * w_cur_j) / sqrt(n_pub * n_cur)

    the SAME identity gives ``||K'/n' - K/n||_F <= kappa sqrt(2 (1 - t^2))``.
    Valid whenever slot j holds the same center position in both vectors —
    exactly the ingest situation (absorption changes masses in place; a
    fresh center lands in a previously-dead ``w_pub_j = 0`` slot, which the
    formula prices like :func:`insert_bound`).  Vectors of different length
    (capacity grew) are zero-padded to align.

    This is what a degraded server reports when a publish FAILS and queries
    keep flowing against the last good snapshot: the error budget of
    serving stale, host-side and O(m) — cheap enough to refresh per failed
    publish (``swap.staleness_bound`` gauge, ``SnapshotInfo``).
    """
    a = np.asarray(w_pub, np.float64).ravel()
    b = np.asarray(w_cur, np.float64).ravel()
    m = max(a.size, b.size)
    if a.size < m:
        a = np.concatenate([a, np.zeros(m - a.size)])
    if b.size < m:
        b = np.concatenate([b, np.zeros(m - b.size)])
    n_pub, n_cur = float(a.sum()), float(b.sum())
    if n_pub <= 0.0 or n_cur <= 0.0:
        return float(kappa) * float(np.sqrt(2.0))  # no overlap information
    t = float(np.sqrt(a * b).sum()) / float(np.sqrt(n_pub * n_cur))
    return float(kappa) * float(np.sqrt(max(2.0 * (1.0 - t * t), 0.0)))


def centroid_error_max(kernel: Kernel, x, x_quant) -> float:
    """max_i ||k_{x_i} - k_{c_alpha(i)}||_H = max_i sqrt(2(kappa - k(x_i, c_i')))."""
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(x_quant, jnp.float32)
    kxc = jnp.exp(
        -((jnp.sum((x - c) ** 2, axis=1)) ** (kernel.p / 2.0))
        / kernel.sigma**kernel.p
    )
    return float(jnp.sqrt(jnp.maximum(2.0 * (kernel.kappa - kxc), 0.0)).max())
