"""Method zoo dispatch (ISSUE 8; DESIGN.md §15).

One registry mapping method names to their resident and out-of-core fit
entrypoints plus the paper-Table-2 cost model, one ``fit_stream`` front door
routing every method through the optimized stack (Pallas gram ops, autotuned
plans, matrix-free eigensolves, chunked out-of-core ingestion), and one
``select_method`` picker that reads the MEASURED accuracy-vs-time-vs-memory
Pareto recorded by benchmarks/methods_bench.py (mode=methods rows in
BENCH_rskpca.json) instead of guessing from asymptotics.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core.kernels_math import Kernel

#: objective -> (accuracy, fit-time, model-bytes) weights over the
#: normalized Pareto frontier.  "balanced" trades a point of accuracy
#: against an order of magnitude of time or memory.
_OBJECTIVES = {
    "balanced": (1.0, 0.5, 0.5),
    "accuracy": (1.0, 0.05, 0.05),
    "speed": (0.25, 1.0, 0.1),
    "memory": (0.25, 0.1, 1.0),
}


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """One row of the zoo: entrypoints + paper-Table-2 asymptotics."""

    name: str
    train: str   # training cost, paper Table 2 notation
    test: str    # per-query embedding cost
    space: str   # model storage

    def fit(self, x, kernel: Kernel, rank: int, **kw):
        from repro.core import rskpca
        return rskpca.fit(x, kernel, rank, method=self.name, **kw)


METHODS = {
    "shadow": MethodSpec("shadow", train="O(mn + m^2 k)", test="O(km)",
                         space="O(m(d + k))"),
    "nystrom": MethodSpec("nystrom", train="O(nm + m^2 k)", test="O(kn)",
                          space="O(n(d + k))"),
    "wnystrom": MethodSpec("wnystrom", train="O(mnT + m^2 k)", test="O(km)",
                           space="O(m(d + k))"),
    "rff": MethodSpec("rff", train="O(nD(d + D))", test="O(D(d + k))",
                      space="O(D(d + k))"),
}


def fit_stream(source, kernel: Kernel, rank: int, *, method: str = "shadow",
               ell: float | None = None, m: int | None = None, **kw):
    """Out-of-core front door: fit any zoo method from a chunk source
    (``.chunks()`` protocol or an iterable of ``(x, n_valid)`` blocks).

    Every route keeps device residency at O(chunk + model) — the ingest
    pipeline for shadow, host-buffered streaming extension for nystrom,
    streaming mini-batch k-means for wnystrom, streamed feature covariance
    for rff.  Returns ``(KPCAModel, IngestStats)``.
    """
    if method == "shadow":
        from repro.core.ingest_pipeline import ingest_fit
        assert ell is not None, "shadow RSDE is parameterized by ell"
        return ingest_fit(source, kernel, rank, ell=ell, **kw)
    if method == "nystrom":
        from repro.core.nystrom import fit_nystrom_stream
        assert m is not None, "nystrom needs an explicit m"
        return fit_nystrom_stream(source, kernel, rank, m, **kw)
    if method == "wnystrom":
        from repro.core.nystrom import fit_weighted_nystrom_stream
        assert m is not None, "weighted nystrom needs an explicit m"
        return fit_weighted_nystrom_stream(source, kernel, rank, m, **kw)
    if method == "rff":
        from repro.core.random_features import (DEFAULT_FEATURES,
                                                fit_rff_stream)
        return fit_rff_stream(source, kernel, rank,
                              n_features=(m or DEFAULT_FEATURES), **kw)
    raise ValueError(f"unknown streaming method {method!r} "
                     f"(choose from {sorted(METHODS)})")


def _bench_path() -> str:
    env = os.environ.get("REPRO_BENCH_JSON")
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, "BENCH_rskpca.json")


def _method_rows() -> list[dict]:
    path = _bench_path()
    if not os.path.exists(path):
        return []
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, ValueError):
        return []
    rows = doc.get("rows", []) if isinstance(doc, dict) else doc
    return [r for r in rows
            if isinstance(r, dict)
            if r.get("mode") == "methods" and r.get("method") in METHODS
            and all(k in r for k in ("n", "fit_s", "knn_acc", "model_bytes"))]


def _pareto(rows: list[dict]) -> list[dict]:
    """Drop rows dominated on (accuracy up, fit_s down, model_bytes down)."""
    keep = []
    for r in rows:
        dominated = any(
            o is not r
            and o["knn_acc"] >= r["knn_acc"]
            and o["fit_s"] <= r["fit_s"]
            and o["model_bytes"] <= r["model_bytes"]
            and (o["knn_acc"] > r["knn_acc"] or o["fit_s"] < r["fit_s"]
                 or o["model_bytes"] < r["model_bytes"])
            for o in rows)
        if not dominated:
            keep.append(r)
    return keep


def _heuristic(n: int, objective: str) -> str:
    """Deterministic fallback when no bench rows exist: Table 2 asymptotics.
    Memory/speed objectives take the n-independent model (rff); accuracy
    stays with the exact-kernel compressed fit (shadow); balanced flips to
    rff once the nystrom-style O(n) storage is the dominant term."""
    if objective == "memory":
        return "rff"
    if objective == "speed":
        return "rff" if n > 100_000 else "nystrom"
    if objective == "accuracy":
        return "shadow"
    return "shadow" if n <= 262_144 else "rff"


def select_method(n: int, d: int, rank: int, *,
                  objective: str = "balanced") -> str:
    """Pick a zoo method for (n, d, rank) from the measured Pareto.

    Uses the bench rows nearest in log(n), drops Pareto-dominated methods,
    then scores the frontier with the objective's (accuracy, time, memory)
    weights — time and memory on log scales, so a 10x cost gap weighs like a
    normalized accuracy point.  Falls back to a deterministic Table-2
    heuristic when no mode=methods rows exist.
    """
    if objective not in _OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r} "
                         f"(choose from {sorted(_OBJECTIVES)})")
    rows = _method_rows()
    if not rows:
        return _heuristic(n, objective)
    dist = {r["n"]: abs(np.log(max(n, 1)) - np.log(max(r["n"], 1)))
            for r in rows}
    n_star = min(dist, key=dist.get)
    cands = _pareto([r for r in rows if r["n"] == n_star])
    wa, wt, wm = _OBJECTIVES[objective]
    acc = np.array([r["knn_acc"] for r in cands], np.float64)
    lt = np.log(np.maximum([r["fit_s"] for r in cands], 1e-9))
    lm = np.log(np.maximum([r["model_bytes"] for r in cands], 1.0))

    def norm(v):  # -> [0, 1] over the frontier; constant -> 0
        span = v.max() - v.min()
        return (v - v.min()) / span if span > 0 else np.zeros_like(v)

    score = wa * norm(acc) - wt * norm(lt) - wm * norm(lm)
    return cands[int(np.argmax(score))]["method"]
