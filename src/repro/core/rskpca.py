"""Reduced-Set KPCA (paper Algorithm 1) + the KPCA baselines it is compared to.

Derivation (paper §3).  Discretizing the continuous eigenproblem (3) with the
reduced empirical density p(x) ~ (1/n) sum_i w_i delta(c_i, x) gives

    K-tilde u = (n lambda) u,   K-tilde_ij = sqrt(w_i) k(c_i, c_j) sqrt(w_j)

with u_i = sqrt(w_i) phi(c_i).  The Nystrom-style extension of eigenfunction
iota to a query point x is

    phi_iota(x) = (1 / (n lambda_iota)) sum_i k(x, c_i) sqrt(w_i) u_i^iota

and the KPCA embedding (unit-variance principal axes, matching classical KPCA's
alpha = v / sqrt(lambda_mat) normalization) collapses to

    z(x) = k(x, C) @ A,    A = diag(sqrt(w)) U  Lambda^{-1/2}

where (Lambda, U) is the eigensystem of K-tilde.  With ell -> inf every point
is its own center (w = 1), K-tilde = K and RSKPCA == KPCA exactly — this is
unit-tested.

Training cost O(mn + m^3), evaluation O(km); the original data is DISCARDED
after center selection (unlike Nystrom).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_math import (Kernel, gram_matrix, gram_matrix_dense,
                                     weighted_gram)
from repro.core.rsde import RSDE, make_rsde
from repro.kernels import ops as kernel_ops

Array = jax.Array

#: Query rows are streamed through transform in slices of this size so a huge
#: query set never materializes a full q x m working set on device.
TRANSFORM_CHUNK = 8192


@dataclasses.dataclass
class KPCAModel:
    """A fitted (RS)KPCA model: everything needed to embed new points.

    ``projector`` already folds in the weight/eigenvalue normalization, so
    embedding is a single fused kernel-eval + matmul: z = k(x, centers) @ projector.
    """

    kernel: Kernel
    centers: np.ndarray      # (m, d) — the ONLY data retained
    projector: np.ndarray    # (m, r)
    eigvals: np.ndarray      # (r,) of the (normalized) reduced operator
    method: str = "rskpca"

    @property
    def m(self) -> int:
        return self.centers.shape[0]

    @property
    def rank(self) -> int:
        return self.projector.shape[1]

    def transform(self, x, chunk: int = TRANSFORM_CHUNK,
                  mesh=None, axis: str = "data") -> np.ndarray:
        """Embed query points: O(q * m * (d + r)), streamed in fixed chunks.

        On the Pallas backend the kernel evaluation and the projection matmul
        are fused (repro.kernels.kpca_project) — the (chunk, m) Gram block
        stays in VMEM and only the (chunk, r) embedding is written back.
        The ragged tail chunk is padded to the fixed chunk size, so a stream
        of arbitrary query sizes compiles exactly once (DESIGN.md §5).

        ``mesh`` shards the query rows over the mesh's ``axis`` and runs the
        fused projection per device with the (m, r) projector replicated —
        the embarrassingly-parallel O(qm) path of DESIGN.md §5.
        """
        if mesh is not None:
            from repro.core import distributed as dist
            z = dist.sharded_kpca_project(
                x, self.centers, self.projector, self.kernel, mesh,
                axis=axis, chunk=chunk)
            return np.asarray(z)
        if self.kernel.backend == "pallas":
            # no host roundtrip: device-resident queries go straight through
            z = kernel_ops.kpca_project(
                x, self.centers, self.projector,
                sigma=self.kernel.sigma, p=self.kernel.p, chunk=chunk,
                precision=self.kernel.precision)
            return np.asarray(z)
        x = np.asarray(x, np.float32)
        chunk = x.shape[0] if chunk is None else chunk  # None = unchunked,
        # matching the pallas branch's kpca_project(chunk=None) contract
        out = np.empty((x.shape[0], self.rank), np.float32)
        proj = jnp.asarray(self.projector)
        cj = jnp.asarray(self.centers)
        for s in range(0, x.shape[0], chunk):
            k_xc = gram_matrix_dense(self.kernel, jnp.asarray(x[s : s + chunk]),
                                     cj)
            out[s : s + chunk] = np.asarray(k_xc @ proj)
        return out


#: Above this matrix size the full O(m^3) eigh is replaced by LOBPCG, which
#: only iterates the top-``rank`` invariant subspace (O(m^2 r) per sweep).
#: Kernel spectra decay fast, so it converges in a handful of iterations to
#: ~1e-4 relative error (parity-tested in tests/test_rskpca.py); small
#: problems keep the exact solver so all paper-parity tests run through
#: eigh unchanged.  1024 is where measured eigh cost (~0.3s, with vectors)
#: clears LOBPCG's (~0.01s) by >10x on CPU — see BENCH_rskpca.json.
_LOBPCG_MIN_M = 1024


def _canonicalize_signs(vec: Array) -> Array:
    """Flip each eigenvector so its largest-|.| component is positive.

    eigh/LOBPCG sign choices are implementation details that differ between
    padded/sharded/single-device solves of the SAME operator; pinning the
    sign makes the sharded path bit-comparable to the single-device one
    (tests/test_sharded.py) without affecting any sign-invariant consumer.
    """
    i = jnp.argmax(jnp.abs(vec), axis=0)
    s = jnp.sign(vec[i, jnp.arange(vec.shape[1])])
    return vec * jnp.where(s == 0, 1.0, s)[None, :]


def _lobpcg_topk(operator, m: int, rank: int):
    """Top-``rank`` eigenpairs (descending) of a PSD operator — a matrix or
    a matvec callable — via LOBPCG, with the repo-standard deterministic
    start and sign convention.  Every large-m eigensolve path (materialized,
    matrix-free, sharded, streaming) shares THIS definition, so iteration
    budget / seed / canonicalization can never drift between the paths the
    parity tests compare."""
    from jax.experimental.sparse.linalg import lobpcg_standard

    x0 = jax.random.normal(jax.random.PRNGKey(0), (m, rank), jnp.float32)
    lam, vec, _ = lobpcg_standard(operator, x0, m=100)
    return lam, _canonicalize_signs(vec)


def _top_eigh(mat: Array, rank: int):
    """Top-``rank`` eigenpairs of a symmetric PSD matrix, descending."""
    m = mat.shape[0]
    if m > _LOBPCG_MIN_M and 5 * rank < m:
        return _lobpcg_topk(mat, m, rank)
    lam, vec = jnp.linalg.eigh(mat)  # ascending
    lam = lam[::-1][:rank]
    vec = vec[:, ::-1][:, :rank]
    return lam, _canonicalize_signs(vec)


def _host_subset_eigh(kt: np.ndarray, rank: int):
    """Top-``rank`` eigenpairs via LAPACK's subset driver (syevr).

    CPU-only fast path: computing just the top-r invariant subspace is ~5x
    faster than the full syevd jnp.linalg.eigh at m ~ 500-1000, which is
    the dominant fit cost at small n (BENCH_rskpca.json n=2048).  Signs are
    canonicalized with the same rule as the device path, so all paths stay
    comparable.  Returns None if scipy is unavailable (callers fall back to
    the fused device fit).
    """
    try:
        from scipy.linalg import eigh as _seigh
    except ImportError:  # pragma: no cover - container ships scipy
        return None
    m = kt.shape[0]
    rank = min(rank, m)  # graceful truncation, matching _top_eigh's slice
    lam, u = _seigh(kt, subset_by_index=[m - rank, m - 1])  # ascending
    lam = np.asarray(lam, np.float32)[::-1]
    u = np.ascontiguousarray(np.asarray(u, np.float32)[:, ::-1])
    # same sign rule as _canonicalize_signs, in numpy (host path stays host)
    s = np.sign(u[np.abs(u).argmax(axis=0), np.arange(u.shape[1])])
    return lam, u * np.where(s == 0, 1.0, s)[None, :].astype(np.float32)


def _fold_projector(lam: np.ndarray, u: np.ndarray, w: np.ndarray, n: float):
    """A = diag(sqrt(w)) U Lambda^{-1/2} / sqrt(n) on host (trivial cost)."""
    lam = np.maximum(lam, 1e-12)
    sw = np.sqrt(w.astype(np.float32))
    proj = (sw[:, None] * u) / np.sqrt(lam)[None, :] / np.sqrt(np.float32(n))
    return lam, proj


@partial(jax.jit, static_argnames=("kernel", "rank", "matfree"),
         donate_argnums=(0, 1))
def _fit_rskpca_device(c: Array, w: Array, n: Array, kernel: Kernel,
                       rank: int, matfree: bool = False):
    """Algorithm 1 on device, end-to-end under one jit: fused W K^C W
    (Pallas on the default backend), eigh, and the projector fold — nothing
    round-trips to host between center selection and the projector.

    ``matfree=True`` (DESIGN.md §6) never materializes the m x m weighted
    Gram: LOBPCG's matvec recomputes kernel tiles on-chip through the fused
    ``gram_matvec`` Pallas kernel, so peak fit memory drops from O(m^2) to
    O(m * block).  The center/weight buffers are donated — callers pass
    freshly created device arrays (fit_rskpca converts from numpy; the fused
    pipeline slices fresh buffers out of the selection output), and XLA
    reuses their storage instead of copying.
    """
    sw = jnp.sqrt(w)
    if matfree:
        def matvec(v):
            return kernel_ops.gram_matvec(
                c, c, v, wx=w, wy=w, sigma=kernel.sigma, p=kernel.p,
                precision=kernel.precision, allow_dense=False) / n

        lam, u = _lobpcg_topk(matvec, c.shape[0], rank)
    else:
        k_tilde = weighted_gram(kernel, c, w) / n  # normalized (divide by n)
        lam, u = _top_eigh(k_tilde, rank)
    lam = jnp.maximum(lam, 1e-12)
    # A = diag(sqrt(w)) U Lambda^{-1/2} / sqrt(n): z(x) = k(x,C) A has the same
    # scale as classical KPCA's z(x) = k(x,X) V Lambda_mat^{-1/2} (checked in
    # tests/test_rskpca.py::test_limit_equals_kpca).
    proj = (sw[:, None] * u) / jnp.sqrt(lam)[None, :] / jnp.sqrt(n)
    return lam, proj


def _use_matfree(kernel: Kernel, m: int, rank: int,
                 matfree: bool | None) -> bool:
    """Matrix-free engage rule: explicit override, else the bytes-budget
    crossover (kernels.ops.matfree_fit) — and only where LOBPCG is sound
    (rank well below m) on the Pallas backend (the dense backend is the
    materializing oracle by definition).  An explicit ``matfree=True`` that
    LOBPCG cannot honor fails loudly HERE, not with a cryptic error deep in
    the solver — and never silently materializes the Gram the caller asked
    us not to build."""
    if matfree:
        if 5 * rank >= m:
            raise ValueError(
                f"matfree=True needs 5*rank < m for a sound LOBPCG solve "
                f"(got rank={rank}, m={m}); drop the override below the "
                "crossover — the materialized path is exact there")
        return True
    if matfree is not None:  # explicit False
        return False
    return (kernel.backend == "pallas" and 5 * rank < m
            and kernel_ops.matfree_fit(m))


def fit_rskpca(rsde: RSDE, kernel: Kernel, rank: int,
               mesh=None, axis: str = "data",
               matfree: bool | None = None) -> KPCAModel:
    """Algorithm 1: weighted m x m Gram, eigh, fold weights into projector.

    With ``mesh``, the m x m weighted Gram assembly is sharded over center
    ROWS (columns replicated) and the large-m eigensolve runs LOBPCG with a
    row-distributed matvec — only the (m, r) projector is ever replicated
    (DESIGN.md §5).  The result matches the single-device fit to fp noise.

    Above the matrix-free crossover (``matfree=None`` consults the
    bytes-budget policy in kernels.ops; True/False force it) the m x m Gram
    is never materialized at all: LOBPCG's matvec streams kernel tiles
    through the fused ``gram_matvec`` Pallas kernel (DESIGN.md §6).  Below
    the crossover the materialized path runs unchanged, bit-identically.
    """
    # materialize to host FIRST: the single-device fits donate (c, w), and
    # building them from numpy guarantees fresh device buffers even when the
    # caller's RSDE already holds jax arrays (jnp.asarray would alias them
    # and donation would consume the caller's data)
    centers_np = np.asarray(rsde.centers, np.float32)
    c = jnp.asarray(centers_np)
    w = jnp.asarray(np.asarray(rsde.weights, np.float32))
    use_mf = _use_matfree(kernel, c.shape[0], rank, matfree)
    if mesh is not None:
        from repro.core import distributed as dist
        lam, proj = dist.fit_rskpca_sharded(c, w, rsde.n, kernel, rank,
                                            mesh, axis=axis, matfree=matfree)
    elif use_mf:
        lam, proj = _fit_rskpca_device(c, w, jnp.float32(rsde.n), kernel,
                                       rank, matfree=True)
    elif (jax.default_backend() == "cpu" and c.shape[0] <= _LOBPCG_MIN_M):
        # CPU dispatch: fused Gram on device, then the LAPACK subset
        # eigensolve on host — 2x the end-to-end fit at m ~ 500 vs keeping
        # the full eigh inside the jit.  TPU keeps the fused single-jit fit.
        kt = np.asarray(weighted_gram(kernel, c, w)) / np.float32(rsde.n)
        top = _host_subset_eigh(kt, rank)
        if top is None:
            lam, proj = _fit_rskpca_device(c, w, jnp.float32(rsde.n),
                                           kernel, rank)
        else:
            lam, proj = _fold_projector(*top, np.asarray(w), rsde.n)
    else:
        lam, proj = _fit_rskpca_device(c, w, jnp.float32(rsde.n), kernel,
                                       rank)
    return KPCAModel(
        kernel=kernel,
        centers=centers_np,
        projector=np.asarray(proj),
        eigvals=np.asarray(lam),
        method=f"rskpca+{rsde.scheme}",
    )


def fit_kpca(x, kernel: Kernel, rank: int) -> KPCAModel:
    """Classical (uncentered) KPCA baseline: O(n^3) train, O(kn) test.

    The paper's operator view (§2) uses the uncentered Gram matrix — KPCA on
    the kernel mean map — so no Gram centering is applied anywhere.
    """
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    k = gram_matrix(kernel, x, x) / n
    lam, v = _top_eigh(k, rank)
    lam = jnp.maximum(lam, 1e-12)
    proj = v / jnp.sqrt(lam)[None, :] / np.sqrt(n)
    return KPCAModel(
        kernel=kernel,
        centers=np.asarray(x),
        projector=np.asarray(proj),
        eigvals=np.asarray(lam),
        method="kpca",
    )


def fit_subsampled_kpca(x, kernel: Kernel, rank: int, m: int,
                        seed: int = 0) -> KPCAModel:
    """Uniform-subsample KPCA baseline (paper §6 'subsampled KPCA'):
    unweighted KPCA on m uniformly chosen points."""
    x = np.asarray(x)
    idx = np.asarray(jax.random.choice(
        jax.random.PRNGKey(seed), x.shape[0], (m,), replace=False))
    return dataclasses.replace(fit_kpca(x[idx], kernel, rank), method="uniform")


def fit(x, kernel: Kernel, rank: int, *, method: str = "shadow",
        ell: float | None = None, m: int | None = None,
        backend: str | None = None, precision: str | None = None,
        mesh=None, axis: str = "data", **kw) -> KPCAModel:
    """One-call front door: RSDE scheme name, 'kpca', or 'uniform'.

    ``backend`` overrides the kernel's compute path ("pallas" | "dense") for
    this fit and the returned model — the parity-testing switch of
    DESIGN.md §3.  ``precision`` overrides the MXU operand dtype the same
    way ("f32" | "bf16").  ``mesh`` runs selection (two-level distributed
    ShDE), the Gram assembly, and the eigensolve sharded over the mesh's
    ``axis`` (DESIGN.md §5); the returned model's ``transform`` accepts the
    same ``mesh=`` for sharded serving.
    """
    if backend is not None:
        kernel = kernel.with_backend(backend)
    if precision is not None:
        kernel = kernel.with_precision(precision)
    if method == "auto":
        # measured accuracy/time/memory Pareto from BENCH_rskpca.json
        # mode=methods rows (benchmarks/methods_bench.py); deterministic
        # heuristic when no bench rows exist (core/methods.py)
        from repro.core.methods import select_method
        method = select_method(np.shape(x)[0], np.shape(x)[1], rank,
                               objective=kw.pop("objective", "balanced"))
        if method == "shadow" and ell is None:
            ell = 4.0  # middle of the paper's ell sweep (configs)
    if method == "nystrom":
        from repro.core.nystrom import fit_nystrom
        assert m is not None, "nystrom needs an explicit m"
        return fit_nystrom(x, kernel, rank, m, mesh=mesh, axis=axis, **kw)
    if method == "wnystrom":
        from repro.core.nystrom import fit_weighted_nystrom
        assert m is not None, "weighted nystrom needs an explicit m"
        return fit_weighted_nystrom(x, kernel, rank, m, mesh=mesh,
                                    axis=axis, **kw)
    if method == "rff":
        from repro.core.random_features import DEFAULT_FEATURES, fit_rff
        return fit_rff(x, kernel, rank,
                       n_features=(m or DEFAULT_FEATURES),
                       mesh=mesh, axis=axis, **kw)
    if method in ("kpca", "uniform"):
        if mesh is not None:
            raise ValueError(
                f"method={method!r} is a deliberately single-device "
                "baseline and ignores mesh=; use an RSDE method for the "
                "sharded pipeline")
        if method == "kpca":
            return fit_kpca(x, kernel, rank)
        assert m is not None
        return fit_subsampled_kpca(x, kernel, rank, m, **kw)
    if method == "shadow" and mesh is None and kw.get("selector") == "fused":
        # single-pass select->fit: device-resident blocked selection streams
        # its accepted centers straight into the (matrix-free above the
        # crossover) fit operator — no host round-trip between the stages
        # (DESIGN.md §6; core/pipeline.py)
        assert ell is not None, "shadow RSDE is parameterized by ell"
        from repro.core.pipeline import fit_shadow_fused
        kw2 = {k: v for k, v in kw.items() if k != "selector"}
        return fit_shadow_fused(x, kernel, rank, ell=ell, **kw2)
    if mesh is not None and method == "shadow":
        assert ell is not None, "shadow RSDE is parameterized by ell"
        from repro.core import distributed as dist
        # **kw forwards so distributed selection kwargs (max_local,
        # max_global) work and unsupported single-device selector kwargs
        # raise instead of being silently dropped
        rsde = dist.distributed_shadow_rsde(x, kernel, ell, mesh, axis=axis,
                                            **kw)
        return fit_rskpca(rsde, kernel, rank, mesh=mesh, axis=axis)
    rsde = make_rsde(method, x, kernel, ell=ell, m=m, **kw)
    return fit_rskpca(rsde, kernel, rank, mesh=mesh, axis=axis)


def embedding_alignment_error(ref: np.ndarray, approx: np.ndarray) -> float:
    """Paper §6 eigenembedding metric: min_A ||ref - approx @ A||_F, the
    Frobenius error after the optimal linear alignment (lstsq)."""
    a, *_ = np.linalg.lstsq(approx, ref, rcond=None)
    return float(np.linalg.norm(ref - approx @ a))


def eigenvalue_error(ref: np.ndarray, approx: np.ndarray) -> float:
    """Frobenius distance between (top-r) eigenvalue vectors, zero-padded."""
    r = max(len(ref), len(approx))
    a = np.zeros(r); a[: len(ref)] = ref
    b = np.zeros(r); b[: len(approx)] = approx
    return float(np.linalg.norm(a - b))
