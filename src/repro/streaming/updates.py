"""Online insert/remove/replace of reduced-set centers (DESIGN.md §7).

Every update is a RANK-ONE perturbation of the weighted Gram operator:

  * an incoming sample within ``eps`` of a live center is ABSORBED into that
    center's weight — Algorithm 2's absorption rule applied online; its
    coordinates are discarded, exactly as in the batch selector;
  * a sample outside every shadow becomes a NEW center in the first dead
    slot: the Pallas ``gram_row`` kernel computes the new row/column of the
    Gram against all centers in one fused pass (the m x m matrix is never
    rebuilt);
  * ``remove`` zeroes a center's mass; ``replace`` composes remove + insert
    in one slot.

Each update's effect on the normalized operator K-tilde/n is bounded in
closed form by ``core.mmd.weight_update_bound`` (the §5 Theorem machinery
applied per update; O(1) to evaluate).  The bounds ACCUMULATE in
``state.err_est``; while the accumulated bound stays within
``state.budget``, the cached eigensystem is patched by a Rayleigh–Ritz step
in the old invariant subspace augmented with the touched coordinate
directions (O(cap^2 r) — no O(cap^3) eigensolve), and beyond the budget the
maintenance falls back to an exact re-solve and resets the budget.  The
Rayleigh residual of whatever eigensystem comes out is measured and stored
in ``state.resid`` — the a-posteriori certificate.

All functions here are jitted pytree -> pytree maps: a whole ingest batch
(scan over rows + one eigen-maintenance) runs as ONE device program with no
host round-trips (ingest.py drives them with fixed-size padded batches).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import mmd as mmd_mod
from repro.core.rskpca import _canonicalize_signs
from repro.kernels import ops as kernel_ops
from repro.obs import metrics as _om
from repro.streaming.state import StreamingRSKPCA, _solve

Array = jax.Array

# update-kind telemetry: counted in the HOST wrappers below (the jitted
# bodies are never instrumented — obs must not alter compiled programs).
# Batched absorb/insert tallies live in ingest.py, which sees the state
# delta; remove/replace are explicit API calls and count here.
_M_REMOVES = _om.counter("stream.updates", {"kind": "remove"})
_M_REPLACES = _om.counter("stream.updates", {"kind": "replace"})


# --------------------------------------------------------------------------
# eigen-maintenance: Rayleigh-Ritz patch vs exact re-solve
# --------------------------------------------------------------------------


def _rr_patch(kgram: Array, w: Array, n: Array, basis: Array, rank1: int):
    """Rayleigh–Ritz on span{current eigenvectors, touched coordinate axes}.

    The Ritz pairs of K-tilde/n in this subspace absorb a rank-one update
    exactly when the operator barely rotated (Theorem 5.x says it barely
    did, or we would not be patching).  Returns (theta, u, residual) with
    residual = ||K-tilde/n u - u diag(theta)||_F measured on the way out.
    """
    q, _ = jnp.linalg.qr(basis)                         # (cap, b)
    sw = jnp.sqrt(w)
    ktq = sw[:, None] * (kgram @ (sw[:, None] * q)) / n  # = (K-tilde/n) Q
    b = q.T @ ktq
    b = 0.5 * (b + b.T)
    theta, s = jnp.linalg.eigh(b)                        # ascending
    theta = theta[::-1][:rank1]
    s = s[:, ::-1][:, :rank1]
    u = q @ s
    resid = jnp.linalg.norm(ktq @ s - u * theta[None, :])
    return theta, _canonicalize_signs(u), resid


def _maintain(state: StreamingRSKPCA, centers: Array, wcount: Array,
              wfrac: Array, kgram: Array, ncount: Array, nfrac: Array,
              err: Array, slots: Array, n_ok) -> StreamingRSKPCA:
    """Patch-or-resolve decision shared by every update entry point.

    ``err`` already includes the new updates' accumulated Theorem-5.x
    bounds; ``slots`` are the touched center indices whose coordinate axes
    augment the Rayleigh–Ritz basis (duplicates and dead-slot no-ops are
    harmless: QR just sees a rank-deficient tail).  ``n_ok`` is the number
    of REAL updates in this maintenance — the masked padding rows of a
    ragged ingest batch are no-ops and must not inflate the patch
    accounting (``n_patched`` feeds the budget diagnostics; counting
    phantom rows made compaction look overdue on ragged streams).
    """
    rank1 = state.rank + 1
    cap = state.cap
    weights = wcount.astype(jnp.float32) + wfrac
    n = ncount.astype(jnp.float32) + nfrac
    onehots = jax.nn.one_hot(slots, cap, dtype=jnp.float32).T  # (cap, B)
    basis = jnp.concatenate([state.u, onehots], axis=1)
    do_patch = err <= state.budget

    def patch(_):
        return _rr_patch(kgram, weights, n, basis, rank1)

    def resolve(_):
        lam, u = _solve(kgram, weights, n, rank1)
        return lam, u, jnp.float32(0.0)

    lam, u, resid = jax.lax.cond(do_patch, patch, resolve, operand=None)
    return dataclasses.replace(
        state, centers=centers, wcount=wcount, wfrac=wfrac, kgram=kgram,
        ncount=ncount, nfrac=nfrac,
        eigvals=lam, u=u,
        err_est=jnp.where(do_patch, err, 0.0),
        resid=resid,
        n_patched=jnp.where(do_patch,
                            state.n_patched + jnp.asarray(n_ok, jnp.int32),
                            0),
    )


# --------------------------------------------------------------------------
# batched ingest: absorb-or-insert, one jitted step
# --------------------------------------------------------------------------


@jax.jit
def ingest_batch(state: StreamingRSKPCA, xb: Array,
                 valid: Array | None = None) -> StreamingRSKPCA:
    """Absorb-or-insert a (B, d) batch in ONE device program.

    Rows scan sequentially (each row sees the centers the previous row may
    have inserted — the same order semantics as Algorithm 2), then a single
    eigen-maintenance covers the whole batch.  ``valid`` masks padding rows
    (False rows are no-ops), so a ragged stream runs through one compiled
    shape per batch size.  If the buffer is full, an out-of-shadow row is
    absorbed into its nearest center anyway (the overflow guard of
    ``shadow_select``); ingest.py's compaction keeps that rare.
    """
    kernel = state.kernel
    eps2 = jnp.float32(state.eps) ** 2
    ok_b = jnp.ones(xb.shape[0], bool) if valid is None \
        else valid.astype(bool)

    def row(carry, inp):
        centers, wc, wf, kgram, nc, nf, err = carry
        x, ok = inp
        krow, d2 = kernel_ops.gram_row(
            x, centers, sigma=kernel.sigma, p=kernel.p)
        alive = (wc > 0) | (wf > 0)
        d2m = jnp.where(alive, d2, jnp.inf)
        j_near = jnp.argmin(d2m)
        has_free = jnp.any(~alive)
        absorb = (d2m[j_near] < eps2) | ~has_free
        j = jnp.where(absorb, j_near, jnp.argmin(alive))  # first dead slot
        w_j = wc[j].astype(jnp.float32) + wf[j]
        n = nc.astype(jnp.float32) + nf
        delta = mmd_mod.weight_update_bound(n, n + 1.0, w_j, w_j + 1.0,
                                            kappa=kernel.kappa)
        # unit mass lands in the INT accumulator — exact at any stream
        # length (a single f32 add saturates at 2^24; class docstring)
        wc = wc.at[j].add(jnp.where(ok, 1, 0))
        nc = nc + jnp.where(ok, 1, 0)
        err = err + jnp.where(ok, delta, 0.0)

        def insert(args):
            c, kg = args
            kr = krow.at[j].set(kernel.kappa)  # k(x, x) for the new slot
            return c.at[j].set(x), kg.at[j, :].set(kr).at[:, j].set(kr)

        centers, kgram = jax.lax.cond(ok & ~absorb, insert, lambda a: a,
                                      (centers, kgram))
        return (centers, wc, wf, kgram, nc, nf, err), j

    (centers, wc, wf, kgram, nc, nf, err), slots = jax.lax.scan(
        row,
        (state.centers, state.wcount, state.wfrac, state.kgram,
         state.ncount, state.nfrac, state.err_est),
        (jnp.asarray(xb, jnp.float32), ok_b),
    )
    # real (unmasked) updates only — padding rows must not count
    n_ok = jnp.sum(ok_b.astype(jnp.int32))
    return _maintain(state, centers, wc, wf, kgram, nc, nf, err, slots, n_ok)


def insert(state: StreamingRSKPCA, x) -> StreamingRSKPCA:
    """Single-sample absorb-or-insert (a B=1 ingest batch)."""
    return ingest_batch(state, jnp.asarray(x, jnp.float32)[None, :])


def remove(state: StreamingRSKPCA, j) -> StreamingRSKPCA:
    """Delete center j: its mass leaves the substitute density entirely —
    the paper's 'remove samples with minimal effect' (§5), with the effect
    bounded by remove_bound = kappa sqrt(2 w_j / n).  No-op on dead slots,
    and REFUSED (no-op) when center j holds all remaining mass: an operator
    with n = 0 is undefined (every normalization divides by n), so the last
    live center can only leave via ``replace``."""
    _M_REMOVES.inc()
    return _remove_jit(state, j)


@jax.jit
def _remove_jit(state: StreamingRSKPCA, j) -> StreamingRSKPCA:
    j = jnp.asarray(j, jnp.int32)
    wcj, wfj = state.wcount[j], state.wfrac[j]
    w_j = wcj.astype(jnp.float32) + wfj
    ok = w_j < state.n  # refuse to empty the operator
    w_j = jnp.where(ok, w_j, 0.0)
    delta = mmd_mod.weight_update_bound(
        state.n, state.n - w_j, w_j, 0.0, kappa=state.kernel.kappa)
    wcount = state.wcount.at[j].set(jnp.where(ok, 0, wcj))
    wfrac = state.wfrac.at[j].set(jnp.where(ok, 0.0, wfj))
    # mass leaves by exact integer/fraction subtraction, never via the
    # rounded f32 view
    ncount = state.ncount - jnp.where(ok, wcj, 0)
    nfrac = state.nfrac - jnp.where(ok, wfj, 0.0)
    return _maintain(state, state.centers, wcount, wfrac, state.kgram,
                     ncount, nfrac, state.err_est + delta, j[None],
                     jnp.int32(1))


def replace(state: StreamingRSKPCA, j, x) -> StreamingRSKPCA:
    """Swap center j's location for ``x`` (unit mass), composing the remove
    and insert bounds — the paper's substitute-sample operation done in
    place, one fused Gram-row pass."""
    _M_REPLACES.inc()
    return _replace_jit(state, j, x)


@jax.jit
def _replace_jit(state: StreamingRSKPCA, j, x) -> StreamingRSKPCA:
    kernel = state.kernel
    j = jnp.asarray(j, jnp.int32)
    x = jnp.asarray(x, jnp.float32)
    wcj, wfj = state.wcount[j], state.wfrac[j]
    w_j = wcj.astype(jnp.float32) + wfj
    n1 = state.n - w_j
    delta = (
        mmd_mod.weight_update_bound(state.n, n1, w_j, 0.0,
                                    kappa=kernel.kappa)
        + mmd_mod.weight_update_bound(n1, n1 + 1.0, 0.0, 1.0,
                                      kappa=kernel.kappa))
    krow, _ = kernel_ops.gram_row(x, state.centers, sigma=kernel.sigma,
                                  p=kernel.p)
    krow = krow.at[j].set(kernel.kappa)
    centers = state.centers.at[j].set(x)
    kgram = state.kgram.at[j, :].set(krow).at[:, j].set(krow)
    wcount = state.wcount.at[j].set(1)
    wfrac = state.wfrac.at[j].set(0.0)
    ncount = state.ncount - wcj + 1
    nfrac = state.nfrac - wfj
    return _maintain(state, centers, wcount, wfrac, kgram, ncount, nfrac,
                     state.err_est + delta, j[None], jnp.int32(1))
