"""The streaming RSKPCA state: a checkpointable pytree (DESIGN.md §7).

``StreamingRSKPCA`` holds everything needed to evolve a fitted reduced-set
operator in place as the stream drifts:

  * a FIXED-capacity center buffer (``cap`` rows, power-of-two bucketed so
    the serving path never retraces — the same bucket-padded contract as the
    PR-3 ragged-chunk serving) with ``weights == 0`` marking dead slots;
  * the cached unweighted center Gram ``kgram`` (cap x cap), so an update
    touches one ROW (the Pallas ``gram_row`` pass) instead of rebuilding the
    m x m matrix;
  * the cached eigensystem (``eigvals``, ``u``) of the normalized weighted
    operator K-tilde/n = diag(sqrt w) kgram diag(sqrt w) / n — ``rank + 1``
    pairs are kept so the spectral gap below the serving rank is observable;
  * the error budget: ``err_est`` accumulates the closed-form Theorem-5.x
    perturbation bounds (core.mmd.weight_update_bound) of every update since
    the last exact solve; while ``err_est <= budget`` the eigensystem is
    patched by a Rayleigh–Ritz step, beyond it the next maintenance does a
    full re-solve.  ``resid`` is the measured Rayleigh residual
    ||K-tilde/n U - U diag(lam)||_F of the CURRENT eigensystem — the
    a-posteriori certificate the property tests check against.

Static configuration (kernel, rank, eps, budget) rides in the pytree aux
data, so every jitted update function specializes on it automatically.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_math import Kernel, gram_matrix
from repro.core.rsde import RSDE
from repro.core.rskpca import (KPCAModel, _LOBPCG_MIN_M,
                               _canonicalize_signs, _lobpcg_topk, _top_eigh)
from repro.kernels import ops as kernel_ops

Array = jax.Array

#: Default error budget: a full re-solve is forced once the accumulated
#: per-update perturbation bounds exceed this fraction of kappa (= 1).
DEFAULT_BUDGET = 0.05


from repro.core.shadow import _pow2_ceil  # single bucketing rule repo-wide


@dataclasses.dataclass(frozen=True)
class StreamingRSKPCA:
    """Stream masses are SPLIT accumulators: ``wcount``/``ncount`` hold the
    integer unit counts (int32 — exact up to 2^31) and ``wfrac``/``nfrac``
    the fractional residuals (f32).  A single f32 accumulator saturates at
    2^24: ``n + 1.0 == n`` there, so a long-running stream's mass silently
    stops growing and every Theorem-5.x bound (which divides by n) goes
    stale.  Unit-mass ingest adds to the int part — exact at any stream
    length (regression-tested past 2^24 in tests/test_streaming.py); the
    f32 ``weights``/``n`` views below are recomposed on read for the
    normalized operator, where relative (not absolute) error is what
    matters."""

    # --- pytree leaves ---
    centers: Array    # (cap, d) center buffer; dead slots hold stale rows
    wcount: Array     # (cap,) int32 integer part of the shadow masses
    wfrac: Array      # (cap,) f32 fractional residual of the shadow masses
    kgram: Array      # (cap, cap) unweighted k(c_i, c_j) cache
    ncount: Array     # () int32 integer part of the total stream mass
    nfrac: Array      # () f32 fractional residual of the total stream mass
    eigvals: Array    # (rank+1,) eigenvalues of K-tilde/n, descending
    u: Array          # (cap, rank+1) orthonormal eigenvectors
    err_est: Array    # () f32 accumulated perturbation since last exact solve
    resid: Array      # () f32 Rayleigh residual of the current eigensystem
    n_patched: Array  # () int32 updates absorbed by patches since last solve
    # --- static aux (hashable; jit specializes on these) ---
    kernel: Kernel
    rank: int
    eps: float        # online absorption radius sigma/ell (Algorithm 2)
    budget: float     # err_est threshold that forces an exact re-solve

    # -- shapes / masks ----------------------------------------------------
    @property
    def cap(self) -> int:
        return self.centers.shape[0]

    @property
    def d(self) -> int:
        return self.centers.shape[1]

    @property
    def weights(self) -> Array:
        """(cap,) f32 view of the shadow masses (count + residual); 0 marks
        a dead slot.  The split leaves are the source of truth — mutate
        those, never this view."""
        return self.wcount.astype(jnp.float32) + self.wfrac

    @property
    def n(self) -> Array:
        """() f32 view of the total stream mass (weights sum to n)."""
        return self.ncount.astype(jnp.float32) + self.nfrac

    @property
    def alive(self) -> Array:
        return (self.wcount > 0) | (self.wfrac > 0)

    @property
    def m(self) -> int:
        """Number of live centers (host sync)."""
        return int(jnp.sum(self.alive))

    @property
    def gap(self) -> float:
        """Spectral gap below the serving rank (host sync)."""
        return float(self.eigvals[self.rank - 1] - self.eigvals[self.rank])

    # -- serving views -----------------------------------------------------
    @property
    def projector(self) -> Array:
        """(cap, rank) A = diag(sqrt w) U Lambda^{-1/2} / sqrt(n); dead slots
        carry sqrt(0) = 0 rows, so the cap-padded buffer serves directly."""
        lam = jnp.maximum(self.eigvals[: self.rank], 1e-12)
        sw = jnp.sqrt(self.weights)
        return (sw[:, None] * self.u[:, : self.rank]) \
            / jnp.sqrt(lam)[None, :] / jnp.sqrt(self.n)

    def as_rsde(self) -> RSDE:
        """Host snapshot of the live centers as an RSDE — the 'equivalent
        center set' a from-scratch fit would see (property tests)."""
        # recompose masses in f64 on host: exact for any int32 count
        w64 = (np.asarray(self.wcount, np.float64)
               + np.asarray(self.wfrac, np.float64))
        alive = w64 > 0
        return RSDE(
            centers=np.asarray(self.centers)[alive],
            weights=w64[alive],
            n=float(np.float64(int(self.ncount)) + float(self.nfrac)),
            scheme="streaming",
        )

    def to_model(self) -> KPCAModel:
        """Freeze the current operator as a static KPCAModel."""
        return KPCAModel(
            kernel=self.kernel,
            centers=np.asarray(self.centers, np.float32),
            projector=np.asarray(self.projector),
            eigvals=np.asarray(self.eigvals[: self.rank]),
            method="rskpca+streaming",
        )

    def transform(self, x, chunk: int | None = 8192, mesh=None,
                  axis: str = "data"):
        """Embed queries under the CURRENT operator (see swap.HotSwapServer
        for the recompile-free serving loop)."""
        proj = self.projector
        if mesh is not None:
            from repro.core import distributed as dist
            return dist.sharded_kpca_project(
                x, self.centers, proj, self.kernel, mesh, axis=axis,
                chunk=chunk)
        return kernel_ops.kpca_project(
            x, self.centers, proj, sigma=self.kernel.sigma,
            p=self.kernel.p, chunk=chunk, precision=self.kernel.precision)


def _flatten(s: StreamingRSKPCA):
    leaves = (s.centers, s.wcount, s.wfrac, s.kgram, s.ncount, s.nfrac,
              s.eigvals, s.u, s.err_est, s.resid, s.n_patched)
    aux = (s.kernel, s.rank, s.eps, s.budget)
    return leaves, aux


def _unflatten(aux, leaves) -> StreamingRSKPCA:
    return StreamingRSKPCA(*leaves, *aux)


jax.tree_util.register_pytree_node(StreamingRSKPCA, _flatten, _unflatten)


def _solve(kgram: Array, weights: Array, n: Array, rank1: int,
           min_m: int | None = None):
    """Exact top-(rank+1) eigensystem of K-tilde/n (jittable; LOBPCG above
    the same crossover as the batch fit).

    Above the crossover the cached unweighted ``kgram`` is used DIRECTLY as
    the LOBPCG operator — sqrt(w) folds into the matvec — so the budget
    re-solve never materializes a second cap x cap weighted copy on top of
    the cache (DESIGN.md §6's operator-reuse rule applied to streaming).
    """
    sw = jnp.sqrt(weights)
    cap = kgram.shape[0]
    min_m = _LOBPCG_MIN_M if min_m is None else int(min_m)
    if cap > min_m and 5 * rank1 < cap:
        def matvec(v):
            return sw[:, None] * (kgram @ (sw[:, None] * v)) / n

        return _lobpcg_topk(matvec, cap, rank1)
    kt = sw[:, None] * kgram * sw[None, :] / n
    lam, u = _top_eigh(kt, rank1)
    return lam, _canonicalize_signs(u)


#: Module-level jitted _solve: a fresh ``jax.jit(_solve)`` per call would
#: carry its own compilation cache and re-trace the cap x cap eigensolve
#: every time (from_rsde, ingest compaction, drift refresh all hit this).
solve_jit = jax.jit(_solve, static_argnames=("rank1", "min_m"))


def from_rsde(rsde: RSDE, kernel: Kernel, rank: int, *,
              ell: float | None = None, eps: float | None = None,
              cap: int | None = None,
              budget: float = DEFAULT_BUDGET) -> StreamingRSKPCA:
    """Lift a batch-fitted RSDE into a streaming state.

    ``cap`` (power-of-two bucketed, >= m, min 128) fixes the buffer size —
    and with it every downstream compiled shape; default leaves ~1/3 of the
    buffer free for inserts.  The eigensystem is solved exactly, so the
    state starts with a zero error budget.
    """
    m = rsde.m
    if eps is None:
        assert ell is not None, "pass the absorption radius via ell= or eps="
        eps = kernel.epsilon(ell)
    if cap is None:
        cap = (4 * m) // 3  # ~1/3 free slots before the first compaction
    cap = _pow2_ceil(max(128, cap, m))
    centers = np.zeros((cap, rsde.centers.shape[1]), np.float32)
    centers[:m] = np.asarray(rsde.centers, np.float32)
    # split each mass into int32 count + f32 residual (see the class
    # docstring: single-f32 accumulators saturate at 2^24)
    wf64 = np.asarray(rsde.weights, np.float64)
    wcount = np.zeros((cap,), np.int32)
    wfrac = np.zeros((cap,), np.float32)
    wcount[:m] = np.floor(wf64).astype(np.int32)
    wfrac[:m] = (wf64 - np.floor(wf64)).astype(np.float32)
    ncount = int(np.floor(float(rsde.n)))
    nfrac = float(rsde.n) - ncount
    centers = jnp.asarray(centers)
    weights = jnp.asarray(wcount.astype(np.float32) + wfrac)
    kgram = gram_matrix(kernel, centers, centers)
    n = jnp.asarray(float(rsde.n), jnp.float32)
    lam, u = solve_jit(kgram, weights, n, rank1=rank + 1)
    return StreamingRSKPCA(
        centers=centers, wcount=jnp.asarray(wcount),
        wfrac=jnp.asarray(wfrac), kgram=kgram,
        ncount=jnp.int32(ncount), nfrac=jnp.float32(nfrac),
        eigvals=lam, u=u,
        err_est=jnp.float32(0.0), resid=jnp.float32(0.0),
        n_patched=jnp.int32(0),
        kernel=kernel, rank=int(rank), eps=float(eps), budget=float(budget),
    )


# --------------------------------------------------------------------------
# checkpointing (repro.checkpoint.store: atomic, sharding-agnostic restore)
# --------------------------------------------------------------------------


def _template(cap: int, d: int, kernel: Kernel, rank: int, eps: float,
              budget: float) -> StreamingRSKPCA:
    z = jnp.zeros
    return StreamingRSKPCA(
        centers=z((cap, d), jnp.float32),
        wcount=z((cap,), jnp.int32), wfrac=z((cap,), jnp.float32),
        kgram=z((cap, cap), jnp.float32),
        ncount=jnp.int32(0), nfrac=jnp.float32(0.0),
        eigvals=z((rank + 1,), jnp.float32),
        u=z((cap, rank + 1), jnp.float32),
        err_est=jnp.float32(0.0), resid=jnp.float32(0.0),
        n_patched=jnp.int32(0),
        kernel=kernel, rank=rank, eps=eps, budget=budget,
    )


def save(state: StreamingRSKPCA, directory: str, step: int) -> str:
    """Atomic checkpoint via checkpoint/store.py; static config rides in the
    meta so ``load`` needs nothing but the directory."""
    from repro.checkpoint import store

    extra = {
        "streaming": {
            "kernel": dataclasses.asdict(state.kernel),
            "rank": state.rank, "eps": state.eps, "budget": state.budget,
            "cap": state.cap, "d": state.d,
        }
    }
    return store.save_checkpoint(directory, step, state, extra_meta=extra)


def load(directory: str, step: int | None = None) -> StreamingRSKPCA:
    from repro.checkpoint import store

    if step is None:
        step = store.latest_step(directory)
        assert step is not None, f"no streaming checkpoint under {directory}"
    with open(os.path.join(directory, f"step_{step:08d}", "meta.json")) as f:
        ex = json.load(f)["extra"]["streaming"]
    tmpl = _template(ex["cap"], ex["d"], Kernel(**ex["kernel"]),
                     ex["rank"], ex["eps"], ex["budget"])
    state, _ = store.restore_checkpoint(directory, tmpl, step=step)
    return state
