"""Windowed MMD drift detection + the partial refit it triggers.

The substitute density p-tilde = (1/n) sum_j w_j k(c_j, .) was fitted to
yesterday's stream; when the stream drifts, the windowed MMD between the
last W raw samples (uniform mass) and p-tilde grows past what center-level
quantization alone can explain — Theorem 5.1 bounds the latter by
``kernel.mmd_bound(ell)``, so that bound (times a slack factor) is the
natural trigger threshold, exactly the spectral/projection-error acceptance
signal the Francis & Raimond comparisons motivate.

The refresh is a PARTIAL refit in the paper's reduced-set sense: it needs
only the live centers (with their masses, optionally decayed) and the raw
window — never the historical stream — because the RSDE weight structure
carries all surviving mass.  Window points are shadow-selected at the same
eps and merged with the decayed centers by ``two_level_merge`` (cover
radius 2*eps, i.e. the §5 bounds with ell -> ell/2, as in the distributed
selector), and the eigensystem is re-solved exactly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_math import Kernel, gram_matrix
from repro.core.rsde import RSDE
from repro.core import shadow as shadow_mod
from repro.streaming.state import StreamingRSKPCA, from_rsde

Array = jax.Array


@partial(jax.jit, static_argnames=("kernel",))
def stream_mmd(kernel: Kernel, window: Array, centers: Array,
               weights: Array, n: Array) -> Array:
    """MMD between the uniform window distribution (1/W each) and the
    substitute density (w_j / n); dead slots carry w = 0 and drop out.
    Jittable, backend-dispatched through ``gram_matrix``."""
    xw = jnp.asarray(window, jnp.float32)
    wgt = jnp.asarray(weights, jnp.float32)
    wn = xw.shape[0]
    kxx = gram_matrix(kernel, xw, xw).sum() / (wn * wn)
    kcc = (wgt[:, None] * gram_matrix(kernel, centers, centers)
           * wgt[None, :]).sum() / (n * n)
    kxc = (gram_matrix(kernel, xw, centers) * wgt[None, :]).sum() / (wn * n)
    return jnp.sqrt(jnp.maximum(kxx + kcc - 2.0 * kxc, 0.0))


class DriftDetector:
    """Ring buffer over the last ``window`` raw samples + the MMD trigger.

    ``factor`` scales the Theorem 5.1 quantization bound: MMD below
    ``factor * kernel.mmd_bound(ell)`` is indistinguishable from the
    quantization the operator was BUILT with, so only excursions above it
    count as drift.  The detector never holds device state — ``push`` is
    pure numpy, the MMD evaluation is one jitted call.
    """

    def __init__(self, kernel: Kernel, ell: float, window: int = 512,
                 factor: float = 1.0):
        self.kernel = kernel
        self.ell = float(ell)
        self.factor = float(factor)
        self.size = int(window)
        self._buf: np.ndarray | None = None
        self._pos = 0
        self._count = 0

    def push(self, xb) -> None:
        xb = np.asarray(xb, np.float32)
        if self._buf is None:
            self._buf = np.zeros((self.size, xb.shape[1]), np.float32)
        for row in xb:  # ring write; windows are small, this is not hot
            self._buf[self._pos] = row
            self._pos = (self._pos + 1) % self.size
            self._count += 1

    @property
    def full(self) -> bool:
        return self._count >= self.size

    def window(self) -> np.ndarray:
        assert self._buf is not None, "push() before window()"
        return self._buf[: min(self._count, self.size)].copy()

    @property
    def threshold(self) -> float:
        return self.factor * self.kernel.mmd_bound(self.ell)

    def mmd(self, state: StreamingRSKPCA) -> float:
        return float(stream_mmd(self.kernel, jnp.asarray(self.window()),
                                state.centers, state.weights, state.n))

    def should_refresh(self, state: StreamingRSKPCA) -> bool:
        """Trigger only on a FULL window (early small windows are noisy)."""
        return self.full and self.mmd(state) > self.threshold


def refresh(state: StreamingRSKPCA, window, decay: float = 1.0
            ) -> StreamingRSKPCA:
    """Drift-triggered partial refit from (decayed centers + raw window).

    ``decay`` < 1 forgets the pre-drift density geometrically (decay=1
    keeps all surviving mass).  The buffer capacity is preserved when the
    merged center set still fits, so a HotSwapServer republish after a
    refresh stays recompile-free.
    """
    window = np.asarray(window, np.float32)
    cw, ww, _, _ = shadow_mod.shadow_select_blocked(window, state.eps)
    live = np.asarray(state.weights) > 0
    all_c = np.concatenate([np.asarray(state.centers)[live], cw])
    all_w = np.concatenate(
        [decay * np.asarray(state.weights)[live], ww.astype(np.float32)])
    out_c, out_w, m = shadow_mod.two_level_merge(
        jnp.asarray(all_c), jnp.asarray(all_w, jnp.float32),
        jnp.float32(state.eps), max_centers=all_c.shape[0])
    m = int(m)
    n_new = float(np.asarray(out_w[:m]).sum())
    rsde = RSDE(np.asarray(out_c[:m]), np.asarray(out_w[:m], np.float64),
                n=n_new, scheme="streaming-refresh")
    cap = state.cap if m <= state.cap else None  # keep the serving bucket
    return from_rsde(rsde, state.kernel, state.rank, eps=state.eps,
                     cap=cap, budget=state.budget)
