# Streaming RSKPCA (DESIGN.md §7): maintain a fitted reduced-set operator
# online — insert/remove/replace centers as rank-one perturbations, patch the
# eigensystem under a tracked Theorem-5.x error budget, detect drift, and
# hot-swap the serving projector without retracing.
from repro.streaming.state import (  # noqa: F401
    StreamingRSKPCA, from_rsde, save, load,
)
from repro.streaming.updates import (  # noqa: F401
    ingest_batch, insert, remove, replace,
)
from repro.streaming.ingest import (  # noqa: F401
    ingest, compact, needs_compaction,
)
from repro.streaming.drift import DriftDetector, stream_mmd, refresh  # noqa: F401
from repro.streaming.swap import HotSwapServer, SnapshotInfo  # noqa: F401
