"""Host-side ingest driver: fixed-shape batches + alive-mask compaction.

``ingest`` slices an arbitrary stream into FIXED-size batches (ragged tail
padded and masked), so the whole stream runs through exactly one compiled
``ingest_batch`` program per batch size — the same fixed-shape contract as
blocked shadow selection.  Between batches (never inside one) it checks the
buffer fill fraction and compacts: live slots are packed to the front of a
fresh power-of-two bucket (so re-jit count stays logarithmic in growth, as
in ``shadow_select_blocked``'s compaction cascade) and the eigensystem is
re-solved exactly, which also resets the error budget.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ingest_pipeline import pad_block
from repro.streaming import updates
from repro.streaming.state import StreamingRSKPCA, _pow2_ceil, solve_jit


def needs_compaction(state: StreamingRSKPCA, max_fill: float = 0.9) -> bool:
    """True once the live-slot fraction exceeds ``max_fill`` — the next
    batch would risk the overflow guard (nearest-center absorption beyond
    eps), so compact/grow first."""
    return state.m > max_fill * state.cap


def compact(state: StreamingRSKPCA, cap: int | None = None) -> StreamingRSKPCA:
    """Pack live slots to the front of a (possibly larger) pow2 buffer.

    The Gram cache moves by pure permutation-gather (no kernel evals); the
    eigensystem is re-solved exactly on the compacted operator (the
    permuted Ritz vectors would no longer be orthonormal after dropping
    dead rows), which resets ``err_est`` — compaction doubles as a refresh
    point.  Changing ``cap`` re-traces downstream programs once per bucket.
    """
    wc = np.asarray(state.wcount)
    wf = np.asarray(state.wfrac)
    live = np.flatnonzero((wc > 0) | (wf > 0))
    m = live.size
    if cap is None:
        cap = (4 * m) // 3  # same ~1/3 headroom rule as from_rsde
    cap = _pow2_ceil(max(128, cap, m))
    centers = np.zeros((cap, state.d), np.float32)
    centers[:m] = np.asarray(state.centers)[live]
    # the split mass accumulators gather exactly — no f32 recompose/resplit
    wcount = np.zeros((cap,), np.int32)
    wcount[:m] = wc[live]
    wfrac = np.zeros((cap,), np.float32)
    wfrac[:m] = wf[live]
    kgram = np.zeros((cap, cap), np.float32)
    kgram[:m, :m] = np.asarray(state.kgram)[np.ix_(live, live)]
    centers = jnp.asarray(centers)
    weights = jnp.asarray(wcount.astype(np.float32) + wfrac)
    kgram = jnp.asarray(kgram)
    lam, u = solve_jit(kgram, weights, state.n, rank1=state.rank + 1)
    return dataclasses.replace(
        state, centers=centers, wcount=jnp.asarray(wcount),
        wfrac=jnp.asarray(wfrac), kgram=kgram,
        eigvals=lam, u=u, err_est=jnp.float32(0.0),
        resid=jnp.float32(0.0), n_patched=jnp.int32(0))


def ingest(state: StreamingRSKPCA, xs, batch: int = 256,
           detector=None, server=None) -> StreamingRSKPCA:
    """Stream ``xs`` (N, d) through fixed-shape jitted ingest batches.

    Optional taps: ``detector`` (drift.DriftDetector) sees every raw batch;
    ``server`` (swap.HotSwapServer) gets the updated operator published
    after every batch — together they form the full online loop of
    examples/streaming_drift.py.
    """
    xs = np.asarray(xs, np.float32)
    n = xs.shape[0]
    for s in range(0, n, batch):
        blk = xs[s : s + batch]
        if needs_compaction(state):
            state = compact(state)
        if blk.shape[0] < batch:  # ragged tail: pad + mask, same compile
            pad, ok = pad_block(blk, batch)
            state = updates.ingest_batch(state, jnp.asarray(pad),
                                         jnp.asarray(ok))
        else:
            state = updates.ingest_batch(state, jnp.asarray(blk))
        if detector is not None:
            detector.push(blk)
        if server is not None:
            server.publish(state)
    return state
