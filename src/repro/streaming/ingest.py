"""Host-side ingest driver: fixed-shape batches + alive-mask compaction.

``ingest`` slices an arbitrary stream into FIXED-size batches (ragged tail
padded and masked), so the whole stream runs through exactly one compiled
``ingest_batch`` program per batch size — the same fixed-shape contract as
blocked shadow selection.  Between batches (never inside one) it checks the
buffer fill fraction and compacts: live slots are packed to the front of a
fresh power-of-two bucket (so re-jit count stays logarithmic in growth, as
in ``shadow_select_blocked``'s compaction cascade) and the eigensystem is
re-solved exactly, which also resets the error budget.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ingest_pipeline import pad_block
from repro.obs import metrics as _om
from repro.obs.trace import span as _span
from repro.streaming import updates
from repro.streaming.state import StreamingRSKPCA, _pow2_ceil, solve_jit

# ingest-driver telemetry (DESIGN.md §16).  Everything here samples at
# BATCH granularity on the host side — the jitted device programs are never
# touched, so enabling observability cannot retrace anything.
_M_BATCHES = _om.counter("stream.batches")
_M_ROWS = _om.counter("stream.rows")
_M_COMPACTIONS = _om.counter("stream.compactions")
_M_BATCH_MS = _om.histogram("stream.ingest_batch_ms")


def _observe_batch(state: StreamingRSKPCA, m_before: int,
                   rows: int) -> None:
    """Post-batch accounting (only under obs: costs a few host syncs).

    Update kinds are recovered from the state delta: live-slot growth counts
    the INSERTS, the remaining real rows were ABSORBED into existing
    shadows.  The eigen-maintenance decision is read off the budget
    machinery: a re-solve zeroes both ``err_est`` and ``n_patched``
    (state.py), a patch leaves ``n_patched`` strictly above its pre-batch
    rollover floor."""
    m_after = state.m
    inserted = max(0, m_after - m_before)
    _om.counter("stream.updates", {"kind": "insert"}).inc(inserted)
    _om.counter("stream.updates", {"kind": "absorb"}).inc(
        max(0, rows - inserted))
    patched_after = int(state.n_patched)
    resolved = patched_after == 0 and float(state.err_est) == 0.0
    _om.counter("stream.maintenance",
                {"decision": "resolve" if resolved else "patch"}).inc()
    _om.gauge("stream.err_est").set(float(state.err_est))
    _om.gauge("stream.n_patched").set(patched_after)
    _om.gauge("stream.m").set(m_after)
    _om.gauge("stream.fill_fraction").set(m_after / state.cap)


def needs_compaction(state: StreamingRSKPCA, max_fill: float = 0.9) -> bool:
    """True once the live-slot fraction exceeds ``max_fill`` — the next
    batch would risk the overflow guard (nearest-center absorption beyond
    eps), so compact/grow first."""
    return state.m > max_fill * state.cap


def compact(state: StreamingRSKPCA, cap: int | None = None) -> StreamingRSKPCA:
    """Pack live slots to the front of a (possibly larger) pow2 buffer.

    The Gram cache moves by pure permutation-gather (no kernel evals); the
    eigensystem is re-solved exactly on the compacted operator (the
    permuted Ritz vectors would no longer be orthonormal after dropping
    dead rows), which resets ``err_est`` — compaction doubles as a refresh
    point.  Changing ``cap`` re-traces downstream programs once per bucket.
    """
    wc = np.asarray(state.wcount)
    wf = np.asarray(state.wfrac)
    live = np.flatnonzero((wc > 0) | (wf > 0))
    m = live.size
    if cap is None:
        cap = (4 * m) // 3  # same ~1/3 headroom rule as from_rsde
    cap = _pow2_ceil(max(128, cap, m))
    centers = np.zeros((cap, state.d), np.float32)
    centers[:m] = np.asarray(state.centers)[live]
    # the split mass accumulators gather exactly — no f32 recompose/resplit
    wcount = np.zeros((cap,), np.int32)
    wcount[:m] = wc[live]
    wfrac = np.zeros((cap,), np.float32)
    wfrac[:m] = wf[live]
    kgram = np.zeros((cap, cap), np.float32)
    kgram[:m, :m] = np.asarray(state.kgram)[np.ix_(live, live)]
    centers = jnp.asarray(centers)
    weights = jnp.asarray(wcount.astype(np.float32) + wfrac)
    kgram = jnp.asarray(kgram)
    lam, u = solve_jit(kgram, weights, state.n, rank1=state.rank + 1)
    return dataclasses.replace(
        state, centers=centers, wcount=jnp.asarray(wcount),
        wfrac=jnp.asarray(wfrac), kgram=kgram,
        eigvals=lam, u=u, err_est=jnp.float32(0.0),
        resid=jnp.float32(0.0), n_patched=jnp.int32(0))


def ingest(state: StreamingRSKPCA, xs, batch: int = 256,
           detector=None, server=None) -> StreamingRSKPCA:
    """Stream ``xs`` (N, d) through fixed-shape jitted ingest batches.

    Optional taps: ``detector`` (drift.DriftDetector) sees every raw batch;
    ``server`` (swap.HotSwapServer) gets the updated operator published
    after every batch — together they form the full online loop of
    examples/streaming_drift.py.
    """
    xs = np.asarray(xs, np.float32)
    n = xs.shape[0]
    obs_on = _om.enabled()
    for s in range(0, n, batch):
        blk = xs[s : s + batch]
        if needs_compaction(state):
            with _span("stream.compact", m=state.m, cap=state.cap):
                state = compact(state)
            _M_COMPACTIONS.inc()
        m_before = state.m if obs_on else 0
        t0 = time.perf_counter() if obs_on else 0.0
        with _span("stream.ingest_batch", rows=blk.shape[0]) as sp:
            if blk.shape[0] < batch:  # ragged tail: pad + mask, same compile
                pad, ok = pad_block(blk, batch)
                state = updates.ingest_batch(state, jnp.asarray(pad),
                                             jnp.asarray(ok))
            else:
                state = updates.ingest_batch(state, jnp.asarray(blk))
            sp.sync(state.eigvals)  # span covers the device maintenance too
        if obs_on:
            _M_BATCHES.inc()
            _M_ROWS.inc(blk.shape[0])
            _M_BATCH_MS.observe((time.perf_counter() - t0) * 1e3)
            _observe_batch(state, m_before, blk.shape[0])
        if detector is not None:
            detector.push(blk)
        if server is not None:
            server.publish(state)
    return state
