"""Hot-swap serving bridge: publish operator updates without retracing.

A live transform stream must not pay a compile when the operator behind it
evolves.  The contract (the PR-3 bucket-padded serving contract, extended to
the operator itself): the published ``(centers, projector)`` snapshot always
has the state's FIXED buffer shapes — (cap, d) and (cap, rank), with dead
slots carrying zero projector rows so they cannot contribute — and queries
stream through ``kernels.ops.kpca_project`` in fixed chunks.  Publishing a
new snapshot therefore changes only array VALUES, never compiled shapes: the
jitted projection program traced for the first snapshot serves every later
one (compile-count asserted in tests/test_streaming.py).  Only a capacity
change (compaction/growth, logarithmically rare) re-traces, once per bucket.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops
from repro.kernels import quantize
from repro.obs import metrics as _om
from repro.obs.trace import span as _span
from repro.runtime import chaos
from repro.streaming.state import StreamingRSKPCA

# publish/serve telemetry (DESIGN.md §16): how often the operator turns
# over, what a publish costs (the quantization pass on int8/fp8 tiers),
# and how stale the snapshot a query just saw was.
_M_PUBLISHES = _om.counter("swap.publishes")
_M_PUB_MS = _om.histogram("swap.publish_ms")
_M_AGE = _om.gauge("swap.snapshot_age_s")
_M_TRANSFORMS = _om.counter("swap.transforms")
# degradation telemetry (DESIGN.md §17): failed publishes and the §5
# operator-drift budget the stale snapshot is serving under.
_M_PUB_FAIL = _om.counter("swap.publish_failures")
_M_DEGRADED = _om.gauge("swap.degraded")
_M_STALENESS = _om.gauge("swap.staleness_bound")


@dataclasses.dataclass(frozen=True)
class SnapshotInfo:
    """What a reader can learn about the operator it is being served by.

    ``degraded`` flips when a publish FAILED and queries are riding the
    last good snapshot; ``staleness_bound`` is then the Theorem-5.x error
    budget ``kappa * sqrt(2 (1 - t^2))`` (``core.mmd.staleness_bound``) of
    that stale operator against the newest state the server has SEEN —
    finite and usually tiny, because mass updates move the normalized
    operator slowly (that is the paper's whole §5 point, repurposed as a
    serving SLO).  ``inf`` only when no live weights have been seen at all.
    """

    version: int
    published_at: float | None
    degraded: bool
    failed_publishes: int
    staleness_bound: float


class HotSwapServer:
    """Single-writer, many-reader serving handle.

    ``publish`` snapshots the state's padded operator (cheap: two device
    arrays, no copies of the Gram/eigensystem); ``transform`` embeds
    queries under the LATEST published operator.  ``version`` counts
    publishes so readers can tag results with the operator they saw.
    """

    def __init__(self, state: StreamingRSKPCA | None = None,
                 chunk: int = 1024):
        self.chunk = int(chunk)
        self.version = 0
        # (centers, projector, kernel, projector_q), swapped whole
        self._snapshot = None
        #: monotonic timestamp of the last publish; transform reports the
        #: served snapshot's age off it (``swap.snapshot_age_s``)
        self.published_at: float | None = None
        #: degradation bookkeeping (DESIGN.md §17): the mass vector the
        #: live snapshot was published with, the newest mass vector the
        #: server has SEEN (a failed try_publish still updates it — that
        #: is what makes the staleness bound honest), and the consecutive
        #: failed-publish count since the last good publish.
        self._pub_weights: np.ndarray | None = None
        self._cur_weights: np.ndarray | None = None
        self.failed_publishes = 0
        self.degraded = False
        if state is not None:
            self.publish(state)

    def publish(self, state: StreamingRSKPCA) -> int:
        """Atomically swap in the state's current operator: the snapshot is
        a SINGLE attribute store (one tuple), so a concurrent reader sees
        either the old or the new operator, never a mix.

        On a quantized serving tier (kernel.precision int8/fp8) the publish
        also quantizes the projector — one O(cap x rank) jitted pass — and
        caches the (Aq, scales) pair in the swap tuple, so serves never pay
        per-batch quantization and in-flight batches keep the pair they
        already read.

        Fault model: ``swap.publish`` is the chaos injection site, fired
        BEFORE the snapshot store — a failed publish can never tear the
        served operator, it leaves the previous snapshot fully intact (the
        last-good-fallback invariant ``try_publish`` builds on)."""
        t0 = time.monotonic()
        with _span("swap.publish", version=self.version + 1):
            weights = np.asarray(state.weights, np.float64)
            self._cur_weights = weights  # seen, even if the store fails
            centers = jnp.asarray(state.centers)
            projector = jnp.asarray(state.projector)
            kernel = state.kernel
            projector_q = (quantize.quantize_projector(projector,
                                                       kernel.precision)
                           if kernel.precision in quantize.QUANT_PRECISIONS
                           else None)
            chaos.inject("swap.publish")
            self._snapshot = (centers, projector, kernel, projector_q)
        self._pub_weights = weights
        self.published_at = time.monotonic()
        self.version += 1
        self.failed_publishes = 0
        self.degraded = False
        _M_PUBLISHES.inc()
        _M_PUB_MS.observe((self.published_at - t0) * 1e3)
        _M_AGE.set(0.0)  # a fresh snapshot: age restarts from zero
        if _om.enabled():
            _M_DEGRADED.set(0.0)
            _M_STALENESS.set(0.0)
        return self.version

    def try_publish(self, state: StreamingRSKPCA) -> bool:
        """Graceful-degradation publish: on ANY failure keep serving the
        last good snapshot and report the §5 staleness budget instead of
        taking the server down.

        Returns True on a clean publish.  On failure the served operator is
        untouched (``publish`` cannot tear it), ``degraded`` flips, and
        ``degraded_info()`` prices the stale snapshot via
        ``core.mmd.staleness_bound`` against the newest mass vector seen —
        the publisher retries on its own cadence (the next ingest tick),
        so no retry loop lives here."""
        try:
            self.publish(state)
            return True
        except Exception:
            self.failed_publishes += 1
            self.degraded = self._snapshot is not None
            _M_PUB_FAIL.inc()
            if _om.enabled():
                info = self.degraded_info()
                _M_DEGRADED.set(1.0 if info.degraded else 0.0)
                if np.isfinite(info.staleness_bound):
                    _M_STALENESS.set(info.staleness_bound)
            if self._snapshot is None:
                raise  # nothing to fall back to: degrade is impossible
            return False

    def degraded_info(self) -> SnapshotInfo:
        """Current serving health + the stale-operator error budget."""
        bound = 0.0
        if self.degraded:
            if self._pub_weights is None or self._cur_weights is None:
                bound = float("inf")
            else:
                from repro.core.mmd import staleness_bound
                kappa = (self._snapshot[2].kappa
                         if self._snapshot is not None else 1.0)
                bound = staleness_bound(self._pub_weights,
                                        self._cur_weights, kappa=kappa)
        return SnapshotInfo(version=self.version,
                            published_at=self.published_at,
                            degraded=self.degraded,
                            failed_publishes=self.failed_publishes,
                            staleness_bound=bound)

    @property
    def published(self) -> bool:
        return self._snapshot is not None

    def transform(self, x, mesh=None, axis: str = "data") -> np.ndarray:
        """Embed queries under the latest published operator; fixed-chunk
        streaming (ragged tails padded) so any query-size sequence reuses
        one compiled program per bucket."""
        # read the snapshot ONCE: a publish() landing mid-call can never
        # pair the new centers with the old projector
        snapshot = self._snapshot
        assert snapshot is not None, "publish() an operator before serving"
        if _om.enabled():
            _M_TRANSFORMS.inc()
            if self.published_at is not None:  # age of the snapshot SERVED
                _M_AGE.set(time.monotonic() - self.published_at)
        centers, projector, kernel, projector_q = snapshot
        if mesh is not None:
            from repro.core import distributed as dist
            z = dist.sharded_kpca_project(
                x, centers, projector, kernel, mesh,
                axis=axis, chunk=self.chunk)
            return np.asarray(z)
        z = kernel_ops.kpca_project(
            x, centers, projector,
            sigma=kernel.sigma, p=kernel.p, chunk=self.chunk,
            precision=kernel.precision, projector_q=projector_q)
        return np.asarray(z)
