"""Hot-swap serving bridge: publish operator updates without retracing.

A live transform stream must not pay a compile when the operator behind it
evolves.  The contract (the PR-3 bucket-padded serving contract, extended to
the operator itself): the published ``(centers, projector)`` snapshot always
has the state's FIXED buffer shapes — (cap, d) and (cap, rank), with dead
slots carrying zero projector rows so they cannot contribute — and queries
stream through ``kernels.ops.kpca_project`` in fixed chunks.  Publishing a
new snapshot therefore changes only array VALUES, never compiled shapes: the
jitted projection program traced for the first snapshot serves every later
one (compile-count asserted in tests/test_streaming.py).  Only a capacity
change (compaction/growth, logarithmically rare) re-traces, once per bucket.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops
from repro.kernels import quantize
from repro.obs import metrics as _om
from repro.obs.trace import span as _span
from repro.streaming.state import StreamingRSKPCA

# publish/serve telemetry (DESIGN.md §16): how often the operator turns
# over, what a publish costs (the quantization pass on int8/fp8 tiers),
# and how stale the snapshot a query just saw was.
_M_PUBLISHES = _om.counter("swap.publishes")
_M_PUB_MS = _om.histogram("swap.publish_ms")
_M_AGE = _om.gauge("swap.snapshot_age_s")
_M_TRANSFORMS = _om.counter("swap.transforms")


class HotSwapServer:
    """Single-writer, many-reader serving handle.

    ``publish`` snapshots the state's padded operator (cheap: two device
    arrays, no copies of the Gram/eigensystem); ``transform`` embeds
    queries under the LATEST published operator.  ``version`` counts
    publishes so readers can tag results with the operator they saw.
    """

    def __init__(self, state: StreamingRSKPCA | None = None,
                 chunk: int = 1024):
        self.chunk = int(chunk)
        self.version = 0
        # (centers, projector, kernel, projector_q), swapped whole
        self._snapshot = None
        #: monotonic timestamp of the last publish; transform reports the
        #: served snapshot's age off it (``swap.snapshot_age_s``)
        self.published_at: float | None = None
        if state is not None:
            self.publish(state)

    def publish(self, state: StreamingRSKPCA) -> int:
        """Atomically swap in the state's current operator: the snapshot is
        a SINGLE attribute store (one tuple), so a concurrent reader sees
        either the old or the new operator, never a mix.

        On a quantized serving tier (kernel.precision int8/fp8) the publish
        also quantizes the projector — one O(cap x rank) jitted pass — and
        caches the (Aq, scales) pair in the swap tuple, so serves never pay
        per-batch quantization and in-flight batches keep the pair they
        already read."""
        t0 = time.monotonic()
        with _span("swap.publish", version=self.version + 1):
            centers = jnp.asarray(state.centers)
            projector = jnp.asarray(state.projector)
            kernel = state.kernel
            projector_q = (quantize.quantize_projector(projector,
                                                       kernel.precision)
                           if kernel.precision in quantize.QUANT_PRECISIONS
                           else None)
            self._snapshot = (centers, projector, kernel, projector_q)
        self.published_at = time.monotonic()
        self.version += 1
        _M_PUBLISHES.inc()
        _M_PUB_MS.observe((self.published_at - t0) * 1e3)
        _M_AGE.set(0.0)  # a fresh snapshot: age restarts from zero
        return self.version

    @property
    def published(self) -> bool:
        return self._snapshot is not None

    def transform(self, x, mesh=None, axis: str = "data") -> np.ndarray:
        """Embed queries under the latest published operator; fixed-chunk
        streaming (ragged tails padded) so any query-size sequence reuses
        one compiled program per bucket."""
        # read the snapshot ONCE: a publish() landing mid-call can never
        # pair the new centers with the old projector
        snapshot = self._snapshot
        assert snapshot is not None, "publish() an operator before serving"
        if _om.enabled():
            _M_TRANSFORMS.inc()
            if self.published_at is not None:  # age of the snapshot SERVED
                _M_AGE.set(time.monotonic() - self.published_at)
        centers, projector, kernel, projector_q = snapshot
        if mesh is not None:
            from repro.core import distributed as dist
            z = dist.sharded_kpca_project(
                x, centers, projector, kernel, mesh,
                axis=axis, chunk=self.chunk)
            return np.asarray(z)
        z = kernel_ops.kpca_project(
            x, centers, projector,
            sigma=kernel.sigma, p=kernel.p, chunk=self.chunk,
            precision=kernel.precision, projector_q=projector_q)
        return np.asarray(z)
