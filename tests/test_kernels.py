"""Pallas kernel sweeps: shapes x dtypes x kernel families vs jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

SHAPES = [(64, 16, 8), (100, 37, 24), (513, 129, 16), (256, 256, 256),
          (1000, 7, 96)]


@pytest.mark.parametrize("n,m,d", SHAPES)
@pytest.mark.parametrize("p", [2, 1])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_gram_sweep(n, m, d, p, dtype):
    rng = np.random.default_rng(hash((n, m, d, p)) % 2**32)
    x = rng.normal(size=(n, d)).astype(dtype)
    y = rng.normal(size=(m, d)).astype(dtype)
    wx = rng.uniform(0.5, 3, n).astype(np.float32)
    wy = rng.uniform(0.5, 3, m).astype(np.float32)
    got = np.asarray(ops.gram(x, y, sigma=2.5, p=p, wx=wx, wy=wy,
                              plan="pallas"))
    want = np.asarray(ref.gram_ref(jnp.asarray(x), jnp.asarray(y), 2.5, p,
                                   jnp.asarray(wx), jnp.asarray(wy)))
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


@pytest.mark.parametrize("n,m,d", SHAPES)
def test_gram_unweighted(n, m, d):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(m, d)).astype(np.float32)
    got = np.asarray(ops.gram(x, y, sigma=1.5, plan="pallas"))
    want = np.asarray(ref.gram_ref(jnp.asarray(x), jnp.asarray(y), 1.5, 2))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("m,d", [(64, 8), (100, 37), (513, 129)])
@pytest.mark.parametrize("p", [2, 1])
def test_gram_row_sweep(m, d, p):
    """Rank-one Gram-row kernel (the streaming update hot path): both plans
    must match the full-Gram oracle row and the raw squared distances."""
    rng = np.random.default_rng(hash((m, d, p)) % 2**32)
    x = rng.normal(size=(d,)).astype(np.float32)
    c = rng.normal(size=(m, d)).astype(np.float32)
    w = rng.uniform(0.5, 3, m).astype(np.float32)
    want_k = np.asarray(ref.gram_ref(jnp.asarray(x[None]), jnp.asarray(c),
                                     2.5, p))[0]
    want_d2 = ((c - x[None]) ** 2).sum(1)
    for plan in ("pallas", "dense"):
        krow, d2 = ops.gram_row(x, c, sigma=2.5, p=p, plan=plan)
        np.testing.assert_allclose(np.asarray(krow), want_k,
                                   atol=3e-5, rtol=3e-5)
        np.testing.assert_allclose(np.asarray(d2), want_d2,
                                   atol=1e-3, rtol=1e-4)
        # weighted form fuses Algorithm 1's sqrt(w) column factor
        krow_w, _ = ops.gram_row(x, c, w, sigma=2.5, p=p, plan=plan)
        np.testing.assert_allclose(np.asarray(krow_w), want_k * np.sqrt(w),
                                   atol=3e-5, rtol=3e-5)


def test_weighted_gram_is_algorithm1_ktilde():
    """ops.weighted_gram == W K^C W of Algorithm 1 (vs core implementation)."""
    from repro.core.kernels_math import weighted_gram as core_wg, gaussian
    rng = np.random.default_rng(3)
    c = rng.normal(size=(57, 12)).astype(np.float32)
    w = rng.uniform(1, 9, 57).astype(np.float32)
    got = np.asarray(ops.weighted_gram(c, w, sigma=2.0, plan="pallas"))
    want = np.asarray(core_wg(gaussian(2.0), jnp.asarray(c), jnp.asarray(w)))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("n,m,d", SHAPES)
def test_shadow_assign_sweep(n, m, d):
    rng = np.random.default_rng(hash((n, m)) % 2**32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(m, d)).astype(np.float32)
    idx, d2 = ops.shadow_assign(x, c, m, plan="pallas")
    idx_r, d2_r = ref.shadow_assign_ref(jnp.asarray(x), jnp.asarray(c), m)
    assert (np.asarray(idx) == np.asarray(idx_r)).all()
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_r),
                               atol=1e-4, rtol=1e-4)


def test_shadow_assign_padding_mask():
    """Padded (invalid) centers must never win the argmin."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(100, 8)).astype(np.float32)
    c = np.concatenate([rng.normal(size=(5, 8)),
                        np.zeros((10, 8))]).astype(np.float32)
    idx, _ = ops.shadow_assign(x, c, m_valid=5, plan="pallas")
    assert (np.asarray(idx) < 5).all()


@pytest.mark.parametrize("n,m,d", SHAPES)
@pytest.mark.parametrize("r", [1, 5, 8])
def test_kpca_project_sweep(n, m, d, r):
    rng = np.random.default_rng(hash((n, m, r)) % 2**32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(m, d)).astype(np.float32)
    a = rng.normal(size=(m, r)).astype(np.float32)
    got = np.asarray(ops.kpca_project(x, c, a, sigma=2.0, plan="pallas"))
    want = np.asarray(ref.kpca_project_ref(jnp.asarray(x), jnp.asarray(c),
                                           jnp.asarray(a), 2.0, 2))
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)


DISPATCH_SHAPES = [(64, 16, 8), (100, 37, 24), (513, 129, 16), (1000, 7, 96)]


@pytest.mark.parametrize("n,m,d", DISPATCH_SHAPES)
@pytest.mark.parametrize("p", [2, 1])
@pytest.mark.parametrize("weighted", [False, True])
def test_backend_dispatch_parity(n, m, d, p, weighted):
    """kernel.backend='pallas' and 'dense' agree to 1e-5 through the public
    gram_matrix / weighted_gram dispatch (non-block-multiple shapes incl.)."""
    from repro.core.kernels_math import (make_kernel, gram_matrix,
                                         weighted_gram)
    rng = np.random.default_rng(hash((n, m, d, p, weighted)) % 2**32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    name = "gaussian" if p == 2 else "laplacian"
    kp = make_kernel(name, 1.7, backend="pallas")
    kd = make_kernel(name, 1.7, backend="dense")
    if weighted:
        w = rng.uniform(0.5, 5, n).astype(np.float32)
        got = np.asarray(weighted_gram(kp, jnp.asarray(x), jnp.asarray(w)))
        want = np.asarray(weighted_gram(kd, jnp.asarray(x), jnp.asarray(w)))
    else:
        y = rng.normal(size=(m, d)).astype(np.float32)
        got = np.asarray(gram_matrix(kp, jnp.asarray(x), jnp.asarray(y)))
        want = np.asarray(gram_matrix(kd, jnp.asarray(x), jnp.asarray(y)))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_default_backend_never_calls_dense_gram(monkeypatch):
    """Acceptance guard: on the default backend neither fit_rskpca, fit_kpca,
    herding, nor transform may touch kernels_math's dense oracle — everything
    must route through the repro.kernels.ops dispatch layer (whose autotuned
    dense FALLBACK is its own policy and deliberately not patched here)."""
    from repro.core import kernels_math, rskpca, rsde

    def boom(*a, **kw):
        raise AssertionError("dense gram_matrix called on default backend")

    monkeypatch.setattr(kernels_math, "gram_matrix_dense", boom)
    monkeypatch.setattr(kernels_math, "pairwise_sq_dists", boom)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(300, 6)).astype(np.float32)
    ker = kernels_math.gaussian(1.0)
    assert ker.backend == "pallas"
    mdl = rskpca.fit(x, ker, 4, method="shadow", ell=3.0)
    z = mdl.transform(x[:50])
    assert np.isfinite(z).all()
    mdl2 = rskpca.fit_kpca(x[:100], ker, 4)
    assert np.isfinite(mdl2.transform(x[:10])).all()
    r = rsde.herding_rsde(x[:100], ker, m=10)
    assert r.m == 10


def test_transform_chunked_matches_unchunked():
    """Streaming transform in small fixed chunks == one-shot transform."""
    from repro.core import gaussian, fit
    rng = np.random.default_rng(1)
    x = rng.normal(size=(700, 12)).astype(np.float32)
    mdl = fit(x, gaussian(1.5), 5, method="shadow", ell=3.0)
    q = rng.normal(size=(1111, 12)).astype(np.float32)
    np.testing.assert_allclose(mdl.transform(q, chunk=128),
                               mdl.transform(q, chunk=10**9),
                               atol=1e-5, rtol=1e-5)


def test_shadow_assign_dynamic_valid_mask():
    """A dynamic per-center mask must behave exactly like the static prefix."""
    rng = np.random.default_rng(9)
    x = rng.normal(size=(200, 8)).astype(np.float32)
    c = rng.normal(size=(17, 8)).astype(np.float32)
    mask = (rng.random(17) > 0.4).astype(np.float32)
    idx, d2 = ops.shadow_assign(x, c, valid=mask, plan="pallas")
    dense = np.linalg.norm(x[:, None] - c[None], axis=2) ** 2
    dense[:, mask == 0] = np.inf
    assert (np.asarray(idx) == dense.argmin(1)).all()
    np.testing.assert_allclose(np.asarray(d2), dense.min(1), atol=1e-4,
                               rtol=1e-4)


def test_ragged_transform_compiles_once(monkeypatch):
    """Recompile-free serving: a stream of ragged query sizes through the
    fixed-chunk transform path must compile the projection exactly ONCE —
    the tail slice is padded UP to the chunk size, never traced at its own
    shape.  Autotune measurement is disabled so the compile count is
    deterministic."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    from repro.core import gaussian, fit
    rng = np.random.default_rng(2)
    x = rng.normal(size=(400, 12)).astype(np.float32)
    mdl = fit(x, gaussian(1.5), 5, method="shadow", ell=3.0)
    queries = [rng.normal(size=(qn, 12)).astype(np.float32)
               for qn in (500, 700, 901, 1000)]
    before = ops.projection_compile_count()
    outs = [mdl.transform(q, chunk=384) for q in queries]
    after = ops.projection_compile_count()
    assert after - before == 1, (before, after)
    for q, z in zip(queries, outs):
        assert z.shape == (q.shape[0], 5)
    # the padded tail must not perturb the embedding
    np.testing.assert_allclose(outs[0], mdl.transform(queries[0], chunk=None),
                               atol=1e-5, rtol=1e-5)


def test_block_size_selection_respects_vmem_budget():
    from repro.kernels.ops import pick_gram_blocks
    for d in (8, 64, 512, 4096, 8192):
        bn, bm, bk = pick_gram_blocks(d)
        assert (2 * bn * bk + bn * bm) * 4 <= 8 * 1024 * 1024
        assert bn % 128 == 0 and bm % 128 == 0 and bk <= max(d, 128)
        # K-chunking must preserve the big output tile even at large d
        assert bn == 512, (d, bn)
