"""The §Perf optimized distribution schedule must stay numerically equal to
the baseline step (and keep learning), and the analytic cost model must stay
internally consistent."""
import os
import subprocess
import sys

import pytest

from repro.configs import get_config
from repro.models import api
from repro.launch.flops import (model_flops, executed_flops_per_device,
                                executed_hbm_bytes_per_device, active_params,
                                total_params)

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


@pytest.mark.parametrize("arch", ["mixtral_8x7b", "kimi_k2"])
def test_optimized_step_matches_baseline(arch):
    """Deferred-grad shard_map + 2D experts == baseline loss (bf16 noise)."""
    from repro.compat import HAS_PARTIAL_AUTO_SHARD_MAP
    if not HAS_PARTIAL_AUTO_SHARD_MAP:
        pytest.skip("partially-manual shard_map needs native jax.shard_map "
                    "(this jax hits XLA CHECK IsManualSubgroup)")
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import api
from repro.launch import steps, sharding as shd
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_config({arch!r}, smoke=True)
shape = api.ShapeSpec("t", 32, 8, "train")
params_spec = api.param_specs(cfg)
batch = {{k: jnp.asarray(v) for k, v in api.make_host_batch(cfg, shape).items()}}
bspec = {{k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}}
b_sh = shd.batch_shardings(bspec, mesh)
p_sh = shd.param_shardings(params_spec, mesh, cfg)
o_spec = steps.opt_specs(cfg, params_spec)
o_sh = shd.opt_shardings(o_spec, params_spec, mesh, cfg)
with mesh:
    params = jax.jit(lambda k: api.init_params(k, cfg),
                     out_shardings=p_sh)(jax.random.PRNGKey(0))
    opt = jax.jit(lambda p: steps.init_opt(cfg, p), out_shardings=o_sh)(params)
    fn = jax.jit(steps.make_train_step(cfg, mesh, accum=2),
                 in_shardings=(p_sh, o_sh, b_sh, NamedSharding(mesh, P())),
                 out_shardings=(p_sh, o_sh, None))
    _, _, m0 = fn(params, opt, batch, jnp.int32(0))
m_sh = steps.master_shardings_opt(params_spec, mesh, cfg)
with mesh:
    params2 = jax.jit(lambda k: api.init_params(k, cfg),
                      out_shardings=m_sh)(jax.random.PRNGKey(0))
    opt2 = jax.jit(lambda p: steps.init_opt(cfg, p))(params2)
    fn2 = jax.jit(steps.make_train_step_opt(cfg, mesh, accum=2),
                  in_shardings=(m_sh, None, b_sh, NamedSharding(mesh, P())),
                  out_shardings=(m_sh, None, None))
    p3, o3, m1 = fn2(params2, opt2, batch, jnp.int32(0))
    _, _, m2 = fn2(p3, o3, batch, jnp.int32(1))
l0, l1, l2 = float(m0["loss"]), float(m1["loss"]), float(m2["loss"])
assert abs(l0 - l1) < 0.05, (l0, l1)   # same math
assert l2 < l1                          # still learns
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        "--xla_disable_hlo_passes=all-reduce-promotion")
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-3000:]


def test_cost_model_consistency():
    """Analytic roofline inputs: MODEL_FLOPS <= executed FLOPs; per-device x
    n_dev covers the global total; actives <= totals; byte model positive."""
    mesh_shape = {"data": 16, "model": 16}
    for arch in ["qwen2_72b", "mixtral_8x7b", "kimi_k2", "rwkv6_1b6",
                 "gemma3_4b", "whisper_base", "jamba_52b"]:
        cfg = get_config(arch)
        assert active_params(cfg) <= total_params(cfg)
        for shape_name in ("train_4k", "decode_32k"):
            shape = api.SHAPES[shape_name]
            ok, _ = api.shape_applicable(cfg, shape)
            if not ok:
                continue
            mf = model_flops(cfg, shape)
            ex = executed_flops_per_device(cfg, shape, mesh_shape)
            # two independent estimates of the same work: the ideal 6ND/2ND
            # count and the per-component executed model.  They differ only
            # by definitional items (embedding gather vs matmul, router,
            # remat multiplier) -> useful ratio must sit in a sane band.
            ratio = mf / ex["executed_total"]
            lo = 0.5 if shape.kind == "train" else 0.8  # train executes 8ND
            assert lo <= ratio <= 1.10, (arch, shape_name, ratio)
            # per-device x 256 >= executed total iff all degrees == 256;
            # replication (degree < 256) only ever adds per-device work
            assert ex["per_device_total"] * 256 >= ex["executed_total"] * 0.99
            by = executed_hbm_bytes_per_device(cfg, shape, mesh_shape,
                                               accum=16, variant="baseline")
            assert by["total"] > 0
            byo = executed_hbm_bytes_per_device(cfg, shape, mesh_shape,
                                                accum=16, variant="optimized")
            if shape.kind == "train" and cfg.num_experts:
                assert byo["total"] <= by["total"]  # resident experts read less


def test_param_counts_match_published_scale():
    """Sanity: total parameter counts land near the published model sizes."""
    expect = {"qwen2_72b": (65e9, 85e9), "mixtral_8x7b": (42e9, 52e9),
              "kimi_k2": (0.9e12, 1.2e12), "yi_9b": (8e9, 10.5e9),
              "gemma2_9b": (8e9, 11e9), "rwkv6_1b6": (1.4e9, 2.0e9),
              "jamba_52b": (46e9, 58e9), "pixtral_12b": (11e9, 14e9)}
    for arch, (lo, hi) in expect.items():
        n = total_params(get_config(arch))
        assert lo <= n <= hi, (arch, f"{n:.3e}")
