"""Mixed-precision (bf16 MXU operands, f32 accumulation) parity tests.

Tolerances (documented contract, DESIGN.md §3): bf16 has an 8-bit mantissa,
so rounding the operands costs ~4e-3 relative in the squared distances.
Gram entries live in [0, 1] (exp of a negative), so we check
atol=rtol=2e-2 for Gram-shaped outputs and 5e-2 for projections (which sum
m kernel values through a second bf16 matmul).  Accumulation and the exp
nonlinearity stay f32, so the error does NOT grow with d or m beyond these
bounds — that is exactly what the sweeps below pin down.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref

GRAM_TOL = 2e-2
PROJ_TOL = 5e-2

SHAPES = [(100, 37, 24), (256, 256, 256), (513, 129, 16)]


@pytest.mark.parametrize("n,m,d", SHAPES)
@pytest.mark.parametrize("plan", ["pallas", "dense"])
def test_gram_bf16_parity(n, m, d, plan):
    rng = np.random.default_rng(hash((n, m, d)) % 2**32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(m, d)).astype(np.float32)
    got = np.asarray(ops.gram(x, y, sigma=2.0, precision="bf16", plan=plan))
    want = np.asarray(ref.gram_ref(jnp.asarray(x), jnp.asarray(y), 2.0, 2))
    np.testing.assert_allclose(got, want, atol=GRAM_TOL, rtol=GRAM_TOL)


@pytest.mark.parametrize("plan", ["pallas", "dense"])
def test_weighted_gram_bf16_parity(plan):
    rng = np.random.default_rng(3)
    c = rng.normal(size=(157, 12)).astype(np.float32)
    w = rng.uniform(1, 9, 157).astype(np.float32)
    got = np.asarray(ops.weighted_gram(c, w, sigma=2.0, precision="bf16",
                                       plan=plan))
    want = np.asarray(ref.gram_ref(jnp.asarray(c), jnp.asarray(c), 2.0, 2,
                                   jnp.asarray(w), jnp.asarray(w)))
    # weighting scales entries by sqrt(w_i w_j) <= 9: scale the tolerance too
    np.testing.assert_allclose(got, want, atol=9 * GRAM_TOL, rtol=GRAM_TOL)


@pytest.mark.parametrize("n,m,d", SHAPES)
@pytest.mark.parametrize("plan", ["pallas", "dense"])
def test_kpca_project_bf16_parity(n, m, d, plan):
    rng = np.random.default_rng(hash((n, m, d, 7)) % 2**32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(m, d)).astype(np.float32)
    a = (rng.normal(size=(m, 8)) / np.sqrt(m)).astype(np.float32)
    got = np.asarray(ops.kpca_project(x, c, a, sigma=2.0, precision="bf16",
                                      plan=plan))
    want = np.asarray(ref.kpca_project_ref(jnp.asarray(x), jnp.asarray(c),
                                           jnp.asarray(a), 2.0, 2))
    scale = np.abs(want).max()
    np.testing.assert_allclose(got, want, atol=PROJ_TOL * scale,
                               rtol=PROJ_TOL)


def test_kernel_precision_field_and_validation():
    from repro.core.kernels_math import gaussian, make_kernel
    k = gaussian(1.5)
    assert k.precision == "f32"
    kb = k.with_precision("bf16")
    assert kb.precision == "bf16" and kb.sigma == k.sigma
    assert make_kernel("laplacian", 2.0, precision="bf16").precision == "bf16"
    with pytest.raises(ValueError):
        gaussian(1.0, precision="f16")
    # the dense backend is the f32 parity oracle: bf16 on it must be loud,
    # not silently computed in f32
    with pytest.raises(ValueError):
        gaussian(1.0, backend="dense", precision="bf16")
    with pytest.raises(ValueError):
        kb.with_backend("dense")


def test_fit_rskpca_bf16_spectral_error_within_bound_slack():
    """bf16 must not move the RSKPCA spectrum by more than the §5 slack:
    the f32-vs-bf16 eigenvalue gap (sum of squares, the Thm 5.2 metric of
    tests/test_bounds.py) stays far inside the eigenvalue_bound(ell) budget
    the quantization itself is allowed to spend."""
    from repro.core import gaussian, shadow_rsde, fit_rskpca
    from repro.data import make_dataset
    x, _, sigma = make_dataset("german", seed=0, n=400)
    ell = 4.0
    ker = gaussian(sigma)
    rsde = shadow_rsde(x, ker, ell)
    m32 = fit_rskpca(rsde, ker, rank=5)
    m16 = fit_rskpca(rsde, ker.with_precision("bf16"), rank=5)
    gap_sq = float(np.sum((m32.eigvals - m16.eigvals) ** 2))
    assert gap_sq <= ker.eigenvalue_bound(ell), (
        gap_sq, ker.eigenvalue_bound(ell))
    # and it is a small fraction of that budget, not merely inside it
    assert gap_sq <= 0.1 * ker.eigenvalue_bound(ell)


def test_transform_bf16_close_to_f32():
    from repro.core import gaussian, fit
    from repro.data import make_dataset
    x, _, sigma = make_dataset("german", seed=0, n=400)
    ker = gaussian(sigma)
    m32 = fit(x, ker, 5, method="shadow", ell=4.0)
    m16 = fit(x, ker, 5, method="shadow", ell=4.0, precision="bf16")
    assert m16.kernel.precision == "bf16"
    z32, z16 = m32.transform(x[:100]), m16.transform(x[:100])
    scale = np.abs(z32).max()
    np.testing.assert_allclose(z16, z32, atol=PROJ_TOL * scale,
                               rtol=PROJ_TOL)
