"""Streaming RSKPCA (DESIGN.md §7): online insert/remove/replace vs
from-scratch refits, the tracked Theorem-5.x error budget, recompile-free
hot swap, drift-triggered refresh, and checkpoint roundtrip."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import gaussian, shadow_rsde, fit_rskpca
from repro.core.rskpca import embedding_alignment_error
from repro import streaming
from repro.streaming import updates
from repro.kernels import ops as kernel_ops

ELL = 1.6
SIGMA = 1.5
RANK = 4


def _blobs(n, d=6, seed=0, shift=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 4, (8, d))
    idx = rng.integers(0, 8, n)
    return (centers[idx] + 0.3 * rng.normal(size=(n, d))
            + shift).astype(np.float32)


def _setup(precision="f32", budget=0.0, n=400, seed=0):
    x = _blobs(n, seed=seed)
    ker = gaussian(SIGMA, precision=precision)
    rsde = shadow_rsde(x, ker, ell=ELL)
    st = streaming.from_rsde(rsde, ker, RANK, ell=ELL, budget=budget)
    return x, ker, st


def _rel_align(z_ref, z) -> float:
    return embedding_alignment_error(z_ref, z) / np.linalg.norm(z_ref)


def test_from_rsde_matches_batch_fit():
    x, ker, st = _setup()
    mdl = fit_rskpca(shadow_rsde(x, ker, ell=ELL), ker, RANK)
    q = _blobs(64, seed=9)
    np.testing.assert_allclose(np.asarray(st.transform(q)), mdl.transform(q),
                               atol=2e-5, rtol=2e-4)
    assert st.cap % 128 == 0 and st.cap >= st.m


def test_streaming_exact_when_budget_zero():
    """budget=0 forces an exact re-solve at every maintenance: the evolving
    state must track a from-scratch fit on the equivalent center set to fp
    noise through interleaved insert/remove/replace."""
    rng = np.random.default_rng(3)
    x, ker, st = _setup(budget=0.0)
    q = _blobs(64, seed=9)
    for rnd in range(3):
        batch = _blobs(16, seed=100 + rnd, shift=0.4 * rnd)
        st = updates.ingest_batch(st, jnp.asarray(batch))
        live = np.flatnonzero(np.asarray(st.weights) > 0)
        st = updates.remove(st, int(live[rng.integers(live.size)]))
        live = np.flatnonzero(np.asarray(st.weights) > 0)
        st = updates.replace(st, int(live[rng.integers(live.size)]),
                             batch[rnd] + 0.1)
        assert float(st.err_est) == 0.0 and float(st.resid) == 0.0
        mdl = fit_rskpca(st.as_rsde(), ker, RANK)
        z_ref = mdl.transform(q)
        z_str = np.asarray(st.transform(q))
        assert _rel_align(z_ref, z_str) < 1e-4, rnd
        np.testing.assert_allclose(np.asarray(st.eigvals[:RANK]),
                                   mdl.eigvals, atol=1e-5, rtol=1e-3)


@pytest.mark.parametrize("precision,tol", [("f32", 1e-3), ("bf16", 4e-2)])
def test_streaming_property_within_tracked_budget(precision, tol):
    """The acceptance property: after K interleaved insert/remove/replace
    updates, the streaming projection matches a from-scratch fit_rskpca on
    the equivalent center set to within the Theorem-5.x bound tracked in
    the state's error budget (Davis-Kahan through the measured residual,
    which itself must sit below the tracked accumulation)."""
    rng = np.random.default_rng(5)
    x, ker, st = _setup(precision=precision, budget=0.05)
    q = _blobs(64, seed=9)
    for rnd in range(4):
        batch = _blobs(12, seed=200 + rnd, shift=0.3 * rnd)
        st = updates.ingest_batch(st, jnp.asarray(batch))
        live = np.flatnonzero(np.asarray(st.weights) > 0)
        st = updates.remove(st, int(live[rng.integers(live.size)]))
        live = np.flatnonzero(np.asarray(st.weights) > 0)
        st = updates.replace(st, int(live[rng.integers(live.size)]),
                             batch[0] + 0.2)
        # budget invariants: maintenance never leaves err_est above budget,
        # and the measured Rayleigh residual sits below the tracked
        # accumulated Theorem-5.x bound (it is the a-posteriori certificate
        # of exactly that perturbation)
        assert float(st.err_est) <= st.budget + 1e-6
        assert float(st.resid) <= 2.0 * float(st.err_est) + 1e-3
        assert abs(float(np.asarray(st.weights).sum()) - float(st.n)) < 0.5
    mdl = fit_rskpca(st.as_rsde(), ker, RANK)
    z_ref = mdl.transform(q)
    z_str = np.asarray(st.transform(q))
    lam = np.asarray(st.eigvals, np.float64)
    gap = max(float(lam[RANK - 1] - lam[RANK]), 1e-9)
    cond = np.sqrt(max(lam[0], 1e-12) / max(lam[RANK - 1], 1e-12))
    # Davis-Kahan: sin(theta) <= resid/gap <= (tracked err_est)/gap; the
    # aligned projection error inherits it scaled by the rank-block
    # conditioning.  4x safety + a dtype floor.
    bound = tol + 4.0 * float(st.err_est) / gap * cond
    assert _rel_align(z_ref, z_str) <= bound
    # eigenvalues: Weyl through the same tracked perturbation
    np.testing.assert_allclose(
        np.asarray(st.eigvals[:RANK], np.float64), mdl.eigvals.astype(np.float64),
        atol=float(st.err_est) + float(st.resid) + tol * float(lam[0]) + 1e-6)


def test_hot_swap_is_recompile_free():
    """A jitted transform stream must observe an operator update without
    retracing (same style as the PR-3 ragged-chunk serving assertion)."""
    x, ker, st = _setup(budget=0.05)
    srv = streaming.HotSwapServer(st, chunk=256)
    q_warm = _blobs(300, seed=21)
    q = _blobs(412, seed=22)
    srv.transform(q_warm)  # settle the trace + the autotuned plan
    srv.transform(q)
    before = kernel_ops.projection_compile_count()
    z1 = srv.transform(q)
    st = updates.ingest_batch(
        st, jnp.asarray(_blobs(24, seed=23, shift=2.0)))
    assert srv.publish(st) == 2
    z2 = srv.transform(q)
    after = kernel_ops.projection_compile_count()
    assert after == before, (before, after)
    assert np.abs(z1 - z2).max() > 1e-6  # the operator really moved
    assert z2.shape == (412, RANK)


def test_checkpoint_roundtrip(tmp_path):
    x, ker, st = _setup(budget=0.05)
    st = updates.ingest_batch(st, jnp.asarray(_blobs(16, seed=31, shift=1.0)))
    streaming.save(st, str(tmp_path), step=7)
    st2 = streaming.load(str(tmp_path))
    assert (st2.kernel, st2.rank, st2.eps, st2.budget) == \
        (st.kernel, st.rank, st.eps, st.budget)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    q = _blobs(32, seed=33)
    np.testing.assert_allclose(np.asarray(st.transform(q)),
                               np.asarray(st2.transform(q)), atol=1e-6)
    # the restored state keeps evolving
    st3 = updates.ingest_batch(st2, jnp.asarray(_blobs(8, seed=34)))
    assert float(st3.n) == float(st2.n) + 8


def test_drift_detector_and_partial_refresh():
    x, ker, st = _setup(budget=0.05)
    det = streaming.DriftDetector(ker, ell=ELL, window=128, factor=0.55)
    det.push(_blobs(128, seed=41))  # in-distribution: below threshold
    assert det.full
    assert det.mmd(st) <= det.threshold, (det.mmd(st), det.threshold)
    assert not det.should_refresh(st)
    # drift: the stream collapses onto a new mode the operator never saw
    rng = np.random.default_rng(42)
    mode = (np.full((1, x.shape[1]), 8.0)
            + 0.3 * rng.normal(size=(128, x.shape[1]))).astype(np.float32)
    det.push(mode)
    assert det.should_refresh(st)
    mmd_before = det.mmd(st)
    st2 = streaming.refresh(st, det.window(), decay=0.2)
    assert det.mmd(st2) < min(mmd_before, det.threshold)  # back under
    assert float(st2.err_est) == 0.0  # refresh re-solves exactly
    z = np.asarray(st2.transform(mode[:16]))
    assert np.isfinite(z).all() and np.abs(z).max() > 0


def test_ingest_ragged_stream_and_compaction():
    x, ker, st = _setup(budget=0.05, n=300)
    stream = _blobs(333, seed=51, shift=1.0)  # ragged vs batch=64
    st2 = streaming.ingest(st, stream, batch=64)
    assert abs(float(st2.n) - float(st.n) - 333) < 1e-2
    assert abs(float(np.asarray(st2.weights).sum()) - float(st2.n)) < 0.5
    assert streaming.needs_compaction(st2, max_fill=0.0)
    stc = streaming.compact(st2)
    assert stc.m == st2.m
    assert float(stc.n) == float(st2.n)
    # compaction is exact: pure permutation-gather + exact re-solve
    mdl = fit_rskpca(stc.as_rsde(), ker, RANK)
    q = _blobs(48, seed=52)
    assert _rel_align(mdl.transform(q), np.asarray(stc.transform(q))) < 1e-4


def test_buffer_overflow_falls_back_to_nearest_absorb():
    x, ker, st = _setup(budget=0.05)
    cap = st.cap
    far = _blobs(2 * cap, seed=61, shift=20.0)  # out-of-shadow flood
    st2 = streaming.ingest(st, far, batch=128)
    assert st2.m <= st2.cap  # never exceeds the buffer
    # mass is conserved even through the overflow guard
    assert abs(float(np.asarray(st2.weights).sum()) - float(st2.n)) < 0.5


def test_remove_refuses_to_empty_the_operator():
    """Removing every live center would drive n to 0 (every normalization
    divides by n): deleting the LAST live mass must be a refused no-op, and
    the state must stay finite throughout the teardown."""
    x, ker, st = _setup(budget=0.05)
    for j in np.flatnonzero(np.asarray(st.weights) > 0):
        st = updates.remove(st, int(j))
    assert float(st.n) > 0  # the last center's mass survived
    assert st.m == 1
    assert np.isfinite(np.asarray(st.eigvals)).all()
    z = np.asarray(st.transform(_blobs(8, seed=81)))
    assert np.isfinite(z).all()
    # ...but replace CAN move the last center (mass stays positive)
    st = updates.replace(st, int(np.argmax(np.asarray(st.weights))),
                         _blobs(1, seed=82)[0])
    assert float(st.n) > 0 and np.isfinite(np.asarray(st.eigvals)).all()


def test_streaming_mesh_transform_matches_single_device():
    from repro.launch.mesh import data_mesh
    x, ker, st = _setup()
    mesh = data_mesh(1)
    q = _blobs(70, seed=71)
    z0 = np.asarray(st.transform(q))
    z1 = np.asarray(st.transform(q, mesh=mesh))
    np.testing.assert_allclose(z0, z1, atol=1e-5, rtol=1e-4)
    srv = streaming.HotSwapServer(st)
    np.testing.assert_allclose(srv.transform(q, mesh=mesh), z0,
                               atol=1e-5, rtol=1e-4)


def test_mass_counters_exact_past_f32_saturation():
    """Regression (DESIGN.md §8 accounting fix): a single-f32 mass counter
    freezes at n = 2^24 (f32 has a 24-bit mantissa, so 2^24 + 1 == 2^24 and
    every later arrival silently vanishes from the normalization).  The
    split int32-count + f32-residual accumulators must keep counting
    exactly at any stream length."""
    # the failure mode the split representation removes:
    assert np.float32(2**24) + np.float32(1.0) == np.float32(2**24)
    x, ker, st = _setup(budget=10.0, n=200)
    st = dataclasses.replace(st, ncount=jnp.int32(1 << 24))
    n0 = int(st.ncount)
    xb = _blobs(64, seed=91, shift=0.5)
    st2 = updates.ingest_batch(st, xb)
    assert int(st2.ncount) - n0 == 64          # exact, not frozen
    assert float(st2.n) == float(n0 + 64) + float(st2.nfrac)
    # per-center weights ride the same split accumulators
    st3 = updates.ingest_batch(
        dataclasses.replace(st2, wcount=st2.wcount.at[0].set(1 << 24)),
        xb)
    assert int(st3.ncount) - n0 == 128


def test_ragged_batch_patch_accounting_counts_real_rows():
    """Regression: a masked (ragged-tail) ingest batch must add only its
    REAL rows to ``n_patched`` — the old code added the padded batch size,
    so ragged streams looked compaction-overdue after a few batches."""
    x, ker, st = _setup(budget=10.0, n=200)  # budget huge => always patch
    assert int(st.n_patched) == 0
    xb = _blobs(8, seed=92, shift=0.5)
    valid = np.zeros(8, bool)
    valid[:3] = True                          # 3 real rows, 5 padding
    st2 = updates.ingest_batch(st, xb, jnp.asarray(valid))
    assert int(st2.n_patched) == 3            # was 8 before the fix
    assert float(st2.n) - float(st.n) == 3.0  # padding adds no mass either
    # and a fully-masked batch is a pure no-op on the accounting
    st3 = updates.ingest_batch(st2, xb, jnp.zeros(8, bool))
    assert int(st3.n_patched) == 3
    assert float(st3.n) == float(st2.n)
