"""Fault injection, crash-consistent ingest, and graceful degradation.

The DESIGN.md §17 contract, tested end to end: deterministic fault plans
(same seed -> same fires, cross-process), retries that absorb transients
without changing results, SIGKILL-crash ingest that resumes BIT-EXACT from
its atomic checkpoints, preemption that drains cleanly, and a serving tier
that degrades to the last good snapshot instead of going down.
"""
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.ingest_pipeline import select_streaming
from repro.data.kpca_datasets import ChunkedDataset
from repro.runtime import chaos
from repro.runtime.chaos import (FaultPlan, FaultSpec, InjectedFault,
                                 TransientFault)
from repro.runtime.fault import Preempted, PreemptionGuard, RetryPolicy, \
    retry_call

_EPS = 0.25


def _src(n=1536, chunk=256, seed=3):
    return ChunkedDataset("pendigits", n=n, chunk=chunk, seed=seed)


# ---------------------------------------------------------------- plans --

def test_fault_plan_every_and_at_schedules():
    plan = FaultPlan({"s": FaultSpec(kind="error", every=3, at=(5,))})
    with chaos.active(plan):
        fired = []
        for k in range(1, 10):
            try:
                chaos.inject("s")
                fired.append(False)
            except InjectedFault:
                fired.append(True)
    assert fired == [False, False, True, False, True, True,
                     False, False, True]
    assert plan.stats()["calls"]["s"] == 9


def test_fault_plan_coin_is_deterministic_across_plans():
    """p-faults are a pure function of (seed, site, call#): two plans with
    the same seed fire on EXACTLY the same calls; a different seed gives a
    different (but equally reproducible) pattern."""
    def pattern(seed):
        plan = FaultPlan({"s": FaultSpec(kind="error", p=0.3)}, seed=seed)
        out = []
        with chaos.active(plan):
            for _ in range(200):
                try:
                    chaos.inject("s")
                    out.append(0)
                except InjectedFault:
                    out.append(1)
        return out

    a, b, c = pattern(1), pattern(1), pattern(2)
    assert a == b
    assert a != c
    assert 20 <= sum(a) <= 100  # the coin is actually ~0.3, not 0 or 1


def test_no_plan_is_a_noop_and_uninstall_restores_it():
    assert chaos.plan() is None
    chaos.inject("anything")  # must not raise
    with chaos.active(FaultPlan({})):
        assert chaos.plan() is not None
    assert chaos.plan() is None


def test_retry_absorbs_transients_but_not_permanent_faults():
    calls = []

    def flaky():
        calls.append(1)
        chaos.inject("s")
        return 42

    with chaos.active(FaultPlan({"s": FaultSpec(kind="transient",
                                                at=(1, 2))})):
        assert retry_call(flaky, policy=RetryPolicy(base_s=1e-4)) == 42
    assert len(calls) == 3

    with chaos.active(FaultPlan({"s": FaultSpec(kind="error", every=1)})):
        with pytest.raises(InjectedFault):
            retry_call(flaky, policy=RetryPolicy(base_s=1e-4))


def test_retry_honors_deadline():
    def always():
        raise TransientFault("s", 1)

    t0 = time.monotonic()
    with pytest.raises(TransientFault):
        retry_call(always, policy=RetryPolicy(base_s=0.5, max_attempts=10),
                   deadline=time.monotonic() + 0.05)
    assert time.monotonic() - t0 < 0.4  # gave up instead of sleeping 0.5s


def test_corrupt_flips_bits_only_when_firing():
    x = np.zeros(8192, np.uint8)
    assert chaos.corrupt("s", x) is x  # no plan: passthrough, no copy
    with chaos.active(FaultPlan({"s": FaultSpec(kind="corrupt", every=1)})):
        y = chaos.corrupt("s", x)
    assert y is not x and (y != x).sum() >= 2  # >= 1 flip per 4KiB page
    assert (x == 0).all()  # the original is never touched


# ------------------------------------------------- zero-cost / no-retrace --

def test_plan_toggle_never_retraces_the_serving_program():
    """Injection sites are host-side only: installing/uninstalling a plan
    around a jitted transform adds ZERO compiled programs."""
    from repro import streaming
    from repro.core import gaussian
    from repro.core.rsde import RSDE
    from repro.kernels import ops as kernel_ops

    rng = np.random.default_rng(0)
    c = rng.normal(size=(32, 4)).astype(np.float32)
    rsde = RSDE(c, np.ones(32, np.float64), n=32.0, scheme="test")
    st = streaming.from_rsde(rsde, gaussian(1.0), 3, eps=0.5, cap=32)
    srv = streaming.HotSwapServer(st)
    x = rng.normal(size=(8, 4)).astype(np.float32)
    np.asarray(srv.transform(x))  # warm
    before = kernel_ops.projection_compile_count()

    plan = FaultPlan({"swap.publish": FaultSpec(every=10**9),
                      "serve.dispatch": FaultSpec(every=10**9)})
    with chaos.active(plan):
        np.asarray(srv.transform(x))
    np.asarray(srv.transform(x))
    assert kernel_ops.projection_compile_count() == before


# ------------------------------------------------------ faulted ingest ----

def test_ingest_with_transient_faults_is_bit_exact():
    ref, _ = select_streaming(_src(), _EPS, block=128)
    fault = FaultSpec(kind="transient", at=(2,), p=0.05)
    plan = FaultPlan({"data.chunk": fault, "ingest.feed": fault,
                      "ingest.merge": fault}, seed=11)
    with chaos.active(plan) as p:
        got, _ = select_streaming(_src(), _EPS, block=128)
        assert p.stats()["total_injected"] >= 3
    np.testing.assert_array_equal(np.asarray(ref.centers),
                                  np.asarray(got.centers))
    np.testing.assert_array_equal(np.asarray(ref.weights),
                                  np.asarray(got.weights))


def test_ingest_checkpoint_resume_is_bit_exact(tmp_path):
    """Interrupt-by-truncation: ingest the first 3 chunks with
    checkpointing, then resume over the full stream — identical to an
    uninterrupted run (the ChunkedDataset-is-a-seed property)."""
    d = str(tmp_path)
    ref, _ = select_streaming(_src(), _EPS, block=128)
    select_streaming(_src(n=768), _EPS, block=128,
                     checkpoint_dir=d, checkpoint_every=1)
    got, stats = select_streaming(_src(), _EPS, block=128,
                                  checkpoint_dir=d, resume=True)
    assert stats.rows == 1536  # resumed counters cover the WHOLE stream
    np.testing.assert_array_equal(np.asarray(ref.centers),
                                  np.asarray(got.centers))
    np.testing.assert_array_equal(np.asarray(ref.weights),
                                  np.asarray(got.weights))
    assert got.weights.dtype == np.float64
    assert float(got.weights.sum()) == 1536.0  # weight-exact through resume


def test_resume_falls_back_over_a_corrupt_checkpoint(tmp_path):
    """Rot the NEWEST checkpoint's shard: resume must walk back to the
    previous intact step (crc catches the rot) and still finish bit-exact."""
    from repro.checkpoint.store import available_steps
    d = str(tmp_path)
    ref, _ = select_streaming(_src(), _EPS, block=128)
    select_streaming(_src(n=1024), _EPS, block=128,
                     checkpoint_dir=d, checkpoint_every=1)
    newest = available_steps(d)[-1]
    shard = os.path.join(d, f"step_{newest:08d}", "shard_0.npz")
    raw = bytearray(open(shard, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(shard, "wb").write(bytes(raw))

    got, _ = select_streaming(_src(), _EPS, block=128,
                              checkpoint_dir=d, resume=True)
    np.testing.assert_array_equal(np.asarray(ref.centers),
                                  np.asarray(got.centers))
    np.testing.assert_array_equal(np.asarray(ref.weights),
                                  np.asarray(got.weights))


def test_preemption_drains_checkpoints_and_resumes_bit_exact(tmp_path):
    """A stop request mid-stream raises Preempted AFTER persisting the
    cursor; resuming completes the run bit-exact."""
    d = str(tmp_path)
    ref, _ = select_streaming(_src(), _EPS, block=128)

    guard = PreemptionGuard(signals=())
    base = _src()

    class StopsAfter3:
        d = base.d

        def chunks(self, start=0):
            for k, item in enumerate(base.chunks(start=start)):
                if k == 3:
                    guard.request_stop()
                yield item

    with pytest.raises(Preempted) as ei:
        select_streaming(StopsAfter3(), _EPS, block=128,
                         checkpoint_dir=d, checkpoint_every=1, guard=guard)
    assert ei.value.step is not None and 1 <= ei.value.step < 6

    got, _ = select_streaming(_src(), _EPS, block=128,
                              checkpoint_dir=d, resume=True)
    np.testing.assert_array_equal(np.asarray(ref.centers),
                                  np.asarray(got.centers))
    np.testing.assert_array_equal(np.asarray(ref.weights),
                                  np.asarray(got.weights))


_CRASH_CHILD = """
import time
from repro.data.kpca_datasets import ChunkedDataset
from repro.core.ingest_pipeline import select_streaming

base = ChunkedDataset("pendigits", n=1536, chunk=256, seed=3)

class Slow:  # ~0.15s/chunk: the parent has time to SIGKILL mid-stream
    d = base.d
    def chunks(self, start=0):
        for item in base.chunks(start=start):
            time.sleep(0.15)
            yield item

select_streaming(Slow(), 0.25, block=128,
                 checkpoint_dir=@DIR@, checkpoint_every=1)
print("FINISHED")  # the parent asserts we never get here
"""


def test_sigkill_mid_ingest_resumes_bit_exact(tmp_path):
    """The tentpole crash test: SIGKILL (no cleanup, no atexit) an ingest
    mid-stream; a fresh process resumes from the atomic checkpoints and
    produces the bit-exact centers and f64 masses of an uninterrupted run."""
    from repro.checkpoint.store import available_steps
    d = str(tmp_path)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    child = subprocess.Popen(
        [sys.executable, "-c", _CRASH_CHILD.replace("@DIR@", repr(d))],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    try:
        deadline = time.monotonic() + 120
        while len(available_steps(d)) < 2:
            assert child.poll() is None, \
                f"child exited early: {child.communicate()[1][-2000:]}"
            assert time.monotonic() < deadline, "no checkpoint in 120s"
            time.sleep(0.05)
        child.send_signal(signal.SIGKILL)
        child.wait(timeout=30)
    finally:
        if child.poll() is None:
            child.kill()
    assert child.returncode == -signal.SIGKILL
    steps = available_steps(d)
    assert steps and steps[-1] < 6  # it really died mid-stream

    ref, _ = select_streaming(_src(), _EPS, block=128)
    got, stats = select_streaming(_src(), _EPS, block=128,
                                  checkpoint_dir=d, resume=True)
    assert stats.rows == 1536
    np.testing.assert_array_equal(np.asarray(ref.centers),
                                  np.asarray(got.centers))
    np.testing.assert_array_equal(np.asarray(ref.weights),
                                  np.asarray(got.weights))


# -------------------------------------------------- degraded serving ------

def _server(m=24, d=4, rank=3):
    from repro import streaming
    from repro.core import gaussian
    from repro.core.rsde import RSDE

    rng = np.random.default_rng(7)
    c = rng.normal(size=(m, d)).astype(np.float32)
    rsde = RSDE(c, np.ones(m, np.float64), n=float(m), scheme="test")
    st = streaming.from_rsde(rsde, gaussian(1.0), rank, eps=0.5, cap=m)
    return streaming.HotSwapServer(st), st


def test_failed_publish_degrades_to_last_good_snapshot():
    srv, st = _server()
    v0 = srv.version
    x = np.zeros((4, 4), np.float32)
    want = np.asarray(srv.transform(x))

    with chaos.active(FaultPlan({"swap.publish": FaultSpec(kind="error",
                                                           every=1)})):
        assert srv.try_publish(st) is False
    assert srv.version == v0 and srv.degraded
    info = srv.degraded_info()
    assert info.degraded and info.failed_publishes == 1
    assert np.isfinite(info.staleness_bound)
    np.testing.assert_array_equal(np.asarray(srv.transform(x)), want)

    assert srv.try_publish(st) is True  # fault cleared: recovers
    assert not srv.degraded and srv.version == v0 + 1
    assert srv.degraded_info().staleness_bound == 0.0


def test_first_publish_failure_cannot_degrade():
    """With no last-good snapshot there is nothing to fall back to: the
    failure propagates instead of leaving a server that can't serve."""
    from repro.streaming import HotSwapServer
    _, st = _server()
    srv = HotSwapServer()  # nothing published yet
    with chaos.active(FaultPlan({"swap.publish": FaultSpec(kind="error",
                                                           every=1)})):
        with pytest.raises(InjectedFault):
            srv.try_publish(st)


def test_staleness_bound_matches_single_update_identity():
    """The whole-vector bound must agree with the closed-form single-update
    bound on a one-center mass change, and grow with drift."""
    import jax.numpy as jnp
    from repro.core.mmd import staleness_bound, weight_update_bound

    w = np.full(16, 4.0)
    w2 = w.copy()
    w2[3] += 1.0  # absorb one sample into center 3
    got = staleness_bound(w, w2)
    want = float(weight_update_bound(jnp.asarray(64.0), jnp.asarray(65.0),
                                     jnp.asarray(4.0), jnp.asarray(5.0)))
    assert got == pytest.approx(want, rel=1e-5)
    assert staleness_bound(w, w) == 0.0
    w3 = w.copy()
    w3[3] += 40.0
    assert staleness_bound(w, w3) > got  # more drift, bigger budget
    # capacity growth: a fresh center in a new slot prices like an insert
    assert staleness_bound(w, np.concatenate([w, [1.0]])) > 0.0
