"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The container image has no ``hypothesis`` wheel and the brief forbids
installing one, so the property tests fall back to this shim: each
``@given`` test runs against ``max_examples`` pseudo-random draws from the
declared strategies, seeded from the test name so failures reproduce.

Only the tiny surface these tests use is implemented: ``integers``,
``floats``, ``given``, ``settings``.  No shrinking, no database — a failing
example is reported via the test's own assertion message (the kwargs are
attached to the AssertionError text).
"""
from __future__ import annotations

import zlib

import numpy as np

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # NOT functools.wraps: pytest must see a zero-arg signature, not the
        # strategy parameters (it would treat them as fixtures).
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                kwargs = {k: s.example(rng) for k, s in strats.items()}
                try:
                    fn(**kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {kwargs}: {e}"
                    ) from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
