"""Validation of the paper's experimental claims (relative claims — see
DESIGN.md §14 for the synthetic-dataset caveat).

Claims validated:
  C1 (Table 2 / §1): RSKPCA trains faster than KPCA (here >= 3x at n=1200)
     and stores O(mr) vs Nystrom's O(nr).
  C2 (Fig 2-3): embedding error decreases with ell; shadow beats uniform.
  C3 (Fig 4-5): shadow k-nn accuracy within 3 points of full KPCA at ell=4.
  C4 (Fig 6): retention is monotone in ell and < 100%.
  C5 (Figs 7-8): RSDE scheme influences accuracy mostly at small ell.
"""
import numpy as np
import pytest

from repro.core import (gaussian, fit_kpca, fit, fit_nystrom, fit_rskpca,
                        shadow_rsde, fit_subsampled_kpca,
                        embedding_alignment_error)
from repro.data import make_dataset, train_test_split, knn_classify, DATASETS
import time


@pytest.fixture(scope="module")
def pendigits():
    x, y, sigma = make_dataset("pendigits", seed=0, n=1200)
    return x, y, sigma


def test_c1_train_speedup_and_storage(pendigits):
    x, y, sigma = pendigits
    ker = gaussian(sigma)
    xtr, ytr, xte, yte = train_test_split(x, y)

    # warm up both paths (jit compilation must not pollute the timing)
    fit_kpca(xtr[:200], ker, 5)
    fit(xtr, ker, 5, method="shadow", ell=4.0)

    t0 = time.perf_counter()
    kp = fit_kpca(xtr, ker, 5)
    t_kpca = time.perf_counter() - t0

    t0 = time.perf_counter()
    rs = fit(xtr, ker, 5, method="shadow", ell=4.0)
    t_rs = time.perf_counter() - t0

    # >=2x wall speedup (the benchmark harness shows ~8x; keep the
    # test threshold loose against CI-machine load)
    assert t_rs < t_kpca / 2, (t_rs, t_kpca)
    ny = fit_nystrom(xtr, ker, 5, m=rs.m)
    assert rs.centers.shape[0] < 0.5 * ny.centers.shape[0]  # O(m) vs O(n)


def test_c2_embedding_error_decreases_with_ell(pendigits):
    x, _, sigma = pendigits
    ker = gaussian(sigma)
    xtr, _, xte, _ = train_test_split(x, np.zeros(len(x), np.int32))
    ref = fit_kpca(xtr, ker, 5)
    ref_emb = ref.transform(xte)
    errs = {}
    for ell in (3.0, 4.0, 5.0):
        rsde = shadow_rsde(xtr, ker, ell)
        sh = fit_rskpca(rsde, ker, 5)
        un = fit_subsampled_kpca(xtr, ker, 5, m=rsde.m, seed=0)
        errs[ell] = (embedding_alignment_error(ref_emb, sh.transform(xte)),
                     embedding_alignment_error(ref_emb, un.transform(xte)))
    assert errs[5.0][0] < errs[3.0][0]          # error shrinks with ell
    assert errs[4.0][0] < errs[4.0][1]          # shadow beats uniform
    assert errs[5.0][0] < errs[5.0][1]


def test_c3_classification_within_3pts_of_kpca(pendigits):
    x, y, sigma = pendigits
    ker = gaussian(sigma)
    k = DATASETS["pendigits"].knn_k
    xtr, ytr, xte, yte = train_test_split(x, y)
    ref = fit_kpca(xtr, ker, 5)
    acc_ref = (knn_classify(ref.transform(xtr), ytr,
                            ref.transform(xte), k) == yte).mean()
    sh = fit(xtr, ker, 5, method="shadow", ell=4.0)
    acc_sh = (knn_classify(sh.transform(xtr), ytr,
                           sh.transform(xte), k) == yte).mean()
    assert acc_sh >= acc_ref - 0.03, (acc_sh, acc_ref)


def test_c4_retention_monotone(pendigits):
    x, _, sigma = pendigits
    ker = gaussian(sigma)
    rets = [shadow_rsde(x, ker, ell).retention
            for ell in (3.0, 3.5, 4.0, 4.5, 5.0)]
    assert all(a <= b + 1e-9 for a, b in zip(rets, rets[1:]))
    assert rets[0] < 0.5 and rets[-1] <= 1.0


def test_c5_rsde_scheme_gap_shrinks_with_ell(pendigits):
    from repro.core import make_rsde
    x, y, sigma = pendigits
    ker = gaussian(sigma)
    k = DATASETS["pendigits"].knn_k
    xtr, ytr, xte, yte = train_test_split(x, y)
    gaps = {}
    for ell in (3.0, 5.0):
        sh = shadow_rsde(xtr, ker, ell)
        accs = {}
        for scheme in ("shadow", "kmeans", "paring"):
            rsde = sh if scheme == "shadow" else make_rsde(
                scheme, xtr, ker, m=max(sh.m, 6))
            mdl = fit_rskpca(rsde, ker, 5)
            accs[scheme] = (knn_classify(mdl.transform(xtr), ytr,
                                         mdl.transform(xte), k) == yte).mean()
        gaps[ell] = max(accs.values()) - min(accs.values())
    # quality of the RSDE matters less once the cover is fine (paper §6)
    assert gaps[5.0] <= gaps[3.0] + 0.05
