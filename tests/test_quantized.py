"""Quantized serving-tier (int8/fp8 projector) parity and bound tests.

The contract (DESIGN.md §8, kernels/quantize.py): only the projector
contraction drops precision — distances, the exp nonlinearity, and the
accumulator stay f32 — and the per-channel rounding error of the projection
is bounded by ``projection_error_bound``, a budget the caller can weigh
against the §5 eigenvalue slack.  Three layers are pinned here:

  * BITWISE pallas/dense parity for int8 (both paths round Gram values with
    the identical expression and accumulate in int32, so the dense oracle
    and the kernel must agree to the last bit — not approximately);
  * measured error vs the f32 oracle stays within the reported bound, on
    pow2 bucket shapes AND ragged tails, for both precisions (property
    swept hypothesis-style over random shapes/scales);
  * the publish-time cache path: a pre-quantized ``projector_q`` must give
    exactly the per-call-quantized answer, and the chunked ragged stream
    must stay recompile-free.
"""
import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, quantize, ref

SIGMA = 1.7

# pow2 bucket shapes and ragged tails (n % 128 != 0, odd m/r)
SHAPES = [(256, 128, 16, 8), (512, 256, 32, 16), (300, 190, 24, 11)]


def _problem(n, m, d, r, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(m, d)).astype(np.float32)
    a = (rng.normal(size=(m, r)) / np.sqrt(m)).astype(np.float32)
    return x, c, a


def _oracle(x, c, a):
    return np.asarray(ref.kpca_project_ref(jnp.asarray(x), jnp.asarray(c),
                                           jnp.asarray(a), SIGMA, 2))


@pytest.mark.parametrize("n,m,d,r", SHAPES)
def test_int8_pallas_dense_bitwise(n, m, d, r):
    """int8 rounds the Gram with one shared expression and accumulates in
    int32, so the pallas kernel and the dense oracle are integer-exact:
    equality to the last bit, not a tolerance."""
    x, c, a = _problem(n, m, d, r)
    zs = [np.asarray(ops.kpca_project(x, c, a, sigma=SIGMA, precision="int8",
                                      plan=plan))
          for plan in ("pallas", "dense")]
    np.testing.assert_array_equal(zs[0], zs[1])


@pytest.mark.parametrize("n,m,d,r", SHAPES)
@pytest.mark.parametrize("prec", quantize.QUANT_PRECISIONS)
@pytest.mark.parametrize("plan", ["pallas", "dense"])
def test_quantized_error_within_reported_bound(n, m, d, r, prec, plan):
    x, c, a = _problem(n, m, d, r, seed=hash((n, m, prec)) % 2**32)
    got = np.asarray(ops.kpca_project(x, c, a, sigma=SIGMA, precision=prec,
                                      plan=plan))
    err = np.abs(got - _oracle(x, c, a)).max(axis=0)      # per channel
    bound = np.asarray(quantize.projection_error_bound(a, prec))
    assert err.shape == bound.shape == (r,)
    assert np.all(err <= bound), (err, bound)
    assert np.all(np.isfinite(bound)) and np.all(bound > 0)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 200), r=st.integers(1, 24),
       scale=st.floats(1e-3, 30.0), seed=st.integers(0, 2**16))
def test_bound_property_random_projectors(m, r, scale, seed):
    """Property: for ANY projector magnitude the dense quantized projection
    errs within projection_error_bound — the Theorem-5.x-style budget the
    swap publisher reports must never under-promise."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(64, 6)).astype(np.float32)
    c = rng.normal(size=(m, 6)).astype(np.float32)
    a = (rng.normal(size=(m, r)) * scale).astype(np.float32)
    want = _oracle(x, c, a)
    for prec in quantize.QUANT_PRECISIONS:
        got = np.asarray(ops.kpca_project(x, c, a, sigma=SIGMA,
                                          precision=prec, plan="dense"))
        err = np.abs(got - want).max(axis=0)
        bound = np.asarray(quantize.projection_error_bound(a, prec))
        assert np.all(err <= bound), (prec, err, bound)


@pytest.mark.parametrize("prec", quantize.QUANT_PRECISIONS)
def test_quantize_projector_roundtrip_and_zero_channels(prec):
    rng = np.random.default_rng(5)
    a = (rng.normal(size=(90, 7)) * 3.0).astype(np.float32)
    a[:, 3] = 0.0                       # an all-zero channel must not NaN
    q, s = quantize.quantize_projector(a, prec)
    s = np.asarray(s)
    assert s.shape == (7,) and np.all(s > 0) and s[3] == 1.0
    deq = np.asarray(quantize.dequantize_projector(q, s))
    np.testing.assert_array_equal(deq[:, 3], 0.0)
    if prec == "int8":
        assert np.asarray(q).dtype == np.int8
        # symmetric rounding: dequantized entries within half a step
        assert np.abs(deq - a).max() <= (s / 2 + 1e-7).max()
    else:
        assert np.abs(deq - a).max() <= np.abs(a).max() * quantize.FP8_U


@pytest.mark.parametrize("prec", quantize.QUANT_PRECISIONS)
def test_publish_time_projector_q_matches_per_call(prec):
    """The snapshot-publish cache (swap.py stores (Aq, s) once) must be a
    pure caching move: identical output to quantizing inside the call."""
    x, c, a = _problem(320, 150, 12, 6, seed=9)
    pq = quantize.quantize_projector(a, prec)
    for plan in ("pallas", "dense"):
        per_call = np.asarray(ops.kpca_project(
            x, c, a, sigma=SIGMA, precision=prec, plan=plan))
        cached = np.asarray(ops.kpca_project(
            x, c, a, sigma=SIGMA, precision=prec, plan=plan, projector_q=pq))
        np.testing.assert_array_equal(per_call, cached)


def test_projector_q_rejected_for_full_precision():
    x, c, a = _problem(64, 32, 4, 3)
    pq = quantize.quantize_projector(a, "int8")
    with pytest.raises(ValueError):
        ops.kpca_project(x, c, a, sigma=SIGMA, precision="f32",
                         projector_q=pq)


def test_quantized_chunked_stream_recompile_free():
    """Ragged quantized query streams ride the same fixed-chunk padding as
    f32: after the first (compile) call, arbitrary ragged row counts add
    ZERO compiled shapes — the serving contract of DESIGN.md §8."""
    _, c, a = _problem(1, 100, 8, 5, seed=2)
    pq = quantize.quantize_projector(a, "int8")
    rng = np.random.default_rng(3)

    def go(n):
        x = rng.normal(size=(n, 8)).astype(np.float32)
        return np.asarray(ops.kpca_project(
            x, c, a, sigma=SIGMA, precision="int8", plan="pallas",
            chunk=128, projector_q=pq))

    go(128)  # warm the single (chunk, m_pad) shape
    before = ops.projection_compile_count()
    for n in (17, 128, 300, 513):
        z = go(n)
        assert z.shape == (n, 5)
    assert ops.projection_compile_count() == before


def test_swap_publish_caches_quantized_projector():
    """HotSwapServer.publish quantizes ONCE per snapshot for quantized-tier
    kernels (and not at all for f32), and the served tier stays close to
    the f32 oracle."""
    from repro import streaming
    from repro.core import gaussian
    from repro.core.rsde import RSDE

    rng = np.random.default_rng(11)
    c = rng.normal(size=(60, 5)).astype(np.float32)
    w = np.ones(60, np.float64)
    rsde = RSDE(c, w, n=60.0, scheme="test")

    def server(precision):
        ker = gaussian(1.0, precision=precision)
        st_ = streaming.from_rsde(rsde, ker, 4, eps=0.5, cap=60)
        return streaming.HotSwapServer(st_)

    x = rng.normal(size=(32, 5)).astype(np.float32)
    s32, s8 = server("f32"), server("int8")
    assert s32._snapshot[3] is None
    q, s = s8._snapshot[3]
    assert np.asarray(q).dtype == np.int8 and np.asarray(s).ndim == 1
    z32, z8 = np.asarray(s32.transform(x)), np.asarray(s8.transform(x))
    # the served tier's deviation from the f32 oracle stays inside the
    # per-channel budget publish reported for this exact projector
    bound = np.asarray(quantize.projection_error_bound(
        np.asarray(s8._snapshot[1]), "int8"))
    assert np.all(np.abs(z8 - z32).max(axis=0) <= bound)
