"""Matrix-free fit: gram_matvec parity, LOBPCG eigenpair property tests,
the fused select->fit pipeline, and the donation (no-copy) contracts
(DESIGN.md §6)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref

MV_SHAPES = [(64, 16, 8, 4), (100, 37, 24, 8), (513, 129, 16, 5),
             (256, 250, 96, 1)]  # incl. ragged (non-pow2, non-128-mult) m


@pytest.mark.parametrize("n,m,d,r", MV_SHAPES)
@pytest.mark.parametrize("p", [2, 1])
def test_gram_matvec_parity_f32(n, m, d, r, p):
    """gram_matvec == weighted_gram(...) @ V for every plan, f32."""
    rng = np.random.default_rng(hash((n, m, d, r, p)) % 2**32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(m, d)).astype(np.float32)
    v = rng.normal(size=(m, r)).astype(np.float32)
    wx = rng.uniform(0.5, 3, n).astype(np.float32)
    wy = rng.uniform(0.5, 3, m).astype(np.float32)
    want = np.asarray(ref.gram_ref(jnp.asarray(x), jnp.asarray(y), 2.5, p,
                                   jnp.asarray(wx), jnp.asarray(wy))) @ v
    for plan in ("pallas", "pallas_fat", "dense"):
        got = np.asarray(ops.gram_matvec(x, y, v, sigma=2.5, p=p, wx=wx,
                                         wy=wy, plan=plan))
        np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4,
                                   err_msg=plan)


@pytest.mark.parametrize("p", [2, 1])
def test_gram_matvec_parity_unweighted(p):
    """Unweighted ragged m: the zero v-row padding must make padded centers
    contribute exactly nothing (k(x, 0-pad) != 0 for the Gaussian!)."""
    rng = np.random.default_rng(7)
    x = rng.normal(size=(130, 24)).astype(np.float32)
    y = rng.normal(size=(37, 24)).astype(np.float32)  # pads up to 128 rows
    v = rng.normal(size=(37, 5)).astype(np.float32)
    want = np.asarray(ref.gram_ref(jnp.asarray(x), jnp.asarray(y), 1.5, p)) @ v
    for plan in ("pallas", "pallas_fat", "dense"):
        got = np.asarray(ops.gram_matvec(x, y, v, sigma=1.5, p=p, plan=plan))
        np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4,
                                   err_msg=plan)


def test_gram_matvec_bf16_tolerance():
    """bf16 operands, f32 accumulation: same tolerance class as the bf16
    Gram (tests/test_precision.py)."""
    rng = np.random.default_rng(3)
    c = rng.normal(size=(200, 32)).astype(np.float32)
    w = rng.uniform(0.5, 3, 200).astype(np.float32)
    v = rng.normal(size=(200, 8)).astype(np.float32)
    want = np.asarray(ref.gram_ref(jnp.asarray(c), jnp.asarray(c), 2.0, 2,
                                   jnp.asarray(w), jnp.asarray(w))) @ v
    got = np.asarray(ops.weighted_gram_matvec(c, w, v, sigma=2.0,
                                              precision="bf16",
                                              plan="pallas"))
    assert np.abs(got - want).max() <= 3e-2 * np.abs(want).max()


def test_gram_matvec_zero_weight_rows_are_inert():
    """Zero-weight centers (the fit path's capacity padding) must not move
    the matvec: appending them changes nothing."""
    rng = np.random.default_rng(11)
    c = rng.normal(size=(90, 12)).astype(np.float32)
    w = rng.uniform(1, 5, 90).astype(np.float32)
    v = rng.normal(size=(90, 4)).astype(np.float32)
    cpad = np.concatenate([c, rng.normal(size=(38, 12)).astype(np.float32)])
    wpad = np.concatenate([w, np.zeros(38, np.float32)])
    vpad = np.concatenate([v, rng.normal(size=(38, 4)).astype(np.float32)])
    base = np.asarray(ops.gram_matvec(c, c, v, sigma=1.5, wx=w, wy=w,
                                      plan="pallas"))
    padded = np.asarray(ops.gram_matvec(cpad, cpad, vpad, sigma=1.5,
                                        wx=wpad, wy=wpad, plan="pallas"))
    # padded-out rows: sqrt(0) kills them; live rows match the unpadded run
    np.testing.assert_allclose(padded[:90], base, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(padded[90:], 0.0, atol=5e-6)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(min_value=60, max_value=220),
       rank=st.integers(min_value=2, max_value=6),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_matvec_lobpcg_eigenpairs_match_dense_eigh(m, rank, seed):
    """Property: LOBPCG driven purely by gram_matvec recovers the top-r
    eigenpairs of the dense weighted Gram (the matfree fit's soundness)."""
    from jax.experimental.sparse.linalg import lobpcg_standard

    rng = np.random.default_rng(seed)
    d = 8
    c = rng.normal(size=(m, d)).astype(np.float32) * 2.0
    w = rng.uniform(0.5, 4, m).astype(np.float32)
    n = float(w.sum())
    kt = np.asarray(ref.gram_ref(jnp.asarray(c), jnp.asarray(c), 1.5, 2,
                                 jnp.asarray(w), jnp.asarray(w))) / n
    lam_ref = np.linalg.eigvalsh(kt)[::-1][:rank]

    def matvec(v):
        return ops.gram_matvec(c, c, v, sigma=1.5, p=2, wx=w, wy=w,
                               plan="pallas") / np.float32(n)

    x0 = jax.random.normal(jax.random.PRNGKey(0), (m, rank), jnp.float32)
    lam, u, _ = lobpcg_standard(matvec, x0, m=100)
    lam, u = np.asarray(lam), np.asarray(u)
    np.testing.assert_allclose(lam, lam_ref, rtol=5e-3, atol=1e-5)
    # eigenpair residual of the MATVEC operator (not just the values)
    resid = kt @ u - u * lam[None, :]
    assert np.linalg.norm(resid) <= 1e-3 * max(1.0, np.linalg.norm(lam))


def test_matfree_fit_matches_materialized(monkeypatch):
    """fit_rskpca(matfree=True) == the materialized path: eigvals and the
    aligned embedding, at a small m where both are cheap."""
    from repro.core import (gaussian, shadow_rsde, fit_rskpca,
                            embedding_alignment_error)
    from repro.data import make_dataset

    x, _, sigma = make_dataset("german", seed=0, n=400)
    ker = gaussian(sigma)
    rsde = shadow_rsde(x, ker, 3.0)
    dense = fit_rskpca(rsde, ker, 5)
    mf = fit_rskpca(rsde, ker, 5, matfree=True)
    np.testing.assert_allclose(mf.eigvals, dense.eigvals, rtol=1e-3)
    q = x[:80]
    ref_z = dense.transform(q)
    err = embedding_alignment_error(ref_z, mf.transform(q))
    assert err <= 1e-3 * np.linalg.norm(ref_z)


def test_matfree_crossover_policy(monkeypatch):
    """Default policy: materialized below the bytes budget (bit-identical
    contract), matrix-free above it; env overrides force the threshold."""
    monkeypatch.delenv("REPRO_MATFREE_MIN_M", raising=False)
    monkeypatch.delenv("REPRO_GRAM_BYTES_BUDGET", raising=False)
    assert not ops.matfree_fit(4096)   # 64 MB Gram: stays materialized
    assert ops.matfree_fit(8192)       # 256 MB Gram: goes matrix-free
    monkeypatch.setenv("REPRO_MATFREE_MIN_M", "100")
    assert ops.matfree_fit(100) and not ops.matfree_fit(99)
    monkeypatch.delenv("REPRO_MATFREE_MIN_M", raising=False)
    monkeypatch.setenv("REPRO_GRAM_BYTES_BUDGET", str(4 * 512 * 512))
    assert ops.matfree_fit(513) and not ops.matfree_fit(512)


def test_forced_matfree_with_unsound_rank_fails_loudly():
    """matfree=True where LOBPCG is unsound (5*rank >= m) must raise a
    clear error at the API boundary — never a cryptic solver failure, never
    a silent fall-back to the materialized Gram the caller forbade."""
    from repro.core import gaussian, fit_rskpca
    from repro.core.rsde import RSDE

    rng = np.random.default_rng(8)
    rsde = RSDE(rng.normal(size=(16, 4)).astype(np.float32),
                np.ones(16), n=64.0, scheme="bench")
    with pytest.raises(ValueError, match="5\\*rank < m"):
        fit_rskpca(rsde, gaussian(1.0), 4, matfree=True)


def test_fused_pipeline_matches_blocked_selection():
    """selector="fused" (single-pass select->fit) produces the same center
    set and an equivalent model as blocked selection + separate fit."""
    from repro.core import gaussian, fit, embedding_alignment_error
    from repro.data import make_dataset

    x, _, sigma = make_dataset("german", seed=0, n=400)
    ker = gaussian(sigma)
    fused = fit(x, ker, 4, method="shadow", ell=6.0, selector="fused")
    blocked = fit(x, ker, 4, method="shadow", ell=6.0, selector="blocked")
    assert fused.method == "rskpca+shadow-fused"
    assert fused.m == blocked.m
    q = x[:100]
    ref_z = blocked.transform(q)
    err = embedding_alignment_error(ref_z, fused.transform(q))
    assert err <= 1e-3 * np.linalg.norm(ref_z)


def test_fused_pipeline_full_capacity_alias_survives():
    """Regression: with n <= 128 the pow2 capacity bucket equals n, so the
    cap slice IS the selection buffer (jax full-slice fast path) and with
    rank == d XLA aliases the donated buffer into the projector output —
    the model's centers must be materialized BEFORE that donation."""
    from repro.core import gaussian
    from repro.core.pipeline import fit_shadow_fused

    rng = np.random.default_rng(5)
    x = rng.normal(size=(128, 4)).astype(np.float32)
    mdl = fit_shadow_fused(x, gaussian(1.0), 4, ell=4.0)
    assert mdl.centers.shape[1] == 4 and mdl.m >= 1
    assert np.isfinite(mdl.transform(x[:16])).all()


def test_fused_pipeline_matfree_end_to_end(monkeypatch):
    """The full tentpole dataflow at test scale: fused selection streaming
    into a matrix-free fit (forced via env), vs the all-default pipeline."""
    monkeypatch.setenv("REPRO_MATFREE_MIN_M", "1")
    from repro.core import gaussian, fit, embedding_alignment_error
    from repro.data import make_dataset

    x, _, sigma = make_dataset("german", seed=1, n=400)
    ker = gaussian(sigma)
    fused = fit(x, ker, 4, method="shadow", ell=5.0, selector="fused")
    monkeypatch.delenv("REPRO_MATFREE_MIN_M")
    base = fit(x, ker, 4, method="shadow", ell=5.0, selector="blocked")
    q = x[:100]
    ref_z = base.transform(q)
    err = embedding_alignment_error(ref_z, fused.transform(q))
    assert err <= 1e-2 * np.linalg.norm(ref_z)


def test_sharded_matfree_matches_single_device():
    """Row-tile-distributed matvec LOBPCG == single-device matfree fit
    (1-device mesh in-process; the 8-device variant runs in
    tests/test_sharded.py's subprocess harness)."""
    from repro.compat import make_mesh
    from repro.core import gaussian
    from repro.core.distributed import fit_rskpca_sharded
    from repro.core.rskpca import _fit_rskpca_device

    rng = np.random.default_rng(2)
    c = rng.normal(size=(160, 12)).astype(np.float32)
    w = rng.uniform(1, 6, 160).astype(np.float32)
    n = float(w.sum())
    ker = gaussian(1.5)
    mesh = make_mesh((1,), ("data",))
    lam_s, proj_s = fit_rskpca_sharded(c, w, n, ker, 4, mesh,
                                       lobpcg_min_m=64, matfree=True)
    lam_1, proj_1 = _fit_rskpca_device(jnp.asarray(c), jnp.asarray(w),
                                       jnp.float32(n), ker, 4, matfree=True)
    np.testing.assert_allclose(np.asarray(lam_s), np.asarray(lam_1),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(proj_s), np.asarray(proj_1),
                               atol=1e-4, rtol=1e-3)


def test_streaming_solve_reuses_cached_gram_operator():
    """Above the crossover the streaming re-solve must run LOBPCG straight
    off the cached unweighted kgram (weights folded into the matvec) and
    agree with the materialized small-cap solve."""
    from repro.streaming.state import _solve

    rng = np.random.default_rng(4)
    cap = 256
    c = rng.normal(size=(cap, 10)).astype(np.float32)
    w = np.zeros(cap, np.float32)
    w[:200] = rng.uniform(1, 5, 200).astype(np.float32)  # dead tail slots
    kgram = np.asarray(ref.gram_ref(jnp.asarray(c), jnp.asarray(c), 1.5, 2))
    n = jnp.float32(w.sum())
    lam_mat, u_mat = _solve(jnp.asarray(kgram), jnp.asarray(w), n, 5,
                            min_m=10**9)   # force the materialized branch
    lam_mf, u_mf = _solve(jnp.asarray(kgram), jnp.asarray(w), n, 5,
                          min_m=32)        # force the matvec-reuse branch
    np.testing.assert_allclose(np.asarray(lam_mf), np.asarray(lam_mat),
                               rtol=1e-4)
    np.testing.assert_allclose(np.abs(np.asarray(u_mf)),
                               np.abs(np.asarray(u_mat)), atol=1e-3)


# --------------------------------------------------------------------------
# donation (no-copy) contracts
# --------------------------------------------------------------------------


def test_fit_donates_and_aliases_center_buffer():
    """With d == rank the projector output matches the donated center
    buffer's shape, so XLA aliases it in place: the input buffer must be
    CONSUMED (deleted) — the asserted no-copy contract."""
    from repro.core import gaussian
    from repro.core.rskpca import _fit_rskpca_device

    rng = np.random.default_rng(0)
    ker = gaussian(1.0)
    c = jnp.asarray(rng.normal(size=(256, 8)).astype(np.float32))
    w = jnp.asarray(rng.uniform(1, 5, 256).astype(np.float32))
    lam, proj = _fit_rskpca_device(c, w, jnp.float32(1000.0), ker, 8)
    jax.block_until_ready(proj)
    assert c.is_deleted(), "donated center buffer was copied, not aliased"
    assert np.isfinite(np.asarray(proj)).all()


def test_fit_rskpca_survives_device_resident_rsde():
    """Regression: an RSDE already holding jax f32 arrays must not be
    consumed by the donating fit — jnp.asarray would alias the caller's
    buffers, so fit_rskpca builds its device operands from a host copy."""
    from repro.core import gaussian, fit_rskpca
    from repro.core.rsde import RSDE

    rng = np.random.default_rng(6)
    c = jnp.asarray(rng.normal(size=(96, 8)).astype(np.float32))
    w = jnp.asarray(rng.uniform(1, 5, 96).astype(np.float32))
    rsde = RSDE(centers=c, weights=w, n=500.0, scheme="bench")
    mdl = fit_rskpca(rsde, gaussian(1.0), 8)  # rank == d: alias-capable
    assert not c.is_deleted() and not w.is_deleted()
    np.testing.assert_allclose(np.asarray(c), mdl.centers, atol=0)
    assert np.isfinite(mdl.transform(np.asarray(c[:10]))).all()


def test_transform_never_consumes_caller_buffer():
    """kpca_project donates its internal padded chunk, but a caller-owned
    device array — even one whose shape could alias — must survive."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    c = rng.normal(size=(64, 128)).astype(np.float32)
    a = rng.normal(size=(64, 128)).astype(np.float32)
    z = ops.kpca_project(x, c, a, sigma=1.0, plan="pallas")
    jax.block_until_ready(z)
    assert not x.is_deleted()
    # and the result still matches the oracle
    want = np.asarray(ref.kpca_project_ref(x, jnp.asarray(c), jnp.asarray(a),
                                           1.0, 2))
    np.testing.assert_allclose(np.asarray(z), want, atol=5e-4, rtol=5e-4)
