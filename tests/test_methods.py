"""Method zoo (ISSUE 8): nystrom / wnystrom / rff on the optimized stack.

Parity (Pallas vs dense, f32 and bf16), RFF spectral convergence (hypothesis
property), sharded-fit equivalence per method, stream-vs-resident
equivalence, the fit() front door dispatch, and the measured-Pareto method
selector."""
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

import repro.core as core
from repro.core import gaussian, laplacian
from repro.core.nystrom import _landmark_eigs_matfree
from repro.core.ingest_pipeline import pad_block
from repro.core.random_features import sample_rff
from repro.data import make_dataset
from repro.launch.mesh import data_mesh


@pytest.fixture(scope="module")
def data():
    x, y, sigma = make_dataset("pendigits", seed=0, n=600)
    return np.asarray(x, np.float32), gaussian(sigma)


def _chunks(x, rows=256):
    for s in range(0, len(x), rows):
        xb, ok = pad_block(x[s : s + rows], rows)
        yield xb, int(ok.sum())


# ---------------------------------------------------------------- parity


def test_nystrom_pallas_dense_parity_f32(data):
    x, ker = data
    a = core.fit_nystrom(x, ker, 5, 96, seed=3)
    b = core.fit_nystrom(x, ker.with_backend("dense"), 5, 96, seed=3)
    # jax.random landmarks are backend-independent -> same subproblem
    np.testing.assert_allclose(a.eigvals, b.eigvals, rtol=1e-4)
    np.testing.assert_allclose(a.projector, b.projector, atol=1e-5)
    np.testing.assert_allclose(a.transform(x[:64]), b.transform(x[:64]),
                               atol=1e-4)


def test_nystrom_bf16_close_to_f32(data):
    x, ker = data
    a = core.fit_nystrom(x, ker, 5, 96, seed=3)
    c = core.fit_nystrom(x, ker.with_precision("bf16"), 5, 96, seed=3)
    # bf16 operands, f32 accumulation: same eigensystem to ~1e-2
    np.testing.assert_allclose(c.eigvals, a.eigvals, rtol=5e-2)
    scale = np.abs(a.projector).max()
    assert np.abs(c.projector - a.projector).max() < 5e-2 * scale


def test_nystrom_keeps_full_data_and_chunking_invariance(data):
    x, ker = data
    a = core.fit_nystrom(x, ker, 5, 80)
    assert a.centers.shape[0] == len(x)          # O(n) storage — the point
    b = core.fit_nystrom(x, ker, 5, 80, rows=128)
    np.testing.assert_allclose(b.projector, a.projector, atol=1e-5)


def test_wnystrom_pallas_dense_parity(data):
    x, ker = data
    a = core.fit_weighted_nystrom(x, ker, 5, 64, seed=1)
    b = core.fit_weighted_nystrom(x, ker.with_backend("dense"), 5, 64,
                                  seed=1)
    assert a.method == b.method == "wnystrom"
    assert a.centers.shape == (64, x.shape[1])
    np.testing.assert_allclose(a.eigvals, b.eigvals, rtol=1e-3)
    np.testing.assert_allclose(np.abs(a.projector), np.abs(b.projector),
                               atol=1e-4)


def test_rff_pallas_dense_parity(data):
    x, ker = data
    a = core.fit_rff(x, ker, 5, n_features=256, seed=0)
    b = core.fit_rff(x, ker.with_backend("dense"), 5, n_features=256, seed=0)
    # the fit is backend-independent (chunked covariance); the transform
    # runs the fused Pallas kernel vs the jnp oracle
    np.testing.assert_allclose(a.projector, b.projector, atol=1e-5)
    np.testing.assert_allclose(a.transform(x[:100]), b.transform(x[:100]),
                               atol=1e-4)


def test_rff_bf16_close_to_f32(data):
    x, ker = data
    a = core.fit_rff(x, ker, 5, n_features=256, seed=0)
    c = core.fit_rff(x, ker.with_precision("bf16"), 5, n_features=256,
                     seed=0)
    za, zc = a.transform(x[:100]), c.transform(x[:100])
    assert np.abs(za - zc).max() < 5e-2 * max(np.abs(za).max(), 1e-6)


# ------------------------------------------------------------ rff math


def test_rff_gram_approximates_kernel(data):
    x, ker = data
    q = x[:32]
    omega, phase = sample_rff(ker, q.shape[1], 4096, seed=0)
    feat = np.sqrt(2.0 / 4096) * np.cos(q @ omega.T + phase[None, :])
    from repro.core.kernels_math import gram_matrix
    k_true = np.asarray(gram_matrix(ker.with_backend("dense"), q, q))
    assert np.abs(feat @ feat.T - k_true).max() < 0.08


def test_rff_laplacian_spectral_measure(data):
    x, _ = data
    ker = laplacian(2.0)
    mdl = core.fit_rff(x, ker, 4, n_features=512, seed=1)
    z = mdl.transform(x[:50])
    assert z.shape == (50, 4) and np.isfinite(z).all()
    q = x[:24]
    omega, phase = sample_rff(ker, q.shape[1], 8192, seed=0)
    feat = np.sqrt(2.0 / 8192) * np.cos(q @ omega.T + phase[None, :])
    from repro.core.kernels_math import gram_matrix
    k_true = np.asarray(gram_matrix(ker.with_backend("dense"), q, q))
    # Cauchy spectral draws are heavy-tailed: looser tolerance than Gaussian
    assert np.abs(feat @ feat.T - k_true).max() < 0.2


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=50))
def test_rff_eigenvalues_converge_with_features(seed):
    """Property: the RFF eigenvalue error vs exact KPCA shrinks (weakly) as
    D grows — D=2048 must not be worse than D=128 beyond noise."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(256, 8)).astype(np.float32)
    ker = gaussian(2.0)
    lam_ref = core.fit_kpca(x, ker, 4).eigvals
    errs = {}
    for nfeat in (128, 2048):
        lam = core.fit_rff(x, ker, 4, n_features=nfeat, seed=seed).eigvals
        errs[nfeat] = float(np.linalg.norm(lam - lam_ref))
    tol = 0.1 * float(np.linalg.norm(lam_ref))
    assert errs[2048] <= errs[128] + tol, (errs, seed)


# ------------------------------------------------------- sharded parity


def test_sharded_equivalence_per_method(data):
    x, ker = data
    mesh = data_mesh(1)
    for fitter in (
        lambda **kw: core.fit_nystrom(x, ker, 5, 96, seed=2, **kw),
        lambda **kw: core.fit_weighted_nystrom(x, ker, 5, 64, seed=1, **kw),
        lambda **kw: core.fit_rff(x, ker, 5, n_features=256, seed=0, **kw),
    ):
        a, b = fitter(), fitter(mesh=mesh)
        np.testing.assert_allclose(b.eigvals, a.eigvals, rtol=1e-4)
        np.testing.assert_allclose(np.abs(b.projector),
                                   np.abs(a.projector), atol=1e-4)


def test_sharded_rff_transform_matches(data):
    x, ker = data
    mesh = data_mesh(1)
    mdl = core.fit_rff(x, ker, 5, n_features=256, seed=0)
    np.testing.assert_allclose(mdl.transform(x[:200], mesh=mesh),
                               mdl.transform(x[:200]), atol=1e-5)


# ------------------------------------------------------ streaming fits


def test_nystrom_stream_equals_resident(data):
    x, ker = data
    a = core.fit_nystrom(x, ker, 5, 96, seed=2)
    b, stats = core.fit_nystrom_stream(_chunks(x), ker, 5, 96, seed=2)
    # same jax.random landmark draw over the same n -> identical fit
    np.testing.assert_allclose(b.projector, a.projector, atol=1e-6)
    assert stats.rows == len(x) and stats.m == 96


def test_rff_stream_equals_resident(data):
    x, ker = data
    a = core.fit_rff(x, ker, 5, n_features=256, seed=0, chunk=256)
    b, stats = core.fit_rff_stream(_chunks(x), ker, 5, n_features=256,
                                   seed=0)
    np.testing.assert_allclose(b.projector, a.projector, atol=1e-5)
    assert stats.rows == len(x) and stats.m == 256


def test_kmeans_rsde_stream_weights_sum_to_n(data):
    x, ker = data
    rsde, stats = core.kmeans_rsde_stream(_chunks(x), ker, 48, seed=0)
    assert rsde.centers.shape == (48, x.shape[1])
    assert rsde.weights.sum() == pytest.approx(len(x))
    assert rsde.n == len(x) == stats.rows
    assert np.isfinite(rsde.centers).all()


def test_fit_stream_front_door_all_methods(data):
    x, ker = data
    for method, kw in (("nystrom", dict(m=96)), ("wnystrom", dict(m=48)),
                       ("rff", dict(m=128)), ("shadow", dict(ell=4.0))):
        mdl, stats = core.fit_stream(_chunks(x), ker, 5, method=method, **kw)
        assert stats.rows == len(x)
        z = mdl.transform(x[:32])
        assert z.shape == (32, 5) and np.isfinite(z).all()
    with pytest.raises(ValueError):
        core.fit_stream(_chunks(x), ker, 5, method="nope")


# ------------------------------------------------- dispatch + selector


def test_fit_front_door_dispatch(data):
    x, ker = data
    for method, kw, mcls in (
        ("nystrom", dict(m=96), core.KPCAModel),
        ("wnystrom", dict(m=48), core.KPCAModel),
        ("rff", dict(m=128), core.RFFKPCAModel),
    ):
        mdl = core.fit(x, ker, 5, method=method, **kw)
        assert mdl.method == method and isinstance(mdl, mcls)
        assert mdl.projector.shape[1] == 5


def test_fit_auto_uses_measured_rows(data, tmp_path, monkeypatch):
    x, ker = data
    rows = [
        dict(mode="methods", n=600, method="rff", fit_s=0.1, knn_acc=0.95,
             model_bytes=1000),
        dict(mode="methods", n=600, method="nystrom", fit_s=1.0,
             knn_acc=0.95, model_bytes=100000),   # dominated by rff
        dict(mode="methods", n=600, method="wnystrom", fit_s=0.5,
             knn_acc=0.99, model_bytes=2000),
    ]
    p = tmp_path / "bench.json"
    p.write_text(json.dumps({"rows": rows}))
    monkeypatch.setenv("REPRO_BENCH_JSON", str(p))
    assert core.select_method(600, 16, 5, objective="accuracy") == "wnystrom"
    assert core.select_method(600, 16, 5, objective="memory") == "rff"
    # the dominated method never wins under any objective
    for obj in ("balanced", "accuracy", "speed", "memory"):
        assert core.select_method(600, 16, 5, objective=obj) != "nystrom"
    mdl = core.fit(x, ker, 5, method="auto", m=64, objective="memory")
    assert mdl.method == "rff"


def test_select_method_heuristic_without_rows(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_JSON", str(tmp_path / "missing.json"))
    assert core.select_method(600, 16, 5) in core.METHODS
    assert core.select_method(600, 16, 5, objective="memory") == "rff"
    with pytest.raises(ValueError):
        core.select_method(600, 16, 5, objective="nope")


def test_methods_registry_cost_models():
    assert set(core.METHODS) == {"shadow", "nystrom", "wnystrom", "rff"}
    for spec in core.METHODS.values():
        assert spec.train and spec.test and spec.space


# ----------------------------------------------------- determinism + structure


def test_landmarks_deterministic_across_calls(data):
    x, ker = data
    a = core.fit_nystrom(x, ker, 5, 64, seed=7)
    b = core.fit_nystrom(x, ker, 5, 64, seed=7)
    np.testing.assert_array_equal(a.projector, b.projector)
    c = core.fit_nystrom(x, ker, 5, 64, seed=8)
    assert np.abs(a.projector - c.projector).max() > 0


def test_matfree_landmark_eigensolve_no_mxm_buffer(data):
    """PR-5 style structural check: the matrix-free landmark eigensolve
    lowers with no m x m tensor in the HLO."""
    import jax.numpy as jnp
    x, ker = data
    # m must dodge the Pallas tile extents (512/128): a (512, 512) VMEM
    # tile is legal and would false-positive the string match
    m = 768
    lowered = _landmark_eigs_matfree.lower(
        jnp.concatenate([jnp.asarray(x), jnp.asarray(x[:168])]), ker, 5)
    assert f"{m}x{m}" not in lowered.as_text()
