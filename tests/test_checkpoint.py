"""Checkpoint store: roundtrip, atomic publish, async, elastic restore."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step, AsyncCheckpointer)


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones(3, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_checkpoint(d, 7, tree)
    assert latest_step(d) == 7
    restored, step = restore_checkpoint(d, jax.eval_shape(lambda: tree))
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_points_to_newest_and_resume_picks_it(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_checkpoint(d, 5, tree)
    tree2 = jax.tree.map(lambda x: x + 1, tree)
    save_checkpoint(d, 10, tree2)
    restored, step = restore_checkpoint(d, jax.eval_shape(lambda: tree))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree2["params"]["w"]))


def test_no_torn_checkpoint_on_partial_write(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    # simulate a crashed half-written step dir: tmp dir left behind
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert latest_step(d) == 1  # LATEST still points at the published one


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ck = AsyncCheckpointer(d)
    ck.save(3, _tree())
    ck.wait()
    assert latest_step(d) == 3


def test_elastic_restore_to_different_device_count(tmp_path):
    """Save on 4 host devices, restore on 2 — the elastic-restart path."""
    d = str(tmp_path)
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=@N@"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.compat import make_mesh
mesh = make_mesh((@N@,), ("data",))
sh = NamedSharding(mesh, P("data", None))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh)
if @SAVE@:
    save_checkpoint(@DIR@, 1, {"w": w})
else:
    spec = jax.eval_shape(lambda: jnp.zeros((8, 8)))
    tree, step = restore_checkpoint(@DIR@, {"w": spec},
                                    shardings={"w": sh})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.arange(64.0).reshape(8, 8))
    assert len(tree["w"].addressable_shards) == @N@
print("OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    for n, save in ((4, 1), (2, 0)):
        code = (script.replace("@N@", str(n)).replace("@SAVE@", str(save))
                .replace("@DIR@", repr(d)))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True)
        assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]


def test_available_steps_skips_tmp_and_orders(tmp_path):
    from repro.checkpoint.store import available_steps
    d = str(tmp_path)
    assert available_steps(d) == []
    save_checkpoint(d, 4, _tree())
    save_checkpoint(d, 2, _tree())
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert available_steps(d) == [2, 4]


def test_crc_catches_corruption_and_fallback_restores(tmp_path):
    import pytest
    from repro.checkpoint.store import CheckpointCorrupt, available_steps
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    save_checkpoint(d, 2, _tree())
    shard = os.path.join(d, "step_00000002", "shard_0.npz")
    raw = bytearray(open(shard, "rb").read())
    raw[len(raw) // 3] ^= 0x40
    open(shard, "wb").write(bytes(raw))
    with pytest.raises(CheckpointCorrupt):
        restore_checkpoint(d, jax.eval_shape(lambda: _tree()))
    # the resumer contract: walk available_steps newest-first past the rot
    steps = [s for s in available_steps(d)]
    restored, step = restore_checkpoint(d, jax.eval_shape(lambda: _tree()),
                                        step=steps[-2])
    assert step == 1


def test_chaos_corrupt_site_is_caught_on_restore(tmp_path):
    import pytest
    from repro.checkpoint.store import CheckpointCorrupt
    from repro.runtime import chaos
    from repro.runtime.chaos import FaultPlan, FaultSpec
    d = str(tmp_path)
    with chaos.active(FaultPlan({"checkpoint.shard":
                                 FaultSpec(kind="corrupt", every=1)})):
        save_checkpoint(d, 3, _tree())
    with pytest.raises(CheckpointCorrupt):
        restore_checkpoint(d, jax.eval_shape(lambda: _tree()))


def test_numpy_template_restores_numpy_with_f64_intact(tmp_path):
    """The ingest-state contract: a float64 leaf saved and restored against
    a NUMPY template keeps float64 (jnp.asarray would silently round to f32
    with x64 off)."""
    d = str(tmp_path)
    tree = {"w": np.array([1.0, 2.0 + 2**-40], np.float64),
            "c": np.arange(6, dtype=np.float32).reshape(3, 2)}
    save_checkpoint(d, 1, tree)
    restored, _ = restore_checkpoint(
        d, {"w": np.zeros((0,), np.float64), "c": np.zeros((0, 2),
                                                           np.float32)})
    assert isinstance(restored["w"], np.ndarray)
    assert restored["w"].dtype == np.float64
    np.testing.assert_array_equal(restored["w"], tree["w"])  # bit-exact


def test_save_racing_interpreter_exit_publishes_atomically(tmp_path):
    """Satellite (b): an async save STILL in flight when the interpreter
    exits must complete its atomic publish (the atexit hook joins it before
    daemon threads are reaped) — never a step_<N>.tmp as the final state."""
    d = str(tmp_path)
    script = f"""
import numpy as np
from repro.checkpoint import AsyncCheckpointer
ck = AsyncCheckpointer({str(d)!r})
ck.save(5, {{"w": np.arange(4096.0)}})
# exit IMMEDIATELY: no wait(), the save races interpreter teardown
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert latest_step(d) == 5
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
    restored, _ = restore_checkpoint(d, {"w": np.zeros((0,))})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(4096.0))
