"""Checkpoint store: roundtrip, atomic publish, async, elastic restore."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step, AsyncCheckpointer)


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones(3, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_checkpoint(d, 7, tree)
    assert latest_step(d) == 7
    restored, step = restore_checkpoint(d, jax.eval_shape(lambda: tree))
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_points_to_newest_and_resume_picks_it(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_checkpoint(d, 5, tree)
    tree2 = jax.tree.map(lambda x: x + 1, tree)
    save_checkpoint(d, 10, tree2)
    restored, step = restore_checkpoint(d, jax.eval_shape(lambda: tree))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree2["params"]["w"]))


def test_no_torn_checkpoint_on_partial_write(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    # simulate a crashed half-written step dir: tmp dir left behind
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert latest_step(d) == 1  # LATEST still points at the published one


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ck = AsyncCheckpointer(d)
    ck.save(3, _tree())
    ck.wait()
    assert latest_step(d) == 3


def test_elastic_restore_to_different_device_count(tmp_path):
    """Save on 4 host devices, restore on 2 — the elastic-restart path."""
    d = str(tmp_path)
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=@N@"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.compat import make_mesh
mesh = make_mesh((@N@,), ("data",))
sh = NamedSharding(mesh, P("data", None))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh)
if @SAVE@:
    save_checkpoint(@DIR@, 1, {"w": w})
else:
    spec = jax.eval_shape(lambda: jnp.zeros((8, 8)))
    tree, step = restore_checkpoint(@DIR@, {"w": spec},
                                    shardings={"w": sh})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.arange(64.0).reshape(8, 8))
    assert len(tree["w"].addressable_shards) == @N@
print("OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    for n, save in ((4, 1), (2, 0)):
        code = (script.replace("@N@", str(n)).replace("@SAVE@", str(save))
                .replace("@DIR@", repr(d)))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True)
        assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
