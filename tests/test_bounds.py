"""Property tests for the paper's §5 theorems (hypothesis-driven).

Every bound must hold for ANY dataset, kernel in {gaussian, laplacian},
and ell — this is the strongest validation of the reproduction's math.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import gaussian, laplacian, shadow_select_host
from repro.core import mmd as M


def _data(n, d, seed, spread):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 1, (max(2, n // 10), d))
    idx = rng.integers(0, centers.shape[0], n)
    return (centers[idx] + spread * rng.normal(size=(n, d))).astype(np.float32)


KERNELS = [lambda s: gaussian(s), lambda s: laplacian(s)]


@settings(max_examples=20, deadline=None)
@given(n=st.integers(30, 150), d=st.integers(1, 10),
       ell=st.floats(2.0, 6.0), seed=st.integers(0, 10**6),
       kern=st.integers(0, 1), sigma=st.floats(0.2, 3.0))
def test_thm51_mmd_bound(n, d, ell, seed, kern, sigma):
    x = _data(n, d, seed, 0.1)
    ker = KERNELS[kern](sigma)
    c, w, a, m = shadow_select_host(x, ker.epsilon(ell))
    xq = M.quantized_dataset(x, c, a)
    val = M.mmd_biased(ker, x, xq)
    assert val <= ker.mmd_bound(ell) + 1e-5
    # weighted form computes the same quantity without materializing C-tilde
    assert abs(val - M.mmd_weighted(ker, x, c, w)) < 1e-3


@settings(max_examples=15, deadline=None)
@given(n=st.integers(30, 120), d=st.integers(1, 8),
       ell=st.floats(2.0, 6.0), seed=st.integers(0, 10**6),
       kern=st.integers(0, 1), sigma=st.floats(0.3, 2.0))
def test_thm52_eigenvalue_bound(n, d, ell, seed, kern, sigma):
    x = _data(n, d, seed, 0.08)
    ker = KERNELS[kern](sigma)
    c, w, a, m = shadow_select_host(x, ker.epsilon(ell))
    xq = M.quantized_dataset(x, c, a)
    gap = M.eigenvalue_gap_sq(ker, x, xq)
    assert gap <= ker.eigenvalue_bound(ell) + 1e-6


@settings(max_examples=15, deadline=None)
@given(n=st.integers(30, 100), d=st.integers(1, 8),
       ell=st.floats(2.0, 6.0), seed=st.integers(0, 10**6),
       kern=st.integers(0, 1), sigma=st.floats(0.3, 2.0))
def test_thm53_hs_operator_bound(n, d, ell, seed, kern, sigma):
    x = _data(n, d, seed, 0.08)
    ker = KERNELS[kern](sigma)
    c, w, a, m = shadow_select_host(x, ker.epsilon(ell))
    xq = M.quantized_dataset(x, c, a)
    hs = M.hs_operator_distance(ker, x, xq)
    assert hs <= ker.hs_bound(ell) + 1e-5
    # tighter intermediate: HS distance <= 2 kappa max_i ||eps_i||
    assert hs <= 2.0 * ker.kappa * M.centroid_error_max(ker, x, xq) + 1e-5


def test_thm54_eigenspace_projection_bound():
    # deterministic check (the Cholesky-based projector distance is O(n^3))
    x = _data(80, 5, 1, 0.08)
    for kern in KERNELS:
        ker = kern(1.0)
        for ell in (3.0, 4.0, 5.0):
            c, w, a, m = shadow_select_host(x, ker.epsilon(ell))
            xq = M.quantized_dataset(x, c, a)
            import jax.numpy as jnp
            from repro.core.kernels_math import gram_matrix
            lam = np.linalg.eigvalsh(
                np.asarray(gram_matrix(ker, jnp.asarray(x))) / len(x))[::-1]
            rank = 3
            delta = 0.5 * (lam[rank - 1] - lam[rank])
            eps_max = M.centroid_error_max(ker, x, xq)
            if 2 * np.sqrt(ker.kappa) * eps_max >= delta / 2 or delta <= 1e-9:
                continue  # theorem precondition not met
            dist = M.eigenspace_projection_distance(ker, x, xq, rank)
            bound = 2 * np.sqrt(
                2 * ker.kappa * (ker.kappa - np.exp(-1.0 / ell**ker.p))
            ) / delta
            assert dist <= bound + 1e-4


@settings(max_examples=25, deadline=None)
@given(m=st.integers(3, 40), d=st.integers(1, 6), seed=st.integers(0, 10**6),
       kern=st.integers(0, 1), sigma=st.floats(0.3, 2.0),
       kind=st.integers(0, 2), j=st.integers(0, 10**6))
def test_online_weight_update_bound(m, d, seed, kern, sigma, kind, j):
    """The closed-form rank-two bound behind every streaming update
    (core.mmd.weight_update_bound) must dominate the TRUE Frobenius change
    of the normalized weighted operator for absorb/insert/remove."""
    import jax.numpy as jnp
    from repro.core.kernels_math import gram_matrix

    rng = np.random.default_rng(seed)
    c = rng.normal(size=(m, d)).astype(np.float32)
    w = rng.integers(1, 10, size=m).astype(np.float64)
    j = j % m
    if kind == 1:  # insert: model the new center as a live slot of weight 0
        w[j] = 0.0
    n = w.sum()
    if kind == 2 and n <= w[j]:  # removing the only mass: undefined, skip
        return
    k = np.asarray(gram_matrix(KERNELS[kern](sigma), jnp.asarray(c)),
                   np.float64)
    w2 = w.copy()
    if kind == 0:    # absorb one sample into center j
        w2[j] += 1.0
    elif kind == 1:  # insert a fresh unit-mass center
        w2[j] = 1.0
    else:            # remove center j outright
        w2[j] = 0.0
    n2 = w2.sum()
    kt = np.sqrt(w)[:, None] * k * np.sqrt(w)[None, :] / n
    kt2 = np.sqrt(w2)[:, None] * k * np.sqrt(w2)[None, :] / n2
    true = np.linalg.norm(kt2 - kt)
    bound = float(M.weight_update_bound(n, n2, w[j], w2[j],
                                        kappa=KERNELS[kern](sigma).kappa))
    assert true <= bound + 1e-6, (true, bound, kind)


def test_bounds_tighten_with_ell():
    ker = gaussian(1.0)
    bounds = [ker.mmd_bound(ell) for ell in (2.0, 3.0, 4.0, 6.0, 10.0)]
    assert all(b1 > b2 for b1, b2 in zip(bounds, bounds[1:]))
    assert ker.mmd_bound(1e6) < 1e-2  # vanishes as the cover refines
