import os

# Tests run single-device CPU (the dry-run sets its own 512-device flag in a
# subprocess; per the brief we do NOT set xla_force_host_platform_device_count
# globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
