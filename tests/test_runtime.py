"""Fault-tolerance runtime: watchdog, preemption, elastic plan."""
import time

from repro.runtime import PreemptionGuard, StepWatchdog, ElasticPlan


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(window=16, threshold=3.0)
    for step in range(10):
        wd.start()
        time.sleep(0.002)
        wd.stop(step)
    wd.start()
    time.sleep(0.05)  # 25x median — a straggler step
    wd.stop(10)
    assert wd.flags and wd.flags[-1][0] == 10


def test_preemption_guard_cooperative_stop():
    g = PreemptionGuard(signals=())
    assert not g.should_stop
    g.request_stop()
    assert g.should_stop


def test_elastic_plan_preserves_global_batch():
    plan = ElasticPlan(old_devices=16, new_devices=8)
    assert plan.microbatch_factor(4) == 8   # half the devices -> 2x accum
    plan_up = ElasticPlan(old_devices=8, new_devices=16)
    assert plan_up.microbatch_factor(4) == 2


def test_elastic_plan_scale_policy():
    plan = ElasticPlan(old_devices=16, new_devices=8,
                       batch_policy="scale_with_devices")
    assert plan.microbatch_factor(4) == 4  # accum unchanged; batch shrinks
