"""Fault-tolerance runtime: watchdog, preemption, elastic plan."""
import time

from repro.runtime import PreemptionGuard, StepWatchdog, ElasticPlan


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(window=16, threshold=3.0)
    for step in range(10):
        wd.start()
        time.sleep(0.002)
        wd.stop(step)
    wd.start()
    time.sleep(0.05)  # 25x median — a straggler step
    wd.stop(10)
    assert wd.flags and wd.flags[-1][0] == 10


def test_preemption_guard_cooperative_stop():
    g = PreemptionGuard(signals=())
    assert not g.should_stop
    g.request_stop()
    assert g.should_stop


def test_elastic_plan_preserves_global_batch():
    plan = ElasticPlan(old_devices=16, new_devices=8)
    assert plan.microbatch_factor(4) == 8   # half the devices -> 2x accum
    plan_up = ElasticPlan(old_devices=8, new_devices=16)
    assert plan_up.microbatch_factor(4) == 2


def test_elastic_plan_scale_policy():
    plan = ElasticPlan(old_devices=16, new_devices=8,
                       batch_policy="scale_with_devices")
    assert plan.microbatch_factor(4) == 4  # accum unchanged; batch shrinks


def test_preemption_guard_uninstall_restores_handlers():
    """Satellite fix for the handler leak: a guard restores EXACTLY the
    handlers it displaced, and nested guards restore LIFO."""
    import signal

    prev = signal.getsignal(signal.SIGTERM)
    with PreemptionGuard() as outer:
        h_outer = signal.getsignal(signal.SIGTERM)
        assert h_outer == outer._handler
        with PreemptionGuard() as inner:
            assert signal.getsignal(signal.SIGTERM) == inner._handler
        # inner gone: the OUTER guard's handler is back, not the original
        assert signal.getsignal(signal.SIGTERM) == h_outer
    assert signal.getsignal(signal.SIGTERM) == prev


def test_preemption_guard_uninstall_is_idempotent_and_keeps_flag():
    import signal

    prev = signal.getsignal(signal.SIGTERM)
    g = PreemptionGuard()
    g.request_stop()
    g.uninstall()
    g.uninstall()  # idempotent
    assert signal.getsignal(signal.SIGTERM) == prev
    assert g.should_stop  # uninstalling never un-rings the bell


def test_retry_policy_backoff_is_deterministic_and_bounded():
    from repro.runtime import RetryPolicy

    p = RetryPolicy(base_s=0.01, factor=2.0, max_s=0.05, jitter=0.5, seed=3)
    seq = [p.backoff_s(k, key="feed7") for k in range(8)]
    assert seq == [p.backoff_s(k, key="feed7") for k in range(8)]  # replay
    assert all(0.01 <= s <= 0.05 * 1.5 for s in seq)
    assert p.backoff_s(0, key="a") != p.backoff_s(0, key="b")  # de-synced
