"""Optimizer correctness: AdamW vs analytic reference, Adafactor memory."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import adamw_init, adamw_update, global_norm, \
    clip_by_global_norm
from repro.optim.adafactor import adafactor_init, adafactor_update, \
    _is_factored


def test_adamw_matches_reference_step():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    st = adamw_init(params)
    new, st2, m = adamw_update(grads, st, params, lr=0.01, b1=0.9, b2=0.999,
                               eps=1e-8, weight_decay=0.0,
                               max_grad_norm=None)
    # bias-corrected first step: update == lr * sign-ish g/sqrt(g^2)
    g = np.array([0.1, -0.2, 0.3])
    mu = 0.1 * g / (1 - 0.9)
    nu = 0.001 * g**2 / (1 - 0.999)
    want = np.array([1.0, -2.0, 3.0]) - 0.01 * mu / (np.sqrt(nu) + 1e-8)
    np.testing.assert_allclose(np.asarray(new["w"]), want, rtol=1e-5)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.ones(8) * 5.0}
    st = adamw_init(params)
    for i in range(300):
        grads = {"w": 2 * params["w"]}
        params, st, _ = adamw_update(grads, st, params, lr=0.05,
                                     weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clipping():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


def test_adafactor_memory_is_factored():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,)),
              "s": jnp.zeros((3, 16, 24))}
    st = adafactor_init(params)
    assert st.vr["w"].shape == (64,) and st.vc["w"].shape == (32,)
    assert st.vr["b"].shape == (64,)          # vectors keep full moment
    assert st.vr["s"].shape == (3, 16) and st.vc["s"].shape == (3, 24)
    # factored state is ~O(n+m) not O(nm)
    assert st.vr["w"].size + st.vc["w"].size < params["w"].size


def test_adafactor_converges_on_quadratic():
    params = {"w": jnp.ones((16, 8)) * 3.0}
    st = adafactor_init(params)
    for i in range(400):
        grads = {"w": 2 * params["w"]}
        params, st, _ = adafactor_update(grads, st, params, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adafactor_bf16_params():
    params = {"w": jnp.ones((16, 8), jnp.bfloat16)}
    st = adafactor_init(params)
    grads = {"w": jnp.ones((16, 8), jnp.bfloat16) * 0.5}
    new, st, _ = adafactor_update(grads, st, params, lr=0.01)
    assert new["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(new["w"], np.float32)).all()
