"""Data pipeline: stateless determinism (the restart contract) + sharding."""
import numpy as np

from repro.data.tokens import TokenPipeline
from repro.data import make_dataset, train_test_split


def test_stateless_determinism():
    pipe = TokenPipeline(vocab_size=512, seq_len=16, global_batch=8, seed=3)
    a = np.asarray(pipe.batch(7)["tokens"])
    b = np.asarray(pipe.batch(7)["tokens"])
    c = np.asarray(pipe.batch(8)["tokens"])
    assert (a == b).all()          # restartable: same step -> same batch
    assert not (a == c).all()      # different step -> different batch


def test_host_shards_partition_global_batch():
    pipe = TokenPipeline(vocab_size=128, seq_len=8, global_batch=8, seed=0)
    full = pipe.global_batch_np(5)
    parts = [pipe.host_shard(5, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_tokens_in_vocab_and_structured():
    pipe = TokenPipeline(vocab_size=64, seq_len=512, global_batch=2, seed=1)
    b = pipe.batch(0)
    toks = np.asarray(b["tokens"])
    assert toks.min() >= 0 and toks.max() < 64
    # markov structure: some mass concentrated (learnable signal)
    _, counts = np.unique(toks, return_counts=True)
    assert counts.max() > 2 * counts.mean()


def test_datasets_reproducible_and_split_disjoint():
    x1, y1, s1 = make_dataset("german", seed=4, n=200)
    x2, y2, s2 = make_dataset("german", seed=4, n=200)
    np.testing.assert_array_equal(x1, x2)
    assert s1 == s2
    xtr, ytr, xte, yte = train_test_split(x1, y1, seed=0)
    assert len(xtr) + len(xte) == 200
    # disjoint split (no row duplicated across train/test)
    joined = np.concatenate([xtr, xte])
    assert joined.shape[0] == 200
