"""Unified telemetry layer (DESIGN.md §16): span tracing, the metric
registry, spectral health gauges, the instrumented serving/streaming/ingest
paths, and the bench-row provenance stamp.

Every test that enables observability goes through the ``obs_on`` fixture,
which resets metric values and the trace ring on both sides — the layer is
process-global state, and leaking an enabled flag or a counter value into
an unrelated test would be exactly the kind of action at a distance the
off-by-default design exists to prevent."""
import json
import threading
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro import obs, streaming
from repro.core import gaussian, shadow_rsde
from repro.obs import metrics, trace
from repro.obs.spectral import SpectralHealth
from repro.serving import BatchingFrontEnd
from repro.streaming import updates
from repro.streaming.drift import DriftDetector
from repro.streaming.ingest import ingest

ELL = 1.6
SIGMA = 1.5
RANK = 4


@pytest.fixture
def obs_on():
    metrics.clear()
    trace.clear()
    obs.enable()
    yield
    obs.disable()
    metrics.clear()
    trace.clear()


def _blobs(n, d=6, seed=0, shift=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 4, (8, d))
    idx = rng.integers(0, 8, n)
    return (centers[idx] + 0.3 * rng.normal(size=(n, d))
            + shift).astype(np.float32)


def _state(precision="f32", budget=0.05, n=300, seed=0):
    x = _blobs(n, seed=seed)
    ker = gaussian(SIGMA, precision=precision)
    rsde = shadow_rsde(x, ker, ell=ELL)
    return x, ker, streaming.from_rsde(rsde, ker, RANK, ell=ELL,
                                       budget=budget)


# -------------------------------------------------------------------------
# disabled-mode contract
# -------------------------------------------------------------------------


def test_disabled_by_default_everything_is_noop():
    assert not obs.enabled()
    # span() hands out ONE shared null object — no allocation per site
    s1 = obs.span("x.y", a=1)
    s2 = obs.span("z.w")
    assert s1 is s2
    with s1 as sp:
        sp.set(found=3)
        assert sp.sync(123) == 123
    assert trace.events() == []
    c = metrics.counter("noop.c")
    g = metrics.gauge("noop.g")
    h = metrics.histogram("noop.h")
    c.inc()
    g.set(5.0)
    h.observe(1.0)
    assert c.value == 0 and g.value == 0.0 and h.count == 0


def test_enable_disable_roundtrip(obs_on):
    assert obs.enabled() and trace.enabled() and metrics.enabled()
    obs.disable()
    assert not (obs.enabled() or trace.enabled() or metrics.enabled())
    obs.enable()
    metrics.counter("rt.c").inc(3)
    assert metrics.counter("rt.c").value == 3


# -------------------------------------------------------------------------
# spans + exporters
# -------------------------------------------------------------------------


def test_span_nesting_depth_and_attrs(obs_on):
    with obs.span("outer.op", chunk=1):
        with obs.span("inner.op") as sp:
            sp.set(rows=7)
    evs = trace.events()
    assert [e["name"] for e in evs] == ["inner.op", "outer.op"]  # exit order
    inner, outer = evs
    assert inner["depth"] == 1 and outer["depth"] == 0
    assert inner["rows"] == 7 and outer["chunk"] == 1
    assert outer["dur_s"] >= inner["dur_s"] >= 0.0


def test_span_records_error_and_reraises(obs_on):
    with pytest.raises(ValueError, match="boom"):
        with obs.span("bad.op"):
            raise ValueError("boom")
    (ev,) = trace.events()
    assert ev["error"] == "ValueError"


def test_span_sync_blocks_device_work(obs_on):
    with obs.span("dev.op") as sp:
        z = sp.sync(jnp.arange(8) * 2)
    np.testing.assert_array_equal(np.asarray(z), np.arange(8) * 2)
    (ev,) = trace.events()
    assert ev["sync_s"] >= 0.0 and ev["dur_s"] >= ev["sync_s"]


def test_ring_bound_drops_oldest(obs_on):
    trace.set_ring(8)
    try:
        for k in range(20):
            with obs.span("ring.op", k=k):
                pass
        evs = trace.events()
        assert len(evs) == 8
        assert [e["k"] for e in evs] == list(range(12, 20))  # oldest gone
    finally:
        trace.set_ring(trace._DEFAULT_RING)


def test_chrome_and_jsonl_export(tmp_path, obs_on):
    def worker():
        with obs.span("thread.op"):
            pass

    with obs.span("main.op", rows=4):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    chrome = tmp_path / "trace.json"
    flat = tmp_path / "trace.jsonl"
    assert trace.export_chrome(str(chrome)) == 2
    assert trace.export_jsonl(str(flat)) == 2
    doc = json.loads(chrome.read_text())
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert by_name["main.op"]["ph"] == "X"
    assert by_name["main.op"]["args"]["rows"] == 4
    # one track per thread
    assert by_name["main.op"]["tid"] != by_name["thread.op"]["tid"]
    lines = [json.loads(ln) for ln in flat.read_text().splitlines()]
    assert {ln["name"] for ln in lines} == {"main.op", "thread.op"}


# -------------------------------------------------------------------------
# metric registry
# -------------------------------------------------------------------------


def test_registry_get_or_create_identity(obs_on):
    assert metrics.counter("id.c") is metrics.counter("id.c")
    assert metrics.counter("id.c", {"a": 1}) is not metrics.counter("id.c")
    # label ORDER does not split series
    assert metrics.gauge("id.g", {"a": 1, "b": 2}) \
        is metrics.gauge("id.g", {"b": 2, "a": 1})


def test_clear_keeps_handle_identity(obs_on):
    c = metrics.counter("keep.c")
    c.inc(5)
    metrics.clear()
    obs.enable()  # clear() drops hooks/values, not the enabled flag
    assert metrics.counter("keep.c") is c  # still registered
    assert c.value == 0
    c.inc(2)
    assert "keep_c 2" in metrics.dump()


def test_histogram_buckets_and_quantiles(obs_on):
    h = metrics.histogram("q.h", bounds=(1.0, 2.0, 4.0, 8.0))
    assert h.quantile(0.5) == 0.0  # empty
    for v in (0.5, 1.5, 1.5, 3.0, 3.0, 3.0, 5.0, 9.0):
        h.observe(v)
    assert h.count == 8 and h.sum == pytest.approx(26.5)
    assert h.counts == [1, 2, 3, 1, 1]  # (..1], (1..2], (2..4], (4..8], inf
    q50 = h.quantile(0.5)
    assert 2.0 < q50 <= 4.0  # rank 4 lands in the (2, 4] bucket
    assert h.quantile(0.99) >= q50
    assert h.quantile(1.0) == 8.0  # top finite bound caps the estimate


def test_prometheus_dump_shape(obs_on):
    metrics.counter("serve.req-total").inc(3)
    metrics.gauge("g.v", {"k": 2}).set(1.5)
    h = metrics.histogram("lat.ms", bounds=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    text = metrics.dump()
    assert "# TYPE serve_req_total counter" in text  # sanitized name
    assert "serve_req_total 3" in text
    assert 'g_v{k="2"} 1.5' in text
    assert 'lat_ms_bucket{le="1.0"} 1' in text
    assert 'lat_ms_bucket{le="10.0"} 2' in text  # cumulative
    assert 'lat_ms_bucket{le="+Inf"} 2' in text
    assert "lat_ms_count 2" in text
    assert 'lat_ms{quantile="0.5"}' in text


def test_snapshot_and_hooks(obs_on):
    calls = []

    def sampler():
        calls.append(1)
        metrics.gauge("hook.g").set(42.0)

    def broken():
        raise RuntimeError("sampler on fire")

    metrics.add_hook(sampler)
    metrics.add_hook(sampler)  # idempotent
    metrics.add_hook(broken)   # must not kill the scrape
    snap = metrics.snapshot()
    assert snap["hook_g"] == 42.0 and len(calls) == 1
    metrics.remove_hook(sampler)
    metrics.gauge("hook.g").set(0.0)
    metrics.snapshot()
    assert metrics.gauge("hook.g").value == 0.0  # sampler no longer runs


def test_reporter_periodic_dump(tmp_path, obs_on):
    metrics.counter("rep.c").inc()
    path = tmp_path / "metrics.txt"
    rep = metrics.start_reporter(str(path), interval_s=0.02)
    try:
        deadline = time.monotonic() + 2.0
        while not path.exists() and time.monotonic() < deadline:
            time.sleep(0.01)
    finally:
        rep.stop()
    assert "rep_c 1" in path.read_text()  # stop() always writes a final dump


def test_thread_safety_exact_counts(obs_on):
    c = metrics.counter("mt.c")
    h = metrics.histogram("mt.h")

    def work():
        for _ in range(1000):
            c.inc()
            h.observe(1.0)

    ts = [threading.Thread(target=work) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == 4000 and h.count == 4000


# -------------------------------------------------------------------------
# instrumented subsystems
# -------------------------------------------------------------------------


class _StubServer:
    def transform(self, x):
        x = np.asarray(x)
        return np.stack([x.sum(axis=1), np.zeros(x.shape[0])], 1)


def test_serve_frontend_metrics_and_spans(obs_on):
    fe = BatchingFrontEnd(_StubServer(), max_batch=64, autostart=False)
    futs = [fe.submit(np.ones((k, 3), np.float32)) for k in (1, 4, 2)]
    assert fe.step() == 7
    for f in futs:
        f.result(timeout=0)
    assert metrics.counter("serve.requests").value == 3
    assert metrics.counter("serve.rows").value == 7
    assert metrics.counter("serve.batches").value == 1
    assert metrics.gauge("serve.queue_depth").value == 0.0
    assert metrics.histogram("serve.coalesce_rows",
                             bounds=metrics.SIZE_BUCKETS).count == 1
    assert metrics.histogram("serve.deadline_slack_ms").count == 1
    # per-bucket series: 7 rows pad to the pow2 bucket 8
    assert metrics.histogram("serve.service_ms", {"bucket": 8}).count == 1
    assert metrics.gauge("serve.ewma_service_ms", {"bucket": 8}).value > 0.0
    names = [e["name"] for e in trace.events()]
    assert "serve.batch" in names


def test_serve_error_counter(obs_on):
    class Bad:
        def transform(self, x):
            raise RuntimeError("dead operator")

    fe = BatchingFrontEnd(Bad(), max_batch=8, autostart=False)
    f = fe.submit(np.ones((2, 3), np.float32))
    fe.step()
    with pytest.raises(RuntimeError, match="dead operator"):
        f.result(timeout=0)
    assert metrics.counter("serve.errors").value == 1


def test_serve_stats_snapshot_is_consistent_copy():
    fe = BatchingFrontEnd(_StubServer(), max_batch=64, autostart=False)
    fe.submit(np.ones((4, 3), np.float32))
    fe.step()
    snap = fe.snapshot()
    assert snap.batches == 1 and snap.rows == 4
    assert snap.ewma_service_s == fe.stats.ewma_service_s
    # the copy is detached: mutating it cannot corrupt the live stats
    snap.ewma_service_s[4] = 99.0
    snap.batches = 77
    assert fe.stats.batches == 1
    assert 99.0 not in fe.stats.ewma_service_s.values()


def test_swap_publish_metrics_and_age_gauge_resets(obs_on):
    _, _, st = _state()
    srv = streaming.HotSwapServer(st)  # publishes once in __init__
    assert metrics.counter("swap.publishes").value == 1
    age = metrics.gauge("swap.snapshot_age_s")
    assert age.value == 0.0
    time.sleep(0.01)
    srv.transform(np.zeros((4, 6), np.float32))
    assert metrics.counter("swap.transforms").value == 1
    served_age = age.value
    assert served_age > 0.0  # transform saw a snapshot published earlier
    # REGRESSION: a publish must reset the age gauge, not leave the last
    # served age dangling until the next transform happens to overwrite it
    srv.publish(st)
    assert metrics.counter("swap.publishes").value == 2
    assert age.value == 0.0
    assert metrics.histogram("swap.publish_ms").count == 2
    names = [e["name"] for e in trace.events()]
    assert names.count("swap.publish") == 2


def test_streaming_ingest_metrics(obs_on):
    _, _, st = _state(budget=0.05)
    xs = _blobs(64, seed=5, shift=0.5)
    st = ingest(st, xs, batch=32)
    assert metrics.counter("stream.batches").value == 2
    assert metrics.counter("stream.rows").value == 64
    ins = metrics.counter("stream.updates", {"kind": "insert"}).value
    absorbed = metrics.counter("stream.updates", {"kind": "absorb"}).value
    assert ins + absorbed == 64 and ins >= 0 and absorbed >= 0
    # every batch logged exactly one maintenance decision
    n_patch = metrics.counter("stream.maintenance",
                              {"decision": "patch"}).value
    n_resolve = metrics.counter("stream.maintenance",
                                {"decision": "resolve"}).value
    assert n_patch + n_resolve == 2
    assert metrics.gauge("stream.m").value == st.m
    assert 0.0 < metrics.gauge("stream.fill_fraction").value <= 1.0
    assert metrics.histogram("stream.ingest_batch_ms").count == 2
    names = [e["name"] for e in trace.events()]
    assert names.count("stream.ingest_batch") == 2


def test_update_kind_counters(obs_on):
    _, _, st = _state()
    st2 = updates.remove(st, 0)
    updates.replace(st2, 1, jnp.zeros((6,), jnp.float32))
    assert metrics.counter("stream.updates", {"kind": "remove"}).value == 1
    assert metrics.counter("stream.updates", {"kind": "replace"}).value == 1


def test_autotune_plan_cache_counters(obs_on):
    from repro.kernels import autotune

    key = "obstest|n256|m128"
    hits0 = metrics.counter("autotune.plan_hits").value
    miss0 = metrics.counter("autotune.plan_misses").value
    cands = {"a": lambda: None, "b": lambda: time.sleep(0.002)}
    w1 = autotune.best(key, cands, default="a")
    assert w1 == "a"  # the faster thunk wins
    assert metrics.counter("autotune.plan_misses").value == miss0 + 1
    w2 = autotune.best(key, cands, default="b")
    assert w2 == w1
    assert metrics.counter("autotune.plan_hits").value == hits0 + 1


# -------------------------------------------------------------------------
# spectral health
# -------------------------------------------------------------------------


def test_spectral_health_gauges(obs_on):
    _, ker, st = _state(budget=0.05)
    box = {"st": st}
    sh = SpectralHealth(get_state=lambda: box["st"])
    sh.observe()
    lam = np.asarray(st.eigvals)
    for k in range(min(RANK, 16)):
        assert metrics.gauge("spectral.eigval", {"k": k}).value \
            == pytest.approx(float(lam[k]))
    assert metrics.gauge("spectral.gap").value \
        == pytest.approx(float(lam[RANK - 1] - lam[RANK]))
    assert metrics.gauge("spectral.m").value == st.m
    assert metrics.gauge("spectral.budget_ratio").value == 0.0  # fresh solve
    # install(): a metrics scrape self-refreshes from the CURRENT state
    sh.install()
    try:
        box["st"] = updates.ingest_batch(
            st, jnp.asarray(_blobs(8, seed=7, shift=1.0)))
        snap = metrics.snapshot()
        assert snap["spectral_n"] == float(box["st"].n) != float(st.n)
    finally:
        sh.uninstall()


def test_spectral_health_disabled_noop():
    _, _, st = _state()
    SpectralHealth(get_state=lambda: st).observe()
    assert metrics.gauge("spectral.m").value == 0.0


def test_spectral_health_mmd_and_quant_headroom(obs_on):
    x, ker, st = _state(precision="int8", budget=0.05)
    srv = streaming.HotSwapServer(st)
    det = DriftDetector(ker, ELL, window=64)
    sh = SpectralHealth(get_state=lambda: st, server=srv, detector=det)
    sh.observe()
    # window not full yet: no MMD series
    assert metrics.gauge("spectral.mmd").value == 0.0
    det.push(x[:64])
    sh.observe()
    assert det.full
    assert metrics.gauge("spectral.mmd").value > 0.0
    assert metrics.gauge("spectral.mmd_ratio").value > 0.0
    # int8 tier published a quantized projector: bound + headroom present
    qmax = metrics.gauge("spectral.quant_bound_max").value
    assert qmax > 0.0
    assert metrics.gauge("spectral.budget_headroom").value \
        == pytest.approx(float(st.budget) - float(st.err_est) - qmax)


# -------------------------------------------------------------------------
# bench-row provenance (benchmarks/common.py)
# -------------------------------------------------------------------------


def test_merge_rows_stamps_fresh_rows_only():
    from benchmarks import common

    common.set_run_stamp(git_sha="abc1234", measured_at="2026-01-01T00:00")
    try:
        old = [{"mode": "fit", "n": 1, "git_sha": "old"},
               {"mode": "fit", "n": 2, "stale": True}]
        fresh = [{"mode": "fit", "n": 2, "fit_speedup": 1.5}]
        out = common.merge_rows(old, fresh)
        assert len(out) == 2
        kept = next(r for r in out if r["n"] == 1)
        new = next(r for r in out if r["n"] == 2)
        assert kept["git_sha"] == "old"  # untouched rows keep their stamp
        assert new["git_sha"] == "abc1234"
        assert new["measured_at"] == "2026-01-01T00:00"
        assert not new.get("stale")  # re-measured pair drops the stale row
    finally:
        common.set_run_stamp()


def test_merge_rows_without_stamp_adds_nothing():
    from benchmarks import common

    common.set_run_stamp()  # library replay: no ambient stamp
    out = common.merge_rows([], [{"mode": "fit", "n": 4}])
    assert out == [{"mode": "fit", "n": 4}]
    explicit = common.merge_rows([], [{"mode": "fit", "n": 4}],
                                 stamp={"git_sha": "zzz"})
    assert explicit[0]["git_sha"] == "zzz"


def test_make_stamp_shape():
    from benchmarks import common

    stamp = common.make_stamp()
    assert set(stamp) == {"git_sha", "measured_at"}
    assert stamp["git_sha"]  # short sha in a checkout, "unknown" outside
    assert "T" in stamp["measured_at"]


# -------------------------------------------------------------------------
# end-to-end acceptance: one enabled run, all three subsystems visible
# -------------------------------------------------------------------------


def test_end_to_end_trace_and_metrics(tmp_path, obs_on):
    from repro.core.ingest_pipeline import select_streaming

    # ingest: out-of-core selection over a 3-chunk stream
    x = _blobs(192, seed=3)
    chunks = [(x[s : s + 64], 64) for s in range(0, 192, 64)]
    select_streaming(iter(chunks), 0.4, block=32)

    # streaming: operator maintenance + hot-swap publish
    _, _, st = _state()
    srv = streaming.HotSwapServer(st)
    st = ingest(st, _blobs(32, seed=8, shift=0.3), batch=16, server=srv)

    # serving: batched dispatch through the published operator
    sh = SpectralHealth(get_state=lambda: st).install()
    try:
        with BatchingFrontEnd(srv, max_batch=64, autostart=False) as fe:
            futs = [fe.submit(_blobs(4, seed=20 + k)) for k in range(3)]
            fe.drain()
            for f in futs:
                assert f.result(timeout=0).shape == (4, RANK)
        text = metrics.dump()
    finally:
        sh.uninstall()

    chrome = tmp_path / "trace.json"
    assert trace.export_chrome(str(chrome)) > 0
    names = {e["name"] for e in json.loads(chrome.read_text())["traceEvents"]}
    # nested spans from ALL THREE subsystems in one trace
    assert {"ingest.select_chunk", "ingest.merge", "stream.ingest_batch",
            "swap.publish", "serve.batch"} <= names

    # the metrics dump carries spectral health AND per-bucket serving series
    assert "spectral_eigval" in text and 'k="0"' in text
    assert "spectral_err_est" in text
    assert 'serve_service_ms_bucket{bucket="16"' in text
    assert "ingest_overlap_fraction" in text
    assert "stream_m" in text
