"""Algorithm 2 (shadow selection): oracle equivalence + invariant properties."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis wheel
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (shadow_select_np, shadow_select_host,
                        shadow_select_blocked, shadow_select_streaming,
                        gaussian)
from repro.core.shadow import two_level_merge

import jax.numpy as jnp


def _data(n, d, seed):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 1, (max(2, n // 20), d))
    idx = rng.integers(0, centers.shape[0], n)
    return (centers[idx] + 0.05 * rng.normal(size=(n, d))).astype(np.float32)


def test_jax_matches_numpy_oracle():
    x = _data(500, 8, 0)
    for eps in (0.05, 0.1, 0.3, 1.0):
        c_np, w_np, a_np = shadow_select_np(x, eps)
        c_j, w_j, a_j, m = shadow_select_host(x, eps)
        assert m == len(c_np)
        np.testing.assert_allclose(c_j, c_np, atol=1e-6)
        np.testing.assert_allclose(w_j, w_np)
        assert (a_j == a_np).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(20, 300), d=st.integers(1, 16),
       eps=st.floats(0.01, 2.0), seed=st.integers(0, 10**6))
def test_shadow_invariants(n, d, eps, seed):
    x = _data(n, d, seed)
    c, w, a, m = shadow_select_host(x, eps)
    # partition: weights sum to n; every point assigned
    assert w.sum() == n
    assert (a >= 0).all() and (a < m).all()
    # coverage: every point strictly within eps of its center
    dist = np.linalg.norm(x - c[a], axis=1)
    assert (dist < eps + 1e-5).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(20, 200), d=st.integers(1, 8), seed=st.integers(0, 10**6))
def test_center_separation_and_monotonicity(n, d, seed):
    x = _data(n, d, seed)
    prev_m = None
    for eps in (0.05, 0.1, 0.2, 0.4, 0.8):
        c, w, a, m = shadow_select_host(x, eps)
        if m > 1:
            d2 = ((c[:, None, :] - c[None, :, :]) ** 2).sum(-1)
            np.fill_diagonal(d2, np.inf)
            assert np.sqrt(d2.min()) >= eps - 1e-5  # greedy separation
        if prev_m is not None:
            assert m <= prev_m  # m non-increasing in eps
        prev_m = m


def test_permutation_changes_centers_but_keeps_invariants():
    x = _data(300, 6, 3)
    eps = 0.15
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(x))
    c1, w1, _, m1 = shadow_select_host(x, eps)
    c2, w2, _, m2 = shadow_select_host(x[perm], eps)
    # order-dependent (paper Algorithm 2 takes the *first* element)...
    assert w1.sum() == w2.sum() == len(x)
    # ...but both are eps-covers with separated centers
    for c, m in ((c1, m1), (c2, m2)):
        d2 = ((c[:, None] - c[None]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        if m > 1:
            assert np.sqrt(d2.min()) >= eps - 1e-5


def test_two_level_merge_preserves_weight_and_cover():
    x = _data(400, 5, 7)
    eps = 0.2
    # simulate 4 shards
    shards = np.split(x, 4)
    cs, ws = [], []
    for s in shards:
        c, w, _, m = shadow_select_host(s, eps)
        cs.append(c)
        ws.append(w)
    all_c = jnp.asarray(np.concatenate(cs))
    all_w = jnp.asarray(np.concatenate(ws), jnp.float32)
    out_c, out_w, m = two_level_merge(all_c, all_w, jnp.float32(eps),
                                      max_centers=len(all_c))
    m = int(m)
    assert float(out_w[:m].sum()) == len(x)
    # 2-eps cover (DESIGN.md two-level bound)
    d = np.linalg.norm(x[:, None] - np.asarray(out_c[:m])[None], axis=2).min(1)
    assert (d < 2 * eps + 1e-5).all()


@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 300), d=st.integers(1, 16),
       eps=st.floats(0.01, 2.0), block=st.integers(1, 64),
       seed=st.integers(0, 10**6))
def test_blocked_matches_sequential_invariants(n, d, eps, block, seed):
    """Blocked selection must satisfy the SAME cover invariants as the
    sequential algorithm: strict eps-cover, weights partition n, centers
    pairwise >= eps apart (the center set itself may differ)."""
    x = _data(n, d, seed)
    c, w, a, m = shadow_select_blocked(x, eps, block=block)
    assert w.sum() == n
    assert (a >= 0).all() and (a < m).all()
    dist = np.linalg.norm(x - c[a], axis=1)
    assert (dist < eps + 1e-5).all()
    if m > 1:
        d2 = ((c[:, None] - c[None]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        assert np.sqrt(d2.min()) >= eps - 1e-5


def test_blocked_block1_matches_sequential_exactly():
    """With B=1 the blocked selector degenerates to Algorithm 2 verbatim."""
    x = _data(250, 5, 2)
    for eps in (0.1, 0.3, 0.8):
        c_s, w_s, a_s, m_s = shadow_select_host(x, eps)
        c_b, w_b, a_b, m_b = shadow_select_blocked(x, eps, block=1)
        assert m_b == m_s
        np.testing.assert_allclose(c_b, c_s, atol=1e-6)
        np.testing.assert_allclose(w_b, w_s)
        assert (a_b == a_s).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(50, 400), d=st.integers(1, 8),
       eps=st.floats(0.05, 1.0), seed=st.integers(0, 10**6))
def test_streaming_two_level_cover(n, d, eps, seed):
    """Streaming selection: weights partition n; 2*eps cover (two-level)."""
    x = _data(n, d, seed)
    c, w, a, m = shadow_select_streaming(x, eps, chunk=max(32, n // 3),
                                         block=32)
    assert abs(w.sum() - n) < 1e-3
    assert (a >= 0).all() and (a < m).all()
    dist = np.linalg.norm(x - c[a], axis=1)
    assert (dist < 2 * eps + 1e-5).all()


def test_streaming_ragged_final_block():
    """The last chunk can be far smaller than ``chunk`` (and smaller than
    ``block``); its centers must still merge into a valid 2*eps cover."""
    x = _data(101, 4, 5)  # chunk=32 -> chunks of 32,32,32,5
    for eps in (0.1, 0.25):
        c, w, a, m = shadow_select_streaming(x, eps, chunk=32, block=8)
        assert abs(w.sum() - 101) < 1e-3
        assert (a >= 0).all() and (a < m).all()
        dist = np.linalg.norm(x - c[a], axis=1)
        assert (dist < 2 * eps + 1e-5).all()


def test_two_level_merge_block_fully_absorbed():
    """A partition whose centers are ALL within eps of an earlier
    partition's centers must contribute zero surviving centers — only its
    weight mass."""
    x = _data(200, 4, 8)
    eps = 0.2
    c1, w1, _, m1 = shadow_select_host(x, eps)
    # second "shard" re-selects the SAME data: every candidate lies within
    # eps of (in fact on top of) a first-shard center
    all_c = jnp.asarray(np.concatenate([c1, c1]))
    all_w = jnp.asarray(np.concatenate([w1, w1]), jnp.float32)
    out_c, out_w, m = two_level_merge(all_c, all_w, jnp.float32(eps),
                                      max_centers=len(all_c))
    m = int(m)
    assert m == m1  # zero survivors from the absorbed block
    np.testing.assert_allclose(np.asarray(out_c[:m]), c1, atol=1e-6)
    assert abs(float(out_w[:m].sum()) - 2 * len(x)) < 1e-3  # mass conserved


def test_two_level_merge_unequal_weight_partitions():
    """Shards of very different sizes (so very different weight scales)
    must merge into a cover that conserves total mass exactly."""
    x = _data(330, 3, 12)
    eps = 0.25
    parts = [x[:10], x[10:50], x[50:]]  # 10 / 40 / 280 rows
    cs, ws = [], []
    for part in parts:
        c, w, _, _ = shadow_select_host(part, eps)
        cs.append(c)
        ws.append(w)
    all_c = jnp.asarray(np.concatenate(cs))
    all_w = jnp.asarray(np.concatenate(ws), jnp.float32)
    out_c, out_w, m = two_level_merge(all_c, all_w, jnp.float32(eps),
                                      max_centers=len(all_c))
    m = int(m)
    assert abs(float(out_w[:m].sum()) - len(x)) < 1e-3
    assert (np.asarray(out_w[:m]) > 0).all()
    d = np.linalg.norm(x[:, None] - np.asarray(out_c[:m])[None], axis=2).min(1)
    assert (d < 2 * eps + 1e-5).all()


def test_blocked_whole_block_absorbed_in_one_round():
    """eps larger than the data diameter: the first round's single keeper
    absorbs every row (no survivors for later rounds)."""
    rng = np.random.default_rng(0)
    x = (0.01 * rng.normal(size=(150, 3))).astype(np.float32)
    c, w, a, m = shadow_select_blocked(x, 10.0, block=64)
    assert m == 1 and w.sum() == 150 and (a == 0).all()


def test_blocked_weighted_masses_conserved():
    """The weighted variant (the streaming merge's level-2 selector): unit
    masses reduce to the unweighted selector bit-exactly; arbitrary masses
    keep the SAME centers/assignment and partition sum(masses)."""
    x = _data(300, 5, 21)
    masses = np.random.default_rng(0).integers(1, 9, 300).astype(np.float32)
    for eps in (0.1, 0.3):
        c_u, w_u, a_u, m_u = shadow_select_blocked(x, eps, block=32)
        c_1, w_1, a_1, m_1 = shadow_select_blocked(
            x, eps, block=32, weights=np.ones(300, np.float32))
        assert m_1 == m_u and (a_1 == a_u).all()
        np.testing.assert_array_equal(c_1, c_u)
        np.testing.assert_allclose(w_1, w_u)
        c_m, w_m, a_m, m_m = shadow_select_blocked(x, eps, block=32,
                                                   weights=masses)
        assert m_m == m_u and (a_m == a_u).all()
        np.testing.assert_array_equal(c_m, c_u)
        assert w_m.sum() == masses.sum()
        ref = np.zeros(m_m)
        np.add.at(ref, a_m, masses)  # mass really lands on the absorber
        np.testing.assert_allclose(w_m, ref)


def test_streaming_budget_caps_centers():
    """``budget`` makes m deterministic: over-budget candidates spill
    weight-exactly into the nearest retained center."""
    x = _data(500, 4, 13)
    c, w, a, m = shadow_select_streaming(x, 0.05, chunk=128, block=16,
                                         budget=32)
    assert m == 32 and c.shape[0] == 32
    assert w.sum() == 500.0  # exact (f64 mass bookkeeping)
    assert (a >= 0).all() and (a < 32).all()
    c2, w2, _, m2 = shadow_select_streaming(x, 0.05, chunk=128, block=16)
    assert m2 > 32  # the budget really was binding


def test_max_centers_overflow_guard():
    x = _data(100, 4, 11)
    c, w, a, m = (None,) * 4
    import jax
    from repro.core.shadow import shadow_select
    c, w, a, m = jax.jit(
        lambda x: shadow_select(x, 1e-9, max_centers=10))(jnp.asarray(x))
    assert int(m) == 10 and float(w.sum()) == 100  # absorbed remainder
