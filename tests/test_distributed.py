"""Distributed core (two-level shadow, blocked gram) + distribution layer
(sharding rules, lowering) on multi host-device meshes via subprocess."""
import os
import subprocess
import sys

import numpy as np

import jax

from repro.configs import get_config
from repro.launch import sharding as shd
from repro.models import api

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def _run_multidevice(code: str, n_dev: int = 8):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True)
    assert r.returncode == 0 and "OK" in r.stdout, \
        (r.stdout[-1000:], r.stderr[-3000:])


def test_two_level_shadow_and_blocked_gram_8dev():
    _run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import gaussian, shadow_rsde, gram_matrix
from repro.core.distributed import (distributed_shadow_rsde,
                                    blocked_gram_rows, distributed_assign)
from repro.core import mmd as M
from repro.data import make_dataset
x, y, sigma = make_dataset("pendigits", seed=1, n=1024)
ker = gaussian(sigma)
from repro.compat import make_mesh
mesh = make_mesh((8,), ("data",))
r1 = shadow_rsde(x, ker, 4.0)
r2 = distributed_shadow_rsde(x, ker, 4.0, mesh)
assert abs(r2.weights.sum() - 1024) < 1e-3
mmd2 = M.mmd_weighted(ker, x, r2.centers, r2.weights)
assert mmd2 <= ker.mmd_bound(2.0) + 1e-6   # ell/2 worst case (2-level)
assert mmd2 <= 2 * M.mmd_weighted(ker, x, r1.centers, r1.weights) + 0.05
g = blocked_gram_rows(x, r2.centers, ker, mesh)
g_ref = gram_matrix(ker, jnp.asarray(x), jnp.asarray(r2.centers))
np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)
a = distributed_assign(x, r2.centers, mesh)
d = np.linalg.norm(x - r2.centers[np.asarray(a)], axis=1)
assert (d < 2 * ker.epsilon(4.0) + 1e-4).all()
print("OK")
""")


def test_chunked_ingest_select_8dev():
    """Out-of-core sharded selection (core/ingest_pipeline.py): per-chunk
    rows shard over 8 devices, candidates merge weight-exactly on host —
    covering the uneven-last-shard and empty-local-shard regressions."""
    _run_multidevice("""
import numpy as np
from repro.compat import make_mesh
from repro.core.ingest_pipeline import pad_block, select_streaming

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
cent = rng.uniform(0, 1, (20, 5))
x = (cent[rng.integers(0, 20, 2000)]
     + 0.05 * rng.normal(size=(2000, 5))).astype(np.float32)
eps, chunk = 0.2, 512  # 2000 % 512 != 0: ragged final chunk

def chunks():
    for s in range(0, 2000, chunk):
        blk = x[s : s + chunk]
        yield pad_block(blk, chunk)[0], blk.shape[0]

rsde, stats = select_streaming(chunks(), eps, block=32, mesh=mesh)
assert stats.chunks == 4 and stats.rows == 2000
assert rsde.weights.sum() == 2000.0, rsde.weights.sum()  # weight-exact
d = np.linalg.norm(x[:, None] - rsde.centers[None], axis=2).min(1)
assert (d < 2 * eps + 1e-5).all()                        # 2*eps cover
# empty-local-shard regression: 100 valid rows of a 512-row chunk leave
# six of the eight devices with ZERO valid rows (zero survivors each)
rsde2, st2 = select_streaming(
    iter([(pad_block(x[:100], chunk)[0], 100)]), eps, block=32, mesh=mesh)
assert st2.rows == 100 and rsde2.weights.sum() == 100.0
print("OK")
""")


def test_train_step_runs_on_2x2_mesh():
    """Numerically execute one sharded train step (not just lower) on a
    (data=2, model=2) host mesh — validates the full distribution stack."""
    _run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import api
from repro.launch import steps, sharding as shd
from jax.sharding import NamedSharding, PartitionSpec as P
cfg = get_config("mixtral_8x7b", smoke=True)
from repro.compat import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
shape = api.ShapeSpec("t", 32, 4, "train")
params_spec = api.param_specs(cfg)
p_sh = shd.param_shardings(params_spec, mesh, cfg)
opt_spec = steps.opt_specs(cfg, params_spec)
o_sh = shd.opt_shardings(opt_spec, params_spec, mesh, cfg)
batch = {k: jnp.asarray(v) for k, v in api.make_host_batch(cfg, shape).items()}
b_sh = shd.batch_shardings(
    {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}, mesh)
with mesh:
    params = jax.jit(lambda k: api.init_params(k, cfg), out_shardings=p_sh)(
        jax.random.PRNGKey(0))
    opt = jax.jit(lambda p: steps.init_opt(cfg, p), out_shardings=o_sh)(params)
    fn = jax.jit(steps.make_train_step(cfg, mesh, accum=2),
                 in_shardings=(p_sh, o_sh, b_sh, NamedSharding(mesh, P())),
                 out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
    losses = []
    for s in range(3):
        params, opt, metrics = fn(params, opt, batch, jnp.int32(s))
        losses.append(float(metrics["loss"]))
assert all(np.isfinite(l) for l in losses), losses
assert losses[-1] < losses[0], losses  # same batch 3x must overfit
print("OK")
""", n_dev=4)


def test_decode_step_runs_on_2x2_mesh():
    _run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import api
from repro.launch import steps, sharding as shd
cfg = get_config("gemma2_9b", smoke=True)
from repro.compat import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
shape = api.ShapeSpec("d", 32, 4, "decode")
lowered, _ = steps.lower_decode(cfg, shape, mesh)
compiled = lowered.compile()
params = api.init_params(jax.random.PRNGKey(0), cfg)
cache = api.init_cache(cfg, 4, 32)
with mesh:
    logits, cache2 = jax.jit(
        steps.make_decode_step(cfg, mesh))(params, cache,
                                           jnp.zeros((4, 1), jnp.int32),
                                           jnp.int32(0))
assert np.isfinite(np.asarray(logits)).all()
print("OK")
""", n_dev=4)


def test_param_rules_cover_every_leaf():
    """Every parameter leaf of every arch must match a sharding rule (no
    accidental replication of big tensors)."""
    import jax
    mesh_like = type("M", (), {})()
    for arch in ["qwen2_72b", "mixtral_8x7b", "jamba_52b", "rwkv6_1b6",
                 "whisper_base", "kimi_k2"]:
        cfg = get_config(arch, smoke=True)
        spec = api.param_specs(cfg)
        flat, _ = jax.tree_util.tree_flatten_with_path(spec)
        for path, leaf in flat:
            ps = shd._path_str(path)
            matched = any(__import__("re").search(pat, ps)
                          for pat, _ in shd._PARAM_RULES)
            big = np.prod(leaf.shape) > 4096
            assert matched or not big, (arch, ps, leaf.shape)
