"""The measured compute-plan autotuner (repro.kernels.autotune)."""
import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import autotune, ops, ref


@pytest.fixture
def fresh_cache(tmp_path, monkeypatch):
    """Isolate both cache layers: empty disk file in tmp, empty memory."""
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    autotune.clear(in_memory_only=False)
    yield path
    autotune.clear(in_memory_only=False)


def test_bucket_pow2_ceiling():
    assert autotune.bucket(1) == 128          # lo clip
    assert autotune.bucket(128) == 128
    assert autotune.bucket(129) == 256
    assert autotune.bucket(1000) == 1024
    assert autotune.bucket(10**9) == 1 << 17  # hi clip
    assert autotune.bucket(24, lo=8) == 32


def test_best_measures_once_and_caches(fresh_cache):
    calls = {"a": 0, "b": 0}

    def mk(name, cost):
        def thunk():
            calls[name] += 1
            import time
            time.sleep(cost)
        return thunk

    cands = {"a": mk("a", 0.0), "b": mk("b", 0.01)}
    assert autotune.best("k1", cands, default="b") == "a"
    first_calls = dict(calls)
    assert first_calls["a"] >= 2 and first_calls["b"] >= 2  # warmup + reps
    # second request: served from memory, thunks untouched
    assert autotune.best("k1", cands, default="b") == "a"
    assert calls == first_calls


def test_best_persists_to_disk_and_reloads(fresh_cache):
    autotune.best("k2", {"fast": lambda: None,
                         "slow": lambda: __import__("time").sleep(0.01)},
                  default="slow")
    disk = json.load(open(fresh_cache))
    assert disk["schema"] == autotune._SCHEMA
    assert disk["plans"][autotune.qualified("k2")]["winner"] == "fast"
    # a fresh process (cleared memory) must reload the winner WITHOUT
    # measuring: candidates that raise would disqualify themselves
    autotune.clear(in_memory_only=False)

    def boom():
        raise AssertionError("re-measured despite disk cache")

    assert autotune.best("k2", {"fast": boom, "slow": boom},
                         default="slow") == "fast"


def test_keys_qualified_by_device_and_jax_version(fresh_cache):
    """Persisted plans must carry the device kind AND jax version, so a
    cache file copied across machines/upgrades can never be replayed."""
    import jax

    autotune.best("kq", {"a": lambda: None,
                         "b": lambda: __import__("time").sleep(0.005)},
                  default="b")
    (key,) = json.load(open(fresh_cache))["plans"].keys()
    assert jax.devices()[0].device_kind.replace(" ", "_") in key
    assert f"jax{jax.__version__}" in key


def test_old_schema_cache_invalidated(fresh_cache):
    """A pre-versioned (schema-1 flat dict) cache file must be ignored on
    load and overwritten on save — stale plans never replay."""
    stale_key = autotune.qualified("kold")
    with open(fresh_cache, "w") as f:
        json.dump({stale_key: {"winner": "slow"}}, f)  # schema-1 layout
    autotune.clear(in_memory_only=False)
    assert autotune.best("kold", {"fast": lambda: None,
                                  "slow": lambda: __import__("time")
                                  .sleep(0.01)},
                         default="slow") == "fast"  # re-measured, not replayed
    disk = json.load(open(fresh_cache))
    assert disk["schema"] == autotune._SCHEMA
    assert disk["plans"][stale_key]["winner"] == "fast"


def test_single_candidate_skips_measurement(fresh_cache):
    calls = []
    assert autotune.best("k3", {"only": lambda: calls.append(1)},
                         default="only") == "only"
    assert not calls


def test_failing_candidate_disqualified(fresh_cache):
    def boom():
        raise RuntimeError("no backend")

    assert autotune.best("k4", {"bad": boom, "ok": lambda: None},
                         default="bad") == "ok"


def test_measurement_disabled_uses_heuristic(fresh_cache, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert not autotune.measurement_enabled()
    # small problem, interpret mode -> dense; huge -> pallas
    assert autotune.heuristic_plan(100, 100, interpret=True) == "dense"
    assert autotune.heuristic_plan(10**5, 10**5, interpret=True) == "pallas"
    # ops must not record anything while disabled
    rng = np.random.default_rng(0)
    x = rng.normal(size=(100, 8)).astype(np.float32)
    ops.gram(x, x, sigma=1.0)
    assert not os.path.exists(fresh_cache)


def test_autotuned_gram_matches_ref(fresh_cache):
    """Whatever plan wins the measurement, the result is the same Gram."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(200, 16)).astype(np.float32)
    y = rng.normal(size=(90, 16)).astype(np.float32)
    got = np.asarray(ops.gram(x, y, sigma=1.7))
    want = np.asarray(ref.gram_ref(jnp.asarray(x), jnp.asarray(y), 1.7, 2))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    # and the measurement was recorded under a gram| key
    disk = json.load(open(fresh_cache))
    assert any(k.startswith("gram|") for k in disk["plans"])


def test_disk_cache_defaults_off_under_pytest(monkeypatch):
    """Without an explicit REPRO_AUTOTUNE_CACHE, a pytest process must
    neither read nor write the repo-root cache file (hermetic test runs);
    the in-process cache still works."""
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE", raising=False)
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert os.environ.get("PYTEST_CURRENT_TEST")  # pytest sets this
    assert not autotune._disk_enabled()
    autotune.clear(in_memory_only=False)
    key = "hermetic-probe-key"
    assert autotune.best(
        key, {"a": lambda: None,
              "b": lambda: __import__("time").sleep(0.005)},
        default="b") == "a"
    # memory has it, the repo-root disk file does not
    assert autotune._MEM[autotune.qualified(key)]["winner"] == "a"
    try:
        with open(autotune._cache_path()) as f:
            disk = json.load(f)
        assert autotune.qualified(key) not in disk.get("plans", disk)
    except OSError:
        pass  # no cache file at all: equally hermetic
    autotune.clear(in_memory_only=False)


def test_dense_candidate_capped_for_huge_problems(monkeypatch):
    """Beyond DENSE_MAX_CELLS the dense path must not even be a measurement
    candidate (its intermediates would not fit); the plan must come back
    pallas-tiled."""
    seen = {}

    def fake_best(key, candidates, default):
        seen[key] = set(candidates)
        return "pallas"

    monkeypatch.setattr(autotune, "best", fake_best)
    kind, blocks = ops._gram_plan(1 << 16, 1 << 14, 64, "f32",
                                  interpret=True)
    assert kind == "pallas" and blocks is not None
    (names,) = seen.values()
    assert "dense" not in names and "pallas" in names


def test_assign_plan_tag_namespaces_key(fresh_cache, monkeypatch):
    """The chunked-ingest assign path measures under its own ``|ingest``
    key: tagged and untagged requests at one shape must not share (or
    clobber) a cache entry."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    autotune.clear(in_memory_only=False)
    ops._assign_plan(256, 128, 8, True)
    ops._assign_plan(256, 128, 8, True, tag="ingest")
    keys = [k for k in autotune._MEM
            if k.startswith("assign|n256|m128|d8|interp")]
    assert len(keys) == 2
    assert sum("|ingest|" in k for k in keys) == 1
