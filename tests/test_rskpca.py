"""RSKPCA (Algorithm 1) correctness + baselines."""
import numpy as np
import pytest

from repro.core import (
    gaussian, laplacian, shadow_rsde, fit_rskpca, fit_kpca,
    fit_subsampled_kpca, fit_nystrom, fit_weighted_nystrom, fit,
    embedding_alignment_error, make_rsde,
    reduced_laplacian_eigenmaps, reduced_diffusion_maps,
)
from repro.data import make_dataset


@pytest.fixture(scope="module")
def data():
    x, y, sigma = make_dataset("german", seed=0, n=400)
    return x, y, sigma


def test_limit_equals_kpca(data):
    """ell -> inf: every point its own center, RSKPCA == KPCA exactly."""
    x, _, sigma = data
    x = x[:150]
    ker = gaussian(sigma)
    rsde = shadow_rsde(x, ker, ell=1e9)
    assert rsde.m == len(x) and (rsde.weights == 1).all()
    rs = fit_rskpca(rsde, ker, rank=5)
    kp = fit_kpca(x, ker, rank=5)
    np.testing.assert_allclose(rs.eigvals, kp.eigvals, rtol=1e-4)
    q = x[:40]
    err = embedding_alignment_error(kp.transform(q), rs.transform(q))
    assert err <= 1e-3 * np.linalg.norm(kp.transform(q))


def test_rskpca_approaches_kpca_as_ell_grows(data):
    x, _, sigma = data
    ker = gaussian(sigma)
    kp = fit_kpca(x, ker, rank=5)
    ref = kp.transform(x[:100])
    errs = []
    for ell in (2.0, 4.0, 8.0, 16.0):
        mdl = fit_rskpca(shadow_rsde(x, ker, ell), ker, rank=5)
        errs.append(embedding_alignment_error(ref, mdl.transform(x[:100])))
    assert errs[-1] < errs[0]  # error shrinks with finer cover
    assert errs[-1] < 0.1 * np.linalg.norm(ref)


def test_weights_matter_rskpca_beats_uniform(data):
    """Paper §6: subsampled KPCA performs worse than any weighted method."""
    x, _, sigma = data
    ker = gaussian(sigma)
    kp = fit_kpca(x, ker, rank=5)
    ref = kp.transform(x[:100])
    errs_sh, errs_un = [], []
    for seed in range(3):
        rsde = shadow_rsde(x, ker, 3.5)
        sh = fit_rskpca(rsde, ker, rank=5)
        un = fit_subsampled_kpca(x, ker, rank=5, m=rsde.m, seed=seed)
        errs_sh.append(embedding_alignment_error(ref, sh.transform(x[:100])))
        errs_un.append(embedding_alignment_error(ref, un.transform(x[:100])))
    assert np.mean(errs_sh) < np.mean(errs_un)


def test_nystrom_variants(data):
    x, _, sigma = data
    ker = gaussian(sigma)
    kp = fit_kpca(x, ker, rank=5)
    ref = kp.transform(x[:80])
    ny = fit_nystrom(x, ker, rank=5, m=80)
    wy = fit_weighted_nystrom(x, ker, rank=5, m=80)
    for mdl, max_rel in ((ny, 0.8), (wy, 0.8)):
        err = embedding_alignment_error(ref, mdl.transform(x[:80]))
        assert err < max_rel * np.linalg.norm(ref), mdl.method
    # storage asymmetry (paper Table 2): Nystrom keeps all n, RSKPCA keeps m
    assert ny.centers.shape[0] == len(x)
    assert wy.centers.shape[0] == 80


def test_front_door_and_schemes(data):
    x, _, sigma = data
    ker = gaussian(sigma)
    for method, kw in [("kpca", {}), ("shadow", dict(ell=4.0)),
                       ("uniform", dict(m=40)), ("kmeans", dict(m=40)),
                       ("paring", dict(m=40)), ("herding", dict(m=40))]:
        mdl = fit(x[:200], ker, 4, method=method, **kw)
        z = mdl.transform(x[:10])
        assert z.shape == (10, 4) and np.isfinite(z).all(), method


def test_backend_switch_parity(data):
    """fit(..., backend=...) must give numerically matching models, and the
    backend must propagate to the returned model's transform path."""
    x, _, sigma = data
    ker = gaussian(sigma)
    mp = fit(x, ker, 5, method="shadow", ell=3.0, backend="pallas")
    md = fit(x, ker, 5, method="shadow", ell=3.0, backend="dense")
    assert mp.kernel.backend == "pallas" and md.kernel.backend == "dense"
    np.testing.assert_allclose(mp.eigvals, md.eigvals, rtol=1e-4)
    q = x[:64]
    np.testing.assert_allclose(mp.transform(q), md.transform(q),
                               atol=1e-4, rtol=1e-3)


def test_selector_variants_fit_equivalently(data):
    """blocked / sequential / streaming / fused selectors all produce usable
    RSKPCA models with comparable embedding quality."""
    x, _, sigma = data
    ker = gaussian(sigma)
    ref = fit_kpca(x, ker, rank=4).transform(x[:100])
    errs = {}
    for sel in ("blocked", "sequential", "streaming", "fused"):
        mdl = fit(x, ker, 4, method="shadow", ell=6.0, selector=sel)
        errs[sel] = embedding_alignment_error(ref, mdl.transform(x[:100]))
    scale = np.linalg.norm(ref)
    assert all(e < 0.5 * scale for e in errs.values()), errs


def test_top_eigh_lobpcg_branch_matches_eigh():
    """The large-m LOBPCG path (unreachable from the small fixtures) must
    agree with exact eigh on a kernel-shaped spectrum."""
    import jax.numpy as jnp
    from repro.core.rskpca import _top_eigh, _LOBPCG_MIN_M

    m = _LOBPCG_MIN_M + 150
    rng = np.random.default_rng(0)
    q, _ = np.linalg.qr(rng.normal(size=(m, 40)))
    lam_true = 2.0 ** -np.arange(40)  # fast-decaying, like a kernel spectrum
    mat = jnp.asarray((q * lam_true) @ q.T, jnp.float32)
    lam, vec = _top_eigh(mat, 6)
    assert vec.shape == (m, 6)
    np.testing.assert_allclose(np.asarray(lam), lam_true[:6], rtol=5e-4)


def test_rank_exceeding_m_truncates_gracefully(data):
    """rank > m must truncate to m components on every eigensolver path
    (the CPU subset-eigh fast path regressed this once)."""
    x, _, sigma = data
    ker = gaussian(sigma)
    rsde = shadow_rsde(x[:60], ker, ell=1.5)  # coarse cover -> tiny m
    assert rsde.m < 10
    mdl = fit_rskpca(rsde, ker, rank=rsde.m + 4)
    assert mdl.rank == rsde.m
    assert np.isfinite(mdl.transform(x[:5])).all()


def test_laplacian_kernel_works(data):
    x, _, sigma = data
    ker = laplacian(sigma)
    mdl = fit(x[:200], ker, 4, method="shadow", ell=4.0)
    assert np.isfinite(mdl.transform(x[:10])).all()


def test_kmla_reduced_embeddings(data):
    x, _, sigma = data
    ker = gaussian(sigma)
    rsde = shadow_rsde(x[:300], ker, 4.0)
    le = reduced_laplacian_eigenmaps(rsde, ker, rank=3)
    dm = reduced_diffusion_maps(rsde, ker, rank=3)
    for mdl in (le, dm):
        assert mdl.embedding.shape == (rsde.m, 3)
        assert np.isfinite(mdl.embedding).all()
        assert (mdl.eigvals <= 1.0 + 1e-5).all()  # normalized operators
