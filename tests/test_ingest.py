"""Out-of-core ingestion pipeline (DESIGN.md §9): chunked-source
determinism, streaming-merge weight exactness, prefetch-feed behavior, and
select->fit equivalence against the in-memory paths."""
import time

import numpy as np
import pytest

from repro.core import gaussian
from repro.core.ingest_pipeline import (_PrefetchFeed, IngestStats,
                                        ingest_fit, pad_block,
                                        select_streaming)
from repro.core.pipeline import fit_shadow_fused
from repro.core.shadow import StreamingMerge, shadow_select_blocked
from repro.data.kpca_datasets import ChunkedDataset


def test_chunked_source_deterministic_across_chunk_sizes():
    """Row i depends only on (name, seed, i): chunk size and total n must
    not change a single shared row's bits."""
    a = ChunkedDataset("pendigits", n=9000, chunk=4096, seed=3).materialize()
    b = ChunkedDataset("pendigits", n=9000, chunk=1000, seed=3).materialize()
    c = ChunkedDataset("pendigits", n=9000, chunk=9000, seed=3).materialize()
    assert np.array_equal(a, b) and np.array_equal(a, c)
    # a LONGER stream agrees bit-exactly on the shared prefix
    big = ChunkedDataset("pendigits", n=50000, chunk=8192, seed=3)
    assert np.array_equal(a, big.rows(0, 9000))
    # different seeds genuinely differ
    d = ChunkedDataset("pendigits", n=9000, chunk=4096, seed=4).materialize()
    assert not np.array_equal(a, d)


def test_chunked_source_ragged_final_chunk():
    src = ChunkedDataset("pendigits", n=10000, chunk=4096, seed=0)
    chunks = list(src.chunks())
    assert [nv for _, nv in chunks] == [4096, 4096, 1808]
    for x, nv in chunks:
        assert x.shape == (4096, src.d) and x.dtype == np.float32
        assert (x[nv:] == 0).all()  # padding rows are zero (and masked)
    got = np.concatenate([x[:nv] for x, nv in chunks])
    assert np.array_equal(got, src.materialize())


def test_chunked_source_stream_matches_make_dataset_geometry():
    """Same mixture family: bandwidth of the stream's prefix sample is a
    sane, positive sigma (the ingest bench derives eps from it)."""
    src = ChunkedDataset("pendigits", n=4096, chunk=1024, seed=0)
    assert src.bandwidth() > 0
    assert src.nbytes_f32 == 4 * 4096 * 16
    with pytest.raises(AssertionError):
        ChunkedDataset("pendigits", n=1 << 23, chunk=1024).materialize()


def test_pad_block_contract():
    x = np.ones((5, 3), np.float32)
    xp, ok = pad_block(x, 8)
    assert xp.shape == (8, 3) and ok.sum() == 5 and (xp[5:] == 0).all()
    xf, okf = pad_block(x, 5)  # full block: no copy, mask all-true
    assert okf.all() and np.array_equal(xf, x)
    with pytest.raises(AssertionError):
        pad_block(x, 4)


def _chunks_of(x, chunk):
    """Bare-iterable source protocol: (fixed-shape block, n_valid)."""
    for s in range(0, len(x), chunk):
        yield pad_block(x[s : s + chunk], chunk)[0], min(chunk, len(x) - s)


def _mix(n, d=6, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 1, (max(2, n // 30), d))
    idx = rng.integers(0, centers.shape[0], n)
    return (centers[idx] + 0.05 * rng.normal(size=(n, d))).astype(np.float32)


def test_single_chunk_matches_blocked_exactly():
    """chunk >= n on one device: the stream is a single blocked selection
    and the merge must pass its centers/weights through UNCHANGED."""
    x = _mix(700)
    for eps in (0.1, 0.3):
        rsde, stats = select_streaming(_chunks_of(x, 1024), eps, block=64)
        c, w, _, m = shadow_select_blocked(x, eps, block=64)
        assert rsde.centers.shape[0] == m and stats.m == m
        np.testing.assert_array_equal(rsde.centers, c[:m])
        np.testing.assert_allclose(rsde.weights, w[:m])
        assert rsde.weights.sum() == len(x)  # exact, not approx


def test_multichunk_weight_exact_and_2eps_cover():
    x = _mix(1500, seed=5)
    eps = 0.2
    rsde, stats = select_streaming(_chunks_of(x, 256), eps, block=32)
    assert stats.chunks == 6 and stats.rows == 1500
    assert rsde.weights.dtype == np.float64
    assert rsde.weights.sum() == 1500.0  # EXACT f64 mass bookkeeping
    assert (rsde.weights > 0).all()
    d = np.linalg.norm(x[:, None] - rsde.centers[None], axis=2).min(1)
    assert (d < 2 * eps + 1e-5).all()
    # merged centers stay pairwise >= eps apart (absorb-then-select)
    if rsde.m > 1:
        d2 = ((rsde.centers[:, None] - rsde.centers[None]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        assert np.sqrt(d2.min()) >= eps - 1e-5


def test_budget_spill_caps_m_exactly():
    x = _mix(1200, seed=9)
    eps = 0.05  # tiny eps -> many more candidates than the budget
    rsde, stats = select_streaming(_chunks_of(x, 256), eps, block=32,
                                   budget=40)
    assert rsde.m == 40  # deterministic m under the budget
    assert stats.spilled > 0 and stats.max_spill_dist > 0
    assert rsde.weights.sum() == 1200.0  # spill hands mass over exactly
    un_capped, _ = select_streaming(_chunks_of(x, 256), eps, block=32)
    assert un_capped.m > 40


def test_ragged_and_empty_tail_chunks():
    """A final chunk with few valid rows — and an all-padding chunk — must
    neither crash nor perturb the mass invariant."""
    x = _mix(300, seed=2)
    chunks = list(_chunks_of(x, 128))  # valid: 128, 128, 44
    chunks.append((np.zeros_like(chunks[0][0]), 0))  # fully-empty chunk
    rsde, stats = select_streaming(iter(chunks), 0.2, block=16)
    assert stats.rows == 300 and rsde.weights.sum() == 300.0


def test_prefetch_feed_stats_and_order():
    stats = IngestStats()
    items = [(np.full((4, 2), i, np.float32), 4) for i in range(7)]
    out = list(_PrefetchFeed(iter(items), lambda x, nv: (x, nv), stats,
                             depth=3))
    assert [int(x[0, 0]) for x, _ in out] == list(range(7))  # order kept
    assert stats.feed_s >= 0 and stats.stall_s >= 0


def test_prefetch_feed_propagates_producer_error():
    def bad_source():
        yield np.zeros((4, 2), np.float32), 4
        raise RuntimeError("disk on fire")

    stats = IngestStats()
    with pytest.raises(RuntimeError, match="disk on fire"):
        list(_PrefetchFeed(bad_source(), lambda x, nv: (x, nv), stats))


def test_select_streaming_empty_source_raises():
    with pytest.raises(ValueError, match="empty source"):
        select_streaming(iter([]), 0.1)


def test_streaming_merge_duplicate_centers_across_batches():
    """The same centers arriving from two chunk/shard boundaries must merge
    (d2 == 0 < eps^2), not accumulate as near-duplicates."""
    x = _mix(400, seed=11)
    c, w, _, m = shadow_select_blocked(x, 0.2, block=32)
    merge = StreamingMerge(x.shape[1], 0.2)
    merge.update(c[:m], w[:m])
    merge.update(c[:m], w[:m])  # identical batch again
    assert merge.m == m
    np.testing.assert_array_equal(merge.centers, c[:m])
    assert merge.weights.sum() == 2 * len(x)


def test_streaming_merge_empty_and_padded_updates():
    merge = StreamingMerge(3, 0.2)
    merge.update(np.zeros((0, 3)), np.zeros((0,)))          # empty shard
    merge.update(np.zeros((5, 3)), np.zeros((5,)))          # all padding
    assert merge.m == 0
    merge.update(np.eye(3, dtype=np.float32), np.ones((3,)))
    assert merge.m == 3 and merge.weights.sum() == 3.0


def test_ingest_fit_matches_fused_fit_on_one_chunk():
    """Single-chunk stream: ingest_fit and fit_shadow_fused see the exact
    same center set, so the fitted models must embed identically."""
    x = _mix(600, seed=4)
    sigma = float(np.median(np.linalg.norm(x[:50, None] - x[None, :50],
                                           axis=2)))
    ker = gaussian(sigma)
    model_f = fit_shadow_fused(x, ker, 4, ell=3.0, block=64)
    model_i, stats = ingest_fit(_chunks_of(x, 1024), ker, 4, ell=3.0,
                                block=64)
    assert model_i.method == "rskpca+shadow-ingest"
    assert stats.wall_s > 0 and stats.fit_s > 0
    np.testing.assert_array_equal(model_i.centers, model_f.centers)
    q = x[:64]
    np.testing.assert_allclose(model_i.transform(q), model_f.transform(q),
                               atol=1e-5)


def test_ingest_fit_multichunk_end_to_end():
    src = ChunkedDataset("pendigits", n=6000, chunk=2048, seed=1)
    ker = gaussian(src.bandwidth())
    model, stats = ingest_fit(src, ker, 6, ell=3.0, block=64, budget=256)
    assert model.centers.shape[0] == stats.m <= 256
    assert stats.rows == 6000 and stats.chunks == 3
    assert 0.0 <= stats.overlap_fraction <= 1.0
    assert stats.rows_per_s > 0
    z = model.transform(src.rows(0, 100))
    assert z.shape == (100, 6) and np.isfinite(z).all()


def test_overlap_fraction_edge_cases():
    """The overlap metric must stay in [0, 1] at the degenerate corners:
    an all-cached feed (feed_s == 0) counts as fully hidden, and a stall
    measured LONGER than the feed work (clock skew between the producer
    and consumer threads) clips to 0 instead of going negative."""
    assert IngestStats(feed_s=0.0, stall_s=0.0).overlap_fraction == 1.0
    assert IngestStats(feed_s=0.0, stall_s=0.5).overlap_fraction == 1.0
    assert IngestStats(feed_s=1.0, stall_s=2.0).overlap_fraction == 0.0
    assert IngestStats(feed_s=2.0, stall_s=0.5).overlap_fraction \
        == pytest.approx(0.75)


def test_prefetch_feed_excludes_queue_blocking_from_feed_s():
    """feed_s is producer WORK, not producer waiting: with an instant
    source and a slow consumer, the producer spends almost all its wall
    time blocked on the full queue, and none of that may count as feed
    time (else overlap_fraction would read ~0 for a pipeline whose feed is
    actually infinitely ahead of compute)."""
    stats = IngestStats()
    items = [(np.zeros((4, 2), np.float32), 4) for _ in range(8)]
    feed = _PrefetchFeed(iter(items), lambda x, nv: (x, nv), stats, depth=2)
    consumer_s = 0.0
    n_out = 0
    for _ in feed:
        t0 = time.perf_counter()
        time.sleep(0.05)  # slow consumer: the queue stays full
        consumer_s += time.perf_counter() - t0
        n_out += 1
    assert n_out == 8 and consumer_s > 0.3
    # producer was blocked ~consumer_s total; its recorded work is tiny
    assert stats.feed_s < 0.5 * consumer_s
    assert stats.feed_s < 0.1
