"""End-to-end behaviour tests for the full system.

Covers: the train driver (loss decreases, checkpoints publish, restart
resumes the same data stream), the RSKPCA activation probe as a training
feature, the serving loop, and the dry-run cell machinery at smoke scale.
"""
import json
import os

import numpy as np
import pytest

from repro.models.config import ArchConfig

TINY = ArchConfig(
    name="sys-tiny", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256, vocab_pad_multiple=32, attn_kind="full", attn_chunk=16,
    subquadratic=False)


def test_train_loss_decreases_and_checkpoints(tmp_path):
    from repro.launch.train import TrainRun, run
    tr = TrainRun(cfg=TINY, global_batch=4, seq_len=32, steps=12,
                  accum=2, lr=3e-3, ckpt_dir=str(tmp_path), ckpt_every=5)
    params, opt, history, extras = run(tr)
    losses = [h["loss"] for h in history]
    assert len(losses) == 12
    assert losses[-1] < losses[0]
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) is not None


def test_restart_resumes_from_checkpoint(tmp_path):
    from repro.launch.train import TrainRun, run
    from repro.checkpoint import latest_step
    tr = TrainRun(cfg=TINY, global_batch=4, seq_len=32, steps=10,
                  accum=1, lr=1e-3, ckpt_dir=str(tmp_path), ckpt_every=5)
    run(tr, max_steps=6)           # "crash" after 6 steps
    start = latest_step(str(tmp_path))
    # periodic ckpt at 5 + final shutdown ckpt at 6 -> resume from 6
    assert start == 6
    _, _, history, _ = run(tr)     # resume
    assert history[0]["step"] == start  # restarted from the checkpoint step


def test_preemption_checkpoint(tmp_path):
    from repro.launch.train import TrainRun, run
    from repro.runtime.fault import PreemptionGuard
    # preempt immediately: guard trips before step 0 completes the loop
    tr = TrainRun(cfg=TINY, global_batch=4, seq_len=32, steps=50,
                  ckpt_dir=str(tmp_path), ckpt_every=1000)
    import repro.launch.train as T
    orig = T.PreemptionGuard

    class TrippedGuard(orig):
        def __init__(self, *a, **k):
            super().__init__(signals=())
            self._count = 0

        @property
        def should_stop(self):
            self._count += 1
            return self._count > 4  # stop after a few steps

    T.PreemptionGuard = TrippedGuard
    try:
        _, _, history, _ = run(tr)
    finally:
        T.PreemptionGuard = orig
    assert len(history) < 50  # stopped early, cleanly
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) is not None  # final sync checkpoint


def test_probe_reports_during_training():
    from repro.launch.train import TrainRun, run
    # reservoir needs >= 64 rows before the first probe: 16 rows/step
    tr = TrainRun(cfg=TINY, global_batch=16, seq_len=32, steps=10,
                  probe_every=4, probe_rank=3)
    _, _, history, extras = run(tr)
    probe = extras["probe"]
    assert probe is not None and len(probe.reports) >= 1
    rep = probe.reports[-1]
    assert rep.m > 0 and 0 < rep.retention <= 1
    assert np.isfinite(rep.spectrum).all()
    assert (np.diff(rep.spectrum) <= 1e-9).all()  # sorted spectrum


def test_serving_loop_completes_requests():
    from repro.launch.serve import serve, Request
    from repro.configs import get_config
    cfg = get_config("yi_9b", smoke=True)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 3).astype(np.int32),
                    max_new=5) for _ in range(5)]
    served, stats = serve(cfg, reqs, batch_slots=2, max_seq=64)
    assert len(served) == 5
    assert all(len(r.out) == 5 for r in served)
    assert stats["tokens"] == 25


def test_dryrun_cell_smoke(tmp_path):
    """run_cell end-to-end on the real dryrun module (tiny mesh via env)."""
    import subprocess, sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "rwkv6_1b6",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    path = os.path.join(str(tmp_path), "rwkv6_1b6__decode_32k__pod16x16.json")
    rec = json.load(open(path))
    assert rec["status"] == "ok"
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s",
                                           "collective_s")


def test_loop_multiplier_parser():
    from repro.launch.dryrun import _split_computations, _loop_multipliers
    hlo = """
%body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(f32[4]{0} %x), replica_groups={}
}
%cond.1 (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(s32[] %i, s32[] %c), direction=LT
}
ENTRY %main.2 (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%t), condition=%cond.1, body=%body.1
}
"""
    comps = _split_computations(hlo)
    mult = _loop_multipliers(comps)
    assert mult["body.1"] == 7
    assert mult["main.2"] == 1
