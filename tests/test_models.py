"""Per-arch smoke tests + decode/forward consistency.

The decode-equivalence test is the strongest model correctness check: a
token-by-token decode with caches (KV, ring-SWA, Mamba state, RWKV state)
must reproduce the teacher-forced forward logits.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.models.api import ShapeSpec

SMOKE_TRAIN = ShapeSpec("smoke_train", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_shapes(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v)
             for k, v in api.make_host_batch(cfg, SMOKE_TRAIN).items()}
    loss, metrics = api.loss_fn(params, batch, cfg, remat=False)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 20


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_grad_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v)
             for k, v in api.make_host_batch(cfg, SMOKE_TRAIN).items()}
    (loss, _), grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, batch, cfg, remat=True), has_aux=True)(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.isfinite(np.asarray(g)).all(), path


DECODE_ARCHS = ["yi_9b", "gemma2_9b", "gemma3_4b", "mixtral_8x7b",
                "rwkv6_1b6", "jamba_52b", "qwen2_72b", "kimi_k2",
                "whisper_base"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_teacher_forced_forward(arch):
    """Token-by-token decode must reproduce the full-sequence logits.

    Run in f32: the algorithmic check must not be polluted by bf16
    accumulation-order noise (verified: bf16 deviates up to ~0.7 on random
    init; f32 agrees to ~1e-5)."""
    import dataclasses
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              compute_dtype="float32")
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    T = 12
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, T)), jnp.int32)

    if cfg.is_encdec():
        audio = jnp.asarray(rng.normal(size=(2, cfg.encoder_seq, cfg.d_model)),
                            jnp.float32)
        batch = {"audio_embed": audio, "tokens": tokens}
        full = api.prefill_logits(params, batch, cfg)  # (2, T, V)
        from repro.models import encdec
        cache = encdec.init_cache(cfg, 2, T)
        # populate cross K/V from the encoder output
        enc = encdec.encode(params, audio, cfg)

        def xkv(p):
            k = jnp.einsum("bsd,dhk->bshk", enc,
                           p["cross_attn"]["wk"].astype(enc.dtype))
            v = jnp.einsum("bsd,dhk->bshk", enc,
                           p["cross_attn"]["wv"].astype(enc.dtype))
            return k, v
        ks, vs = jax.vmap(xkv, in_axes=(0,))(params["dec_blocks"])
        cache["dec"]["xk"] = ks   # (L, B, enc_seq, KV, hd)
        cache["dec"]["xv"] = vs
    else:
        batch = {"tokens": tokens}
        full = api.prefill_logits(params, batch, cfg)
        cache = api.init_cache(cfg, 2, T)

    step = jax.jit(lambda p, c, t, pos: api.decode_step(p, c, t, pos, cfg))
    got = []
    for t in range(T):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        got.append(np.asarray(logits))
    got = np.stack(got, axis=1)  # (2, T, V)
    want = np.asarray(full)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and near-uniform routing, few tokens drop."""
    from repro.models.layers.moe import init_moe, moe_forward
    key = jax.random.PRNGKey(0)
    p = init_moe(key, 32, 64, n_experts=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    y, aux = moe_forward(p, x, top_k=2, capacity_factor=2.0)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["load_balance_loss"]) > 0.5  # ~1 for uniform routing


def test_moe_matches_dense_reference_when_capacity_ample():
    """Sort-based dispatch == per-token dense gather when nothing drops."""
    from repro.models.layers.moe import init_moe, moe_forward
    key = jax.random.PRNGKey(2)
    d, f, e, k = 16, 32, 4, 2
    p = init_moe(key, d, f, n_experts=e)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, d))
    y, _ = moe_forward(p, x, top_k=k, capacity_factor=8.0)

    # dense reference: every token through its top-k experts via direct gather
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gw, ge = jax.lax.top_k(probs, k)
    gw = gw / gw.sum(-1, keepdims=True)
    outs = []
    for t in range(xf.shape[0]):
        acc = jnp.zeros((d,), x.dtype)
        for j in range(k):
            eidx = int(ge[t, j])
            h = xf[t] @ p["w_in"][eidx]
            g = jax.nn.silu(xf[t] @ p["w_gate"][eidx]) * h
            acc = acc + gw[t, j].astype(x.dtype) * (g @ p["w_out"][eidx])
        outs.append(acc)
    want = jnp.stack(outs).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_rwkv_chunked_equals_recurrence():
    """Chunked-parallel RWKV6 forward == naive O(T) recurrence oracle."""
    from repro.models.layers.rwkv6 import init_rwkv6, rwkv6_forward, \
        rwkv6_decode
    d, hs = 32, 8
    p = init_rwkv6(jax.random.PRNGKey(0), d, hs)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d)) * 0.5
    full = rwkv6_forward(p, x, head_size=hs, chunk=4)
    # decode-step recurrence oracle
    state = jnp.zeros((2, d // hs, hs, hs), jnp.float32)
    shift = jnp.zeros((2, d), x.dtype)
    outs = []
    for t in range(16):
        y, state, shift = rwkv6_decode(p, x[:, t:t + 1], state, shift,
                                       head_size=hs)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=1e-3, rtol=1e-3)


def test_mamba_chunked_equals_recurrence():
    from repro.models.layers.mamba import init_mamba, mamba_forward, \
        mamba_decode
    d = 32
    p = init_mamba(jax.random.PRNGKey(0), d, d_state=4, d_conv=4, expand=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d)) * 0.5
    full = mamba_forward(p, x, chunk=4)
    ssm = jnp.zeros((2, 2 * d, 4), jnp.float32)
    conv = jnp.zeros((2, 3, 2 * d), x.dtype)
    outs = []
    for t in range(12):
        y, ssm, conv = mamba_decode(p, x[:, t:t + 1], ssm, conv)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=1e-3, rtol=1e-3)


def test_flash_attention_equals_naive():
    from repro.models.layers.attention import flash_attention
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 16, 4, 8))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 16, 2, 8))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 16, 2, 8))
    for window, softcap in [(None, None), (4, None), (None, 5.0), (8, 3.0)]:
        out = flash_attention(q, k, v, causal=True, window=window,
                              softcap=softcap, chunk=4)
        # naive reference
        g = 2
        qh = q.reshape(2, 16, 2, g, 8)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qh, k) / np.sqrt(8)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = jnp.arange(16)[:, None]
        kpos = jnp.arange(16)[None, :]
        mask = qpos >= kpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        pr = jax.nn.softmax(s, axis=-1)
        want = jnp.einsum("bkgqs,bskd->bqkgd", pr, v).reshape(2, 16, 4, 8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
