"""Continuous-batching front end: coalescing, scatter, SLO, hot-swap.

Most tests drive the queue deterministically (``autostart=False`` +
``step()``/``drain()``) against a stub server so they pin the dispatcher
logic, not jax timing; the integration tests at the bottom run the real
HotSwapServer and assert the recompile-free pow2-bucket contract.
"""
import threading
import time

import numpy as np
import pytest

from repro.serving import BatchingFrontEnd


class StubServer:
    """Deterministic 'transform': z[i] = (sum(x[i]), tag).  Records every
    batch shape it was handed so tests can assert coalescing/padding."""

    def __init__(self, tag=0.0):
        self.tag = tag
        self.calls = []

    def transform(self, x):
        x = np.asarray(x)
        self.calls.append(x.shape)
        return np.stack([x.sum(axis=1), np.full(x.shape[0], self.tag)], 1)


def _expect(srv, x):
    x = np.atleast_2d(np.asarray(x, np.float32))
    return np.stack([x.sum(axis=1), np.full(x.shape[0], srv.tag)], 1)


def test_step_coalesces_and_scatters_exactly():
    srv = StubServer()
    fe = BatchingFrontEnd(srv, max_batch=64, autostart=False)
    rng = np.random.default_rng(0)
    reqs = [rng.normal(size=(k, 3)).astype(np.float32) for k in (1, 4, 2)]
    futs = [fe.submit(r) for r in reqs]
    assert fe.step() == 7
    # ONE fused call, padded to the pow2 bucket (7 -> 8 rows)
    assert srv.calls == [(8, 3)]
    for r, f in zip(reqs, futs):
        np.testing.assert_allclose(f.result(timeout=0), _expect(srv, r))
    assert fe.stats.batches == 1 and fe.stats.batched_rows == 7
    assert fe.step() == 0  # queue drained


def test_padding_rows_never_reach_callers():
    srv = StubServer(tag=7.0)
    fe = BatchingFrontEnd(srv, max_batch=32, autostart=False)
    f = fe.submit(np.ones((5, 2), np.float32))
    fe.step()
    z = f.result(timeout=0)
    assert z.shape == (5, 2)           # 3 padding rows sliced off
    assert srv.calls == [(8, 2)]


def test_max_batch_splits_fifo():
    srv = StubServer()
    fe = BatchingFrontEnd(srv, max_batch=8, autostart=False)
    futs = [fe.submit(np.full((3, 2), i, np.float32)) for i in range(5)]
    assert fe.drain() == 15
    # whole requests only: 3+3 / 3+3 / 3 (never a split request)
    assert [s[0] for s in srv.calls] == [8, 8, 4]
    assert fe.stats.full_dispatches == 0  # 6 < 8: window closed, not full
    for i, f in enumerate(futs):
        np.testing.assert_allclose(f.result(timeout=0)[:, 0], 2.0 * i)


def test_oversized_request_ships_alone():
    srv = StubServer()
    fe = BatchingFrontEnd(srv, max_batch=4, autostart=False)
    big = fe.submit(np.ones((10, 2), np.float32))
    small = fe.submit(np.ones((2, 2), np.float32))
    assert fe.step() == 10 and fe.step() == 2
    # the bucket rule clips at max_batch, so an oversized request is NOT
    # padded (the server's transform chunks internally); the small one pads
    # to its pow2 bucket
    assert [s[0] for s in srv.calls] == [10, 2]
    assert big.result(timeout=0).shape == (10, 2)
    assert small.result(timeout=0).shape == (2, 2)


def test_single_row_and_1d_submit():
    srv = StubServer()
    fe = BatchingFrontEnd(srv, autostart=False)
    f = fe.submit(np.arange(3, dtype=np.float32))  # (d,) -> (1, d)
    fe.step()
    np.testing.assert_allclose(f.result(timeout=0), [[3.0, 0.0]])


def test_transform_exception_propagates_to_every_future():
    class Boom:
        def transform(self, x):
            raise RuntimeError("device fell over")

    fe = BatchingFrontEnd(Boom(), autostart=False)
    futs = [fe.submit(np.zeros((2, 2), np.float32)) for _ in range(3)]
    fe.step()
    for f in futs:
        with pytest.raises(RuntimeError, match="fell over"):
            f.result(timeout=0)


def test_submit_after_close_raises():
    fe = BatchingFrontEnd(StubServer(), autostart=False)
    fe.close()
    with pytest.raises(RuntimeError):
        fe.submit(np.zeros((1, 2), np.float32))


def test_close_drains_pending():
    srv = StubServer()
    fe = BatchingFrontEnd(srv, autostart=False)
    f = fe.submit(np.ones((3, 2), np.float32))
    fe.close()
    assert f.result(timeout=0).shape == (3, 2)


def test_hot_swap_between_batches_never_tears_one():
    """A publish lands between dispatches: every request inside one batch
    sees ONE operator version (the stub's tag), never a mix."""

    class Swappable(StubServer):
        pass

    srv = Swappable(tag=1.0)
    fe = BatchingFrontEnd(srv, autostart=False)
    f1 = fe.submit(np.ones((2, 2), np.float32))
    f2 = fe.submit(np.ones((2, 2), np.float32))
    fe.step()
    srv.tag = 2.0  # "publish": single attribute store, next batch sees it
    f3 = fe.submit(np.ones((2, 2), np.float32))
    fe.step()
    assert set(f1.result(0)[:, 1]) == set(f2.result(0)[:, 1]) == {1.0}
    assert set(f3.result(0)[:, 1]) == {2.0}


def test_threaded_dispatcher_coalesces_under_load():
    """With the dispatcher thread live and min_wait floored, concurrent
    submitters coalesce into far fewer batches than requests, and every
    result is still exact."""
    srv = StubServer()
    with BatchingFrontEnd(srv, max_batch=256, slo_ms=500.0,
                          min_wait_ms=20.0) as fe:
        results = {}

        def client(i):
            x = np.full((2, 3), float(i), np.float32)
            results[i] = (x, fe.submit(x))

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i, (x, f) in results.items():
            np.testing.assert_allclose(f.result(timeout=5), _expect(srv, x))
    assert fe.stats.requests == 16 and fe.stats.rows == 32
    assert fe.stats.batches < 16          # coalescing actually happened
    assert fe.stats.ewma_service_s        # EWMA learned at least one bucket


def test_deadline_slack_bounds_the_wait():
    """The coalescing window never extends past the oldest deadline's slack
    minus the (pessimistic) service estimate."""
    fe = BatchingFrontEnd(StubServer(), max_batch=64, slo_ms=100.0,
                          min_wait_ms=10_000.0, autostart=False)
    fe.stats.ewma_service_s[8] = 0.040    # 40ms estimate for this bucket
    fe.submit(np.ones((5, 2), np.float32))
    with fe._cond:
        wait = fe._wait_s_locked(time.monotonic())
    # slack = 100ms - 40ms*1.25 - 1ms = 49ms, far below the 10s min_wait
    assert 0.0 < wait <= 0.050
    # a full queue dispatches immediately no matter the window
    fe.submit(np.ones((64, 2), np.float32))
    with fe._cond:
        assert fe._wait_s_locked(time.monotonic()) == 0.0
    fe.close()


def test_front_end_over_hot_swap_server_recompile_free():
    """Integration: the real HotSwapServer behind the front end — pow2
    bucket padding means a ragged request mix adds ZERO compiled shapes
    after the buckets are warm, and batched answers match direct calls."""
    from repro import streaming
    from repro.core import gaussian
    from repro.kernels import ops as kernel_ops
    from repro.core.rsde import RSDE

    rng = np.random.default_rng(4)
    c = rng.normal(size=(40, 4)).astype(np.float32)
    rsde = RSDE(c, np.ones(40, np.float64), n=40.0, scheme="test")
    st_ = streaming.from_rsde(rsde, gaussian(1.0), 3, eps=0.5, cap=40)
    srv = streaming.HotSwapServer(st_)

    fe = BatchingFrontEnd(srv, max_batch=16, autostart=False)
    for b in (1, 2, 4, 8, 16):           # warm every bucket
        np.asarray(srv.transform(np.zeros((b, 4), np.float32)))
    before = kernel_ops.projection_compile_count()

    reqs = [rng.normal(size=(k, 4)).astype(np.float32)
            for k in (3, 1, 5, 2, 7, 16, 4)]
    futs = [fe.submit(r) for r in reqs]
    fe.drain()
    assert kernel_ops.projection_compile_count() == before
    for r, f in zip(reqs, futs):
        np.testing.assert_allclose(f.result(timeout=0),
                                   np.asarray(srv.transform(r)),
                                   rtol=1e-5, atol=1e-6)


# ----------------------------------------------- failure paths (§17) ------

def test_dispatch_exception_during_hot_swap_resolves_every_future():
    """The server blows up exactly while a publish swaps under it: every
    future in the in-flight batch still resolves (with the error), and the
    NEXT batch serves normally off the new operator."""

    class SwapBoom(StubServer):
        def __init__(self):
            super().__init__(tag=1.0)
            self.boom = False

        def transform(self, x):
            if self.boom:
                self.boom = False
                self.tag = 2.0  # the "publish" lands mid-dispatch
                raise RuntimeError("snapshot store raced")
            return super().transform(x)

    srv = SwapBoom()
    fe = BatchingFrontEnd(srv, autostart=False)
    srv.boom = True
    doomed = [fe.submit(np.ones((2, 2), np.float32)) for _ in range(3)]
    fe.step()
    for f in doomed:
        with pytest.raises(RuntimeError, match="raced"):
            f.result(timeout=0)
    after = fe.submit(np.ones((2, 2), np.float32))
    fe.step()
    assert set(after.result(timeout=0)[:, 1]) == {2.0}  # new operator


def test_deadline_expiry_while_queued_still_serves():
    """A request whose SLO expired before dispatch is SERVED, not dropped —
    deadlines bound retry budgets, they are not admission control (the
    zero-non-shed-drops contract)."""
    srv = StubServer()
    fe = BatchingFrontEnd(srv, autostart=False, slo_ms=1.0)
    f = fe.submit(np.ones((2, 2), np.float32))
    time.sleep(0.02)  # well past the 1ms deadline
    assert fe.step() == 2
    np.testing.assert_allclose(f.result(timeout=0),
                               _expect(srv, np.ones((2, 2))))


def test_expired_deadline_bounds_retries_not_results():
    """With the deadline already gone, a transient dispatch fault is NOT
    retried (no backoff can land inside the deadline) — the fault reaches
    the futures instead of hanging the dispatcher in a retry loop."""
    from repro.runtime import chaos
    from repro.runtime.chaos import FaultPlan, FaultSpec, TransientFault
    from repro.runtime.fault import RetryPolicy

    fe = BatchingFrontEnd(StubServer(), autostart=False, slo_ms=1.0,
                          retry=RetryPolicy(base_s=0.05))
    f = fe.submit(np.ones((2, 2), np.float32))
    time.sleep(0.02)
    with chaos.active(FaultPlan({"serve.dispatch":
                                 FaultSpec(kind="transient", every=1)})):
        fe.step()
    with pytest.raises(TransientFault):
        f.result(timeout=0)


def test_transient_dispatch_fault_is_retried_in_place():
    from repro.runtime import chaos
    from repro.runtime.chaos import FaultPlan, FaultSpec
    from repro.runtime.fault import RetryPolicy

    srv = StubServer()
    fe = BatchingFrontEnd(srv, autostart=False, slo_ms=5000.0,
                          retry=RetryPolicy(base_s=1e-4))
    x = np.ones((3, 2), np.float32)
    f = fe.submit(x)
    with chaos.active(FaultPlan({"serve.dispatch":
                                 FaultSpec(kind="transient", at=(1,))})):
        fe.step()
    np.testing.assert_allclose(f.result(timeout=0), _expect(srv, x))
    assert fe.stats.retries == 1
    assert len(srv.calls) == 1  # the fault fired BEFORE the transform ran


def test_max_queue_sheds_with_explicit_exception():
    from repro.serving import RequestShed

    srv = StubServer()
    fe = BatchingFrontEnd(srv, autostart=False, max_queue=2)
    futs = [fe.submit(np.ones((1, 2), np.float32)) for _ in range(5)]
    assert fe.stats.shed == 3
    fe.drain()
    outcomes = []
    for f in futs:
        try:
            f.result(timeout=0)
            outcomes.append("served")
        except RequestShed:
            outcomes.append("shed")
    # FIFO: the first max_queue requests serve, the overflow sheds, and
    # nothing is silently dropped
    assert outcomes == ["served", "served", "shed", "shed", "shed"]


def test_close_with_in_flight_futures_resolves_all():
    """close() racing a slow in-flight batch plus queued work: every
    future resolves (the in-flight batch finishes, the queue drains)."""

    class Slow(StubServer):
        def transform(self, x):
            time.sleep(0.05)
            return super().transform(x)

    srv = Slow()
    fe = BatchingFrontEnd(srv, max_batch=4, slo_ms=5000.0, min_wait_ms=0.0)
    futs = [fe.submit(np.full((2, 2), i, np.float32)) for i in range(6)]
    fe.close()
    for i, f in enumerate(futs):
        np.testing.assert_allclose(
            f.result(timeout=5), _expect(srv, np.full((2, 2), i)))


def test_preemption_guard_closes_admission_and_drains():
    from repro.runtime.fault import PreemptionGuard

    guard = PreemptionGuard(signals=())
    srv = StubServer()
    fe = BatchingFrontEnd(srv, slo_ms=5000.0, guard=guard)
    futs = [fe.submit(np.ones((2, 2), np.float32)) for _ in range(3)]
    guard.request_stop()
    for f in futs:  # everything admitted before the stop still serves
        np.testing.assert_allclose(f.result(timeout=5),
                                   _expect(srv, np.ones((2, 2))))
    deadline = time.monotonic() + 5
    while time.monotonic() < deadline and not fe._closed:
        time.sleep(0.01)
    with pytest.raises(RuntimeError):  # admission is closed after drain
        fe.submit(np.ones((1, 2), np.float32))
    fe.close()


def test_degraded_batches_are_tagged_with_staleness_info():
    from repro.serving import ServedRows
    from repro.streaming.swap import SnapshotInfo

    class Degraded(StubServer):
        degraded = True

        def degraded_info(self):
            return SnapshotInfo(version=5, published_at=None, degraded=True,
                                failed_publishes=2, staleness_bound=0.03)

    srv = Degraded()
    fe = BatchingFrontEnd(srv, autostart=False)
    f = fe.submit(np.ones((2, 2), np.float32))
    fe.step()
    z = f.result(timeout=0)
    assert isinstance(z, ServedRows) and z.info.staleness_bound == 0.03
    np.testing.assert_allclose(np.asarray(z), _expect(srv, np.ones((2, 2))))
    assert fe.stats.degraded_batches == 1
    # a healthy server's responses carry no tag (plain ndarray path)
    srv2 = StubServer()
    fe2 = BatchingFrontEnd(srv2, autostart=False)
    f2 = fe2.submit(np.ones((2, 2), np.float32))
    fe2.step()
    assert getattr(f2.result(timeout=0), "info", None) is None
