"""Sharded fit/transform path (DESIGN.md §5): single-device-mesh parity
in-process, multi-device parity via an 8-host-device subprocess (the same
harness pattern as tests/test_distributed.py)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import gaussian, shadow_rsde, fit_rskpca, fit
from repro.core import distributed as dist
from repro.launch.mesh import data_mesh
from repro.data import make_dataset

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


@pytest.fixture(scope="module")
def fitted():
    x, _, sigma = make_dataset("german", seed=0, n=400)
    ker = gaussian(sigma)
    rsde = shadow_rsde(x, ker, 4.0)
    return x, ker, rsde


def test_sharded_fit_matches_single_device_on_1dev_mesh(fitted):
    x, ker, rsde = fitted
    mesh = data_mesh(1)
    m0 = fit_rskpca(rsde, ker, 5)
    m1 = fit_rskpca(rsde, ker, 5, mesh=mesh)
    np.testing.assert_allclose(m1.eigvals, m0.eigvals, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(m1.projector, m0.projector, atol=1e-5)
    z0 = m0.transform(x[:100])
    z1 = m1.transform(x[:100], mesh=mesh)
    np.testing.assert_allclose(z1, z0, atol=1e-5)


def test_sharded_lobpcg_matvec_path(fitted):
    """Force the row-distributed LOBPCG eigensolve at small m (the shard_map
    matvec inside the iteration) and check it recovers the eigh spectrum."""
    x, ker, rsde = fitted
    mesh = data_mesh(1)
    m0 = fit_rskpca(rsde, ker, 5)
    lam, proj = dist.fit_rskpca_sharded(
        rsde.centers, rsde.weights, rsde.n, ker, 5, mesh, lobpcg_min_m=8)
    np.testing.assert_allclose(np.asarray(lam), m0.eigvals, rtol=1e-3)
    assert proj.shape == m0.projector.shape
    assert np.isfinite(np.asarray(proj)).all()


def test_sharded_shadow_assign_matches_ops(fitted):
    from repro.kernels import ops
    x, ker, rsde = fitted
    mesh = data_mesh(1)
    idx, d2 = dist.sharded_shadow_assign(x[:333], rsde.centers, mesh)
    idx_r, d2_r = ops.shadow_assign(x[:333], rsde.centers)
    assert (np.asarray(idx) == np.asarray(idx_r)).all()
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_r), atol=1e-4)


def test_sharded_serving_compiles_per_bucket(fitted):
    """Mesh serving must re-trace per shape BUCKET, not per query size:
    two ragged queries inside one (ndev*128) bucket share a compile."""
    x, ker, rsde = fitted
    mesh = data_mesh(1)
    mdl = fit_rskpca(rsde, ker, 5, mesh=mesh)
    before = dist._sharded_project_jit._cache_size()
    z1 = mdl.transform(x[:130], mesh=mesh)  # pads to the 256-row bucket
    mid = dist._sharded_project_jit._cache_size()
    z2 = mdl.transform(x[:200], mesh=mesh)  # same bucket: no new trace
    after = dist._sharded_project_jit._cache_size()
    assert mid - before == 1 and after == mid, (before, mid, after)
    assert z1.shape == (130, 5) and z2.shape == (200, 5)


def test_mesh_rejected_for_single_device_baselines(fitted):
    x, ker, _ = fitted
    with pytest.raises(ValueError, match="single-device"):
        fit(x, ker, 4, method="kpca", mesh=data_mesh(1))
    with pytest.raises(ValueError, match="single-device"):
        fit(x, ker, 4, method="uniform", m=40, mesh=data_mesh(1))


def test_front_door_mesh_produces_usable_model(fitted):
    x, ker, _ = fitted
    mesh = data_mesh(1)
    mdl = fit(x, ker, 4, method="shadow", ell=4.0, mesh=mesh)
    z = mdl.transform(x[:10], mesh=mesh)
    assert z.shape == (10, 4) and np.isfinite(z).all()
    # bf16 composes with the sharded path
    mdl16 = fit(x, ker, 4, method="shadow", ell=4.0, mesh=mesh,
                precision="bf16")
    assert mdl16.kernel.precision == "bf16"
    assert np.isfinite(mdl16.transform(x[:10], mesh=mesh)).all()


def test_sharded_fit_transform_8dev_matches_single():
    """Acceptance: sharded results match single-device to 1e-5 on a real
    multi-device (host) mesh, with only the (m, r) projector replicated."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", """
import numpy as np
from repro.core import gaussian, shadow_rsde, fit_rskpca
from repro.core import distributed as dist
from repro.launch.mesh import smoke_mesh
from repro.data import make_dataset

x, _, sigma = make_dataset("pendigits", seed=1, n=1024)
ker = gaussian(sigma)
rsde = shadow_rsde(x, ker, 4.0)
mesh = smoke_mesh()
assert len(mesh.devices.flat) == 8
m0 = fit_rskpca(rsde, ker, 5)
m1 = fit_rskpca(rsde, ker, 5, mesh=mesh)
np.testing.assert_allclose(m1.eigvals, m0.eigvals, atol=1e-5, rtol=1e-5)
np.testing.assert_allclose(m1.projector, m0.projector, atol=1e-5)
z0 = m0.transform(x[:333])
z1 = m1.transform(x[:333], mesh=mesh)
np.testing.assert_allclose(z1, z0, atol=1e-5)
# forced distributed-LOBPCG eigensolve agrees with eigh
lam, _ = dist.fit_rskpca_sharded(rsde.centers, rsde.weights, rsde.n,
                                 ker, 5, mesh, lobpcg_min_m=8)
np.testing.assert_allclose(np.asarray(lam), m0.eigvals, rtol=1e-3)
# row-sharded assign agrees with the single-device kernel
from repro.kernels import ops
idx, d2 = dist.sharded_shadow_assign(x[:999], rsde.centers, mesh)
i0, d0 = ops.shadow_assign(x[:999], rsde.centers)
assert (np.asarray(idx) == np.asarray(i0)).all()
np.testing.assert_allclose(np.asarray(d2), np.asarray(d0), atol=1e-4)
# n NOT divisible by the axis: padding rows must carry no weight and the
# front door must work end-to-end (data_mesh's 'always safe' contract)
from repro.core import fit
mdl = fit(x[:1001], ker, 4, method="shadow", ell=4.0, mesh=mesh)
r = dist.distributed_shadow_rsde(x[:1001], ker, 4.0, mesh)
assert abs(r.weights.sum() - 1001) < 1e-3, r.weights.sum()
assert np.isfinite(mdl.transform(x[:77], mesh=mesh)).all()
print("OK")
"""], env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, \
        (r.stdout[-1000:], r.stderr[-3000:])
