"""Quickstart: latency-SLO serving of a streaming RSKPCA operator.

    PYTHONPATH=src python examples/serve_slo.py

The DESIGN.md §8 serving tier end-to-end: a quantized (int8) projector
published through the hot-swap server, a continuous-batching front end
coalescing concurrent requests into the compiled pow2 buckets, and the
closed-form quantization budget that certifies what the cheap tier costs.
"""
import threading

import numpy as np

from repro import streaming
from repro.core import gaussian, shadow_rsde
from repro.data import make_dataset
from repro.kernels import quantize
from repro.serving import BatchingFrontEnd

# 1. select once, stream forever: a shadow RSDE seeds a streaming operator
x, y, sigma = make_dataset("pendigits", n=1500)
kernel = gaussian(sigma, precision="int8")  # quantized SERVING tier
rsde = shadow_rsde(x, kernel, ell=4.0)
state = streaming.from_rsde(rsde, kernel, rank=5, ell=4.0)
server = streaming.HotSwapServer(state)  # publish() caches (A_q, scales)

# 2. what does int8 cost?  The per-channel budget publish computed, in the
#    same currency as the Theorem-5.x slack
bound = quantize.projection_error_bound(np.asarray(server._snapshot[1]),
                                        "int8")
print("int8 per-channel error budget:", np.round(np.asarray(bound), 4))

# 3. concurrent callers -> one fused transform per dispatch window; each
#    submit() returns a Future immediately and the dispatcher coalesces
#    into the pow2 buckets the projection already compiled
with BatchingFrontEnd(server, max_batch=256, slo_ms=50.0) as fe:
    futures = []

    def client(i):
        futures.append((i, fe.submit(x[4 * i : 4 * i + 4])))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i, fut in futures:
        z = fut.result(timeout=10)  # (4, rank) embedding rows
        assert z.shape == (4, 5) and np.isfinite(z).all()

s = fe.stats
print(f"{s.requests} requests ({s.rows} rows) served in {s.batches} "
      f"fused dispatches; largest batch {s.max_batch_rows} rows")

# 4. hot swap under load: ingest fresh samples, publish — the NEXT batch
#    serves the updated operator, in-flight batches are never torn
state = streaming.ingest(state, x[:200], batch=64)
server.publish(state)
print("published updated operator; serving continues without recompiling")
