"""Observability walkthrough: trace + metrics + spectral health, one run.

    PYTHONPATH=src python examples/observe_serving.py

The DESIGN.md §16 telemetry layer over the full online loop: chunked
ingestion selects an operator, a streaming state maintains it while a
drift detector watches the input window, a hot-swap server publishes every
update, and a continuous-batching front end serves concurrent callers —
all with observability ENABLED, ending in two artifacts:

  * ``obs_trace.json``  — open in https://ui.perfetto.dev (or
    chrome://tracing): nested span bars per thread, ingest chunks next to
    serve dispatches;
  * ``obs_metrics.txt`` — Prometheus text exposition, including the
    ``spectral.*`` health gauges a production deployment would scrape.
"""
import threading

import numpy as np

from repro import obs
from repro.core import gaussian, shadow_rsde
from repro import streaming
from repro.data import make_dataset
from repro.obs import metrics, trace
from repro.obs.spectral import SpectralHealth
from repro.serving import BatchingFrontEnd

obs.enable()  # everything below is a no-op without this line

# 1. seed an operator and publish it through the hot-swap server
x, y, sigma = make_dataset("pendigits", n=2000)
kernel = gaussian(sigma)
rsde = shadow_rsde(x[:1200], kernel, ell=4.0)
state = streaming.from_rsde(rsde, kernel, rank=5, ell=4.0)
server = streaming.HotSwapServer(state)

# 2. spectral health: sampled automatically at every metrics scrape
detector = streaming.DriftDetector(kernel, ell=4.0, window=256)
box = {"state": state}
health = SpectralHealth(get_state=lambda: box["state"], server=server,
                        detector=detector).install()

# 3. serve a burst of concurrent clients while fresh samples stream in:
#    every ingest batch republishes, every dispatch coalesces
with BatchingFrontEnd(server, max_batch=256, slo_ms=50.0) as fe:
    futures = []

    def client(i):
        futures.append(fe.submit(x[8 * i : 8 * i + 8]))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(16)]
    for t in threads:
        t.start()
    box["state"] = streaming.ingest(box["state"], x[1200:], batch=128,
                                    detector=detector, server=server)
    for t in threads:
        t.join()
    for fut in futures:
        assert np.isfinite(fut.result(timeout=10)).all()

# 4. read the telemetry back
snap = metrics.snapshot()  # runs the spectral sampler first
print(f"served {snap['serve_requests']} requests in "
      f"{snap['serve_batches']} fused dispatches; "
      f"queue drained to {snap['serve_queue_depth']:.0f}")
print(f"ingested {snap['stream_rows']} rows -> m={snap['stream_m']:.0f} "
      f"centers, err_est={snap['spectral_err_est']:.2e} "
      f"({snap['spectral_budget_ratio']:.0%} of the re-solve budget)")
if detector.full:
    print(f"windowed MMD at {snap['spectral_mmd_ratio']:.0%} of the "
          f"drift threshold")

n_spans = trace.export_chrome("obs_trace.json")
metrics.write("obs_metrics.txt")
print(f"wrote obs_trace.json ({n_spans} spans) and obs_metrics.txt")
