"""Streaming drift demo (DESIGN.md §7): the full online loop.

    PYTHONPATH=src python examples/streaming_drift.py

Story: an operator fitted on yesterday's distribution serves a live query
stream through a hot-swap server.  The stream stays in-distribution for a
while (updates absorb into existing shadows; the eigensystem is patched
under the Theorem-5.x error budget), then COLLAPSES onto a mode the
operator has never seen.  The windowed-MMD drift detector fires, a partial
refresh re-anchors the substitute density to the recent window (no
historical data needed — only the RSDE weight structure), the server
republishes without retracing, and the live operator's projection error
against a from-scratch refit stays within budget throughout.
"""
import numpy as np

from repro.core import fit_rskpca, gaussian, shadow_rsde
from repro.core.rskpca import embedding_alignment_error
from repro import streaming

RANK, ELL, SIGMA, D = 4, 1.6, 1.5, 6


def base_dist(n, seed):
    """Yesterday's distribution: 8 loose blobs in [0, 4]^d."""
    rng = np.random.default_rng(seed)
    blobs = np.random.default_rng(0).uniform(0, 4, (8, D))
    return (blobs[rng.integers(0, 8, n)]
            + 0.3 * rng.normal(size=(n, D))).astype(np.float32)


def drifted_dist(n, seed):
    """Today's surprise: the stream collapses onto one far-away mode."""
    rng = np.random.default_rng(seed)
    return (np.full((1, D), 8.0)
            + 0.3 * rng.normal(size=(n, D))).astype(np.float32)


def report(tag, state, det, rel_err):
    print(f"[{tag}] m={state.m:4d} n={float(state.n):7.0f} "
          f"err_budget={float(state.err_est):.4f} "
          f"patched={int(state.n_patched):3d} "
          f"mmd={det.mmd(state):.3f} (trigger {det.threshold:.3f}) "
          f"proj_rel_err={rel_err:.2e}")


def rel_error_vs_refit(state, queries):
    """Aligned projection error of the LIVE operator vs a from-scratch
    fit_rskpca on the equivalent center set — the §7 acceptance metric."""
    mdl = fit_rskpca(state.as_rsde(), state.kernel, state.rank)
    z_ref = mdl.transform(queries)
    z_live = np.asarray(state.transform(queries))
    return embedding_alignment_error(z_ref, z_live) / np.linalg.norm(z_ref)


# 1. fit on yesterday's data, lift into a streaming state + serving handle
x0 = base_dist(600, seed=1)
ker = gaussian(SIGMA)
state = streaming.from_rsde(shadow_rsde(x0, ker, ell=ELL), ker, RANK,
                            ell=ELL, budget=0.5)
det = streaming.DriftDetector(ker, ell=ELL, window=128, factor=0.55)
srv = streaming.HotSwapServer(state, chunk=256)
queries = np.concatenate([base_dist(64, 7), drifted_dist(64, 8)])
print(f"fitted: m={state.m}, cap={state.cap}, serving version {srv.version}")

# 2. in-distribution traffic: absorb/patch, detector stays quiet
state = streaming.ingest(state, base_dist(256, seed=2), batch=64,
                         detector=det, server=srv)
assert not det.should_refresh(state)
report("steady   ", state, det, rel_error_vs_refit(state, queries))

# 3. the distribution shifts under the live stream
state = streaming.ingest(state, drifted_dist(192, seed=3), batch=64,
                         detector=det, server=srv)
report("drifting ", state, det, rel_error_vs_refit(state, queries))

# 4. the trigger fires -> partial refresh from (decayed centers + window),
#    hot-swapped into serving without retracing the transform program
if det.should_refresh(state):
    print("drift trigger: refreshing the operator from the live window")
    state = streaming.refresh(state, det.window(), decay=0.05)
    srv.publish(state)
rel = rel_error_vs_refit(state, queries)
report("refreshed", state, det, rel)
assert det.mmd(state) < det.threshold, "refresh must re-absorb the drift"
assert rel < 1e-3, "refreshed operator must match a from-scratch refit"

# 5. serving continued through every swap: same compiled program, new values
z = srv.transform(queries)
print(f"served {z.shape} under operator version {srv.version} "
      f"(projection error within budget throughout)")
