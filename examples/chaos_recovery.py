"""Fault-tolerant ingest + serving, demonstrated under a live fault plan.

    PYTHONPATH=src python examples/chaos_recovery.py

Walks the DESIGN.md §17 failure model end to end, with deterministic chaos
injection standing in for the real world:

  1. a fault-free ingest (the reference);
  2. the same ingest under ~5% transient faults on the chunk-read / feed /
     merge sites, with periodic atomic checkpoints — retries absorb every
     fault and the result is BIT-EXACT;
  3. a simulated crash: ingest a truncated stream, then resume from the
     checkpoints over the full stream — bit-exact again;
  4. serving under failed publishes: the hot-swap server degrades to the
     last good snapshot and prices the staleness with the Theorem-5.x
     error budget, while the batching front end retries transient
     dispatches and sheds (never silently drops) overflow load.
"""
import tempfile
import time

import numpy as np

from repro.core.ingest_pipeline import select_streaming
from repro.data.kpca_datasets import ChunkedDataset
from repro.runtime import chaos
from repro.runtime.chaos import FaultPlan, FaultSpec
from repro.runtime.fault import RetryPolicy
from repro.serving import BatchingFrontEnd, RequestShed

N, CHUNK, EPS = 8192, 512, 0.25


def src():
    return ChunkedDataset("pendigits", n=N, chunk=CHUNK, seed=0)


def main():
    # 1. fault-free reference ------------------------------------------
    t0 = time.perf_counter()
    ref, stats = select_streaming(src(), EPS, block=256)
    print(f"[1] fault-free ingest: {stats.rows} rows -> m={ref.m} centers "
          f"in {time.perf_counter() - t0:.2f}s")

    # 2. the same ingest under a transient-fault storm -----------------
    fault = FaultSpec(kind="transient", p=0.05)
    plan = FaultPlan({"data.chunk": fault, "ingest.feed": fault,
                      "ingest.merge": fault}, seed=42)
    with tempfile.TemporaryDirectory() as ckdir:
        t0 = time.perf_counter()
        with chaos.active(plan) as p:
            got, _ = select_streaming(src(), EPS, block=256,
                                      checkpoint_dir=ckdir,
                                      checkpoint_every=4)
        exact = (np.array_equal(ref.centers, got.centers)
                 and np.array_equal(ref.weights, got.weights))
        print(f"[2] chaos ingest: {p.stats()['total_injected']} faults "
              f"injected, all retried -> bit-exact={exact} "
              f"in {time.perf_counter() - t0:.2f}s")

    # 3. crash mid-stream, resume from the atomic checkpoints ----------
    with tempfile.TemporaryDirectory() as ckdir:
        select_streaming(ChunkedDataset("pendigits", n=N // 2, chunk=CHUNK,
                                        seed=0),
                         EPS, block=256, checkpoint_dir=ckdir,
                         checkpoint_every=1)
        from repro.checkpoint.store import available_steps
        print(f"[3] 'crashed' after {available_steps(ckdir)[-1]} chunks; "
              f"resuming...")
        got, stats = select_streaming(src(), EPS, block=256,
                                      checkpoint_dir=ckdir, resume=True)
        exact = (np.array_equal(ref.centers, got.centers)
                 and np.array_equal(ref.weights, got.weights))
        print(f"    resumed to {stats.rows} rows -> bit-exact={exact}, "
              f"f64 mass sum={float(got.weights.sum()):.1f}")

    # 4. serving: degraded publish + retried dispatch + shed load ------
    from repro import streaming
    from repro.core import gaussian

    st = streaming.from_rsde(ref, gaussian(1.0), rank=8, eps=EPS,
                             cap=ref.m)
    srv = streaming.HotSwapServer(st)
    with chaos.active(FaultPlan({"swap.publish": FaultSpec(kind="error",
                                                           every=1)})):
        ok = srv.try_publish(st)
    info = srv.degraded_info()
    print(f"[4] publish failed (ok={ok}): serving the last good snapshot, "
          f"staleness bound {info.staleness_bound:.4g} "
          f"(degraded={info.degraded})")

    fe = BatchingFrontEnd(srv, autostart=False, max_queue=8,
                          retry=RetryPolicy(base_s=1e-3))
    with chaos.active(FaultPlan({"serve.dispatch":
                                 FaultSpec(kind="transient", at=(1,))})):
        futs = [fe.submit(np.asarray(ref.centers)[k % ref.m][None])
                for k in range(12)]
        fe.drain()
    served = shed = 0
    for f in futs:
        try:
            z = f.result(timeout=0)
            served += 1
            tag = getattr(z, "info", None)
        except RequestShed:
            shed += 1
    fe.close()
    print(f"    front end: {served} served (first dispatch retried a "
          f"transient), {shed} shed with an explicit RequestShed, "
          f"0 dropped; degraded responses tagged="
          f"{tag is not None and tag.degraded}")
    srv.try_publish(st)
    print(f"    publisher recovered: degraded={srv.degraded}, "
          f"version={srv.version}")


if __name__ == "__main__":
    main()
