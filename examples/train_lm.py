"""End-to-end training driver: LM + RSKPCA activation probe + checkpointing.

The probe runs the paper's ShDE+RSKPCA on reservoir-sampled hidden states
every N steps — an O(mn + m^3) representation monitor (spectrum, retention,
embedding drift) instead of the O(n^2) naive kernel spectrum.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 30
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300  # TPU-scale
"""
import argparse
import dataclasses

from repro.models.config import ArchConfig
from repro.launch.train import TrainRun, run

PRESETS = {
    # ~10M params: runs a real loss curve on this CPU container
    "tiny": (ArchConfig(
        name="lm-tiny", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024,
        vocab_size=8192, vocab_pad_multiple=128, attn_kind="full",
        attn_chunk=64, subquadratic=False), 8, 128),
    # ~160M params: the 'train ~100M for a few hundred steps' deliverable
    # (a few s/step on one v5e chip; hours on this 1-core CPU container)
    "100m": (ArchConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072,
        vocab_size=32768, vocab_pad_multiple=128, attn_kind="full",
        attn_chunk=256, subquadratic=False), 32, 256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--probe-every", type=int, default=10)
    args = ap.parse_args()

    cfg, batch, seq = PRESETS[args.preset]
    tr = TrainRun(cfg=cfg, global_batch=batch, seq_len=seq, steps=args.steps,
                  ckpt_dir=args.ckpt_dir, ckpt_every=10,
                  probe_every=args.probe_every, lr=1e-3)
    params, opt, history, extras = run(tr)
    losses = [h["loss"] for h in history]
    print(f"\nloss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({len(losses)} steps)")
    probe = extras["probe"]
    if probe and probe.reports:
        print("probe reports:")
        for r in probe.reports:
            print(" ", r.summary())
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
