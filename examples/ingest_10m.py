"""Out-of-core ingestion at n=10M: chunked source -> sharded select -> fit
-> quantized serving snapshot.

    # CI-sized (~1 min):
    PYTHONPATH=src python examples/ingest_10m.py --smoke

    # the real thing (~10 min on CPU; n=10M never materializes):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/ingest_10m.py

The DESIGN.md §9 pipeline end-to-end: a deterministic chunk stream feeds
per-device blocked shadow selection through the async double-buffered
host->device feed; candidate centers reconcile weight-exactly in the
streaming merge under a center budget; the merged set fits Algorithm 1
(sharded/matrix-free above the crossover) in the same pass; and the fitted
projector is published as an int8 serving snapshot.  Peak host memory is
O(chunk), not O(n) — the full dataset exists only as a seed.
"""
import argparse
import dataclasses
import time

import numpy as np

import jax

from repro.core import gaussian
from repro.core.ingest_pipeline import ingest_fit
from repro.data.kpca_datasets import ChunkedDataset
from repro.kernels import quantize
from repro.launch.mesh import data_mesh

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized run: n=200k, center budget 1024")
args = ap.parse_args()

n, chunk, budget = (200_000, 32768, 1024) if args.smoke \
    else (10_000_000, 262144, 32768)

# 1. the dataset is a SEED, not an array: any row regenerates on demand,
#    so restarts and chunk-size changes reproduce bit-exactly
source = ChunkedDataset("pendigits", n=n, chunk=chunk, seed=0)
kernel = gaussian(source.bandwidth())
print(f"source: n={n} d={source.d} in {source.num_chunks} chunks of {chunk} "
      f"({source.nbytes_f32 / 2**20:.0f}MB if it WERE materialized)")

# 2. single-pass select -> fit; chunk rows shard over every available device
ndev = len(jax.devices())
mesh = data_mesh() if ndev > 1 else None
t0 = time.perf_counter()
model, stats = ingest_fit(source, kernel, rank=8, ell=3.0, block=512,
                          budget=budget, mesh=mesh)
print(f"ingested {stats.rows} rows -> m={stats.m} centers on {ndev} "
      f"device(s) in {stats.wall_s:.1f}s "
      f"({stats.rows_per_s:.0f} rows/s, select {stats.select_s:.1f}s + "
      f"fit {stats.fit_s:.1f}s)")
print(f"feed overlap: {stats.overlap_fraction:.2f} "
      f"(feed {stats.feed_s:.2f}s vs stall {stats.stall_s:.2f}s); "
      f"{stats.spilled} over-budget candidates spilled")

# 3. quantized serving snapshot: the int8 transform tier plus its
#    closed-form per-channel error budget (DESIGN.md §8)
serve_model = dataclasses.replace(
    model, kernel=model.kernel.with_precision("int8"))
bound = quantize.projection_error_bound(model.projector, "int8")
q = source.rows(0, 512)  # fresh queries, regenerated from the seed
z = serve_model.transform(q)
z_ref = model.transform(q)
err = np.abs(z - z_ref).max(axis=0)
print(f"int8 snapshot serves ({z.shape[0]}, {z.shape[1]}) embeddings; "
      f"max |int8 - f32| {err.max():.4f} within budget "
      f"{np.asarray(bound).max():.4f}: {bool((err <= np.asarray(bound)).all())}")
