"""Batched serving example: slot-based continuous batching on the decode
program the multi-pod dry-run lowers for decode_32k.

    PYTHONPATH=src python examples/serve_batched.py --arch mixtral_8x7b
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
