"""End-to-end paper experiment: k-nn classification in the (RS)KPCA embedding
(paper Figs. 4-5 protocol) on one dataset.

    PYTHONPATH=src python examples/kpca_classification.py --dataset usps
"""
import argparse
import time

from repro.core import (gaussian, fit_kpca, fit, fit_nystrom,
                        fit_weighted_nystrom, shadow_rsde)
from repro.data import make_dataset, train_test_split, knn_classify, DATASETS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="usps",
                    choices=list(DATASETS))
    ap.add_argument("--n", type=int, default=1500)
    ap.add_argument("--ell", type=float, default=4.0)
    ap.add_argument("--rank", type=int, default=10)
    args = ap.parse_args()

    x, y, sigma = make_dataset(args.dataset, n=args.n)
    k = DATASETS[args.dataset].knn_k
    ker = gaussian(sigma)
    xtr, ytr, xte, yte = train_test_split(x, y)
    m = shadow_rsde(xtr, ker, args.ell).m

    print(f"{args.dataset}: n_t={len(xtr)} sigma={sigma:.2f} "
          f"ell={args.ell} -> m={m}")
    for name, f in {
        "kpca": lambda: fit_kpca(xtr, ker, args.rank),
        "shadow+rskpca": lambda: fit(xtr, ker, args.rank, method="shadow",
                                     ell=args.ell),
        "nystrom": lambda: fit_nystrom(xtr, ker, args.rank, m=m),
        "wnystrom": lambda: fit_weighted_nystrom(xtr, ker, args.rank, m=m),
    }.items():
        t0 = time.perf_counter()
        model = f()
        t_fit = time.perf_counter() - t0
        t0 = time.perf_counter()
        pred = knn_classify(model.transform(xtr), ytr,
                            model.transform(xte), k)
        t_eval = time.perf_counter() - t0
        acc = (pred == yte).mean()
        print(f"  {name:14s} acc={acc:.3f} fit={t_fit*1e3:7.1f}ms "
              f"eval={t_eval*1e3:7.1f}ms m={model.m}")


if __name__ == "__main__":
    main()
