"""Quickstart: Reduced-Set KPCA in ~30 lines (paper Algorithms 1+2).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import gaussian, shadow_rsde, fit_rskpca, fit_kpca, mmd
from repro.data import make_dataset

# 1. data + bandwidth (median heuristic)
x, y, sigma = make_dataset("pendigits", n=1500)
kernel = gaussian(sigma)

# 2. shadow density estimate: single-pass eps-cover with eps = sigma/ell
rsde = shadow_rsde(x, kernel, ell=4.0)
print(f"ShDE: {rsde.m}/{rsde.n} centers retained "
      f"({100 * rsde.retention:.1f}% of the data)")

# 3. reduced-set KPCA: eigendecompose the m x m weighted Gram (not n x n!)
model = fit_rskpca(rsde, kernel, rank=5)
embedding = model.transform(x[:10])
print("embedding of 10 points:\n", np.round(embedding, 3))

# 4. how good is the approximation? (Theorem 5.1 bound check)
val = mmd.mmd_weighted(kernel, x, rsde.centers, rsde.weights)
print(f"MMD(KDE, ShDE) = {val:.4f}  <=  bound {kernel.mmd_bound(4.0):.4f}")

# 5. versus exact KPCA
exact = fit_kpca(x, kernel, rank=5)
print(f"top-5 eigenvalues  rskpca: {np.round(model.eigvals, 4)}")
print(f"                   kpca  : {np.round(exact.eigvals, 4)}")
