"""Quickstart: Reduced-Set KPCA on the real hot path (~40 lines).

    PYTHONPATH=src python examples/quickstart.py

This exercises the current API surface (DESIGN.md §3, §5): the one-call
``fit`` front door (blocked Algorithm 2 selection + fused Pallas weighted
Gram + top-r eigensolve under one jit), the ``Kernel.backend`` compute
switch, bf16 mixed precision, and the sharded fit/serve path over a device
mesh.
"""
import numpy as np

from repro.core import fit, gaussian, mmd
from repro.data import make_dataset
from repro.launch.mesh import data_mesh

# 1. data + bandwidth (median heuristic)
x, y, sigma = make_dataset("pendigits", n=1500)
kernel = gaussian(sigma)  # backend="pallas", precision="f32" by default

# 2. one-call fit: ShDE centers from eps = sigma/ell, then Algorithm 1 on
#    the m x m weighted Gram (never n x n)
model = fit(x, kernel, rank=5, method="shadow", ell=4.0)
print(f"ShDE kept {model.m}/{len(x)} centers "
      f"({100.0 * model.m / len(x):.1f}% of the data)")
print(f"top-5 eigenvalues: {np.round(model.eigvals, 4)}")

# 3. serving: fused kernel-eval + projection, streamed in fixed chunks so a
#    ragged query stream compiles exactly once
z = model.transform(x[:10])
print("embedding of 10 points:\n", np.round(z, 3))

# 4. the parity/precision switches on the SAME pipeline:
#    backend="dense" is the pure-jnp f32 oracle, precision="bf16" feeds
#    bf16 MXU operands with f32 accumulation
oracle = fit(x, kernel, rank=5, method="shadow", ell=4.0, backend="dense")
half = fit(x, kernel, rank=5, method="shadow", ell=4.0, precision="bf16")
print(f"|pallas - dense| eigval gap: "
      f"{np.abs(model.eigvals - oracle.eigvals).max():.2e}")
print(f"|bf16 - f32|    eigval gap: "
      f"{np.abs(model.eigvals - half.eigvals).max():.2e}")

# 5. the sharded pipeline: two-level distributed selection, row-sharded Gram
#    assembly, sharded serving (1 device here; a pod scales the axis)
mesh = data_mesh()
sharded = fit(x, kernel, rank=5, method="shadow", ell=4.0, mesh=mesh)
print("sharded (two-level) fit kept", sharded.m, "centers")
# sharded SERVING of the same operator matches single-device serving
z_mesh = model.transform(x[:10], mesh=mesh)
print("sharded serve parity:", bool(np.allclose(z, z_mesh, atol=1e-4)))

# 6. how good is the reduced operator? Theorem 5.1 bounds the MMD between
#    the KDE and ANY shadow quantization at this ell
print(f"worst-case MMD bound at ell=4: {kernel.mmd_bound(4.0):.4f}")
