"""Pallas kernel microbenchmarks (interpret mode on CPU = correctness-path
timing; the BlockSpec tiling targets TPU v5e VMEM — see kernels/*.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from benchmarks.common import timeit, emit


def main(fast: bool = True):
    rng = np.random.default_rng(0)
    shapes = [(1024, 128, 64), (2048, 256, 256)] if fast else \
        [(1024, 128, 64), (4096, 512, 256), (8192, 1024, 520)]
    for n, m, d in shapes:
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        w = jnp.asarray(rng.uniform(1, 4, size=m), jnp.float32)
        a = jnp.asarray(rng.normal(size=(m, 8)), jnp.float32)

        t_ref = timeit(lambda: jax.block_until_ready(
            ref.gram_ref(x, c, 3.0, 2, None, w)), repeat=3)
        t_pal = timeit(lambda: jax.block_until_ready(
            ops.gram(x, c, sigma=3.0, wy=w)), repeat=3)
        emit(f"kernel_gram_n{n}_m{m}_d{d}", t_pal,
             ref_us=round(t_ref, 1), impl="pallas_interpret")

        t_ref = timeit(lambda: jax.block_until_ready(
            ref.kpca_project_ref(x, c, a, 3.0, 2)), repeat=3)
        t_pal = timeit(lambda: jax.block_until_ready(
            ops.kpca_project(x, c, a, sigma=3.0)), repeat=3)
        emit(f"kernel_project_n{n}_m{m}_d{d}", t_pal,
             ref_us=round(t_ref, 1), impl="pallas_interpret")

        t_pal = timeit(lambda: jax.block_until_ready(
            ops.shadow_assign(x, c)[0]), repeat=3)
        emit(f"kernel_assign_n{n}_m{m}_d{d}", t_pal, impl="pallas_interpret")


if __name__ == "__main__":
    main()
