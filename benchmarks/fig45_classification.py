"""Paper Figs. 4-5: k-nn classification in the KPCA embedding vs ell
(usps, yale), comparing KPCA / shadow / uniform / Nystrom / WNyström.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    gaussian, fit_kpca, fit_rff, fit_subsampled_kpca, fit_nystrom,
    fit_weighted_nystrom, fit_rskpca, shadow_rsde,
)
from repro.data import make_dataset, train_test_split, knn_classify, DATASETS
from benchmarks.common import timeit, emit, pin_autotune_cache


def run_dataset(name: str, n: int | None, ells, n_runs: int, rank: int):
    x, y, sigma = make_dataset(name, seed=0, n=n)
    k = DATASETS[name].knn_k
    ker = gaussian(sigma)
    for ell in ells:
        rows = {}
        for run in range(n_runs):
            xtr, ytr, xte, yte = train_test_split(x, y, seed=run)
            # warmup=1 everywhere: repeat=1/warmup=0 folded jit compile +
            # autotune measurement into every reported train-time ratio
            # (the pinned cache in main() keeps reruns hermetic too)
            t_ref = timeit(lambda: fit_kpca(xtr, ker, rank), repeat=1,
                           warmup=1)
            ref = fit_kpca(xtr, ker, rank)
            rsde = shadow_rsde(xtr, ker, ell)
            m = max(rsde.m, rank + 1)
            fits = {
                "none": lambda: ref,
                "shadow": lambda: fit_rskpca(shadow_rsde(xtr, ker, ell),
                                             ker, rank),
                "uniform": lambda: fit_subsampled_kpca(xtr, ker, rank, m,
                                                       seed=run),
                "nystrom": lambda: fit_nystrom(xtr, ker, rank, m, seed=run),
                "wnystrom": lambda: fit_weighted_nystrom(xtr, ker, rank, m,
                                                         seed=run),
                # D = m: model-size-matched random-feature comparison
                "rff": lambda: fit_rff(xtr, ker, rank, n_features=m,
                                       seed=run),
            }
            for meth, f in fits.items():
                t_train = t_ref if meth == "none" else timeit(f, repeat=1,
                                                              warmup=1)
                mdl = f()
                tr_emb = mdl.transform(xtr)
                te_emb = mdl.transform(xte)
                acc = float((knn_classify(tr_emb, ytr, te_emb, k) == yte).mean())
                rows.setdefault(meth, []).append(
                    (acc, t_ref / t_train, rsde.retention))
        for meth, vals in rows.items():
            arr = np.array(vals, float).mean(axis=0)
            emit(f"fig45_{name}_{meth}_l{ell:.1f}", 0.0,
                 accuracy=round(float(arr[0]), 4),
                 train_speedup=round(float(arr[1]), 2),
                 retention=round(float(arr[2]), 3))


def main(fast: bool = True):
    pin_autotune_cache()
    ells = [3.0, 4.0, 5.0] if fast else \
        [round(e, 1) for e in np.arange(3.0, 5.01, 0.2)]
    n_runs = 2 if fast else 10
    run_dataset("usps", 1500 if fast else None, ells, n_runs,
                rank=15)
    run_dataset("yale", 1200 if fast else None, ells, n_runs,
                rank=10)


if __name__ == "__main__":
    main()
