"""Poisson open-loop serving latency: continuous batching vs one-at-a-time.

The latency section replays ONE Poisson arrival trace (seeded, open-loop:
requests arrive on schedule whether or not the server kept up) against two
front ends over the SAME hot-swap server:

  * ``single`` — a BatchingFrontEnd whose ``max_batch`` equals the request
    size, so every dispatch carries exactly one request: the
    request-at-a-time baseline, same dispatcher machinery, no coalescing;
  * ``batched`` — continuous batching (max_batch >> request size): arrivals
    landing while a batch is in flight coalesce into the next one.

Arrival rates are derived from the measured single-request service time
``s0`` — ``0.5/s0`` (half load) and ``2.0/s0`` (2x saturated for the
baseline) — so the bench is meaningful on any machine speed.  At 2x
saturation the baseline's queue grows without bound and its p99 explodes;
continuous batching amortizes dispatch overhead across queued requests and
stays bounded.  run.py --serve gates on the batched p99 beating the
baseline p99 at the saturated rate.

The tier section measures bulk transform THROUGHPUT (rows/s) of each
precision tier through the autotuned plan, quantized tiers served from a
publish-time (Aq, scales) pair exactly as swap.HotSwapServer does.  run.py
gates quantized-beats-bf16 on the best quantized tier: int8 carries the
gate everywhere (integer matmul), fp8 is recorded but ungated off-TPU
(e4m3 arithmetic is software-emulated on CPU).

Appends ``mode="serve"`` (latency) and ``mode="serve_tier_*"`` (throughput)
rows to BENCH_rskpca.json.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from benchmarks.rskpca_scale import (BENCH_JSON, _merge_into_bench,
                                     _timed_interleaved)

#: Load points as fractions of single-request saturation (1/s0); the int
#: percentage doubles as the stable row key (mode="serve", n=load_pct).
LOADS = (0.5, 2.0)


def _build_server(m: int, d: int, rank: int, precision: str = "f32",
                  chunk: int = 1024):
    from repro import streaming
    from repro.core import gaussian
    from repro.core.rsde import RSDE

    rng = np.random.default_rng(0)
    c = (rng.normal(size=(m, d)) * 2.0).astype(np.float32)
    w = rng.integers(1, 8, m).astype(np.float64)
    rsde = RSDE(c, w, n=float(w.sum()), scheme="bench")
    ker = gaussian(1.0, precision=precision)
    st = streaming.from_rsde(rsde, ker, rank, eps=0.4, cap=m)
    return streaming.HotSwapServer(st, chunk=chunk)


def _warm_buckets(srv, d: int, lo: int, hi: int) -> None:
    """Compile every pow2 serving bucket in [lo, hi] up front: the latency
    runs must measure serving, not tracing."""
    b = lo
    while b <= hi:
        np.asarray(srv.transform(np.zeros((b, d), np.float32)))
        b *= 2


def _open_loop(frontend, reqs, arrivals) -> np.ndarray:
    """Replay the arrival schedule; per-request latency (s), completion
    measured on the dispatcher thread via the future's done-callback."""
    lat = [None] * len(reqs)
    futs = []
    t0 = time.monotonic()
    for k, (x, at) in enumerate(zip(reqs, arrivals)):
        target = t0 + at
        now = time.monotonic()
        if target > now:
            time.sleep(target - now)
        arrived = time.monotonic()

        def cb(f, k=k, arrived=arrived):
            lat[k] = time.monotonic() - arrived

        futs.append(frontend.submit(x))
        futs[-1].add_done_callback(cb)
    for f in futs:
        f.result(timeout=300)
    return np.asarray(lat, np.float64)


def bench_serve(fast: bool = True, m: int = 512, d: int = 16, rank: int = 8,
                req_rows: int = 4, max_batch: int = 256):
    """Latency + tier-throughput rows; returns the fresh rows."""
    from repro.serving import BatchingFrontEnd

    srv = _build_server(m, d, rank)
    _warm_buckets(srv, d, req_rows, max_batch)

    rng = np.random.default_rng(7)
    n_req = 120 if fast else 300
    pool = [(rng.normal(size=(req_rows, d)) * 2.0).astype(np.float32)
            for _ in range(n_req)]

    # measured single-request service time anchors the arrival rates
    s0 = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        np.asarray(srv.transform(pool[0]))
        s0 = min(s0, time.perf_counter() - t0)

    rows = []
    for load in LOADS:
        rate = load / s0
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n_req))
        lats = {}
        stats = {}
        for name, mb in (("single", req_rows), ("batched", max_batch)):
            fe = BatchingFrontEnd(srv, max_batch=mb, slo_ms=1000.0)
            try:
                lats[name] = _open_loop(fe, pool, arrivals)
            finally:
                fe.close()
            # locked consistent copy — never read .stats fields raw across
            # threads (the dispatcher mutates them under the lock)
            stats[name] = fe.snapshot()
        p = {f"p{q}_{name}_ms": round(
                float(np.percentile(lats[name], q)) * 1e3, 2)
             for name in lats for q in (50, 99)}
        row = dict(
            n=int(load * 100), mode="serve", load=load,
            rate_hz=round(rate, 1), requests=n_req, req_rows=req_rows,
            m=m, service_s0_ms=round(s0 * 1e3, 3), **p,
            p99_speedup=round(p["p99_single_ms"]
                              / max(p["p99_batched_ms"], 1e-3), 2),
            batches_single=stats["single"].batches,
            batches_batched=stats["batched"].batches,
            max_batch_rows=stats["batched"].max_batch_rows,
        )
        rows.append(row)
        emit(f"rskpca_serve_load{row['n']}", p["p99_batched_ms"] * 1e3,
             **{k: v for k, v in row.items() if k not in ("n", "mode")})

    rows += bench_serve_tiers(fast=fast, m=m, d=d, rank=rank)
    _merge_into_bench(rows)
    print(f"# appended serve rows to {BENCH_JSON}", flush=True)
    return rows


def bench_serve_tiers(fast: bool = True, m: int = 512, d: int = 16,
                      rank: int = 8, n: int = 8192):
    """Bulk-transform throughput per precision tier (autotuned plan each;
    quantized projectors pre-quantized, as at snapshot publish)."""
    import jax

    from repro.kernels import ops as kernel_ops
    from repro.kernels import quantize

    rng = np.random.default_rng(1)
    x = (rng.normal(size=(n, d)) * 2.0).astype(np.float32)
    c = (rng.normal(size=(m, d)) * 2.0).astype(np.float32)
    a = (rng.normal(size=(m, rank)) * 0.3).astype(np.float32)

    def run(prec):
        pq = (quantize.quantize_projector(a, prec)
              if prec in quantize.QUANT_PRECISIONS else None)
        return lambda: jax.block_until_ready(kernel_ops.kpca_project(
            x, c, a, sigma=1.0, p=2, precision=prec, projector_q=pq))

    tiers = ("f32", "bf16", "int8", "fp8")
    best, _ = _timed_interleaved({p: run(p) for p in tiers},
                                 reps=2 if fast else 3)
    on_tpu = kernel_ops._on_tpu()
    rows = []
    for prec in tiers:
        t = best[prec]
        rows.append(dict(
            m=m, mode=f"serve_tier_{prec}", n_rows=n,
            transform_s=round(t, 5),
            rows_per_s=round(n / t, 1),
            vs_bf16=round(best["bf16"] / t, 2),
            gated=bool(prec == "int8" or (prec == "fp8" and on_tpu)),
        ))
        emit(f"rskpca_serve_tier_{prec}", t * 1e6,
             rows_per_s=rows[-1]["rows_per_s"], vs_bf16=rows[-1]["vs_bf16"])
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    bench_serve(fast=not args.full)
