"""ISSUE 8: the paper's method comparison (Table 2 / Figs. 4-5) at 100x the
paper's n, every method on the optimized stack.

Three measurement groups, all writing ``mode="methods"`` rows to
BENCH_rskpca.json (the rows ``core.methods.select_method`` reads as the
measured accuracy-vs-time-vs-memory Pareto):

  1. ``bench_gate`` — the CI gate point (n=262144, m=2048, pendigits):
     the NEW ``fit_nystrom`` (jax.random landmarks, solver-ladder eigensolve,
     streamed ``gram_matvec`` extension) against the PRE-PR dense
     implementation replicated verbatim, interleaved min-of-reps; gates
     ``fit_speedup >= 5`` and knn accuracy within 1pt of the dense oracle.
     Also rows for wnystrom / rff at the same n for the Pareto.
  2. ``bench_structural`` — no-dense-Gram certificates: the matrix-free
     landmark eigensolve lowers with NO m x m buffer at m=8192 (XLA
     memory-analysis, PR-5 style), and the gate-point nystrom fit's peak
     live-buffer bytes stay far below one n x m Gram.
  3. ``bench_scale`` — out-of-core certificates at n=1M: each method fits
     from a ChunkedDataset in a subprocess with peak live-buffer bytes
     < 25% of the materialized dataset (ChunkedDataset has no labels, so
     1M rows record perf + residency; accuracy parity lives at the gate
     point where labels exist).

Method knobs at the gate point: nystrom/wnystrom share m=2048; rff gets
D=512 (n x D^2 covariance flops dominate its fit — D=512 holds the smoke
budget while landing knn accuracy in the same band).  At n=1M the children
use m=1024 / D=256 for the same reason.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import RssSampler, emit, pin_autotune_cache
from benchmarks.rskpca_scale import (BENCH_JSON, _merge_into_bench,
                                     _timed_interleaved)

GATE_N = 262144
GATE_M = 2048
GATE_D = 512
RANK = 8
KNN_SUB = 4096  # train and test subset size for the accuracy columns


def _dense_nystrom_fit(x, ker, rank: int, m: int, seed: int = 0):
    """The PRE-PR ``fit_nystrom`` replicated verbatim as the perf/accuracy
    baseline: host np.random landmarks, fully materialized n x m and m x m
    dense Grams, unfused extension arithmetic."""
    import jax
    import jax.numpy as jnp
    from repro.core.kernels_math import gram_matrix
    from repro.core.rskpca import _top_eigh

    xj = jnp.asarray(x, jnp.float32)
    n = xj.shape[0]
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.choice(n, size=m, replace=False))
    landmarks = xj[idx]
    dker = ker.with_backend("dense")
    k_nm = gram_matrix(dker, xj, landmarks)           # (n, m) materialized
    k_mm = gram_matrix(dker, landmarks, landmarks)    # (m, m) materialized
    lam_m, u_m = _top_eigh(k_mm / m, rank)
    lam_m = jnp.maximum(lam_m, 1e-12)
    v = jnp.sqrt(m / n) * (k_nm / m) @ (u_m / lam_m[None, :])
    proj = v / jnp.sqrt(lam_m)[None, :] / np.sqrt(n)
    jax.block_until_ready(proj)
    return np.asarray(proj), np.asarray(lam_m)


def _model_bytes(model) -> int:
    """f32 bytes the fitted model retains (paper Table 2 storage row)."""
    extra = model.phase.size if getattr(model, "phase", None) is not None \
        else 0
    return 4 * (model.centers.size + model.projector.size + extra)


def _knn_accs(models: dict, x, y, k: int) -> dict:
    """knn accuracy per model on a fixed train/test subsample (one draw for
    every model, so the accuracy columns differ only through the fits)."""
    from repro.data import knn_classify

    rng = np.random.default_rng(0)
    perm = rng.permutation(len(x))
    tr, te = perm[:KNN_SUB], perm[KNN_SUB : 2 * KNN_SUB]
    accs = {}
    for name, model in models.items():
        tr_emb = model.transform(x[tr])
        te_emb = model.transform(x[te])
        accs[name] = float((knn_classify(tr_emb, y[tr], te_emb, k)
                            == y[te]).mean())
    return accs


def bench_gate(fast: bool = True) -> list:
    """The n=262144 comparison rows + the nystrom speedup/accuracy gate."""
    from repro.core import (KPCAModel, fit_nystrom, fit_rff, fit_stream,
                            gaussian)
    from repro.data import DATASETS, make_dataset
    from repro.core.ingest_pipeline import pad_block

    x, y, sigma = make_dataset("pendigits", seed=0, n=GATE_N)
    ker = gaussian(sigma)
    k = DATASETS["pendigits"].knn_k

    box = {}

    def dense_fit():
        box["dense"] = _dense_nystrom_fit(x, ker, RANK, GATE_M, seed=0)
        return box["dense"]

    def new_fit():
        box["new"] = fit_nystrom(x, ker, RANK, GATE_M, seed=0)
        return box["new"]

    best, _ = _timed_interleaved(
        {"fit_dense": dense_fit, "fit_new": new_fit}, 1 if fast else 2)

    # peak live-buffer bytes of one fresh new-path fit (warm): the runtime
    # no-n x m certificate — one n x m f32 Gram would be 4*n*m bytes
    samp = RssSampler().start()
    new_fit()
    samp.stop()
    nm_bytes = 4 * GATE_N * GATE_M
    peak_live_frac_nm = samp.peak_live / nm_bytes

    # wnystrom: streaming mini-batch k-means + Algorithm-1 fit (the resident
    # scan-based k-means would materialize an (n, m) one-hot per iteration
    # at this n; the stream path is the optimized-stack route being gated)
    def wn_chunks():
        for s in range(0, GATE_N, 65536):
            xb, ok = pad_block(x[s : s + 65536], 65536)
            yield xb, int(ok.sum())

    fit_stream(wn_chunks(), ker, RANK, method="wnystrom", m=GATE_M)  # warm
    t0 = time.perf_counter()
    wn_model, _ = fit_stream(wn_chunks(), ker, RANK, method="wnystrom",
                             m=GATE_M)
    wn_s = time.perf_counter() - t0

    fit_rff(x, ker, RANK, n_features=GATE_D)  # warm
    t0 = time.perf_counter()
    rff_model = fit_rff(x, ker, RANK, n_features=GATE_D)
    rff_s = time.perf_counter() - t0

    proj_dense, lam_dense = box["dense"]
    oracle = KPCAModel(kernel=ker, centers=np.asarray(x, np.float32),
                       projector=proj_dense, eigvals=lam_dense,
                       method="nystrom-dense")
    ny_model = box["new"]
    accs = _knn_accs({"dense": oracle, "nystrom": ny_model,
                      "wnystrom": wn_model, "rff": rff_model}, x, y, k)

    speedup = best["fit_dense"] / best["fit_new"]
    rows = [
        dict(mode="methods", n=GATE_N, method="nystrom", m=GATE_M, rank=RANK,
             fit_s=round(best["fit_new"], 4),
             dense_fit_s=round(best["fit_dense"], 4),
             fit_speedup=round(speedup, 2),
             knn_acc=round(accs["nystrom"], 4),
             knn_acc_dense=round(accs["dense"], 4),
             model_bytes=_model_bytes(ny_model),
             peak_live_frac_nm=round(peak_live_frac_nm, 4)),
        dict(mode="methods", n=GATE_N, method="wnystrom", m=GATE_M,
             rank=RANK, fit_s=round(wn_s, 4),
             knn_acc=round(accs["wnystrom"], 4),
             model_bytes=_model_bytes(wn_model)),
        dict(mode="methods", n=GATE_N, method="rff", m=GATE_D, rank=RANK,
             fit_s=round(rff_s, 4), knn_acc=round(accs["rff"], 4),
             model_bytes=_model_bytes(rff_model)),
    ]
    for r in rows:
        emit(f"methods_{r['method']}_n{r['n']}", r["fit_s"] * 1e6,
             **{k_: v for k_, v in r.items()
                if k_ not in ("mode", "n", "fit_s")})
    return rows


def bench_structural(m: int = 8192) -> None:
    """No-dense-Gram certificates (PR-5 memory-analysis idiom): the
    matrix-free landmark eigensolve must lower with no m x m tensor and a
    peak temp far below one materialized Gram."""
    import jax.numpy as jnp
    from repro.core import gaussian
    from repro.core.nystrom import _landmark_eigs_matfree
    from repro.kernels import ops as kernel_ops

    assert kernel_ops.matfree_fit(m), \
        f"m={m} sits below the matrix-free crossover; raise m"
    ker = gaussian(1.0)
    lowered = _landmark_eigs_matfree.lower(
        jnp.zeros((m, 16), jnp.float32), ker, RANK)
    assert f"{m}x{m}" not in lowered.as_text(), \
        "matrix-free landmark eigensolve lowered an m x m tensor"
    temp = lowered.compile().memory_analysis().temp_size_in_bytes
    assert temp < 4 * m * m, \
        f"matfree landmark solve peak temp {temp} ~ a dense m x m Gram"
    emit(f"methods_structural_m{m}", 0.0, temp_bytes=int(temp),
         gram_bytes=4 * m * m, ok=True)


_SCALE_CHILD = """
import os, time
os.environ.setdefault("JAX_PLATFORMS", "cpu")
from benchmarks.common import RssSampler, pin_autotune_cache
pin_autotune_cache()
from benchmarks.methods_bench import _model_bytes
from repro.core import fit_stream, gaussian
from repro.data import ChunkedDataset

method, n, mknob = {method!r}, {n}, {mknob}
sigma = ChunkedDataset("pendigits", n=4096, chunk=4096, seed=0).bandwidth()
ker = gaussian(sigma)
# compile warmup at the production chunk shape so the timed 1M pass
# measures the pipeline, not tracing
warm = ChunkedDataset("pendigits", n=131072, chunk=65536, seed=0)
fit_stream(warm, ker, {rank}, method=method, m=mknob)
ds = ChunkedDataset("pendigits", n=n, chunk=65536, seed=0)
samp = RssSampler().start()
t0 = time.perf_counter()
model, stats = fit_stream(ds, ker, {rank}, method=method, m=mknob)
wall = time.perf_counter() - t0
samp.stop()
frac = samp.peak_live / ds.nbytes_f32
print(f"SCALE method={{method}} n={{n}} m={{stats.m}} wall_s={{wall:.3f}} "
      f"rows_per_s={{stats.rows / wall:.0f}} "
      f"peak_live={{samp.peak_live}} peak_live_frac={{frac:.4f}} "
      f"model_bytes={{_model_bytes(model)}}")
"""


def bench_scale(n: int = 1_048_576, methods=("nystrom", "wnystrom", "rff")
                ) -> list:
    """Out-of-core fits at n=1M, one subprocess per method (fresh process =
    honest peak-residency accounting).  ``peak_live_frac`` is the out-of-core
    certificate run.py gates at < 0.25: device-resident bytes never approach
    the materialized dataset.  (nystrom's O(nd) retained model is a HOST
    numpy buffer — the method's honest Table-2 storage — and deliberately
    not counted as device residency.)"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + repo
    rows = []
    for method in methods:
        mknob = 256 if method == "rff" else 1024
        child = _SCALE_CHILD.format(method=method, n=n, mknob=mknob,
                                    rank=RANK)
        r = subprocess.run([sys.executable, "-c", child], env=env,
                           capture_output=True, text=True, timeout=1800)
        if r.returncode != 0:
            print(r.stderr[-3000:])
            raise SystemExit(f"bench_scale child failed for {method}")
        for line in r.stdout.splitlines():
            if not line.startswith("SCALE"):
                continue
            kv = dict(p.split("=") for p in line.split()[1:])
            row = dict(
                mode="methods", n=int(kv["n"]), method=kv["method"],
                m=int(kv["m"]), rank=RANK,
                fit_s=round(float(kv["wall_s"]), 3),
                rows_per_s=int(float(kv["rows_per_s"])),
                peak_live_bytes=int(kv["peak_live"]),
                peak_live_frac=round(float(kv["peak_live_frac"]), 4),
                model_bytes=int(kv["model_bytes"]),
                out_of_core=True,
            )
            rows.append(row)
            emit(f"methods_{method}_n{row['n']}", row["fit_s"] * 1e6,
                 **{k: v for k, v in row.items()
                    if k not in ("mode", "n", "fit_s")})
    return rows


def main(fast: bool = True):
    pin_autotune_cache()
    bench_structural()
    rows = bench_gate(fast=fast)
    rows += bench_scale()
    _merge_into_bench(rows)
    print(f"# appended methods rows to {BENCH_JSON}", flush=True)
    return rows


if __name__ == "__main__":
    main()
