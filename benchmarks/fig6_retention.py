"""Paper Fig. 6: percentage of data retained by the ShDE vs ell."""
from __future__ import annotations

import numpy as np

from repro.core import gaussian, shadow_rsde
from repro.data import make_dataset
from benchmarks.common import timeit, emit


def main(fast: bool = True):
    caps = {"german": None, "pendigits": 1500 if fast else None,
            "usps": 1200 if fast else None, "yale": 1200 if fast else None}
    ells = [3.0, 3.5, 4.0, 4.5, 5.0] if fast else \
        [round(e, 1) for e in np.arange(3.0, 5.01, 0.1)]
    for name, n in caps.items():
        x, _, sigma = make_dataset(name, seed=0, n=n)
        ker = gaussian(sigma)
        for ell in ells:
            t = timeit(lambda: shadow_rsde(x, ker, ell), repeat=1, warmup=0)
            r = shadow_rsde(x, ker, ell)
            emit(f"fig6_{name}_l{ell:.1f}", t,
                 retention=round(r.retention, 4), m=r.m, n=r.n)


if __name__ == "__main__":
    main()
