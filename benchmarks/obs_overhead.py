"""Observability overhead gate — ``mode="obs"`` rows of BENCH_rskpca.json.

The telemetry layer (DESIGN.md §16) promises ~zero cost while disabled and
<= 2% while enabled.  This bench measures both promises on the two hottest
instrumented paths:

  * ``serve`` — deterministic dispatch latency of the continuous-batching
    front end: ``autostart=False`` + ``step()`` so every measured sample is
    one coalesce + one fused transform + one scatter, with no Poisson
    sleeps or dispatcher-thread wakeups adding noise.  Gate metric: MEDIAN
    dispatch latency — instrumentation cost is per-dispatch, so it moves
    the whole distribution, and the median is the statistic a
    share-throttled box can actually resolve (the p99 of a few hundred
    samples is one scheduler hiccup; it is recorded per mode for the
    trajectory but not gated).
  * ``ingest`` — ``select_streaming`` rows/s over a small chunked source
    (the per-chunk span + gauge path of core/ingest_pipeline.py).

Methodology: share-throttled CI boxes drift on second scales, so the
estimator is PAIRED — each rep runs ``off``, ``on``, ``off`` back-to-back
and compares the on leg against the MEAN of its two bracketing off legs
(the unbiased local baseline: charging the faster off leg would charge
half the box's drift to the instrumentation).  Per-rep fractions are then
reduced by MEDIAN across reps, so one rep landing in a slow scheduler
window cannot set the result.  The same per-rep pairing yields the A/A
delta |off1 - off2| / base — the drift over exactly the leg spacing the
on-vs-off comparison bridges, i.e. the measurement's true noise floor.
The gate is

    overhead_frac <= max(OBS_OVERHEAD_FRAC_MAX, aa_delta_frac)

i.e. enabled overhead must sit under 2% OR under the bench's demonstrated
noise — a run that cannot resolve 2% must not fail on its own jitter, but
a real regression (overhead above both) always fails.  Both fractions are
recorded in the row, so the trajectory shows when overhead creeps.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from benchmarks.rskpca_scale import BENCH_JSON, _merge_into_bench

#: Enabled-telemetry budget: the DESIGN.md §16 contract ("<= 2% on the
#: serving and ingest hot paths").  run.py --obs gates on this, floored by
#: the run's own A/A noise.
OBS_OVERHEAD_FRAC_MAX = 0.02

#: Paired off/on/off cycles; the median across reps is the estimate, so
#: odd counts >= 5 keep one outlier rep from mattering at all.
_REPS = 5
_DISPATCHES = 60
_REQS_PER_DISPATCH = 4
_REQ_ROWS = 4

_INGEST_N = 24576
_INGEST_CHUNK = 4096


def _serve_lats_ms(srv, d: int) -> np.ndarray:
    """Per-dispatch latencies (ms) of one step()-driven serving run."""
    from repro.serving import BatchingFrontEnd

    rng = np.random.default_rng(11)
    reqs = [(rng.normal(size=(_REQ_ROWS, d)) * 2.0).astype(np.float32)
            for _ in range(_REQS_PER_DISPATCH)]
    fe = BatchingFrontEnd(srv, max_batch=256, slo_ms=1000.0, autostart=False)
    lat = np.empty(_DISPATCHES)
    for k in range(_DISPATCHES):
        futs = [fe.submit(x) for x in reqs]
        t0 = time.perf_counter()
        fe.step()
        lat[k] = time.perf_counter() - t0
        for f in futs:
            f.result(timeout=60)
    fe.close()
    return lat * 1e3


def _ingest_rows_per_s(eps: float) -> float:
    """Throughput of one select_streaming pass over the chunked source."""
    from repro.core.ingest_pipeline import select_streaming
    from repro.data.kpca_datasets import ChunkedDataset

    src = ChunkedDataset("pendigits", n=_INGEST_N, chunk=_INGEST_CHUNK,
                         seed=0)
    t0 = time.perf_counter()
    _, stats = select_streaming(src, eps, block=256, budget=1024)
    wall = time.perf_counter() - t0
    assert stats.rows == _INGEST_N
    return _INGEST_N / wall


def _aba_triples(run) -> list:
    """``_REPS`` paired (off1, on, off2) measurements; obs left disabled."""
    from repro import obs

    triples = []
    for _ in range(_REPS):
        vals = {}
        for leg in ("off1", "on", "off2"):
            (obs.enable if leg == "on" else obs.disable)()
            try:
                vals[leg] = run()
            finally:
                obs.disable()
        triples.append((vals["off1"], vals["on"], vals["off2"]))
    return triples


def _fracs(triples, better):
    """Median-across-reps (overhead_frac, aa_delta_frac) of paired reps.

    Each rep's on leg compares against the mean of its bracketing off legs;
    ``better`` orients the sign: ``min`` for latency (overhead = on above
    baseline), ``max`` for throughput (overhead = on below baseline)."""
    ovs, aas = [], []
    for off1, on, off2 in triples:
        base = 0.5 * (off1 + off2)
        aas.append(abs(off1 - off2) / base)
        ovs.append((on - base) / base if better is min
                   else (base - on) / base)
    return float(np.median(ovs)), float(np.median(aas))


def bench_obs(fast: bool = True, m: int = 512, d: int = 16, rank: int = 8):
    """Measure enabled-vs-disabled on serve + ingest; returns fresh rows."""
    from benchmarks.serve_latency import _build_server, _warm_buckets
    from repro import obs
    from repro.data.kpca_datasets import ChunkedDataset

    obs.disable()  # a stray REPRO_OBS=1 must not poison the baseline legs

    srv = _build_server(m, d, rank)
    _warm_buckets(srv, d, _REQ_ROWS, 256)
    raw = _aba_triples(lambda: _serve_lats_ms(srv, d))
    triples = [tuple(float(np.median(leg)) for leg in t) for t in raw]
    ov, aa = _fracs(triples, min)
    # pooled percentiles per mode, for the trajectory (not gated)
    off_all = np.concatenate([np.concatenate((t[0], t[2])) for t in raw])
    on_all = np.concatenate([t[1] for t in raw])
    rows = [dict(
        n=_DISPATCHES, mode="obs", method="serve",
        p50_off_ms=round(float(np.median(off_all)), 3),
        p50_on_ms=round(float(np.median(on_all)), 3),
        p99_off_ms=round(float(np.percentile(off_all, 99)), 3),
        p99_on_ms=round(float(np.percentile(on_all, 99)), 3),
        overhead_frac=round(ov, 4), aa_delta_frac=round(aa, 4),
        budget_frac=OBS_OVERHEAD_FRAC_MAX,
    )]
    emit("rskpca_obs_serve", float(np.median(on_all)) * 1e3,
         overhead_frac=rows[0]["overhead_frac"],
         aa_delta_frac=rows[0]["aa_delta_frac"])

    sigma = ChunkedDataset("pendigits", n=_INGEST_N, chunk=_INGEST_CHUNK,
                           seed=0).bandwidth()
    eps = sigma / 4.0
    _ingest_rows_per_s(eps)  # warmup: compile select/merge programs
    triples = _aba_triples(lambda: _ingest_rows_per_s(eps))
    ov, aa = _fracs(triples, max)
    offs = [t[0] for t in triples] + [t[2] for t in triples]
    ons = [t[1] for t in triples]
    rows.append(dict(
        n=_INGEST_N, mode="obs", method="ingest",
        rows_per_s_off=round(float(np.median(offs)), 1),
        rows_per_s_on=round(float(np.median(ons)), 1),
        overhead_frac=round(ov, 4), aa_delta_frac=round(aa, 4),
        budget_frac=OBS_OVERHEAD_FRAC_MAX,
    ))
    emit("rskpca_obs_ingest", _INGEST_N / float(np.median(ons)) * 1e6,
         overhead_frac=rows[1]["overhead_frac"],
         aa_delta_frac=rows[1]["aa_delta_frac"])

    _merge_into_bench(rows)
    print(f"# appended obs rows to {BENCH_JSON}", flush=True)
    return rows


if __name__ == "__main__":
    bench_obs()
