"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` runs the paper's
full protocol (50 runs, 0.1 ell grid, full n); default is the fast CI-scale
variant with identical structure.
"""
from __future__ import annotations

import argparse
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (e.g. table2,fig6)")
    ap.add_argument("--smoke", action="store_true",
                    help="<60s perf smoke: only the RSKPCA fit/transform "
                         "scaling bench; writes BENCH_rskpca.json")
    args = ap.parse_args()
    fast = not args.full

    if args.smoke:
        from benchmarks import rskpca_scale
        print("# --- rskpca fit/transform smoke ---", flush=True)
        rskpca_scale.bench_fit(fast=True)
        return

    from benchmarks import (table2_cost, fig23_eigenembedding,
                            fig45_classification, fig6_retention,
                            fig78_rsde_schemes, kernel_bench, roofline,
                            rskpca_scale)
    modules = {
        "table2": table2_cost, "fig23": fig23_eigenembedding,
        "fig45": fig45_classification, "fig6": fig6_retention,
        "fig78": fig78_rsde_schemes, "kernels": kernel_bench,
        "roofline": roofline, "rskpca_scale": rskpca_scale,
    }
    selected = (args.only.split(",") if args.only else list(modules))
    failures = []
    for name in selected:
        print(f"# --- {name} ---", flush=True)
        try:
            modules[name].main(fast=fast)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == '__main__':
    main()
