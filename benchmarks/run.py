"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` runs the paper's
full protocol (50 runs, 0.1 ell grid, full n); default is the fast CI-scale
variant with identical structure.
"""
from __future__ import annotations

import argparse
import traceback


def _assert_no_fit_regression() -> None:
    """Perf gate: every row of BENCH_rskpca.json must report fit_speedup
    >= 1.0 (the n=2048 small-n regression must stay gone — the autotuned
    dense crossover of DESIGN.md §3 is what buys it)."""
    import json
    from benchmarks.rskpca_scale import BENCH_JSON
    with open(BENCH_JSON) as f:
        rows = json.load(f)["rows"]
    fresh = [r for r in rows if not r.get("stale") and "fit_speedup" in r]
    bad = [r for r in fresh if r["fit_speedup"] < 1.0]
    assert not bad, f"fit_speedup regression below 1.0x: {bad}"
    print(f"# fit_speedup >= 1.0 across all {len(fresh)} freshly-measured "
          f"rows", flush=True)


def _assert_matfree_row() -> None:
    """Acceptance gate for the matrix-free fit (ISSUE 5): a freshly-measured
    mode="matfree" row at m >= 8192 must exist, beat the seed dense path
    (fit_speedup >= 1.0), and show peak temp memory >= 4x below the
    Gram-materializing path (the no-m x m-buffer certificate measured by
    bench_matfree via XLA's memory analysis)."""
    import json
    from benchmarks.rskpca_scale import BENCH_JSON
    with open(BENCH_JSON) as f:
        rows = json.load(f)["rows"]
    fresh = [r for r in rows
             if r.get("mode") == "matfree" and not r.get("stale")]
    assert fresh, "no fresh matfree row was measured"
    bad = [r for r in fresh
           if r["m"] < 8192 or r["fit_speedup"] < 1.0
           or r["peak_mem_ratio"] < 4.0]
    assert not bad, f"matfree gate failed: {bad}"
    print(f"# matfree gate passed on {len(fresh)} row(s): "
          f"speedup {fresh[0]['fit_speedup']}x, "
          f"peak-mem ratio {fresh[0]['peak_mem_ratio']}x", flush=True)


def _assert_stream_speedup() -> None:
    """Perf gate for the streaming subsystem: every freshly-measured
    mode="stream" row must show the incremental operator patch beating a
    full refit (update_speedup >= 1.0; at m=4096 the expectation is >=5x —
    see DESIGN.md §7)."""
    import json
    from benchmarks.rskpca_scale import BENCH_JSON
    with open(BENCH_JSON) as f:
        rows = json.load(f)["rows"]
    fresh = [r for r in rows
             if r.get("mode") == "stream" and not r.get("stale")]
    assert fresh, "no fresh stream rows were measured"
    bad = [r for r in fresh if r["update_speedup"] < 1.0]
    assert not bad, f"incremental update slower than a full refit: {bad}"
    print(f"# update_speedup >= 1.0 across all {len(fresh)} stream rows",
          flush=True)


def _assert_serve_gate() -> None:
    """Acceptance gates for the latency-SLO serving tier (DESIGN.md §8):

    * at the saturated load point (2x the single-request service rate) the
      continuous-batching p99 must beat the request-at-a-time p99 — the
      whole point of coalescing;
    * the gated quantized transform tier(s) must out-throughput bf16
      (int8 everywhere; fp8 only where hardware executes e4m3 natively —
      ``gated`` is set per-row by bench_serve_tiers).
    """
    import json
    from benchmarks.rskpca_scale import BENCH_JSON
    with open(BENCH_JSON) as f:
        rows = json.load(f)["rows"]
    fresh = [r for r in rows if not r.get("stale")]
    serve = [r for r in fresh if r.get("mode") == "serve"]
    assert serve, "no fresh serve rows were measured"
    sat = [r for r in serve if r["load"] >= 2.0]
    assert sat, f"no saturated-load serve row: {serve}"
    bad = [r for r in sat if r["p99_batched_ms"] > r["p99_single_ms"]]
    assert not bad, f"continuous batching lost on p99 at saturation: {bad}"
    tiers = [r for r in fresh
             if str(r.get("mode", "")).startswith("serve_tier_")
             and r.get("gated")]
    assert tiers, "no gated quantized serve_tier rows were measured"
    slow = [r for r in tiers if r["vs_bf16"] < 1.0]
    assert not slow, f"quantized tier slower than bf16: {slow}"
    print(f"# serve gate passed: p99 {sat[0]['p99_batched_ms']}ms batched vs "
          f"{sat[0]['p99_single_ms']}ms single at load 2.0; "
          f"quant vs bf16 {[r['vs_bf16'] for r in tiers]}x", flush=True)


def _assert_ingest_gate() -> None:
    """Acceptance gates for the out-of-core ingestion pipeline (ISSUE 7):
    every freshly-measured mode="ingest" row must clear the throughput
    floor and show the async feed actually overlapping with selection
    compute (overlap_fraction >= 0.5 — below that the pipeline is
    transfer-bound and the double buffer is not doing its job); rows marked
    ``mem_gated`` (the n=10M point) must additionally keep the sampled peak
    of LIVE buffer bytes under 25% of the dataset's full f32 footprint —
    the certificate that the data truly never materialized (a resident
    dataset would appear as a live 640MB array; see ingest_bench on why
    raw RSS additionally counts XLA interpret-mode scratch)."""
    import json
    from benchmarks.ingest_bench import INGEST_ROWS_PER_S_FLOOR
    from benchmarks.rskpca_scale import BENCH_JSON
    with open(BENCH_JSON) as f:
        rows = json.load(f)["rows"]
    fresh = [r for r in rows
             if r.get("mode") == "ingest" and not r.get("stale")]
    assert fresh, "no fresh ingest rows were measured"
    slow = [r for r in fresh if r["rows_per_s"] < INGEST_ROWS_PER_S_FLOOR]
    assert not slow, \
        f"ingest throughput under the {INGEST_ROWS_PER_S_FLOOR} rows/s " \
        f"floor: {slow}"
    serial = [r for r in fresh if r["overlap_fraction"] < 0.5]
    assert not serial, f"feed/compute overlap below 0.5: {serial}"
    fat = [r for r in fresh
           if r.get("mem_gated") and r["peak_live_frac"] >= 0.25]
    assert not fat, f"peak live buffer bytes >= 25% of the dataset: {fat}"
    print(f"# ingest gate passed on {len(fresh)} row(s): "
          f"{[r['rows_per_s'] for r in fresh]} rows/s, overlap "
          f"{[r['overlap_fraction'] for r in fresh]}", flush=True)


def _assert_methods_gate() -> None:
    """Acceptance gates for the method zoo (ISSUE 8):

    * the gate-point nystrom row (n=262144) must show the optimized fit
      >= 5x over the pre-PR dense implementation, knn accuracy within 1pt
      of the dense oracle, and peak live-buffer bytes far below one n x m
      Gram (the runtime no-n x m certificate);
    * every method must have a fresh out-of-core row at n >= 1M whose peak
      live bytes stay under 25% of the materialized dataset.
    """
    import json
    from benchmarks.rskpca_scale import BENCH_JSON
    with open(BENCH_JSON) as f:
        rows = json.load(f)["rows"]
    fresh = [r for r in rows
             if r.get("mode") == "methods" and not r.get("stale")]
    assert fresh, "no fresh methods rows were measured"
    gate = [r for r in fresh
            if r["method"] == "nystrom" and "fit_speedup" in r]
    assert gate, "no gate-point nystrom row (fit_speedup) was measured"
    slow = [r for r in gate if r["fit_speedup"] < 5.0]
    assert not slow, \
        f"nystrom fit under 5x vs the pre-PR dense implementation: {slow}"
    off = [r for r in gate
           if abs(r["knn_acc"] - r["knn_acc_dense"]) > 0.01]
    assert not off, f"knn accuracy off the dense oracle by > 1pt: {off}"
    fat = [r for r in gate if r["peak_live_frac_nm"] >= 0.25]
    assert not fat, f"nystrom fit peak live bytes ~ an n x m Gram: {fat}"
    for method in ("nystrom", "wnystrom", "rff"):
        big = [r for r in fresh
               if r["method"] == method and r.get("out_of_core")
               and r["n"] >= 1_000_000]
        assert big, f"no fresh out-of-core n>=1M row for {method}"
        resident = [r for r in big if r["peak_live_frac"] >= 0.25]
        assert not resident, \
            f"{method} out-of-core fit held >= 25% of the data live: " \
            f"{resident}"
    print(f"# methods gate passed: nystrom {gate[0]['fit_speedup']}x "
          f"(acc {gate[0]['knn_acc']} vs dense {gate[0]['knn_acc_dense']}), "
          f"all methods out-of-core at n>=1M", flush=True)


def _assert_obs_gate() -> None:
    """Acceptance gate for the telemetry layer (DESIGN.md §16): every
    freshly-measured mode="obs" row must keep enabled-telemetry overhead
    under the 2% budget, floored by the run's own interleaved A/A noise —
    a box too jittery to resolve 2% must not fail on jitter, but overhead
    above both the budget and the noise floor always fails."""
    import json
    from benchmarks.obs_overhead import OBS_OVERHEAD_FRAC_MAX
    from benchmarks.rskpca_scale import BENCH_JSON
    with open(BENCH_JSON) as f:
        rows = json.load(f)["rows"]
    fresh = [r for r in rows if r.get("mode") == "obs" and not r.get("stale")]
    assert len(fresh) >= 2, f"expected serve + ingest obs rows, got {fresh}"
    bad = [r for r in fresh
           if r["overhead_frac"] > max(OBS_OVERHEAD_FRAC_MAX,
                                       r["aa_delta_frac"])]
    assert not bad, f"enabled-telemetry overhead above budget + noise: {bad}"
    print(f"# obs gate passed: overhead "
          f"{[(r['method'], r['overhead_frac']) for r in fresh]} vs budget "
          f"{OBS_OVERHEAD_FRAC_MAX} (A/A noise "
          f"{[r['aa_delta_frac'] for r in fresh]})", flush=True)


def _assert_chaos_gate() -> None:
    """Acceptance gates for the fault-tolerance layer (DESIGN.md §17):

    * the ingest chaos row must be BIT-EXACT against its fault-free twin
      (faults injected > 0, or the run proved nothing) at <= 1.5x slowdown
      with checkpointing on;
    * the serve chaos row must keep faulted p99 within 2x of fault-free,
      drop ZERO requests that were not explicit RequestShed admissions,
      and report a finite staleness bound from the degraded-publish path.
    """
    import json
    import math
    from benchmarks.chaos_bench import (CHAOS_INGEST_SLOWDOWN_MAX,
                                        CHAOS_SERVE_P99_RATIO_MAX)
    from benchmarks.rskpca_scale import BENCH_JSON
    with open(BENCH_JSON) as f:
        rows = json.load(f)["rows"]
    fresh = [r for r in rows
             if r.get("mode") == "chaos" and not r.get("stale")]
    ing = [r for r in fresh if r["method"] == "ingest"]
    srv = [r for r in fresh if r["method"] == "serve"]
    assert ing and srv, f"expected ingest + serve chaos rows, got {fresh}"
    bad = [r for r in ing
           if not r["bit_exact"] or r["injected"] < 1
           or r["slowdown"] > CHAOS_INGEST_SLOWDOWN_MAX]
    assert not bad, f"chaos ingest gate failed: {bad}"
    bad = [r for r in srv
           if r["p99_ratio"] > CHAOS_SERVE_P99_RATIO_MAX
           or r["dropped"] != 0 or r["injected"] < 1
           or not r["degraded"] or not math.isfinite(r["staleness_bound"])]
    assert not bad, f"chaos serve gate failed: {bad}"
    print(f"# chaos gate passed: ingest bit-exact at "
          f"{ing[0]['slowdown']}x ({ing[0]['injected']} faults), serve "
          f"p99 ratio {srv[0]['p99_ratio']} with {srv[0]['shed']} shed / "
          f"0 dropped, staleness bound {srv[0]['staleness_bound']:.4g}",
          flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (e.g. table2,fig6)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast perf smoke: only the RSKPCA fit/transform "
                         "scaling bench; writes BENCH_rskpca.json and "
                         "fails on any fit_speedup < 1.0")
    ap.add_argument("--mesh", action="store_true",
                    help="with --smoke: also bench the sharded fit/transform "
                         "path on a multi-host-device mesh and append the "
                         "rows to BENCH_rskpca.json")
    ap.add_argument("--precision", default="f32", choices=("f32", "bf16"),
                    help="precision for the --mesh sharded rows")
    ap.add_argument("--matfree", action="store_true",
                    help="with --smoke: bench the matrix-free fit at m=8192 "
                         "(vs the seed dense Gram + full eigh path), assert "
                         "no m x m buffer is materialized, and append a "
                         "mode=matfree row to BENCH_rskpca.json")
    ap.add_argument("--stream", action="store_true",
                    help="streaming bench: per-update incremental patch vs "
                         "full refit at m in {256,1024,4096}; appends "
                         "mode=stream rows to BENCH_rskpca.json and fails "
                         "on any update_speedup < 1.0")
    ap.add_argument("--serve", action="store_true",
                    help="serving-latency bench: Poisson open-loop p50/p99 "
                         "of continuous batching vs request-at-a-time, plus "
                         "precision-tier throughput; appends mode=serve "
                         "rows to BENCH_rskpca.json and fails if batching "
                         "loses on p99 at saturation or a gated quantized "
                         "tier is slower than bf16")
    ap.add_argument("--methods", action="store_true",
                    help="method-zoo bench: nystrom/wnystrom/rff on the "
                         "optimized stack at n=262144 (+ out-of-core n=1M "
                         "children); appends mode=methods rows to "
                         "BENCH_rskpca.json and fails if the nystrom fit is "
                         "under 5x vs its pre-PR dense implementation, knn "
                         "accuracy drifts > 1pt off the dense oracle, or "
                         "any method's n=1M fit holds >= 25% of the data "
                         "live")
    ap.add_argument("--ingest", action="store_true",
                    help="out-of-core ingestion bench: end-to-end "
                         "select->fit over the chunked source at n=1M "
                         "(plus n=10M on an 8-device mesh with --full); "
                         "appends mode=ingest rows to BENCH_rskpca.json "
                         "and fails on the rows/s floor, overlap_fraction "
                         "< 0.5, or (n=10M) peak host memory >= 25% of "
                         "the dataset footprint")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-tolerance bench: the same ingest + serving "
                         "workloads fault-free vs under a deterministic "
                         "~1% fault plan; appends mode=chaos rows to "
                         "BENCH_rskpca.json and fails unless the faulted "
                         "ingest is bit-exact at <= 1.5x slowdown and "
                         "faulted serving holds p99 <= 2x with zero "
                         "non-shed drops and a finite staleness bound")
    ap.add_argument("--obs", action="store_true",
                    help="telemetry-overhead bench: interleaved A/B/A of "
                         "obs-enabled vs disabled on the serving dispatch "
                         "and ingest selection paths; appends mode=obs rows "
                         "to BENCH_rskpca.json and fails if enabled "
                         "overhead exceeds both the 2% budget and the "
                         "run's A/A noise floor")
    args = ap.parse_args()
    fast = not args.full
    # provenance: stamp every fresh bench row this process writes with the
    # commit + UTC time that measured it (common.merge_rows applies it)
    from benchmarks import common
    common.set_run_stamp(**common.make_stamp())
    if args.mesh and not args.smoke:
        ap.error("--mesh requires --smoke (the sharded bench extends the "
                 "smoke's BENCH_rskpca.json)")
    if args.matfree and not args.smoke:
        ap.error("--matfree requires --smoke (the matfree bench extends the "
                 "smoke's BENCH_rskpca.json)")

    if args.stream:
        from benchmarks import rskpca_scale
        print("# --- rskpca streaming update vs refit ---", flush=True)
        rskpca_scale.bench_stream(fast=fast)
        _assert_stream_speedup()
        if not args.smoke and not args.serve:
            return

    if args.ingest:
        from benchmarks import ingest_bench
        print("# --- rskpca out-of-core ingestion ---", flush=True)
        ingest_bench.bench_ingest(full=args.full)
        _assert_ingest_gate()
        if not args.smoke and not args.serve and not args.methods:
            return

    if args.methods:
        from benchmarks import methods_bench
        print("# --- method zoo (nystrom / wnystrom / rff) ---", flush=True)
        methods_bench.main(fast=fast)
        _assert_methods_gate()
        if not args.smoke and not args.serve:
            return

    if args.chaos:
        from benchmarks import chaos_bench
        print("# --- fault tolerance (chaos vs fault-free) ---", flush=True)
        chaos_bench.bench_chaos(fast=fast)
        _assert_chaos_gate()
        if not args.smoke and not args.serve:
            return

    if args.obs:
        from benchmarks import obs_overhead
        print("# --- telemetry overhead (obs on vs off) ---", flush=True)
        obs_overhead.bench_obs(fast=fast)
        _assert_obs_gate()
        if not args.smoke and not args.serve:
            return

    if args.serve:
        from benchmarks import serve_latency
        print("# --- rskpca serving latency (continuous batching) ---",
              flush=True)
        serve_latency.bench_serve(fast=fast)
        _assert_serve_gate()
        if not args.smoke:
            return

    if args.smoke:
        from benchmarks import rskpca_scale
        print("# --- rskpca fit/transform smoke ---", flush=True)
        rskpca_scale.bench_fit(fast=True)
        if args.mesh:
            print("# --- sharded fit/transform ---", flush=True)
            rskpca_scale.bench_sharded(precision=args.precision)
        if args.matfree:
            print("# --- matrix-free fit (m=8192) ---", flush=True)
            rskpca_scale.bench_matfree()
            _assert_matfree_row()
        _assert_no_fit_regression()
        return

    from benchmarks import (table2_cost, fig23_eigenembedding,
                            fig45_classification, fig6_retention,
                            fig78_rsde_schemes, kernel_bench, roofline,
                            rskpca_scale)
    modules = {
        "table2": table2_cost, "fig23": fig23_eigenembedding,
        "fig45": fig45_classification, "fig6": fig6_retention,
        "fig78": fig78_rsde_schemes, "kernels": kernel_bench,
        "roofline": roofline, "rskpca_scale": rskpca_scale,
    }
    selected = (args.only.split(",") if args.only else list(modules))
    failures = []
    for name in selected:
        print(f"# --- {name} ---", flush=True)
        try:
            modules[name].main(fast=fast)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == '__main__':
    main()
