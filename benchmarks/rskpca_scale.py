"""Beyond-paper §Perf: scaling the paper's own pipeline (ShDE + RSKPCA).

The headline benchmark (``bench_fit``, also the ``--smoke`` target) compares
the SEED fit/transform path — sequential Algorithm 2, dense Gram, full eigh —
against the current default pipeline — blocked selection, fused Pallas
kernels, top-r LOBPCG — at n in {2k, 8k, 32k}, and writes the results to
``BENCH_rskpca.json`` so successive PRs accumulate a perf trajectory.

Two further measurable-on-CPU optimizations of the paper's technique:

  P1. two-level (distributed) shadow selection vs the paper's sequential
      Algorithm 2 — wall-clock speedup at growing n (8 host devices stand in
      for 8 data-parallel workers) and the MMD cost of the 2-eps cover.
  P2. Pallas gram-kernel arithmetic-intensity table: the VMEM block-size
      rule (kernels/ops.pick_gram_blocks) keeps the MXU fed; we report
      AI(block) = flops/bytes per tile vs the v5e ridge point
      (197e12 / 819e9 ~= 240 flops/byte).

Run inside an 8-device subprocess (the harness keeps the main process at 1
device per the brief).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import emit

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_rskpca.json")


def _seed_fit(x, ker, rank, ell):
    """The seed PR's fit path, replicated verbatim for the perf baseline:
    sequential selection + dense Gram + full O(m^3) eigh."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import shadow_select_host
    from repro.core.kernels_math import gram_matrix_dense

    c, w, _, m = shadow_select_host(x, ker.epsilon(ell))
    cj = jnp.asarray(c, jnp.float32)
    sw = jnp.sqrt(jnp.asarray(w, jnp.float32))
    kt = gram_matrix_dense(ker, cj, cj) * sw[:, None] * sw[None, :] / len(x)
    lam, v = jnp.linalg.eigh(kt)
    lam = jnp.maximum(lam[::-1][:rank], 1e-12)
    proj = (sw[:, None] * v[:, ::-1][:, :rank]) / jnp.sqrt(lam)[None, :] \
        / np.sqrt(len(x))
    return np.asarray(c), np.asarray(proj)


def _seed_transform(ker, centers, proj, q):
    """Seed transform: dense q x m Gram materialized, then the matmul."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core.kernels_math import gram_matrix_dense

    k_qc = gram_matrix_dense(ker, jnp.asarray(q, jnp.float32),
                             jnp.asarray(centers))
    return np.asarray(k_qc @ jnp.asarray(proj))


def _timed_interleaved(fns: dict, reps: int):
    """min-of-reps wall clock for several thunks, measured INTERLEAVED.

    The container's CPU is share-throttled, so multi-hundred-ms slowdown
    windows come and go; timing path A fully and then path B would let one
    window hit only one side and invert a speedup ratio.  Interleaving the
    passes (A, B, A, B, ...) makes a window hit adjacent samples of both
    paths, and min-of-reps then keeps each path's cleanest sample.
    """
    outs = {k: fn() for k, fn in fns.items()}          # compile warmup
    best = {k: float("inf") for k in fns}
    for _ in range(reps):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            outs[k] = fn()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best, outs


def bench_fit(fast: bool = True):
    """fit + transform wall-clock, seed path vs current default, ->JSON.

    ``fast`` (the --smoke / default mode) takes the interleaved min of 3
    timed passes for the small points and a single pass at n=32768 to keep
    the smoke fast; --full takes min-of-3 everywhere.
    """
    from repro.core import gaussian, fit
    from repro.data import make_dataset

    rank, ell = 8, 4.0

    rows = []
    for n in (2048, 8192, 32768):
        # small points are noise-dominated: min-of-3 even in fast mode
        reps = 3 if (not fast or n <= 8192) else 1
        x, _, sigma = make_dataset("pendigits", seed=0, n=n)
        ker = gaussian(sigma)

        # transforms need fitted models: the fit thunks stash their outputs
        # in `box`, and _timed_interleaved's warmup pass (insertion order)
        # populates it before the transform thunks first run
        box = {}

        def seed_fit():
            box["seed"] = _seed_fit(x, ker, rank, ell)
            return box["seed"]

        def new_fit():
            box["mdl"] = fit(x, ker, rank, method="shadow", ell=ell)
            return box["mdl"]

        best, outs = _timed_interleaved({
            "fit_seed": seed_fit,
            "fit_new": new_fit,
            "tr_seed": lambda: _seed_transform(ker, *box["seed"], x),
            "tr_new": lambda: box["mdl"].transform(x),
        }, reps)
        mdl = outs["fit_new"]

        row = dict(
            n=n, m=mdl.m,
            fit_seed_s=round(best["fit_seed"], 4),
            fit_s=round(best["fit_new"], 4),
            fit_speedup=round(best["fit_seed"] / best["fit_new"], 2),
            transform_seed_s=round(best["tr_seed"], 4),
            transform_s=round(best["tr_new"], 4),
            transform_speedup=round(best["tr_seed"] / best["tr_new"], 2),
        )
        rows.append(row)
        emit(f"rskpca_fit_n{n}", best["fit_new"] * 1e6, **{
            k: v for k, v in row.items() if k not in ("n",)})
    # preserve any sharded/bf16 rows a previous bench_sharded appended — a
    # plain --smoke refresh must not silently delete them — but mark them
    # stale: their numbers were NOT re-measured this run, so the perf gate
    # must not treat them as fresh evidence either way (bench_sharded
    # replaces them with fresh measurements)
    try:
        with open(BENCH_JSON) as f:
            rows += [dict(r, stale=True)
                     for r in json.load(f)["rows"] if "mode" in r]
    except (OSError, ValueError, KeyError):
        pass
    with open(BENCH_JSON, "w") as f:
        json.dump({"bench": "rskpca_fit_transform", "rank": rank, "ell": ell,
                   "backend_default": "pallas(interpret on CPU)",
                   "rows": rows}, f, indent=2)
    print(f"# wrote {BENCH_JSON}", flush=True)
    return rows


_SHARD_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.core import gaussian, fit
from repro.data import make_dataset
from repro.launch.mesh import smoke_mesh
from benchmarks.rskpca_scale import (_seed_fit, _seed_transform,
                                     _timed_interleaved)

precision = {precision!r}
for n in (8192, 32768):
    # shard count matched to the problem (~4096 rows/shard floor) so the
    # per-shard work amortizes host shard_map overhead; a pod scales the axis
    ndev = max(2, min(8, n // 4096))
    mesh = smoke_mesh(ndev)
    x, _, sigma = make_dataset("pendigits", seed=0, n=n)
    ker = gaussian(sigma)
    reps = 3 if n <= 8192 else 1
    # the child re-measures the SEED baseline itself, interleaved with the
    # sharded path, so each speedup compares samples taken seconds apart in
    # one process (a baseline recorded minutes earlier in another process
    # is a different machine-state); fit thunks stash outputs for the
    # transform thunks, populated by the warmup pass
    box = {{}}

    def seed_fit():
        box["seed"] = _seed_fit(x, ker, 8, 4.0)
        return box["seed"]

    def new_fit():
        box["mdl"] = fit(x, ker, 8, method="shadow", ell=4.0, mesh=mesh,
                         precision=precision)
        return box["mdl"]

    best, outs = _timed_interleaved({{
        "fit_seed": seed_fit,
        "fit_new": new_fit,
        "tr_seed": lambda: _seed_transform(ker, *box["seed"], x),
        "tr_new": lambda: box["mdl"].transform(x, mesh=mesh),
    }}, reps)
    print(f"SHARD n={{n}} m={{outs['fit_new'].m}} ndev={{ndev}} "
          f"fit_seed_s={{best['fit_seed']:.4f}} fit_s={{best['fit_new']:.4f}} "
          f"tr_seed_s={{best['tr_seed']:.4f}} tr_s={{best['tr_new']:.4f}}")
"""


def bench_sharded(precision: str = "bf16"):
    """Sharded (+mixed-precision) fit/transform rows appended to the JSON.

    Runs ``fit(..., mesh=...)`` / ``transform(..., mesh=...)`` in a
    multi-host-device subprocess; the child re-measures the seed baseline
    in-process (interleaved) so its speedups are same-machine-state ratios.
    """
    with open(BENCH_JSON) as f:
        doc = json.load(f)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + repo
    r = subprocess.run(
        [sys.executable, "-c", _SHARD_CHILD.format(precision=precision)],
        env=env, capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        print(r.stderr[-3000:])
        raise SystemExit("bench_sharded child failed")
    rows = [row for row in doc["rows"] if row.get("mode") != f"sharded+{precision}"]
    for line in r.stdout.splitlines():
        if not line.startswith("SHARD"):
            continue
        kv = dict(p.split("=") for p in line.split()[1:])
        n = int(kv["n"])
        seed_fit_s, fit_s = float(kv["fit_seed_s"]), float(kv["fit_s"])
        seed_tr_s, tr_s = float(kv["tr_seed_s"]), float(kv["tr_s"])
        row = dict(
            n=n, m=int(kv["m"]), mode=f"sharded+{precision}",
            ndev=int(kv["ndev"]),
            fit_seed_s=round(seed_fit_s, 4), fit_s=round(fit_s, 4),
            fit_speedup=round(seed_fit_s / fit_s, 2),
            transform_seed_s=round(seed_tr_s, 4),
            transform_s=round(tr_s, 4),
            transform_speedup=round(seed_tr_s / tr_s, 2),
        )
        rows.append(row)
        emit(f"rskpca_shard_{precision}_n{n}", fit_s * 1e6, **{
            k: v for k, v in row.items() if k != "n"})
    doc["rows"] = rows
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended sharded rows to {BENCH_JSON}", flush=True)
    return rows

def bench_stream(fast: bool = True, ms=(256, 1024, 4096), rank: int = 8):
    """Streaming scenario: per-update cost of the incremental operator
    patch (rank-one Gram row + Rayleigh-Ritz eigen-update, DESIGN.md §6)
    vs a FULL refit on the equivalent center set, at m live centers.

    Appends ``mode="stream"`` rows to BENCH_rskpca.json; run.py --stream
    gates on ``update_speedup >= 1.0`` for every freshly-measured row.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import gaussian, fit_rskpca
    from repro.core.rsde import RSDE
    from repro import streaming
    from repro.streaming import updates as supdates

    rng = np.random.default_rng(0)
    d = 16
    batch = 16
    rows = []
    for m in ms:
        c = (rng.normal(size=(m, d)) * 3.0).astype(np.float32)
        w = rng.integers(1, 8, m).astype(np.float64)
        rsde = RSDE(c, w, n=float(w.sum()), scheme="bench")
        ker = gaussian(1.0)
        # budget=inf measures the steady-state PATCH path (the refit column
        # is exactly what the budget check falls back to)
        st = streaming.from_rsde(rsde, ker, rank, eps=0.5, cap=2 * m,
                                 budget=float("inf"))
        # half of every batch lands inside existing shadows (absorb), half
        # in FRESH far-out territory (insert): both rank-one update flavors
        # in every measured step — each rep gets its own far points, or the
        # warmup's inserts would turn later reps absorb-only
        reps = 2 if fast else 3

        def fresh_batch(k):
            near = c[rng.integers(0, m, batch // 2)] \
                + 0.1 * rng.normal(size=(batch // 2, d))
            far = rng.normal(size=(batch - batch // 2, d)) * 3.0 \
                + 25.0 * (k + 1)
            return jnp.asarray(np.concatenate([near, far]).astype(np.float32))

        st = supdates.ingest_batch(st, fresh_batch(0))  # compile warmup
        jax.block_until_ready(st.eigvals)
        best_up = float("inf")
        for rep in range(reps):
            xb = fresh_batch(rep + 1)
            jax.block_until_ready(xb)
            t0 = time.perf_counter()
            st = supdates.ingest_batch(st, xb)
            jax.block_until_ready(st.eigvals)
            best_up = min(best_up, time.perf_counter() - t0)
        update_s = best_up / batch

        rs = st.as_rsde()
        fit_rskpca(rs, ker, rank)  # compile warmup
        best_refit = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fit_rskpca(rs, ker, rank)
            best_refit = min(best_refit, time.perf_counter() - t0)

        row = dict(
            m=m, mode="stream", cap=st.cap, batch=batch,
            update_s=round(update_s, 6), refit_s=round(best_refit, 4),
            update_speedup=round(best_refit / update_s, 1),
        )
        rows.append(row)
        emit(f"rskpca_stream_m{m}", update_s * 1e6,
             **{k: v for k, v in row.items() if k != "m"})

    try:
        with open(BENCH_JSON) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        doc = {"bench": "rskpca_fit_transform", "rows": []}
    doc["rows"] = [r for r in doc["rows"] if r.get("mode") != "stream"] + rows
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended stream rows to {BENCH_JSON}", flush=True)
    return rows


_CHILD = """
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core import gaussian, shadow_rsde
from repro.core.distributed import distributed_shadow_rsde
from repro.core import mmd as M
from repro.data import make_dataset

from repro.compat import make_mesh
mesh = make_mesh((8,), ("data",))
for n in (4096, 16384):
    x, _, sigma = make_dataset("pendigits", seed=0, n=n)
    ker = gaussian(sigma)
    # warmup both paths (compile)
    shadow_rsde(x[:512], ker, 4.0)
    distributed_shadow_rsde(x[:1024], ker, 4.0, mesh)
    t0 = time.perf_counter(); r1 = shadow_rsde(x, ker, 4.0)
    t1 = time.perf_counter(); r2 = distributed_shadow_rsde(x, ker, 4.0, mesh)
    t2 = time.perf_counter()
    m1 = M.mmd_weighted(ker, x, r1.centers, r1.weights)
    m2 = M.mmd_weighted(ker, x, r2.centers, r2.weights)
    print(f"RESULT n={n} seq_s={t1-t0:.3f} two_s={t2-t1:.3f} "
          f"speedup={(t1-t0)/max(t2-t1,1e-9):.2f} "
          f"m1={r1.m} m2={r2.m} mmd1={m1:.5f} mmd2={m2:.5f} "
          f"bound={ker.mmd_bound(4.0):.5f}")
"""


def main(fast: bool = True):
    bench_fit(fast=fast)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                       capture_output=True, text=True, timeout=1800)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT"):
            kv = dict(p.split("=") for p in line.split()[1:])
            emit(f"rskpca_scale_shadow_n{kv['n']}",
                 float(kv["seq_s"]) * 1e6,
                 two_level_us=round(float(kv["two_s"]) * 1e6, 1),
                 speedup=kv["speedup"], m_seq=kv["m1"], m_two=kv["m2"],
                 mmd_seq=kv["mmd1"], mmd_two=kv["mmd2"], bound=kv["bound"])
    if r.returncode != 0:
        print(r.stderr[-2000:])

    # P2: gram-kernel arithmetic intensity vs block size (structural).
    # K-chunked kernel (current) vs the pre-hillclimb square-block fallback.
    from repro.kernels.ops import pick_gram_blocks
    for d in (64, 256, 1024, 4096):
        bn, bm, bk = pick_gram_blocks(d)
        flops = 2 * bn * bm * d
        bytes_ = 4 * (bn * d + bm * d + bn * bm)   # HBM traffic per tile
        old_b = next(b for b in (512, 256, 128)
                     if (2 * b * d + b * b) * 4 <= 8 * 1024 * 1024)             if (2 * 128 * d + 128 * 128) * 4 <= 8 * 1024 * 1024 else 128
        old_bytes = 4 * (2 * old_b * d + old_b * old_b)
        old_ai = 2 * old_b * old_b * d / old_bytes
        emit(f"rskpca_gram_ai_d{d}", 0.0, block=f"{bn}x{bm}x{bk}",
             arith_intensity=round(flops / bytes_, 1),
             pre_hillclimb_ai=round(old_ai, 1),
             v5e_ridge=240.5,
             bound=("compute" if flops / bytes_ > 240.5 else "memory"))


if __name__ == "__main__":
    main()
