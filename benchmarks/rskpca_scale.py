"""Beyond-paper §Perf: scaling the paper's own pipeline (ShDE + RSKPCA).

The headline benchmark (``bench_fit``, also the ``--smoke`` target) compares
the SEED fit/transform path — sequential Algorithm 2, dense Gram, full eigh —
against the current default pipeline — blocked selection, fused Pallas
kernels, top-r LOBPCG — at n in {2k, 8k, 32k}, and writes the results to
``BENCH_rskpca.json`` so successive PRs accumulate a perf trajectory.

Two further measurable-on-CPU optimizations of the paper's technique:

  P1. two-level (distributed) shadow selection vs the paper's sequential
      Algorithm 2 — wall-clock speedup at growing n (8 host devices stand in
      for 8 data-parallel workers) and the MMD cost of the 2-eps cover.
  P2. Pallas gram-kernel arithmetic-intensity table: the VMEM block-size
      rule (kernels/ops.pick_gram_blocks) keeps the MXU fed; we report
      AI(block) = flops/bytes per tile vs the v5e ridge point
      (197e12 / 819e9 ~= 240 flops/byte).

Run inside an 8-device subprocess (the harness keeps the main process at 1
device per the brief).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

# merge_rows/_row_key live in common.py now (they stamp fresh rows with
# run provenance — git SHA + timestamp — installed by run.py); re-exported
# here because every bench writer historically imported them from this
# module.
from benchmarks.common import _row_key, emit, merge_rows  # noqa: F401

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_rskpca.json")


def _merge_into_bench(fresh_rows: list) -> None:
    """Shared read -> merge -> write for the mode= bench writers
    (bench_sharded / bench_stream / bench_matfree).

    Surviving old rows of the SAME mode as this run's fresh rows were NOT
    re-measured (e.g. a stream row at an m outside the current sweep), so
    they are stale-marked — the perf gates must never read a number this
    run did not take.  bench_fit applies the same rule to every mode= row
    when it rewrites the whole file.
    """
    try:
        with open(BENCH_JSON) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        doc = {"bench": "rskpca_fit_transform", "rows": []}
    modes = {r.get("mode") for r in fresh_rows}
    old = [dict(r, stale=True) if r.get("mode") in modes else r
           for r in doc.get("rows", [])]
    doc["rows"] = merge_rows(old, fresh_rows)
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2)


def _seed_fit(x, ker, rank, ell):
    """The seed PR's fit path, replicated verbatim for the perf baseline:
    sequential selection + dense Gram + full O(m^3) eigh."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import shadow_select_host
    from repro.core.kernels_math import gram_matrix_dense

    c, w, _, m = shadow_select_host(x, ker.epsilon(ell))
    cj = jnp.asarray(c, jnp.float32)
    sw = jnp.sqrt(jnp.asarray(w, jnp.float32))
    kt = gram_matrix_dense(ker, cj, cj) * sw[:, None] * sw[None, :] / len(x)
    lam, v = jnp.linalg.eigh(kt)
    lam = jnp.maximum(lam[::-1][:rank], 1e-12)
    proj = (sw[:, None] * v[:, ::-1][:, :rank]) / jnp.sqrt(lam)[None, :] \
        / np.sqrt(len(x))
    return np.asarray(c), np.asarray(proj)


def _seed_transform(ker, centers, proj, q):
    """Seed transform: dense q x m Gram materialized, then the matmul."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core.kernels_math import gram_matrix_dense

    k_qc = gram_matrix_dense(ker, jnp.asarray(q, jnp.float32),
                             jnp.asarray(centers))
    return np.asarray(k_qc @ jnp.asarray(proj))


def _timed_interleaved(fns: dict, reps: int):
    """min-of-reps wall clock for several thunks, measured INTERLEAVED.

    The container's CPU is share-throttled, so multi-hundred-ms slowdown
    windows come and go; timing path A fully and then path B would let one
    window hit only one side and invert a speedup ratio.  Interleaving the
    passes (A, B, A, B, ...) makes a window hit adjacent samples of both
    paths, and min-of-reps then keeps each path's cleanest sample.
    """
    outs = {k: fn() for k, fn in fns.items()}          # compile warmup
    best = {k: float("inf") for k in fns}
    for _ in range(reps):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            outs[k] = fn()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best, outs


def bench_fit(fast: bool = True):
    """fit + transform wall-clock, seed path vs current default, ->JSON.

    ``fast`` (the --smoke / default mode) takes the interleaved min of 3
    timed passes for the small points and a single pass at n=32768 to keep
    the smoke fast; --full takes min-of-3 everywhere.
    """
    from repro.core import gaussian, fit
    from repro.data import make_dataset

    rank, ell = 8, 4.0

    rows = []
    for n in (2048, 8192, 32768):
        # small points are noise-dominated: min-of-3 even in fast mode
        reps = 3 if (not fast or n <= 8192) else 1
        x, _, sigma = make_dataset("pendigits", seed=0, n=n)
        ker = gaussian(sigma)

        # transforms need fitted models: the fit thunks stash their outputs
        # in `box`, and _timed_interleaved's warmup pass (insertion order)
        # populates it before the transform thunks first run
        box = {}

        def seed_fit():
            box["seed"] = _seed_fit(x, ker, rank, ell)
            return box["seed"]

        def new_fit():
            box["mdl"] = fit(x, ker, rank, method="shadow", ell=ell)
            return box["mdl"]

        best, outs = _timed_interleaved({
            "fit_seed": seed_fit,
            "fit_new": new_fit,
            "tr_seed": lambda: _seed_transform(ker, *box["seed"], x),
            "tr_new": lambda: box["mdl"].transform(x),
        }, reps)
        mdl = outs["fit_new"]

        row = dict(
            n=n, m=mdl.m,
            fit_seed_s=round(best["fit_seed"], 4),
            fit_s=round(best["fit_new"], 4),
            fit_speedup=round(best["fit_seed"] / best["fit_new"], 2),
            transform_seed_s=round(best["tr_seed"], 4),
            transform_s=round(best["tr_new"], 4),
            transform_speedup=round(best["tr_seed"] / best["tr_new"], 2),
        )
        rows.append(row)
        emit(f"rskpca_fit_n{n}", best["fit_new"] * 1e6, **{
            k: v for k, v in row.items() if k not in ("n",)})
    # preserve any mode= rows a previous bench_sharded/bench_stream/
    # bench_matfree appended — a plain --smoke refresh must not silently
    # delete them — but mark them stale: their numbers were NOT re-measured
    # this run, so the perf gate must not treat them as fresh evidence
    # either way.  merge_rows drops a stale row the moment its (scale, mode)
    # pair is re-measured.
    try:
        with open(BENCH_JSON) as f:
            old = [dict(r, stale=True)
                   for r in json.load(f)["rows"] if "mode" in r]
    except (OSError, ValueError, KeyError):
        old = []
    rows = merge_rows(old, rows)
    with open(BENCH_JSON, "w") as f:
        json.dump({"bench": "rskpca_fit_transform", "rank": rank, "ell": ell,
                   "backend_default": "pallas(interpret on CPU)",
                   "rows": rows}, f, indent=2)
    print(f"# wrote {BENCH_JSON}", flush=True)
    return rows


_SHARD_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.core import gaussian, fit
from repro.data import make_dataset
from repro.launch.mesh import smoke_mesh
from benchmarks.rskpca_scale import (_seed_fit, _seed_transform,
                                     _timed_interleaved)

precision = {precision!r}
for n in (8192, 32768):
    # shard count matched to the problem (~4096 rows/shard floor) so the
    # per-shard work amortizes host shard_map overhead; a pod scales the axis
    ndev = max(2, min(8, n // 4096))
    mesh = smoke_mesh(ndev)
    x, _, sigma = make_dataset("pendigits", seed=0, n=n)
    ker = gaussian(sigma)
    reps = 3 if n <= 8192 else 1
    # the child re-measures the SEED baseline itself, interleaved with the
    # sharded path, so each speedup compares samples taken seconds apart in
    # one process (a baseline recorded minutes earlier in another process
    # is a different machine-state); fit thunks stash outputs for the
    # transform thunks, populated by the warmup pass
    box = {{}}

    def seed_fit():
        box["seed"] = _seed_fit(x, ker, 8, 4.0)
        return box["seed"]

    def new_fit():
        box["mdl"] = fit(x, ker, 8, method="shadow", ell=4.0, mesh=mesh,
                         precision=precision)
        return box["mdl"]

    best, outs = _timed_interleaved({{
        "fit_seed": seed_fit,
        "fit_new": new_fit,
        "tr_seed": lambda: _seed_transform(ker, *box["seed"], x),
        "tr_new": lambda: box["mdl"].transform(x, mesh=mesh),
    }}, reps)
    print(f"SHARD n={{n}} m={{outs['fit_new'].m}} ndev={{ndev}} "
          f"fit_seed_s={{best['fit_seed']:.4f}} fit_s={{best['fit_new']:.4f}} "
          f"tr_seed_s={{best['tr_seed']:.4f}} tr_s={{best['tr_new']:.4f}}")
"""


def bench_sharded(precision: str = "bf16"):
    """Sharded (+mixed-precision) fit/transform rows appended to the JSON.

    Runs ``fit(..., mesh=...)`` / ``transform(..., mesh=...)`` in a
    multi-host-device subprocess; the child re-measures the seed baseline
    in-process (interleaved) so its speedups are same-machine-state ratios.
    """
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + repo
    r = subprocess.run(
        [sys.executable, "-c", _SHARD_CHILD.format(precision=precision)],
        env=env, capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        print(r.stderr[-3000:])
        raise SystemExit("bench_sharded child failed")
    fresh = []
    for line in r.stdout.splitlines():
        if not line.startswith("SHARD"):
            continue
        kv = dict(p.split("=") for p in line.split()[1:])
        n = int(kv["n"])
        seed_fit_s, fit_s = float(kv["fit_seed_s"]), float(kv["fit_s"])
        seed_tr_s, tr_s = float(kv["tr_seed_s"]), float(kv["tr_s"])
        row = dict(
            n=n, m=int(kv["m"]), mode=f"sharded+{precision}",
            ndev=int(kv["ndev"]),
            fit_seed_s=round(seed_fit_s, 4), fit_s=round(fit_s, 4),
            fit_speedup=round(seed_fit_s / fit_s, 2),
            transform_seed_s=round(seed_tr_s, 4),
            transform_s=round(tr_s, 4),
            transform_speedup=round(seed_tr_s / tr_s, 2),
        )
        fresh.append(row)
        emit(f"rskpca_shard_{precision}_n{n}", fit_s * 1e6, **{
            k: v for k, v in row.items() if k != "n"})
    _merge_into_bench(fresh)
    print(f"# appended sharded rows to {BENCH_JSON}", flush=True)
    return fresh

def bench_stream(fast: bool = True, ms=(256, 1024, 4096), rank: int = 8):
    """Streaming scenario: per-update cost of the incremental operator
    patch (rank-one Gram row + Rayleigh-Ritz eigen-update, DESIGN.md §7)
    vs a FULL refit on the equivalent center set, at m live centers.

    Appends ``mode="stream"`` rows to BENCH_rskpca.json; run.py --stream
    gates on ``update_speedup >= 1.0`` for every freshly-measured row.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import gaussian, fit_rskpca
    from repro.core.rsde import RSDE
    from repro import streaming
    from repro.streaming import updates as supdates

    rng = np.random.default_rng(0)
    d = 16
    batch = 16
    rows = []
    for m in ms:
        c = (rng.normal(size=(m, d)) * 3.0).astype(np.float32)
        w = rng.integers(1, 8, m).astype(np.float64)
        rsde = RSDE(c, w, n=float(w.sum()), scheme="bench")
        ker = gaussian(1.0)
        # budget=inf measures the steady-state PATCH path (the refit column
        # is exactly what the budget check falls back to)
        st = streaming.from_rsde(rsde, ker, rank, eps=0.5, cap=2 * m,
                                 budget=float("inf"))
        # half of every batch lands inside existing shadows (absorb), half
        # in FRESH far-out territory (insert): both rank-one update flavors
        # in every measured step — each rep gets its own far points, or the
        # warmup's inserts would turn later reps absorb-only
        reps = 2 if fast else 3

        def fresh_batch(k):
            near = c[rng.integers(0, m, batch // 2)] \
                + 0.1 * rng.normal(size=(batch // 2, d))
            far = rng.normal(size=(batch - batch // 2, d)) * 3.0 \
                + 25.0 * (k + 1)
            return jnp.asarray(np.concatenate([near, far]).astype(np.float32))

        st = supdates.ingest_batch(st, fresh_batch(0))  # compile warmup
        jax.block_until_ready(st.eigvals)
        best_up = float("inf")
        for rep in range(reps):
            xb = fresh_batch(rep + 1)
            jax.block_until_ready(xb)
            t0 = time.perf_counter()
            st = supdates.ingest_batch(st, xb)
            jax.block_until_ready(st.eigvals)
            best_up = min(best_up, time.perf_counter() - t0)
        update_s = best_up / batch

        rs = st.as_rsde()
        fit_rskpca(rs, ker, rank)  # compile warmup
        best_refit = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fit_rskpca(rs, ker, rank)
            best_refit = min(best_refit, time.perf_counter() - t0)

        row = dict(
            m=m, mode="stream", cap=st.cap, batch=batch,
            update_s=round(update_s, 6), refit_s=round(best_refit, 4),
            update_speedup=round(best_refit / update_s, 1),
        )
        rows.append(row)
        emit(f"rskpca_stream_m{m}", update_s * 1e6,
             **{k: v for k, v in row.items() if k != "m"})

    _merge_into_bench(rows)
    print(f"# appended stream rows to {BENCH_JSON}", flush=True)
    return rows


def bench_matfree(m: int = 8192, d: int = 16, rank: int = 8):
    """Matrix-free fit at m centers (DESIGN.md §6): wall-clock vs the SEED
    dense fit path (dense Gram + full eigh) on the same synthetic center
    set, plus the structural no-m x m-buffer assertions.

    Appends a ``mode="matfree"`` row to BENCH_rskpca.json; run.py gates on
    ``fit_speedup >= 1.0`` and on the peak-memory ratio.  Centers are
    synthesized directly (as bench_stream does) because growing a REAL
    m=8192 cover through sequential seed selection would take the smoke far
    past its budget — the fit-path comparison is identical either way.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import gaussian
    from repro.core.rskpca import _fit_rskpca_device
    from repro.core.kernels_math import gram_matrix_dense
    from repro.kernels import ops as kernel_ops

    assert kernel_ops.matfree_fit(m), \
        f"m={m} sits below the matrix-free crossover; raise m"
    rng = np.random.default_rng(0)
    c = (rng.normal(size=(m, d)) * 3.0).astype(np.float32)
    w = rng.integers(1, 8, m).astype(np.float32)
    n = float(w.sum())
    ker = gaussian(1.0)

    # --- structural assertion: the compiled matfree fit holds NO (m, m)
    # buffer; the materialized path's peak temp is dominated by exactly one.
    # memory_analysis() needs only compilation, never an execution.
    def lower(matfree):
        return _fit_rskpca_device.lower(
            jnp.asarray(c), jnp.asarray(w), jnp.float32(n), ker, rank,
            matfree=matfree)

    mf_lowered = lower(True)
    assert f"{m}x{m}" not in mf_lowered.as_text(), \
        "matrix-free fit lowered an m x m tensor"
    mf_temp = mf_lowered.compile().memory_analysis().temp_size_in_bytes
    gram_temp = lower(False).compile().memory_analysis().temp_size_in_bytes
    ratio = gram_temp / max(mf_temp, 1)
    assert gram_temp >= 4 * m * m, (gram_temp, m)   # sanity: Gram is there
    assert ratio >= 4.0, \
        f"matfree peak temp only {ratio:.1f}x below the materialized path"

    # --- seed dense path (one timed pass: LAPACK eigh dominates at ~m^3,
    # so compile noise is irrelevant and a warmup pass would double a
    # minutes-long measurement for nothing)
    t0 = time.perf_counter()
    cj = jnp.asarray(c)
    sw = jnp.sqrt(jnp.asarray(w))
    kt = gram_matrix_dense(ker, cj, cj) * sw[:, None] * sw[None, :] \
        / jnp.float32(n)
    lam_s, v_s = jnp.linalg.eigh(kt)
    lam_s = jnp.maximum(lam_s[::-1][:rank], 1e-12)
    proj_s = (sw[:, None] * v_s[:, ::-1][:, :rank]) \
        / jnp.sqrt(lam_s)[None, :] / np.sqrt(n)
    jax.block_until_ready(proj_s)
    seed_s = time.perf_counter() - t0
    lam_s = np.asarray(lam_s)
    del kt, v_s, proj_s

    # --- matrix-free fit: warmup (compile + autotune), then min-of-2
    def run_mf():
        lam, proj = _fit_rskpca_device(jnp.asarray(c), jnp.asarray(w),
                                       jnp.float32(n), ker, rank,
                                       matfree=True)
        jax.block_until_ready(proj)
        return lam, proj

    run_mf()
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        lam_mf, _ = run_mf()
        best = min(best, time.perf_counter() - t0)

    # eigenvalue agreement with the seed solve (the row is meaningless if
    # the fast path computed a different operator)
    rel = float(np.max(np.abs(np.asarray(lam_mf) - lam_s) / lam_s))
    assert rel < 5e-3, f"matfree eigenvalues off by {rel:.2e}"

    row = dict(
        m=m, mode="matfree", d=d, rank=rank,
        fit_seed_s=round(seed_s, 4), fit_s=round(best, 4),
        fit_speedup=round(seed_s / best, 2),
        temp_bytes_matfree=int(mf_temp), temp_bytes_gram=int(gram_temp),
        peak_mem_ratio=round(ratio, 1),
    )
    emit(f"rskpca_matfree_m{m}", best * 1e6,
         **{k: v for k, v in row.items() if k != "m"})
    _merge_into_bench([row])
    print(f"# appended matfree row to {BENCH_JSON}", flush=True)
    return [row]


_CHILD = """
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core import gaussian, shadow_rsde
from repro.core.distributed import distributed_shadow_rsde
from repro.core import mmd as M
from repro.data import make_dataset

from repro.compat import make_mesh
mesh = make_mesh((8,), ("data",))
for n in (4096, 16384):
    x, _, sigma = make_dataset("pendigits", seed=0, n=n)
    ker = gaussian(sigma)
    # warmup both paths (compile)
    shadow_rsde(x[:512], ker, 4.0)
    distributed_shadow_rsde(x[:1024], ker, 4.0, mesh)
    t0 = time.perf_counter(); r1 = shadow_rsde(x, ker, 4.0)
    t1 = time.perf_counter(); r2 = distributed_shadow_rsde(x, ker, 4.0, mesh)
    t2 = time.perf_counter()
    m1 = M.mmd_weighted(ker, x, r1.centers, r1.weights)
    m2 = M.mmd_weighted(ker, x, r2.centers, r2.weights)
    print(f"RESULT n={n} seq_s={t1-t0:.3f} two_s={t2-t1:.3f} "
          f"speedup={(t1-t0)/max(t2-t1,1e-9):.2f} "
          f"m1={r1.m} m2={r2.m} mmd1={m1:.5f} mmd2={m2:.5f} "
          f"bound={ker.mmd_bound(4.0):.5f}")
"""


def main(fast: bool = True):
    bench_fit(fast=fast)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                       capture_output=True, text=True, timeout=1800)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT"):
            kv = dict(p.split("=") for p in line.split()[1:])
            emit(f"rskpca_scale_shadow_n{kv['n']}",
                 float(kv["seq_s"]) * 1e6,
                 two_level_us=round(float(kv["two_s"]) * 1e6, 1),
                 speedup=kv["speedup"], m_seq=kv["m1"], m_two=kv["m2"],
                 mmd_seq=kv["mmd1"], mmd_two=kv["mmd2"], bound=kv["bound"])
    if r.returncode != 0:
        print(r.stderr[-2000:])

    # P2: gram-kernel arithmetic intensity vs block size (structural).
    # K-chunked kernel (current) vs the pre-hillclimb square-block fallback.
    from repro.kernels.ops import pick_gram_blocks
    for d in (64, 256, 1024, 4096):
        bn, bm, bk = pick_gram_blocks(d)
        flops = 2 * bn * bm * d
        bytes_ = 4 * (bn * d + bm * d + bn * bm)   # HBM traffic per tile
        old_b = next(b for b in (512, 256, 128)
                     if (2 * b * d + b * b) * 4 <= 8 * 1024 * 1024)             if (2 * 128 * d + 128 * 128) * 4 <= 8 * 1024 * 1024 else 128
        old_bytes = 4 * (2 * old_b * d + old_b * old_b)
        old_ai = 2 * old_b * old_b * d / old_bytes
        emit(f"rskpca_gram_ai_d{d}", 0.0, block=f"{bn}x{bm}x{bk}",
             arith_intensity=round(flops / bytes_, 1),
             pre_hillclimb_ai=round(old_ai, 1),
             v5e_ridge=240.5,
             bound=("compute" if flops / bytes_ > 240.5 else "memory"))


if __name__ == "__main__":
    main()
