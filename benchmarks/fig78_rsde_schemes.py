"""Paper Figs. 7-8: RSKPCA accuracy under different RSDE schemes
(shadow / k-means / KDE-paring / kernel herding) at matched m."""
from __future__ import annotations

import numpy as np

from repro.core import gaussian, fit_rskpca, shadow_rsde, make_rsde
from repro.data import make_dataset, train_test_split, knn_classify, DATASETS
from benchmarks.common import timeit, emit


def run_dataset(name: str, n: int | None, ells, n_runs: int, rank: int):
    x, y, sigma = make_dataset(name, seed=0, n=n)
    k = DATASETS[name].knn_k
    ker = gaussian(sigma)
    for ell in ells:
        rows = {}
        for run in range(n_runs):
            xtr, ytr, xte, yte = train_test_split(x, y, seed=run)
            sh = shadow_rsde(xtr, ker, ell)
            m = max(sh.m, rank + 1)
            for scheme in ("shadow", "kmeans", "paring", "herding"):
                def build(scheme=scheme):
                    rsde = sh if scheme == "shadow" else make_rsde(
                        scheme, xtr, ker, m=m)
                    return fit_rskpca(rsde, ker, rank)
                t_rsde = timeit(build, repeat=1, warmup=0)
                mdl = build()
                acc = float((knn_classify(mdl.transform(xtr), ytr,
                                          mdl.transform(xte), k) == yte).mean())
                rows.setdefault(scheme, []).append((acc, t_rsde))
        for scheme, vals in rows.items():
            arr = np.array(vals, float).mean(axis=0)
            emit(f"fig78_{name}_{scheme}_l{ell:.1f}", float(arr[1]),
                 accuracy=round(float(arr[0]), 4), m=m)


def main(fast: bool = True):
    ells = [3.0, 4.0, 5.0] if fast else \
        [round(e, 1) for e in np.arange(3.0, 5.01, 0.2)]
    n_runs = 2 if fast else 10
    run_dataset("usps", 1200 if fast else None, ells, n_runs, rank=15)
    run_dataset("yale", 1000 if fast else None, ells, n_runs, rank=10)


if __name__ == "__main__":
    main()
