"""Out-of-core ingestion bench — ``mode="ingest"`` rows of BENCH_rskpca.json.

Measures the end-to-end select -> fit pipeline of core/ingest_pipeline.py on
the deterministic chunked source (data never materializes): wall time,
ingest throughput (rows/s), the measured copy/compute overlap fraction of
the async double-buffered feed, and peak memory via ``common.RssSampler`` —
both the sampled peak of LIVE buffer bytes (``peak_live_bytes``, what the
pipeline actually holds resident; on the CPU backend device buffers are
host memory) and the raw RSS growth (``rss_delta_bytes``, informational:
on CPU it additionally counts XLA's per-execution interpret-mode scratch
high-water, which lives in device HBM on real hardware and plateaus at a
shape-dependent constant unrelated to n).

Two scales share one child template:

  * smoke (CI, ``run.py --ingest``): n=1M rows, center budget 4096, one
    device — gated on the throughput floor and ``overlap_fraction >= 0.5``;
  * full (``run.py --ingest --full``): n=10M rows, budget 32768, chunk rows
    sharded over an 8-device mesh — additionally gated on
    ``peak_live_bytes`` < 25% of the 640MB the dataset would occupy
    resident (the out-of-core certificate: a materialized dataset would
    appear as a live 640MB array; the pipeline's window is O(chunk)).
    ``mem_gated`` marks which rows the gate reads.

The timed region includes chunk generation (``common.timeit_stream``
semantics: feeding the pipeline IS the workload) and the Algorithm 1 fit.
Warmup runs a 2-chunk source of the same chunk shape (compiles the
selection/feed/fit programs) and then drives a throwaway ``StreamingMerge``
through every pow2 bucket up to the center budget, so the merge-path
compilations and allocator high-water land BEFORE the RSS baseline — the
sampled peak measures data-path growth, not one-time jit arenas.
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import emit
from benchmarks.rskpca_scale import BENCH_JSON, _merge_into_bench

#: CI throughput floor (rows/s) for the n=1M smoke — measured ~31k rows/s
#: on the dev box (CPU, interpret-mode Pallas); ~4x headroom for slower
#: runners.  Real accelerators clear it by orders of magnitude.
INGEST_ROWS_PER_S_FLOOR = 8000.0

_INGEST_CHILD = """
import os
if {ndev} > 1:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
from benchmarks.common import RssSampler, timeit_stream
from repro.core import gaussian
from repro.core.ingest_pipeline import ingest_fit
from repro.data.kpca_datasets import ChunkedDataset

n, chunk, budget = {n}, {chunk}, {budget}
block, ell, ndev = {block}, {ell}, {ndev}
mesh = None
if ndev > 1:
    from repro.launch.mesh import smoke_mesh
    mesh = smoke_mesh(ndev)
sigma = ChunkedDataset("pendigits", n=n, chunk=chunk, seed=0).bandwidth()
ker = gaussian(sigma)
box = {{}}

def run(src):
    box["out"] = ingest_fit(src, ker, 8, ell=ell, block=block,
                            budget=budget, mesh=mesh)

# warmup 1: 2 chunks of the same shape compile the selection/feed/fit
# programs and autotune plans; the timed run then measures the pipeline
timeit_stream(
    lambda: ChunkedDataset("pendigits", n=2 * chunk, chunk=chunk, seed=0),
    run, repeat=1, warmup=0)
# warmup 2: merge shape sweep.  The host merge recompiles (and the XLA CPU
# allocator grows) at every pow2 bucket the merged set passes through on
# its way to ``budget``; drive a throwaway merge through the whole bucket
# ladder NOW — widely-spread random candidates all survive selection — so
# the RSS baseline below sits above the one-time compilation high-water
# and the sampled delta measures the DATA path, not jit arenas.
import numpy as np
from repro.core.shadow import StreamingMerge
sweep = StreamingMerge(16, ker.epsilon(ell), budget=budget, block=block)
rng = np.random.default_rng(0)
while sweep.m < budget:
    sweep.update(rng.uniform(0, 1e3, (8192, 16)).astype(np.float32),
                 np.ones(8192))
for _ in range(2):  # and the over-budget spill path
    sweep.update(rng.uniform(0, 1e3, (8192, 16)).astype(np.float32),
                 np.ones(8192))
del sweep
import gc
gc.collect()
rss = RssSampler().start()
timeit_stream(
    lambda: ChunkedDataset("pendigits", n=n, chunk=chunk, seed=0),
    run, repeat=1, warmup=0)
peak_rss = rss.stop()
model, st = box["out"]
ds_bytes = 4 * n * model.centers.shape[1]
print(f"INGEST n={{n}} m={{st.m}} ndev={{ndev}} chunk={{chunk}} "
      f"budget={{budget}} wall_s={{st.wall_s:.3f}} "
      f"select_s={{st.select_s:.3f}} fit_s={{st.fit_s:.3f}} "
      f"rows_per_s={{st.rows_per_s:.1f}} "
      f"overlap_fraction={{st.overlap_fraction:.4f}} "
      f"feed_s={{st.feed_s:.3f}} stall_s={{st.stall_s:.3f}} "
      f"spilled={{st.spilled}} peak_live_bytes={{rss.peak_live}} "
      f"rss_delta_bytes={{peak_rss}} dataset_bytes={{ds_bytes}}")
"""


def _run_child(n: int, chunk: int, budget: int, block: int, ell: float,
               ndev: int, timeout: int) -> dict:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + repo
    code = _INGEST_CHILD.format(n=n, chunk=chunk, budget=budget, block=block,
                                ell=ell, ndev=ndev)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        print(r.stderr[-3000:])
        raise SystemExit("ingest bench child failed")
    line = next(l for l in r.stdout.splitlines() if l.startswith("INGEST"))
    return dict(p.split("=") for p in line.split()[1:])


def bench_ingest(full: bool = False) -> list:
    """Appends mode="ingest" row(s) to BENCH_rskpca.json.

    ``full=False`` measures only the CI smoke point; ``full=True`` also runs
    the n=10M mesh row (several minutes end to end) — both carry distinct
    (mode, n) identities, so ``merge_rows`` refreshes each independently.
    """
    points = [dict(n=1_000_000, chunk=65536, budget=4096, block=512,
                   ell=3.0, ndev=1, mem_gated=False, timeout=1800)]
    if full:
        points.append(dict(n=10_000_000, chunk=262144, budget=32768,
                           block=512, ell=3.0, ndev=8, mem_gated=True,
                           timeout=7200))
    fresh = []
    for p in points:
        kv = _run_child(p["n"], p["chunk"], p["budget"], p["block"],
                        p["ell"], p["ndev"], p["timeout"])
        live, ds = int(kv["peak_live_bytes"]), int(kv["dataset_bytes"])
        row = dict(
            n=int(kv["n"]), m=int(kv["m"]), mode="ingest",
            ndev=int(kv["ndev"]), chunk=int(kv["chunk"]),
            budget=int(kv["budget"]), block=p["block"], ell=p["ell"],
            wall_s=float(kv["wall_s"]), select_s=float(kv["select_s"]),
            fit_s=float(kv["fit_s"]),
            rows_per_s=round(float(kv["rows_per_s"]), 1),
            overlap_fraction=float(kv["overlap_fraction"]),
            feed_s=float(kv["feed_s"]), stall_s=float(kv["stall_s"]),
            spilled=int(kv["spilled"]),
            peak_live_bytes=live,
            rss_delta_bytes=int(kv["rss_delta_bytes"]), dataset_bytes=ds,
            peak_live_frac=round(live / ds, 4),
            mem_gated=p["mem_gated"],
        )
        fresh.append(row)
        emit(f"rskpca_ingest_n{row['n']}", row["wall_s"] * 1e6, **{
            k: v for k, v in row.items() if k not in ("n", "mode")})
    _merge_into_bench(fresh)
    print(f"# appended ingest rows to {BENCH_JSON}", flush=True)
    return fresh
