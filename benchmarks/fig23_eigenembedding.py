"""Paper Figs. 2-3: eigenembedding fidelity vs ell (german, pendigits).

Protocol (paper §6): train KPCA on the full training split (the baseline);
train shadow/uniform/Nystrom/WNyström on the same split; embed the held-out
20% with rank r=5; align embeddings with the optimal linear map; report the
Frobenius embedding error, eigenvalue error, train/test speedups, retention.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    gaussian, fit_kpca, fit_subsampled_kpca, fit_nystrom,
    fit_weighted_nystrom, fit_rskpca, shadow_rsde,
    embedding_alignment_error, eigenvalue_error,
)
from repro.data import make_dataset, train_test_split
from benchmarks.common import timeit, emit


def run_dataset(name: str, n: int | None, ells, n_runs: int, rank: int = 5):
    x, y, sigma = make_dataset(name, seed=0, n=n)
    ker = gaussian(sigma)
    for ell in ells:
        rows = []
        for run in range(n_runs):
            xtr, _, xte, _ = train_test_split(x, y, seed=run)
            t0 = timeit(lambda: fit_kpca(xtr, ker, rank), repeat=1, warmup=0)
            ref = fit_kpca(xtr, ker, rank)
            ref_emb = ref.transform(xte)
            t_ref_test = timeit(lambda: ref.transform(xte), repeat=1, warmup=0)

            rsde = shadow_rsde(xtr, ker, ell)
            m = max(rsde.m, rank + 1)
            fits = {
                "shadow": lambda: fit_rskpca(shadow_rsde(xtr, ker, ell),
                                             ker, rank),
                "uniform": lambda: fit_subsampled_kpca(xtr, ker, rank, m,
                                                       seed=run),
                "nystrom": lambda: fit_nystrom(xtr, ker, rank, m, seed=run),
                "wnystrom": lambda: fit_weighted_nystrom(xtr, ker, rank, m,
                                                         seed=run),
            }
            for meth, f in fits.items():
                t_train = timeit(f, repeat=1, warmup=0)
                mdl = f()
                emb = mdl.transform(xte)
                t_test = timeit(lambda: mdl.transform(xte), repeat=1, warmup=0)
                rows.append((meth, ell,
                             embedding_alignment_error(ref_emb, emb),
                             eigenvalue_error(ref.eigvals, mdl.eigvals),
                             t0 / t_train, t_ref_test / t_test,
                             rsde.retention))
        for meth in ("shadow", "uniform", "nystrom", "wnystrom"):
            sel = [r for r in rows if r[0] == meth]
            arr = np.array([r[2:] for r in sel], float)
            emb_err, eig_err, sp_tr, sp_te, ret = arr.mean(axis=0)
            emit(f"fig23_{name}_{meth}_l{ell:.1f}", 0.0,
                 emb_err=round(float(emb_err), 4),
                 eig_err=round(float(eig_err), 5),
                 train_speedup=round(float(sp_tr), 2),
                 test_speedup=round(float(sp_te), 2),
                 retention=round(float(ret), 3))


def main(fast: bool = True):
    ells = [3.0, 3.5, 4.0, 4.5, 5.0] if fast else \
        [round(e, 1) for e in np.arange(3.0, 5.01, 0.1)]
    n_runs = 3 if fast else 50
    run_dataset("german", 800 if fast else None, ells, n_runs)
    run_dataset("pendigits", 1500 if fast else None, ells, n_runs)


if __name__ == "__main__":
    main()
