"""Paper Table 2: training cost and storage comparison.

Measures wall-clock train/test time and storage (floats retained by the
fitted model) for KPCA / ShDE+RSKPCA / Nystrom / WNyström on pendigits
(n_t = 2,800 as in the paper).  Complexity claims validated:
  ShDE+RSKPCA: O(mn + m^3) train, O(mr) space;  Nystrom: O(nr) space.
"""
from __future__ import annotations

import numpy as np

from repro.core import (gaussian, fit_kpca, fit, fit_nystrom, fit_rff,
                        fit_weighted_nystrom, shadow_rsde)
from repro.data import make_dataset, train_test_split
from benchmarks.common import timeit, emit, pin_autotune_cache


def main(fast: bool = True):
    pin_autotune_cache()  # keep autotune measurement out of the timed fits
    n = 1200 if fast else 3500
    x, y, sigma = make_dataset("pendigits", seed=0, n=n)
    xtr, ytr, xte, yte = train_test_split(x, y)
    ker = gaussian(sigma)
    rank = 5
    ell = 4.0
    m = shadow_rsde(xtr, ker, ell).m  # matched m for the competitors

    fits = {
        "kpca": lambda: fit_kpca(xtr, ker, rank),
        "shadow_rskpca": lambda: fit(xtr, ker, rank, method="shadow", ell=ell),
        "nystrom": lambda: fit_nystrom(xtr, ker, rank, m=m),
        "wnystrom": lambda: fit_weighted_nystrom(xtr, ker, rank, m=m),
        "rff": lambda: fit_rff(xtr, ker, rank, n_features=m),  # D = m
    }
    base_train = base_test = None
    for name, f in fits.items():
        t_train = timeit(f, repeat=3, warmup=1)
        model = f()
        t_test = timeit(lambda: model.transform(xte), repeat=3, warmup=1)
        storage = model.centers.size + model.projector.size
        if name == "kpca":
            base_train, base_test = t_train, t_test
        emit(f"table2_{name}", t_train,
             test_us=round(t_test, 1),
             storage_floats=int(storage),
             m=model.m,
             train_speedup=round(base_train / t_train, 2),
             test_speedup=round(base_test / t_test, 2))


if __name__ == "__main__":
    main()
