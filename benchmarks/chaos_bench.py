"""Chaos gate — ``mode="chaos"`` rows of BENCH_rskpca.json (DESIGN.md §17).

Runs the SAME ingest and serving workloads twice — fault-free, then under a
deterministic ``runtime.chaos`` fault plan — and gates on the two promises
the fault-tolerance layer makes:

  * **ingest**: with ~1% of chunk-read / feed-stage / merge calls throwing
    transient faults (plus periodic checkpointing enabled), the selected
    centers and f64 masses must be BIT-EXACT equal to the fault-free run's
    (retries wrap pure regeneration, never partially-applied mutations),
    at <= ``CHAOS_INGEST_SLOWDOWN_MAX`` wall-clock slowdown;
  * **serve**: with ~1% of dispatches throwing a transient on first try,
    per-dispatch p99 must stay within ``CHAOS_SERVE_P99_RATIO_MAX`` of the
    fault-free p99 (sub-millisecond deterministic backoff — a retry costs
    one extra service time, not a scheduler round-trip), and EVERY request
    must resolve: zero drops that are not explicit ``RequestShed``
    admission rejections.  The row also records the finite Theorem-5.x
    staleness bound a degraded (failed-publish) server reports — the error
    budget of serving stale instead of serving nothing.

Fault triggering is a pure function of (plan seed, site, call#), so a gate
failure replays bit-identically under ``pytest`` or a debugger.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import emit
from benchmarks.rskpca_scale import BENCH_JSON, _merge_into_bench

#: Ingest wall-clock budget under the 1% fault plan (retry backoffs plus
#: checkpoint publishes included).
CHAOS_INGEST_SLOWDOWN_MAX = 1.5
#: Faulted-serving p99 budget relative to fault-free p99.
CHAOS_SERVE_P99_RATIO_MAX = 2.0

_INGEST_N = 16384
_INGEST_CHUNK = 1024

_DISPATCHES = 300
_REQS_PER_DISPATCH = 4
_REQ_ROWS = 4
_FAULT_P = 0.01


def _ingest_once(eps: float, plan=None, checkpoint_dir: str | None = None):
    """One select_streaming pass; returns (rsde, wall_s, injected)."""
    from repro.core.ingest_pipeline import select_streaming
    from repro.data.kpca_datasets import ChunkedDataset
    from repro.runtime import chaos

    src = ChunkedDataset("pendigits", n=_INGEST_N, chunk=_INGEST_CHUNK,
                         seed=0)
    injected = 0
    t0 = time.perf_counter()
    if plan is None:
        rsde, stats = select_streaming(src, eps, block=256, budget=2048)
    else:
        with chaos.active(plan) as p:
            rsde, stats = select_streaming(
                src, eps, block=256, budget=2048,
                checkpoint_dir=checkpoint_dir, checkpoint_every=4)
            injected = sum(p.stats()["injected"].values())
    wall = time.perf_counter() - t0
    assert stats.rows == _INGEST_N
    return rsde, wall, injected


def bench_chaos_ingest() -> dict:
    from repro.data.kpca_datasets import ChunkedDataset
    from repro.runtime.chaos import FaultPlan, FaultSpec

    sigma = ChunkedDataset("pendigits", n=_INGEST_N, chunk=_INGEST_CHUNK,
                           seed=0).bandwidth()
    eps = sigma / 4.0
    _ingest_once(eps)  # warmup: compile the select/merge programs
    ref, wall_ff, _ = _ingest_once(eps)

    # ~1% transient-fault rate across the three ingest sites (crc-keyed
    # coin flips: identical fire pattern on every run/box), plus one
    # GUARANTEED fault per site (``at=(2,)``) so a short fast-mode run can
    # never vacuously pass with zero injections
    fault = FaultSpec(kind="transient", p=_FAULT_P, at=(2,))
    plan = FaultPlan({"data.chunk": fault, "ingest.feed": fault,
                      "ingest.merge": fault}, seed=1)
    with tempfile.TemporaryDirectory() as ckdir:
        got, wall_chaos, injected = _ingest_once(eps, plan=plan,
                                                 checkpoint_dir=ckdir)

    bit_exact = bool(
        np.array_equal(np.asarray(ref.centers), np.asarray(got.centers))
        and np.array_equal(np.asarray(ref.weights), np.asarray(got.weights)))
    slowdown = wall_chaos / wall_ff
    row = dict(n=_INGEST_N, mode="chaos", method="ingest",
               bit_exact=bit_exact, injected=int(injected),
               wall_ff_s=round(wall_ff, 3),
               wall_chaos_s=round(wall_chaos, 3),
               slowdown=round(slowdown, 3),
               slowdown_max=CHAOS_INGEST_SLOWDOWN_MAX)
    emit("rskpca_chaos_ingest", wall_chaos * 1e6,
         bit_exact=int(bit_exact), slowdown=row["slowdown"],
         injected=int(injected))
    return row


def _serve_lats_ms(srv, d: int, plan=None) -> tuple[np.ndarray, int, int]:
    """Step-driven per-dispatch latencies (ms) + (unresolved, shed)."""
    from repro.runtime import chaos
    from repro.runtime.fault import RetryPolicy
    from repro.serving.batching import BatchingFrontEnd, RequestShed

    rng = np.random.default_rng(11)
    reqs = [(rng.normal(size=(_REQ_ROWS, d)) * 2.0).astype(np.float32)
            for _ in range(_REQS_PER_DISPATCH)]
    # sub-ms deterministic backoff: a retried dispatch costs ~one extra
    # service time, which is what keeps the p99 ratio near 2 and not 10
    fe = BatchingFrontEnd(srv, max_batch=256, slo_ms=5000.0,
                          autostart=False,
                          retry=RetryPolicy(base_s=2e-4, max_s=2e-3))
    lat = np.empty(_DISPATCHES)
    unresolved = shed = 0

    def run():
        nonlocal unresolved, shed
        for k in range(_DISPATCHES):
            futs = [fe.submit(x) for x in reqs]
            t0 = time.perf_counter()
            fe.step()
            lat[k] = time.perf_counter() - t0
            for f in futs:
                try:
                    f.result(timeout=60)
                except RequestShed:
                    shed += 1
                except Exception:
                    unresolved += 1

    if plan is None:
        run()
    else:
        with chaos.active(plan):
            run()
    fe.close()
    return lat * 1e3, unresolved, shed


def bench_chaos_serve(m: int = 512, d: int = 16, rank: int = 8) -> dict:
    from benchmarks.serve_latency import _build_server, _warm_buckets
    from repro.runtime import chaos
    from repro.runtime.chaos import FaultPlan, FaultSpec

    srv = _build_server(m, d, rank)
    _warm_buckets(srv, d, _REQ_ROWS, 256)
    _serve_lats_ms(srv, d)  # warmup

    lat_ff, drop_ff, _ = _serve_lats_ms(srv, d)
    plan = FaultPlan(
        {"serve.dispatch": FaultSpec(kind="transient", p=_FAULT_P,
                                     at=(7,))}, seed=2)
    lat_ch, drop_ch, _ = _serve_lats_ms(srv, d, plan=plan)
    injected = plan.stats()["total_injected"]

    p99_ff = float(np.percentile(lat_ff, 99))
    p99_ch = float(np.percentile(lat_ch, 99))

    # admission control under burst: everything beyond max_queue sheds
    # with an explicit RequestShed, everything admitted resolves
    from repro.runtime.fault import RetryPolicy
    from repro.serving.batching import BatchingFrontEnd, RequestShed
    fe = BatchingFrontEnd(srv, max_batch=256, slo_ms=5000.0,
                          autostart=False, max_queue=8,
                          retry=RetryPolicy(base_s=2e-4, max_s=2e-3))
    burst = [fe.submit(np.zeros((_REQ_ROWS, d), np.float32))
             for _ in range(24)]
    fe.drain()
    fe.close()
    shed = served = lost = 0
    for f in burst:
        try:
            f.result(timeout=60)
            served += 1
        except RequestShed:
            shed += 1
        except Exception:
            lost += 1

    # degraded serving: a failed publish falls back to the last good
    # snapshot and prices it with the finite Theorem-5.x staleness bound
    with chaos.active(FaultPlan(
            {"swap.publish": FaultSpec(kind="error", every=1)}, seed=3)):
        srv.try_publish(srv_state(srv))
    info = srv.degraded_info()
    z = srv.transform(np.zeros((_REQ_ROWS, d), np.float32))
    assert z.shape[0] == _REQ_ROWS, "degraded server stopped serving"
    srv.try_publish(srv_state(srv))  # recover for any later bench

    row = dict(n=_DISPATCHES, mode="chaos", method="serve",
               injected=int(injected),
               p99_ff_ms=round(p99_ff, 3), p99_chaos_ms=round(p99_ch, 3),
               p99_ratio=round(p99_ch / p99_ff, 3),
               p99_ratio_max=CHAOS_SERVE_P99_RATIO_MAX,
               dropped=int(drop_ff + drop_ch + lost), shed=int(shed),
               burst_served=int(served),
               staleness_bound=float(info.staleness_bound),
               degraded=bool(info.degraded))
    emit("rskpca_chaos_serve", p99_ch * 1e3, p99_ratio=row["p99_ratio"],
         dropped=row["dropped"], shed=row["shed"],
         staleness_bound=round(row["staleness_bound"], 6))
    return row


def srv_state(srv):
    """The serving state a publish would re-publish (bench convenience:
    rebuild an equivalent state from the live snapshot)."""
    from repro import streaming
    from repro.core.rsde import RSDE

    centers, projector, kernel, _ = srv._snapshot
    w = (np.asarray(srv._pub_weights) if srv._pub_weights is not None
         else np.ones(np.asarray(centers).shape[0]))
    alive = w > 0
    rsde = RSDE(np.asarray(centers)[alive], w[alive], n=float(w.sum()),
                scheme="bench")
    rank = np.asarray(projector).shape[1]
    return streaming.from_rsde(rsde, kernel, rank, eps=0.4,
                               cap=np.asarray(centers).shape[0])


def bench_chaos(fast: bool = True):
    rows = [bench_chaos_ingest(), bench_chaos_serve()]
    _merge_into_bench(rows)
    print(f"# appended chaos rows to {BENCH_JSON}", flush=True)
    return rows


if __name__ == "__main__":
    bench_chaos()
