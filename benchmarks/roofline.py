"""Aggregate dry-run JSONs into the roofline table (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), emits both
the run.py CSV rows and a markdown table to experiments/roofline.md.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

HEADER = ("| arch | shape | mesh | variant | compute s | memory s | "
          "collective s | dominant | MODEL_FLOPS | useful ratio | MFU bound | "
          "args GB/dev | temps GB/dev | note |")
SEP = "|" + "---|" * 14


def load_records(out_dir: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def row(r: dict) -> str:
    var = r.get("variant", "baseline")
    if r["status"] == "skip":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {var} | — | — "
                f"| — | — | — | — | — | — | — | SKIP: {r['skip_reason']} |")
    rf = r["roofline"]
    mem = r.get("memory", {})
    args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
    temp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
    note = "fits" if (args_gb + temp_gb) < 16 else "OVER 16GB HBM"
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {var} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['dominant'].replace('_s','')} "
            f"| {r['model_flops_total']:.3e} | {rf['useful_ratio']:.2f} "
            f"| {rf['model_mfu_bound']:.3f} "
            f"| {args_gb:.2f} | {temp_gb:.2f} | {note} |")


def main(fast: bool = True, out_dir: str = "experiments/dryrun",
         md_path: str = "experiments/roofline.md"):
    recs = load_records(out_dir)
    if not recs:
        emit("roofline_no_records", 0.0, hint="run repro.launch.dryrun --all")
        return
    lines = [HEADER, SEP]
    for r in recs:
        lines.append(row(r))
        if r["status"] == "ok":
            rf = r["roofline"]
            emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
                 f"{'' if r.get('variant', 'baseline') == 'baseline' else '_opt'}",
                 rf["roofline_step_s"] * 1e6,
                 dominant=rf["dominant"],
                 compute_s=round(rf["compute_s"], 5),
                 memory_s=round(rf["memory_s"], 5),
                 collective_s=round(rf["collective_s"], 5),
                 mfu_bound=round(rf["model_mfu_bound"], 4))
    os.makedirs(os.path.dirname(md_path), exist_ok=True)
    with open(md_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# wrote {md_path} ({len(recs)} cells)")


if __name__ == "__main__":
    main()
