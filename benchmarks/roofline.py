"""Roofline tables: dry-run aggregation + the MEASURED transform crossover.

Two sources feed experiments/roofline.md:

  * the original dry-run aggregation — experiments/dryrun/*.json (written by
    repro.launch.dryrun) rendered as the launch-shape roofline table;
  * ``transform_sweep`` — a live sweep that drives the serving projection's
    roofline-driven autotuner (``kernels.ops._project_plan`` ->
    ``autotune.best_roofline``) across query/center shapes and precision
    tiers, then reads back the measured peaks, ridge points, and
    per-candidate predictions the tuner recorded in the schema-2 plan cache.
    This is the measured bytes/FLOPs crossover behind every tile the serving
    path picks — not a model, a recording of what the tuner saw.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

HEADER = ("| arch | shape | mesh | variant | compute s | memory s | "
          "collective s | dominant | MODEL_FLOPS | useful ratio | MFU bound | "
          "args GB/dev | temps GB/dev | note |")
SEP = "|" + "---|" * 14


def load_records(out_dir: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def row(r: dict) -> str:
    var = r.get("variant", "baseline")
    if r["status"] == "skip":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {var} | — | — "
                f"| — | — | — | — | — | — | — | SKIP: {r['skip_reason']} |")
    rf = r["roofline"]
    mem = r.get("memory", {})
    args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
    temp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
    note = "fits" if (args_gb + temp_gb) < 16 else "OVER 16GB HBM"
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {var} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['dominant'].replace('_s','')} "
            f"| {r['model_flops_total']:.3e} | {rf['useful_ratio']:.2f} "
            f"| {rf['model_mfu_bound']:.3f} "
            f"| {args_gb:.2f} | {temp_gb:.2f} | {note} |")


TRANSFORM_HEADER = ("| n | m | d | r | precision | winner | peak GFLOP/s | "
                    "peak GB/s | ridge F/B | measured us | predicted us |")
TRANSFORM_SEP = "|" + "---|" * 11

#: (n, m, d, r) transform shapes swept; fast mode keeps the first two.
TRANSFORM_SHAPES = ((2048, 512, 64, 16), (8192, 1024, 64, 16),
                    (8192, 2048, 128, 32))
TRANSFORM_PRECISIONS = ("f32", "bf16", "int8", "fp8")


def transform_sweep(fast: bool = True, precisions=TRANSFORM_PRECISIONS):
    """Tune the projection plan per (shape, precision) and return the table
    rows the tuner recorded: measured fleet peaks + roofline predictions.

    Needs measurement on (``REPRO_AUTOTUNE`` unset/1); a disabled tuner
    yields no rows.  Already-cached keys replay from the plan cache, so a
    repeated sweep is free — point ``REPRO_AUTOTUNE_CACHE`` somewhere fresh
    to force re-measurement.
    """
    from repro.kernels import autotune
    from repro.kernels import ops as kernel_ops

    if not autotune.measurement_enabled():
        return []
    interpret = not kernel_ops._on_tpu()
    mode = "interp" if interpret else "tpu"
    shapes = TRANSFORM_SHAPES[:2] if fast else TRANSFORM_SHAPES
    rows = []
    for (n, m, d, r) in shapes:
        for prec in precisions:
            plan = kernel_ops._project_plan(n, m, d, r, prec, interpret)
            nb, mb = autotune.bucket(n), autotune.bucket(m)
            db = autotune.bucket(d, lo=8, hi=8192)
            rb = autotune.bucket(r, lo=8, hi=512)
            key = f"project|n{nb}|m{mb}|d{db}|r{rb}|{prec}|{mode}"
            entry = autotune.roofline_entry(key)
            rows.append({"n": n, "m": m, "d": d, "r": r, "precision": prec,
                         "winner": plan, "roofline": entry})
    return rows


def transform_row(t: dict) -> str:
    entry = t["roofline"]
    if entry is None:  # single-candidate key or measurement failure
        return (f"| {t['n']} | {t['m']} | {t['d']} | {t['r']} "
                f"| {t['precision']} | {t['winner']} | — | — | — | — | — |")
    rf = entry["roofline"]
    meas = entry.get("us", {})
    w = t["winner"]
    return (f"| {t['n']} | {t['m']} | {t['d']} | {t['r']} | {t['precision']} "
            f"| {w} | {rf['peak_gflops']} | {rf['peak_gbs']} "
            f"| {rf['ridge_flop_per_byte']} "
            f"| {meas.get(w, '—')} | {rf['pred_us'].get(w, '—')} |")


def main(fast: bool = True, out_dir: str = "experiments/dryrun",
         md_path: str = "experiments/roofline.md",
         sweep_transform: bool = True):
    recs = load_records(out_dir)
    lines = []
    if not recs:
        emit("roofline_no_records", 0.0, hint="run repro.launch.dryrun --all")
    else:
        lines += [HEADER, SEP]
        for r in recs:
            lines.append(row(r))
            if r["status"] == "ok":
                rf = r["roofline"]
                emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
                     f"{'' if r.get('variant', 'baseline') == 'baseline' else '_opt'}",
                     rf["roofline_step_s"] * 1e6,
                     dominant=rf["dominant"],
                     compute_s=round(rf["compute_s"], 5),
                     memory_s=round(rf["memory_s"], 5),
                     collective_s=round(rf["collective_s"], 5),
                     mfu_bound=round(rf["model_mfu_bound"], 4))
    if sweep_transform:
        sweep = transform_sweep(fast=fast)
        if sweep:
            lines += ["", "## Transform plan roofline (measured)", "",
                      TRANSFORM_HEADER, TRANSFORM_SEP]
            for t in sweep:
                lines.append(transform_row(t))
                entry = t["roofline"]
                if entry is not None:
                    rf = entry["roofline"]
                    emit(f"roofline_transform_n{t['n']}_m{t['m']}"
                         f"_{t['precision']}",
                         entry.get("us", {}).get(t["winner"], 0.0),
                         winner=t["winner"],
                         peak_gflops=rf["peak_gflops"],
                         peak_gbs=rf["peak_gbs"],
                         ridge=rf["ridge_flop_per_byte"])
    if not lines:
        return
    os.makedirs(os.path.dirname(md_path), exist_ok=True)
    with open(md_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# wrote {md_path} ({len(recs)} dryrun cells)")


if __name__ == "__main__":
    main()
