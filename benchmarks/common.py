"""Shared benchmark utilities: timing + the run.py CSV contract.

Every benchmark emits rows ``name,us_per_call,derived`` where ``derived``
carries the figure-specific metric(s) as ``key=value|key=value``.
"""
from __future__ import annotations

import time

import numpy as np


def timeit(fn, *args, repeat: int = 3, warmup: int = 1, **kw):
    """Median wall time of fn(*args) in microseconds (host-blocking)."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(name: str, us_per_call: float, **derived):
    parts = "|".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{parts}", flush=True)
