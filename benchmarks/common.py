"""Shared benchmark utilities: timing + the run.py CSV contract.

Every benchmark emits rows ``name,us_per_call,derived`` where ``derived``
carries the figure-specific metric(s) as ``key=value|key=value``.

Bench-trajectory hygiene: ``merge_rows`` (the single merge rule every
BENCH_rskpca.json writer goes through) stamps each freshly-measured row
with the run's git SHA and ISO-8601 UTC timestamp, so any row in the
accumulated file is attributable to the commit and time that measured it.
The stamp is captured ONCE by the entry point (``run.py`` calls
``set_run_stamp(**make_stamp())``) and passed down — library code never
reads the clock or the repo state ambiently, so replaying a bench module
in a test or notebook stamps nothing unless the caller opted in.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np


#: The run-level provenance stamp applied to fresh bench rows; set by the
#: entry point (run.py / a bench module's __main__), never read ambiently.
_RUN_STAMP: dict | None = None


def make_stamp() -> dict:
    """Capture this run's provenance: short git SHA + ISO-8601 UTC time.

    Called by ENTRY POINTS only (run.py main); the values then flow through
    ``set_run_stamp`` -> ``merge_rows`` so library code stays free of
    ambient clock/repo reads."""
    import datetime
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    ts = datetime.datetime.now(datetime.timezone.utc).isoformat(
        timespec="seconds")
    return {"git_sha": sha or "unknown", "measured_at": ts}


def set_run_stamp(**stamp) -> None:
    """Install the provenance stamp ``merge_rows`` applies to fresh rows."""
    global _RUN_STAMP
    _RUN_STAMP = dict(stamp) if stamp else None


def _row_key(r: dict):
    """Identity of a bench row: its mode plus the scale axis it varies
    (n for the fit/transform benches, m for the synthetic-center ones) plus,
    for the method-zoo rows, which method the row measures (mode="methods"
    records several methods at one n)."""
    scale = r["n"] if "n" in r else r.get("m")
    return (r.get("mode"), r.get("method"), scale)


def merge_rows(old_rows: list, fresh_rows: list, stamp: dict | None = None
               ) -> list:
    """Merge freshly-measured rows into the accumulated BENCH file rows.

    Any old row — fresh OR ``"stale": true`` — whose (scale, mode) identity
    was re-measured is DROPPED in favor of the new measurement, so stale
    markers never outlive a refresh of their pair; rows of pairs not touched
    this run are preserved untouched.  Fresh rows are stamped with ``stamp``
    (default: the run-level stamp installed via ``set_run_stamp``) so the
    trajectory stays attributable across PRs.
    """
    stamp = _RUN_STAMP if stamp is None else stamp
    if stamp:
        fresh_rows = [{**r, **stamp} for r in fresh_rows]
    fresh_keys = {_row_key(r) for r in fresh_rows}
    return [r for r in old_rows if _row_key(r) not in fresh_keys] \
        + fresh_rows


def pin_autotune_cache() -> str:
    """Pin the autotune measurement cache to one directory for the process.

    Comparative benchmarks time the same op shapes many times; without a
    pinned cache every subprocess/backend re-measures candidate tile plans
    inside the timed region and the "speedup" column partly measures
    autotuning.  Respects an externally-set ``REPRO_AUTOTUNE_CACHE`` (CI pins
    it to the runner temp dir for hermetic runs)."""
    return os.environ.setdefault(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(tempfile.gettempdir(), "repro_autotune_cache.json"))


def timeit(fn, *args, repeat: int = 3, warmup: int = 1, **kw):
    """Median wall time of fn(*args) in microseconds (host-blocking)."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def timeit_stream(make_input, fn, repeat: int = 1, warmup: int = 1):
    """Median wall time (us) of ``fn(make_input())`` — the generator-input
    path for out-of-core benchmarks.

    ``timeit`` assumes its argument array is already resident; an ingest
    bench must NOT pre-materialize n=10M rows just to time the pipeline, so
    here every (warmup and timed) call receives a FRESH lazily-producing
    source from ``make_input()`` and the production cost is — deliberately —
    inside the timed region: feeding the pipeline IS the workload.
    """
    for _ in range(warmup):
        fn(make_input())
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(make_input())
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def _rss_bytes() -> int:
    with open("/proc/self/status") as f:
        for line in f:
            if line.startswith("VmRSS:"):
                return int(line.split()[1]) * 1024
    return 0


def _live_bytes() -> int:
    import jax

    return sum(a.nbytes for a in jax.live_arrays())


class RssSampler:
    """Peak memory of a measured region: live buffer bytes + host-RSS growth.

    A daemon thread samples two numbers and records the peak of each:

    * ``peak_live`` — total bytes of live jax arrays (``jax.live_arrays``).
      On the CPU backend device buffers ARE host memory, so this is the
      memory the pipeline actually holds resident — the number the
      out-of-core gate reads (a materialized n=10M dataset would show up
      here as a single 640MB array).
    * ``peak_delta`` — peak VmRSS growth over the ``start()`` baseline
      (the delta, not ``ru_maxrss``: the interpreter + XLA baseline is
      hundreds of MB).  Informational: on CPU it also counts XLA's
      per-execution scratch high-water — interpret-mode Pallas workspace
      that lives in device HBM on real hardware — which plateaus at a
      shape-dependent constant unrelated to n.  Start AFTER warmup so
      one-time compile arenas don't count against the pipeline.
    """

    def __init__(self, interval_s: float = 0.01):
        self._interval = interval_s
        self._stop = threading.Event()
        self._base = 0
        self.peak_delta = 0
        self.peak_live = 0
        self._t: threading.Thread | None = None

    def _run(self):
        while not self._stop.is_set():
            self.peak_delta = max(self.peak_delta, _rss_bytes() - self._base)
            self.peak_live = max(self.peak_live, _live_bytes())
            self._stop.wait(self._interval)

    def start(self) -> "RssSampler":
        self._base = _rss_bytes()
        self.peak_delta = 0
        self.peak_live = 0
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()
        return self

    def stop(self) -> int:
        """Returns the peak RSS growth (bytes) since ``start``; the peak
        live-buffer bytes are left in ``self.peak_live``."""
        self._stop.set()
        if self._t is not None:
            self._t.join()
        self.peak_delta = max(self.peak_delta, _rss_bytes() - self._base)
        return self.peak_delta


def emit(name: str, us_per_call: float, **derived):
    parts = "|".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{parts}", flush=True)
